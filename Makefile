# Artifact pipeline: synthetic corpus/glyph data → trained weight zoo
# (+ JAX parity bundles the rust integration tests check against) →
# AOT-lowered HLO artifacts for the PJRT runtime.
#
# Requires python3 with jax (CPU is fine) and numpy; the rust side
# consumes the output from ./artifacts (see `axe::artifacts_dir`).

PY ?= python3

.PHONY: artifacts artifacts-quick clean-artifacts

# Full training budgets — the real zoo.
artifacts:
	cd python && $(PY) -m compile.data --out ../artifacts/data
	cd python && $(PY) -m compile.train --out ../artifacts/weights --data ../artifacts/data
	cd python && $(PY) -m compile.aot --out ../artifacts/hlo --weights ../artifacts/weights

# Tiny training budgets (CI smoke): same artifact layout, same parity
# bundles — enough for the JAX↔rust contract tests, not for accuracy.
artifacts-quick:
	cd python && $(PY) -m compile.data --out ../artifacts/data
	cd python && $(PY) -m compile.train --quick --out ../artifacts/weights --data ../artifacts/data
	cd python && $(PY) -m compile.aot --out ../artifacts/hlo --weights ../artifacts/weights

clean-artifacts:
	rm -rf artifacts
