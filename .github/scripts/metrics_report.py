#!/usr/bin/env python3
"""Merge per-engine telemetry JSONL streams into one operator report.

Reads one or more `axe serve --metrics` JSONL files (one per engine at
--workers > 1), tolerates schema v1 records (the overload counters —
shed, deadline_miss, cancelled, queue_hwm — default to 0), and prints:

  * run totals: steps, tokens, decode/prefill rows, the overflow
    split, admission outcomes, and the max queue high-water mark;
  * step-latency percentiles (p50/p90/p99/max) over the exact wall_ns
    samples — finer than the log2 histograms the engine keeps;
  * a ~10-bin timeline over the merged step index: steps, tokens,
    mean queue depth, max queue_hwm and sheds per bin, so queue
    growth and shedding are visible as a time series rather than a
    single end-of-run number.

Exit codes: 0 on success, 1 if the streams held no records, 2 on
usage errors. Validation is check_jsonl.py's job — this script only
aggregates (it skips blank lines but lets malformed JSON raise).

Usage: metrics_report.py <metrics.jsonl> [more.jsonl ...]
"""

import json
import sys

OVERLOAD_FIELDS = ("shed", "deadline_miss", "cancelled", "queue_hwm")


def load(paths):
    records = []
    for path in paths:
        with open(path, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                for key in OVERLOAD_FIELDS:  # v1 tolerance
                    rec.setdefault(key, 0)
                records.append(rec)
    return records


def quantile(sorted_xs, q):
    if not sorted_xs:
        return 0
    i = min(len(sorted_xs) - 1, int(q * len(sorted_xs)))
    return sorted_xs[i]


def main():
    if len(sys.argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        sys.exit(2)
    records = load(sys.argv[1:])
    if not records:
        print("no telemetry records in " + ", ".join(sys.argv[1:]), file=sys.stderr)
        sys.exit(1)
    records.sort(key=lambda r: r["step"])

    total = lambda key: sum(r[key] for r in records)
    tokens = total("tokens")
    versions = sorted({r["schema_version"] for r in records})
    print(
        f"merged {len(records)} records from {len(sys.argv) - 1} stream(s) "
        f"(schema {', '.join(f'v{v}' for v in versions)})"
    )
    print(
        f"  work       : {tokens} tokens "
        f"({total('decode_rows')} decode + {total('prefill_rows')} prefill rows, "
        f"{total('prefill_chunks')} prefill chunks)"
    )
    print(
        f"  overflow   : {total('overflow_linear')} linear + {total('overflow_attn')} attention "
        f"({(total('overflow_linear') + total('overflow_attn')) / max(tokens, 1):.4f} per row)"
    )
    print(
        f"  admission  : {total('shed')} shed / {total('deadline_miss')} deadline-missed / "
        f"{total('cancelled')} cancelled "
        f"(queue hwm {max(r['queue_hwm'] for r in records)})"
    )
    walls = sorted(r["wall_ns"] for r in records)
    ms = lambda ns: ns / 1e6
    print(
        f"  step wall  : p50 {ms(quantile(walls, 0.50)):.2f} / p90 {ms(quantile(walls, 0.90)):.2f} "
        f"/ p99 {ms(quantile(walls, 0.99)):.2f} / max {ms(walls[-1]):.2f} ms"
    )
    occupied = [r for r in records if r["tokens"] > 0]
    mean_rows = sum(r["tokens"] for r in occupied) / max(len(occupied), 1)
    print(f"  occupancy  : {mean_rows:.2f} mean rows over {len(occupied)} executing steps")

    lo, hi = records[0]["step"], records[-1]["step"]
    span = hi - lo + 1
    bins = min(10, span)
    width = -(-span // bins)  # ceil
    print(f"  timeline   : {bins} bins × {width} steps")
    print("      steps        n   tokens  depth(mean)  hwm(max)  shed")
    for b in range(bins):
        lo_b, hi_b = lo + b * width, lo + (b + 1) * width - 1
        chunk = [r for r in records if lo_b <= r["step"] <= hi_b]
        if not chunk:
            continue
        depth = sum(r["queue_depth"] for r in chunk) / len(chunk)
        print(
            f"      {lo_b:>5}-{hi_b:<5} {len(chunk):>4} {sum(r['tokens'] for r in chunk):>8} "
            f"{depth:>12.2f} {max(r['queue_hwm'] for r in chunk):>9} "
            f"{sum(r['shed'] for r in chunk):>5}"
        )


if __name__ == "__main__":
    main()
