#!/usr/bin/env python3
"""Validate a telemetry JSONL stream emitted by `axe serve --metrics`.

Every line must be a self-contained JSON object carrying the complete
StepRecord field set for its declared schema version (no more, no
less) — v1 streams from older builds, v2 streams with the overload
counters (shed, deadline_miss, cancelled, queue_hwm) and v3 streams
with the speculative-decoding counters (spec_proposed, spec_accepted,
draft_rows, overflow_draft) all pass; steps must be strictly
increasing, every counter a non-negative integer, each record's row
total must decompose into decode + prefill rows, v2+'s queue_hwm must
dominate queue_depth and never regress along the stream, and v3's
spec_accepted can never exceed spec_proposed. Exits non-zero with a
file:line diagnostic on the first violation.

Usage: check_jsonl.py <metrics.jsonl> [min_records]
"""

import json
import sys

REQUIRED_V1 = {
    "arena_capacity_bytes",
    "arena_resident_bytes",
    "attn_bands",
    "decode_rows",
    "overflow_attn",
    "overflow_linear",
    "prefill_chunks",
    "prefill_rows",
    "prefix_dedups",
    "prefix_evictions",
    "prefix_hits",
    "queue_depth",
    "schema_version",
    "step",
    "tokens",
    "wall_ns",
}

REQUIRED_V2 = REQUIRED_V1 | {"cancelled", "deadline_miss", "queue_hwm", "shed"}

REQUIRED_V3 = REQUIRED_V2 | {
    "draft_rows",
    "overflow_draft",
    "spec_accepted",
    "spec_proposed",
}

REQUIRED = {1: REQUIRED_V1, 2: REQUIRED_V2, 3: REQUIRED_V3}


def fail(path, line_no, msg):
    print(f"{path}:{line_no}: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    if len(sys.argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        sys.exit(2)
    path = sys.argv[1]
    min_records = int(sys.argv[2]) if len(sys.argv) > 2 else 1
    prev_step = None
    prev_hwm = 0
    versions = set()
    n = 0
    with open(path, encoding="utf-8") as f:
        for line_no, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                fail(path, line_no, f"not valid JSON: {e}")
            if not isinstance(rec, dict):
                fail(path, line_no, "record is not a JSON object")
            version = rec.get("schema_version")
            required = REQUIRED.get(version)
            if required is None:
                fail(path, line_no, f"schema_version {version!r} not in {sorted(REQUIRED)}")
            versions.add(version)
            missing = required - rec.keys()
            if missing:
                fail(path, line_no, f"missing fields: {sorted(missing)}")
            extra = rec.keys() - required
            if extra:
                fail(path, line_no, f"unknown fields for schema v{version}: {sorted(extra)}")
            for key in sorted(required):
                v = rec[key]
                if isinstance(v, bool) or not isinstance(v, int) or v < 0:
                    fail(path, line_no, f"{key} must be a non-negative integer, got {v!r}")
            if rec["tokens"] != rec["decode_rows"] + rec["prefill_rows"]:
                fail(
                    path,
                    line_no,
                    f"tokens {rec['tokens']} != decode_rows {rec['decode_rows']} "
                    f"+ prefill_rows {rec['prefill_rows']}",
                )
            if prev_step is not None and rec["step"] <= prev_step:
                fail(
                    path,
                    line_no,
                    f"step {rec['step']} not strictly increasing (prev {prev_step})",
                )
            prev_step = rec["step"]
            if version >= 2:
                if rec["queue_hwm"] < rec["queue_depth"]:
                    fail(
                        path,
                        line_no,
                        f"queue_hwm {rec['queue_hwm']} < queue_depth {rec['queue_depth']}",
                    )
                if rec["queue_hwm"] < prev_hwm:
                    fail(
                        path,
                        line_no,
                        f"queue_hwm {rec['queue_hwm']} regressed (prev {prev_hwm})",
                    )
                prev_hwm = rec["queue_hwm"]
            if version >= 3 and rec["spec_accepted"] > rec["spec_proposed"]:
                fail(
                    path,
                    line_no,
                    f"spec_accepted {rec['spec_accepted']} > "
                    f"spec_proposed {rec['spec_proposed']}",
                )
            n += 1
    if n < min_records:
        print(f"{path}: only {n} records, expected at least {min_records}", file=sys.stderr)
        sys.exit(1)
    vs = ", ".join(f"v{v}" for v in sorted(versions)) or "none"
    print(f"{path}: {n} telemetry records OK (schema {vs}, steps strictly increasing)")


if __name__ == "__main__":
    main()
