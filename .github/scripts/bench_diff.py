#!/usr/bin/env python3
"""Diff a fresh BENCH_decode.json against the committed baseline.

Prints a per-configuration tokens/s and TTFT comparison. Once a
measured (non-stub) baseline is committed — the bench-decode job
bootstraps it from its own first run on main — any configuration whose
tokens/s drops more than REGRESSION_PCT fails the job. Shared-runner
noise on the tiny synthetic model is real, hence the generous margin:
this gate catches collapses (an accidentally quadratic hot path), not
single-digit drift.
"""

import json
import pathlib
import sys

# tokens/s drop (percent) beyond which the job fails
REGRESSION_PCT = 25.0


def rows(doc):
    return {
        (c.get("kv"), c.get("in_flight")): c.get("tokens_per_s")
        for c in doc.get("configs", [])
    }


def ttft_rows(doc):
    block = doc.get("ttft_under_load") or {}
    return {c.get("prefill_chunk"): c.get("ttft_ms") for c in block.get("configs", [])}


def ragged_rows(doc):
    block = doc.get("ragged_attention") or {}
    return {
        (c.get("in_flight"), c.get("prefill_chunk")): (
            c.get("serial_tok_s"),
            c.get("parallel_tok_s"),
        )
        for c in block.get("configs", [])
    }


def hist_rows(doc):
    return {
        (c.get("kv"), c.get("in_flight")): c for c in doc.get("step_histograms", [])
    }


def main():
    cur_path = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "BENCH_decode.json")
    base_path = pathlib.Path(
        sys.argv[2] if len(sys.argv) > 2 else "BENCH_decode.baseline.json"
    )
    if not base_path.is_file():
        print(
            f"no {base_path} committed yet — the bench-decode job bootstraps it "
            "from its first measured run on main."
        )
        return
    cur = json.loads(cur_path.read_text())
    base = json.loads(base_path.read_text())
    if str(base.get("schema", "")).endswith("-stub"):
        print(f"{base_path} is a schema stub (no measured numbers) — skipping diff.")
        return
    b, c = rows(base), rows(cur)
    regressions = []
    print(f"decode throughput vs baseline ({base.get('model')}):")
    print(f"{'config':>14} {'baseline':>10} {'current':>10} {'delta':>8}")
    for key in sorted(c, key=str):
        if key in b and isinstance(b[key], (int, float)) and b[key]:
            delta = 100.0 * (c[key] - b[key]) / b[key]
            print(f"{key[0]:>9}@{key[1]:<4} {b[key]:>10.1f} {c[key]:>10.1f} {delta:>+7.1f}%")
            if delta < -REGRESSION_PCT:
                regressions.append((key, delta))
    bt, ct = ttft_rows(base), ttft_rows(cur)
    shared = [k for k in ct if k in bt and isinstance(bt[k], (int, float))]
    if shared:
        print("ttft under load (ms, long prompt vs loaded batch):")
        print(f"{'chunk':>10} {'baseline':>10} {'current':>10}")
        for k in sorted(shared, key=lambda x: (x is None, x)):
            print(f"{k!s:>10} {bt[k]:>10.2f} {ct[k]:>10.2f}")
    cr = ragged_rows(cur)
    if cr:
        # informational: banded vs serial ragged attention in THIS run
        # (in-run before/after, so runner noise cancels; not gated —
        # the speedup depends on the runner's core count)
        print("ragged attention: serial vs banded sweep (tok/s, this run):")
        print(f"{'config':>14} {'serial':>10} {'banded':>10} {'speedup':>8}")
        for (in_flight, chunk), (ser, par) in sorted(cr.items(), key=str):
            if isinstance(ser, (int, float)) and ser and isinstance(par, (int, float)):
                print(
                    f"{in_flight!s:>7}@c{chunk!s:<6} {ser:>10.1f} {par:>10.1f} "
                    f"{par / ser:>7.2f}x"
                )
    ch = hist_rows(cur)
    if ch:
        # informational: the telemetry ring's view of the same serve
        # runs (log2-bucket quantiles). Old baselines predate
        # step_histograms, so this block reads the current run only and
        # is never gated.
        print("step histograms (telemetry ring, this run):")
        print(f"{'config':>14} {'p50_ms':>8} {'p99_ms':>8} {'occ_p50':>8} {'dropped':>8}")
        for (kv, in_flight), h in sorted(ch.items(), key=str):
            p50, p99 = h.get("step_ns_p50"), h.get("step_ns_p99")
            if isinstance(p50, (int, float)) and isinstance(p99, (int, float)):
                print(
                    f"{kv!s:>9}@{in_flight!s:<4} {p50 / 1e6:>8.3f} {p99 / 1e6:>8.3f} "
                    f"{h.get('occupancy_p50')!s:>8} {h.get('records_dropped')!s:>8}"
                )
    ov = cur.get("telemetry_overhead") or {}
    if isinstance(ov.get("overhead_pct"), (int, float)):
        print(
            f"telemetry overhead ({ov.get('kv')}@{ov.get('in_flight')}): "
            f"off {ov.get('off_tok_s')} tok/s, on+jsonl {ov.get('on_tok_s')} tok/s "
            f"({ov['overhead_pct']:+.2f}%)"
        )
    if regressions:
        for (kv, in_flight), delta in regressions:
            print(
                f"REGRESSION: {kv}@{in_flight} tokens/s {delta:+.1f}% "
                f"(limit -{REGRESSION_PCT:.0f}%)"
            )
        sys.exit(1)


if __name__ == "__main__":
    main()
