//! Offline **type-check stub** for the `xla` (xla-rs) PJRT bindings.
//!
//! The real bindings link the XLA C++ runtime and are not available in
//! the offline registry. This crate mirrors exactly the API surface
//! `axe::runtime` uses — [`PjRtClient`], [`PjRtLoadedExecutable`],
//! [`PjRtBuffer`], [`Literal`], [`HloModuleProto`], [`XlaComputation`]
//! — so `cargo check --all-features` (and CI) can type-check the
//! `pjrt`-gated code without network access.
//!
//! Every entry point that would touch XLA returns [`Error`] at runtime;
//! nothing here executes an HLO module. To actually run artifacts,
//! point the `xla` dependency in `rust/Cargo.toml` at a real xla-rs
//! checkout instead of this stub and rebuild with `--features pjrt`.

use std::fmt;

/// Error carrying a description of the operation the stub refused.
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "{what}: this is the vendored `xla` type-check stub — point the `xla` \
             dependency at a real xla-rs checkout to execute artifacts"
        ))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold. Sealed to the primitives the
/// runtime exchanges with the artifacts.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// A host-side tensor. The stub stores nothing.
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    /// Copy the literal out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::stub("reading a literal"))
    }

    /// Reshape to `dims` (row-major).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("reshaping a literal"))
    }

    /// Split a tuple literal into its elements.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::stub("decomposing a tuple literal"))
    }
}

/// A device buffer holding one executable output.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer to the host as a [`Literal`].
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("fetching a device buffer"))
    }
}

/// A PJRT client. The stub's constructor always fails, so the
/// executable/buffer methods below are unreachable at runtime — they
/// exist purely so `pjrt`-gated callers type-check offline.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("creating a PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("compiling a computation"))
    }
}

/// A compiled executable bound to a client.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on one replica; outputs are per-device, per-output
    /// buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("executing"))
    }
}

/// A parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file (the artifact interchange format).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("parsing HLO text"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_refuses_with_description() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(format!("{err:?}").contains("stub"));
        let err = Literal::vec1(&[1.0f32]).to_vec::<f32>().err().unwrap();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn computation_pipeline_types_line_up() {
        // the compile-time contract the runtime relies on
        let proto = HloModuleProto::from_text_file("/nonexistent");
        assert!(proto.is_err());
        let comp = XlaComputation::from_proto(&HloModuleProto);
        let client = PjRtClient::cpu();
        assert!(client.is_err());
        let _ = comp;
    }
}
