//! Offline drop-in for the subset of [`anyhow`](https://docs.rs/anyhow)
//! this workspace uses. The build environment has no network access to
//! crates.io, so the crate is vendored as plain source.
//!
//! Covered surface:
//! - [`Error`]: message + cause chain. `{e}` prints the top message,
//!   `{e:#}` (and `{e:?}`) the full chain joined with `": "`.
//! - [`Result<T>`] with the `E = Error` default.
//! - [`Context`]: `.context(..)` / `.with_context(|| ..)` on both
//!   `Result` and `Option`.
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` impl coherent (and `?` work on any std
//! error type).

use std::fmt;

/// An error carrying a message and its cause chain (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost (most recently attached) message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` with this crate's [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($tt:tt)+) => {
        if !($cond) {
            $crate::bail!($($tt)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("always fails ({})", x))
        }
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "always fails (1)");
    }

    #[test]
    fn chain_iterates_outermost_first() {
        let e = Error::msg("a").context("b").context("c");
        let parts: Vec<&str> = e.chain().collect();
        assert_eq!(parts, vec!["c", "b", "a"]);
        assert_eq!(e.root_message(), "c");
    }
}
