//! Cross-module integration tests: full pipeline runs on synthetic
//! models, method orderings the paper predicts, and the guarantee
//! enforced end-to-end through the faithful datapath.

use axe::coordinator::{quantize_mlp, quantize_transformer, DatapathMode, PipelineConfig};
use axe::eval::{perplexity, synth_corpus, synth_glyphs, top1_accuracy};
use axe::model::{
    random_mlp, random_transformer, Activation, MlpConfig, TransformerConfig,
};
use axe::quant::{AccumTarget, Algorithm, Method};

fn lm_fixture(seed: u64) -> (axe::model::Transformer, Vec<u16>) {
    let cfg = TransformerConfig {
        name: "itest".into(),
        vocab: 64,
        d_model: 24,
        n_layers: 2,
        n_heads: 3,
        d_ff: 48,
        max_seq: 24,
        act: Activation::Gelu,
        parallel_residual: true,
    };
    (random_transformer(cfg, seed), synth_corpus(24 * 40, 64, seed + 1))
}

#[test]
fn all_algorithms_run_and_audit_clean() {
    let (base, toks) = lm_fixture(100);
    let calib: Vec<&[u16]> = toks.chunks_exact(24).take(6).collect();
    for algo in [Algorithm::Gpfq, Algorithm::GpfqMemEff, Algorithm::Optq] {
        for method in [Method::Naive, Method::EpInit, Method::Axe] {
            let mut cfg = PipelineConfig::new(algo, method, 4, 8);
            cfg.target = AccumTarget::MultiStage { p_inner: 15, tile: 16 };
            let mut m = base.clone();
            let report = quantize_transformer(&mut m, &calib, &cfg).unwrap();
            assert!(
                report.guaranteed_safe(),
                "{} + {} must audit clean",
                algo.name(),
                method.name()
            );
            let ppl = perplexity(&m, &toks, 24, 8);
            assert!(ppl.ppl.is_finite(), "{} + {}", algo.name(), method.name());
        }
    }
}

#[test]
fn axe_beats_ep_init_under_tight_budget() {
    // the paper's core claim (Table 2 / frontiers): greedy error
    // correction inside the constraint beats post-hoc projection.
    let (base, toks) = lm_fixture(101);
    let calib: Vec<&[u16]> = toks.chunks_exact(24).take(8).collect();
    let tight = AccumTarget::Monolithic { p_bits: 13 };
    let run = |method: Method| {
        let mut cfg = PipelineConfig::new(Algorithm::Optq, method, 4, 8);
        cfg.target = tight;
        let mut m = base.clone();
        quantize_transformer(&mut m, &calib, &cfg).unwrap();
        perplexity(&m, &toks, 24, 12).ppl
    };
    let ppl_axe = run(Method::Axe);
    let ppl_ep = run(Method::EpInit);
    assert!(
        ppl_axe <= ppl_ep * 1.05,
        "AXE ({ppl_axe:.1}) should not lose to EP-init ({ppl_ep:.1}) under a tight budget"
    );
}

#[test]
fn multistage_beats_monolithic_at_same_inner_width() {
    // Table 1 vs Table 3 mechanics: per-tile budgets are much looser
    // than one monolithic budget of the same width.
    let (base, toks) = lm_fixture(102);
    let calib: Vec<&[u16]> = toks.chunks_exact(24).take(8).collect();
    let run = |target: AccumTarget| {
        let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
        cfg.target = target;
        let mut m = base.clone();
        quantize_transformer(&mut m, &calib, &cfg).unwrap();
        perplexity(&m, &toks, 24, 12).ppl
    };
    let multi = run(AccumTarget::MultiStage { p_inner: 14, tile: 8 });
    let mono = run(AccumTarget::Monolithic { p_bits: 14 });
    assert!(
        multi <= mono * 1.05,
        "multi-stage ({multi:.1}) should beat monolithic ({mono:.1})"
    );
}

#[test]
fn faithful_eval_confirms_guarantee_end_to_end() {
    let (base, toks) = lm_fixture(103);
    let calib: Vec<&[u16]> = toks.chunks_exact(24).take(6).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Gpfq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 14, tile: 16 };
    cfg.datapath = DatapathMode::Faithful;
    let mut m = base.clone();
    quantize_transformer(&mut m, &calib, &cfg).unwrap();
    let r = perplexity(&m, &toks, 24, 10);
    assert_eq!(r.overflows, 0, "guaranteed-safe model must not overflow on real data");
}

#[test]
fn mlp_track_method_ordering() {
    let set = synth_glyphs(400, 8, 10, 200);
    let test = synth_glyphs(200, 8, 10, 201);
    // train a usable MLP quickly with a crude least-squares-ish head:
    // random features + many classes is enough signal for ordering tests
    let cfg = MlpConfig {
        name: "itest-img".into(),
        input_dim: 64,
        hidden: vec![48, 48],
        classes: 10,
        act: Activation::Relu,
        residual: false,
    };
    let base = random_mlp(cfg, 202);
    let calib: Vec<&[f32]> = (0..64).map(|i| set.row(i)).collect();
    // W8A8 naive quantization must track the float model's accuracy closely
    let float_acc = top1_accuracy(&base, &test);
    let mut m = base.clone();
    let qcfg = PipelineConfig::new(Algorithm::Optq, Method::Naive, 8, 8);
    let report = quantize_mlp(&mut m, &calib, &qcfg).unwrap();
    assert!(report.guaranteed_safe());
    let q_acc = top1_accuracy(&m, &test);
    assert!((q_acc - float_acc).abs() < 8.0, "W8A8 acc {q_acc} vs float {float_acc}");
}

#[test]
fn sparsity_grows_as_budget_tightens() {
    // App. D observation: tighter accumulators force more zeros.
    let (base, toks) = lm_fixture(104);
    let calib: Vec<&[u16]> = toks.chunks_exact(24).take(6).collect();
    let sparsity_at = |p: u32| {
        let mut cfg = PipelineConfig::new(Algorithm::Gpfq, Method::Axe, 4, 8);
        cfg.target = AccumTarget::Monolithic { p_bits: p };
        let mut m = base.clone();
        quantize_transformer(&mut m, &calib, &cfg).unwrap().sparsity()
    };
    let loose = sparsity_at(24);
    let tight = sparsity_at(12);
    assert!(
        tight > loose,
        "sparsity must grow as P shrinks: P=12 -> {tight:.3}, P=24 -> {loose:.3}"
    );
}
