//! Property harness for **self-speculative decoding**: under
//! randomized admission schedules, the speculative scheduler — a
//! narrow-register draft pass proposing k tokens per decoding
//! sequence, verified in one full-width chunk-causal ragged step —
//! must emit, for every request, exactly the token stream sequential
//! greedy decode emits AND exactly the overflow events that request
//! triggers when served alone (accepted verify rows only; draft work
//! rolls back and is never attributed). The property must hold for
//! every draft depth k ∈ {1, 2, 4, 8} × draft width (full and
//! aggressively narrowed), on both KV backends, with the prefix cache
//! on and off, through window slides, slot reuse and mid-flight
//! cancellation — a wrong-often draft may cost acceptance, never
//! correctness.

use axe::accum::OverflowMode;
use axe::coordinator::serve::{CancelToken, Request, Response, ServeConfig, Status, StepEngine};
use axe::coordinator::telemetry::MetricsSummary;
use axe::coordinator::{quantize_transformer, DatapathMode, PipelineConfig};
use axe::eval::synth_corpus;
use axe::model::{
    argmax, random_transformer, Activation, Datapath, KvArena, KvCacheKind, KvQuantSpec, Linear,
    Transformer, TransformerConfig,
};
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::util::rng::Rng;
use std::time::Instant;

fn model(seed: u64) -> Transformer {
    random_transformer(
        TransformerConfig {
            name: "spec".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 16,
            act: Activation::Gelu,
            parallel_residual: false,
        },
        seed,
    )
}

/// Sequential single-request reference: the tokens AND the exact
/// overflow events this request costs when served alone — the stream
/// and attribution every speculative configuration must reproduce.
fn sequential_reference(
    m: &Transformer,
    prompt: &[u16],
    n: usize,
    kind: KvCacheKind,
) -> (Vec<u16>, u64) {
    let clipped = m.clip_to_window(prompt);
    let mut arena = KvArena::with_kind(m, 1, kind);
    let slot = arena.alloc().unwrap();
    let mut ovf = 0u64;
    let mut logits = m.prefill_slot_counted(&clipped, slot, &mut arena, &mut ovf);
    let mut context = clipped.clone();
    let mut out: Vec<u16> = Vec::new();
    let mut row = [0u64; 1];
    for i in 0..n {
        if arena.is_full(slot) {
            let keep = m.slide_keep();
            let tail = context[context.len() - keep..].to_vec();
            arena.reset_slot(slot);
            logits = m.prefill_slot_counted(&tail, slot, &mut arena, &mut ovf);
            context = tail;
        }
        let next = argmax(&logits) as u16;
        out.push(next);
        context.push(next);
        if i + 1 < n {
            row[0] = 0;
            logits = m.decode_step_batch_counted(&[next], &[slot], &mut arena, &mut row);
            ovf += row[0];
        }
    }
    (out, ovf)
}

/// Drive a [`StepEngine`] through an admission schedule (request `i`
/// admitted at tick `arrivals[i]`, deferred FCFS while no slot is
/// free), returning the id-sorted responses and the engine's telemetry
/// summary.
fn run_schedule(
    m: &Transformer,
    cfg: ServeConfig,
    reqs: &[Request],
    arrivals: &[usize],
) -> (Vec<Response>, MetricsSummary) {
    let mut eng = StepEngine::new(m, cfg);
    let mut done: Vec<Response> = Vec::new();
    let mut next = 0usize;
    let mut tick = 0usize;
    loop {
        while next < reqs.len() && arrivals[next] <= tick && eng.free_slots() > 0 {
            eng.admit(reqs[next].clone(), Instant::now());
            next += 1;
        }
        eng.step();
        done.extend(eng.take_finished());
        tick += 1;
        if next == reqs.len() && !eng.has_work() {
            break;
        }
        assert!(tick < 100_000, "schedule did not converge");
    }
    let summary = eng.metrics().expect("telemetry is on by default").summary();
    done.sort_by_key(|r| r.id);
    (done, summary)
}

/// Random schedule: prompts 1..=22 tokens (several past max_seq=16 →
/// clipped), generations 1..=28 (several past the window → slides mid
/// speculation chunk), arrivals spread over the first 12 ticks, 3
/// slots for 7 requests → deferred admissions and slot reuse.
fn random_schedule(rng: &mut Rng, n_req: usize) -> (Vec<Request>, Vec<usize>) {
    let mut reqs = Vec::new();
    let mut arrivals: Vec<usize> = (0..n_req).map(|_| rng.int_in(0, 12) as usize).collect();
    arrivals.sort_unstable();
    for id in 0..n_req as u64 {
        let plen = rng.int_in(1, 22) as usize;
        let prompt: Vec<u16> = (0..plen).map(|_| rng.int_in(0, 31) as u16).collect();
        let max_new_tokens = rng.int_in(1, 28) as usize;
        reqs.push(Request { id, prompt, max_new_tokens, ..Request::default() });
    }
    (reqs, arrivals)
}

/// THE speculative-serving property: for every draft depth × draft
/// width × KV backend, randomized schedules emit bit-identical token
/// streams and exact per-request overflow attribution versus the solo
/// sequential reference — identical to what the k = 1 engine is held
/// to, so speculation is pure scheduling, invisible in every output.
#[test]
fn randomized_schedules_are_bit_exact_across_draft_depths() {
    let m = model(42);
    let mut rng = Rng::new(7001);
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)))] {
        let (reqs, arrivals) = random_schedule(&mut rng, 7);
        // solo references once per backend — every configuration below
        // must hit exactly these
        let want: Vec<(Vec<u16>, u64)> = reqs
            .iter()
            .map(|r| sequential_reference(&m, &r.prompt, r.max_new_tokens, kind))
            .collect();
        for &k in &[1usize, 2, 4, 8] {
            for &bits in &[None, Some(4u32)] {
                let label = format!("kind={kind:?} k={k} draft_bits={bits:?}");
                let cfg = ServeConfig::new(3, kind).with_prefill_chunk(5).with_speculate(k, bits);
                let (responses, t) = run_schedule(&m, cfg, &reqs, &arrivals);
                assert_eq!(responses.len(), reqs.len(), "{label}: lost responses");
                for (resp, (req, (want_tokens, want_ovf))) in
                    responses.iter().zip(reqs.iter().zip(want.iter()))
                {
                    assert_eq!(resp.id, req.id);
                    assert_eq!(
                        &resp.tokens, want_tokens,
                        "{label}: request {} token stream diverged from sequential decode",
                        req.id
                    );
                    assert_eq!(
                        resp.overflow_events, *want_ovf,
                        "{label}: request {} overflow attribution diverged from solo serving",
                        req.id
                    );
                }
                assert!(t.spec_accepted <= t.spec_proposed, "{label}");
                assert_eq!(t.draft_rows, t.spec_proposed, "{label}: one draft row per proposal");
                if k == 1 {
                    assert_eq!(t.spec_proposed, 0, "{label}: k=1 must not speculate");
                    assert_eq!(t.overflow_draft, 0, "{label}");
                } else {
                    assert!(t.spec_proposed > 0, "{label}: no draft tokens proposed");
                }
                // float weights + f32 KV leave the narrow knob nothing
                // to bite: the draft is exact, so acceptance is total
                if k > 1 && matches!(kind, KvCacheKind::F32) {
                    assert_eq!(
                        t.spec_accepted, t.spec_proposed,
                        "{label}: an exact draft must be fully accepted"
                    );
                }
            }
        }
    }
}

/// The full paper configuration: an AXE-quantized model on the fused
/// integer kernel with deliberately narrowed linear registers (live
/// linear overflow events), speculating with the draft registers
/// narrowed further. Drafts run the same stored codes through smaller
/// accumulators — often wrong, costing only acceptance — and tokens
/// plus attribution stay exact on both KV backends.
#[test]
fn quantized_model_speculative_serving_is_exact() {
    let base = model(44);
    let toks = synth_corpus(16 * 16, 32, 45);
    let calib: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 14, tile: 8 };
    cfg.datapath = DatapathMode::Faithful;
    let mut qmodel = base;
    quantize_transformer(&mut qmodel, &calib, &cfg).unwrap();
    // narrow every quantized linear so verify-pass overflow events are
    // live — their attribution must survive speculation exactly
    for name in qmodel.linear_names() {
        if let Some(Linear::Quant(q)) = qmodel.get_linear_mut(&name) {
            q.datapath = Datapath::Simulated {
                tile: 8,
                inner_bits: 11,
                outer_bits: 14,
                mode: OverflowMode::Wraparound,
            };
        }
    }
    let mut rng = Rng::new(7002);
    let (reqs, arrivals) = random_schedule(&mut rng, 5);
    let (_, probe_ovf) =
        sequential_reference(&qmodel, &reqs[0].prompt, reqs[0].max_new_tokens, KvCacheKind::F32);
    assert!(probe_ovf > 0, "narrowed linear registers must overflow in this fixture");
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
        for &k in &[2usize, 4] {
            for &bits in &[None, Some(8u32)] {
                let label = format!("qmodel kind={kind:?} k={k} draft_bits={bits:?}");
                let cfg = ServeConfig::new(3, kind).with_prefill_chunk(4).with_speculate(k, bits);
                let (responses, t) = run_schedule(&qmodel, cfg, &reqs, &arrivals);
                assert_eq!(responses.len(), reqs.len(), "{label}: lost responses");
                for (resp, req) in responses.iter().zip(reqs.iter()) {
                    let (want_tokens, want_ovf) =
                        sequential_reference(&qmodel, &req.prompt, req.max_new_tokens, kind);
                    assert_eq!(resp.tokens, want_tokens, "{label}: request {} tokens", req.id);
                    assert_eq!(
                        resp.overflow_events, want_ovf,
                        "{label}: request {} overflow attribution",
                        req.id
                    );
                }
                assert!(t.spec_proposed > 0, "{label}: no proposals");
                assert!(t.spec_accepted <= t.spec_proposed, "{label}");
                if bits == Some(8) {
                    // an 8-bit draft register under 11-bit-live traffic
                    // must overflow — that work is telemetry, never
                    // per-request attribution (checked exactly above)
                    assert!(t.overflow_draft > 0, "{label}: narrow draft must overflow");
                }
            }
        }
    }
}

/// Prefix sharing composes with speculation: overlapping-prefix
/// schedules (7 requests over one system prompt, 3 slots, 4-token
/// pages) emit identical tokens and per-request overflow with the
/// cache on vs off while speculating — accepted verify rows extend
/// pages the followers adopted, rejected rows roll back off them, and
/// none of it may leak into the registered prefix.
#[test]
fn prefix_sharing_composes_with_speculation() {
    let m = model(47);
    let system: Vec<u16> = (0..10u16).map(|i| (i * 7 + 3) % 32).collect();
    let mut rng = Rng::new(7003);
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)))] {
        let mut arrivals: Vec<usize> = (0..7).map(|_| rng.int_in(0, 10) as usize).collect();
        arrivals.sort_unstable();
        let reqs: Vec<Request> = (0..7u64)
            .map(|id| {
                let tail = rng.int_in(0, 5) as usize;
                let mut prompt = system.clone();
                prompt.extend((0..tail).map(|_| rng.int_in(0, 31) as u16));
                Request {
                    id,
                    prompt,
                    max_new_tokens: rng.int_in(1, 24) as usize,
                    ..Request::default()
                }
            })
            .collect();
        let label = format!("kind={kind:?}");
        let run = |sharing: bool| {
            let cfg = ServeConfig::new(3, kind)
                .with_prefill_chunk(5)
                .with_kv_page(4)
                .with_prefix_cache(sharing)
                .with_speculate(4, Some(4));
            run_schedule(&m, cfg, &reqs, &arrivals).0
        };
        let on = run(true);
        let off = run(false);
        assert_eq!(on.len(), reqs.len(), "{label}: lost responses");
        for ((a, b), req) in on.iter().zip(off.iter()).zip(reqs.iter()) {
            assert_eq!(a.id, req.id);
            assert_eq!(
                a.tokens, b.tokens,
                "{label}: request {} tokens depend on prefix sharing",
                req.id
            );
            assert_eq!(
                a.overflow_events, b.overflow_events,
                "{label}: request {} overflow attribution depends on prefix sharing",
                req.id
            );
            assert_eq!(b.prefill_tokens_skipped, 0, "{label}: sharing off must skip nothing");
            let (want_tokens, want_ovf) =
                sequential_reference(&m, &req.prompt, req.max_new_tokens, kind);
            assert_eq!(a.tokens, want_tokens, "{label}: request {} vs solo", req.id);
            assert_eq!(a.overflow_events, want_ovf, "{label}: request {} ovf vs solo", req.id);
        }
        let skipped: usize = on.iter().map(|r| r.prefill_tokens_skipped).sum();
        assert!(skipped > 0, "{label}: no admission ever hit the prefix cache");
    }
}

/// Mid-flight cancellation while the engine is speculating: the reaper
/// resolves the cancelled sequence with a partial, prefix-exact stream
/// (whole accepted chunks — never a half-verified token), frees its
/// slot immediately, and once the survivors retire every page refcount
/// is back to zero — rolled-back draft and rejected verify rows pin
/// nothing.
#[test]
fn cancellation_with_outstanding_draft_tokens_frees_everything() {
    let m = model(46);
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)))] {
        let label = format!("kind={kind:?}");
        let cfg = ServeConfig::new(2, kind)
            .with_prefill_chunk(usize::MAX)
            .with_kv_page(4)
            .with_speculate(8, Some(4));
        let mut eng = StepEngine::new(&m, cfg);
        let tok = CancelToken::new();
        eng.admit(
            Request {
                id: 0,
                prompt: vec![1, 2],
                max_new_tokens: 26, // runs past the window if uncancelled
                cancel: Some(tok.clone()),
                ..Request::default()
            },
            Instant::now(),
        );
        eng.admit(
            Request { id: 1, prompt: vec![3, 4, 5], max_new_tokens: 12, ..Request::default() },
            Instant::now(),
        );
        eng.step(); // both prompts prefill
        eng.step(); // first sample + speculative chunk
        eng.step(); // another speculative step; drafts outstanding for both
        tok.cancel();
        eng.step(); // reaper fires before any further sampling
        let cancelled: Vec<Response> =
            eng.take_finished().into_iter().filter(|r| r.id == 0).collect();
        assert_eq!(cancelled.len(), 1, "{label}: cancel must resolve the request");
        assert_eq!(cancelled[0].status, Status::Cancelled, "{label}");
        let (want, _) = sequential_reference(&m, &[1, 2], 26, kind);
        let got = &cancelled[0].tokens;
        assert!(!got.is_empty(), "{label}: two speculative steps must have emitted");
        assert!(got.len() < want.len(), "{label}: the cancel must land mid-generation");
        assert_eq!(got[..], want[..got.len()], "{label}: partial stream is prefix-exact");
        assert_eq!(eng.free_slots(), 1, "{label}: slot released on cancellation");
        // the survivor decodes on, unperturbed, to the exact stream
        let mut done = Vec::new();
        while eng.has_work() {
            eng.step();
            done.extend(eng.take_finished());
        }
        assert_eq!(done.len(), 1, "{label}: survivor must retire");
        let (want1, want1_ovf) = sequential_reference(&m, &[3, 4, 5], 12, kind);
        assert_eq!(done[0].tokens, want1, "{label}: survivor tokens");
        assert_eq!(done[0].overflow_events, want1_ovf, "{label}: survivor attribution");
        assert_eq!(
            eng.arena().resident_pages(),
            0,
            "{label}: every page refcount must drop to zero after retirement"
        );
    }
}
