//! Integration tests against the real artifacts (weight zoo, datasets,
//! AOT HLO). Each test skips gracefully when `make artifacts` has not
//! run, so `cargo test` stays green on a fresh checkout; CI/the release
//! flow runs them against the trained zoo.

use axe::model::{load_named, read_f32_bin_any, Model};
use axe::runtime::{F32Input, Runtime};

fn have_artifacts() -> bool {
    axe::artifacts_dir().join("weights").is_dir()
        && !axe::model::list_models().is_empty()
}

macro_rules! skip_without_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("[skip] artifacts not built");
            return;
        }
    };
}

#[test]
fn zoo_loads_every_model() {
    skip_without_artifacts!();
    let names = axe::model::list_models();
    assert!(!names.is_empty());
    for n in &names {
        let m = load_named(n).unwrap_or_else(|e| panic!("loading {n}: {e}"));
        assert!(m.param_count() > 1000, "{n}");
    }
}

/// Rust forward must reproduce the JAX forward on the exported parity
/// bundle — the contract that makes the PTQ results transferable.
#[test]
fn rust_jax_parity_lm() {
    skip_without_artifacts!();
    for name in axe::model::list_models() {
        let dir = axe::artifacts_dir().join("weights").join(&name);
        let tok_path = dir.join("parity_tokens.bin");
        if !tok_path.is_file() {
            continue;
        }
        let Model::Lm(m) = load_named(&name).unwrap() else { continue };
        let tok_bytes = std::fs::read(&tok_path).unwrap();
        let tokens: Vec<u16> = tok_bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u16)
            .collect();
        let expected = read_f32_bin_any(&dir.join("parity_logits.bin")).unwrap();
        let got = m.forward(&tokens, None);
        assert_eq!(got.len(), expected.len(), "{name}: logit count");
        let mut max_err = 0.0f32;
        for (g, e) in got.iter().zip(expected.iter()) {
            max_err = max_err.max((g - e).abs());
        }
        assert!(max_err < 2e-2, "{name}: rust/jax logits diverge by {max_err}");
        eprintln!("[parity] {name}: max |Δlogit| = {max_err:.2e}");
    }
}

#[test]
fn rust_jax_parity_img() {
    skip_without_artifacts!();
    for name in axe::model::list_models() {
        let dir = axe::artifacts_dir().join("weights").join(&name);
        let x_path = dir.join("parity_x.bin");
        if !x_path.is_file() {
            continue;
        }
        let Model::Img(m) = load_named(&name).unwrap() else { continue };
        let x = read_f32_bin_any(&x_path).unwrap();
        let expected = read_f32_bin_any(&dir.join("parity_logits.bin")).unwrap();
        let n = expected.len() / m.cfg.classes;
        let dim = m.cfg.input_dim;
        for i in 0..n {
            let logits = m.forward(&x[i * dim..(i + 1) * dim], None);
            for (g, e) in logits.iter().zip(&expected[i * m.cfg.classes..]) {
                assert!((g - e).abs() < 1e-2, "{name} sample {i}: {g} vs {e}");
            }
        }
    }
}

#[test]
fn corpus_and_glyphs_load() {
    skip_without_artifacts!();
    let train = axe::eval::load_corpus_split("train").unwrap();
    let val = axe::eval::load_corpus_split("val").unwrap();
    assert!(train.len() >= 100_000);
    assert!(val.len() >= 10_000);
    assert!(train.iter().all(|&t| t < 64));
    let g = axe::eval::load_glyphs("test").unwrap();
    assert_eq!(g.dim, 256);
    assert_eq!(g.classes, 10);
}

#[test]
fn trained_models_beat_uniform_baseline() {
    skip_without_artifacts!();
    let Ok(Model::Lm(m)) = load_named("pico-160k") else {
        eprintln!("[skip] pico-160k missing");
        return;
    };
    let val = axe::eval::load_corpus_split("val").unwrap();
    let r = axe::eval::perplexity(&m, &val, m.cfg.max_seq, 16);
    assert!(
        r.ppl < 40.0,
        "trained pico-160k must beat the uniform baseline (64): {}",
        r.ppl
    );
}

#[test]
fn pjrt_runtime_runs_lm_artifact() {
    skip_without_artifacts!();
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[skip] PJRT unavailable: {e}");
            return;
        }
    };
    let name = "pico-160k_fwd";
    if !rt.list_artifacts().iter().any(|a| a == name) {
        eprintln!("[skip] {name} not exported");
        return;
    }
    let manifest = axe::runtime::load_manifest().unwrap();
    let entry = manifest
        .req_arr("artifacts")
        .unwrap()
        .iter()
        .find(|a| a.get("name").and_then(|n| n.as_str()) == Some(name))
        .unwrap()
        .clone();
    let batch = entry.req_usize("batch").unwrap();
    let seq = entry.req_usize("seq").unwrap();
    let vocab = entry.req_usize("vocab").unwrap();
    let params: Vec<String> = entry
        .req_arr("params")
        .unwrap()
        .iter()
        .filter_map(|p| p.as_str().map(String::from))
        .collect();
    // build inputs from the weight zoo
    let wdir = axe::artifacts_dir().join("weights").join("pico-160k");
    let mmanifest = axe::util::json::Json::parse(
        &std::fs::read_to_string(wdir.join("manifest.json")).unwrap(),
    )
    .unwrap();
    let mut inputs =
        vec![F32Input::new(vec![1.0f32; batch * seq], &[batch, seq])];
    for p in &params {
        let shape: Vec<usize> = mmanifest
            .get("tensors")
            .unwrap()
            .get(p)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        inputs.push(F32Input::new(
            read_f32_bin_any(&wdir.join(format!("{p}.bin"))).unwrap(),
            &shape,
        ));
    }
    let outs = rt.run_f32(name, &inputs).unwrap();
    assert_eq!(outs[0].len(), batch * seq * vocab);
    assert!(outs[0].iter().all(|v| v.is_finite()));

    // PJRT logits must match the rust-native forward
    let Model::Lm(m) = load_named("pico-160k").unwrap() else { unreachable!() };
    let tokens = vec![1u16; seq];
    let rust_logits = m.forward(&tokens, None);
    let mut max_err = 0.0f32;
    for (a, b) in rust_logits.iter().zip(outs[0][..seq * vocab].iter()) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 2e-2, "PJRT vs rust logits diverge by {max_err}");
    eprintln!("[pjrt] lm artifact matches rust forward: max |Δ| = {max_err:.2e}");
}

#[test]
fn pjrt_qmatmul_matches_rust_simulator() {
    skip_without_artifacts!();
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[skip] PJRT unavailable: {e}");
            return;
        }
    };
    let manifest = match axe::runtime::load_manifest() {
        Ok(m) => m,
        Err(_) => return,
    };
    for entry in manifest.req_arr("artifacts").unwrap() {
        if entry.get("kind").and_then(|k| k.as_str()) != Some("qmatmul") {
            continue;
        }
        let name = entry.req_str("name").unwrap();
        let (m, k, n) = (
            entry.req_usize("m").unwrap(),
            entry.req_usize("k").unwrap(),
            entry.req_usize("n").unwrap(),
        );
        let tile = entry.req_usize("tile").unwrap();
        let p_inner = entry.req_usize("p_inner").unwrap() as u32;
        let p_outer = entry.req_usize("p_outer").unwrap() as u32;
        let mut rng = axe::util::rng::Rng::new(9);
        let x: Vec<i32> = (0..m * k).map(|_| rng.int_in(0, 255) as i32).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.int_in(-7, 7) as i32).collect();
        let outs = rt
            .run_i32(
                name,
                &[
                    axe::runtime::I32Input::new(x.clone(), &[m, k]),
                    axe::runtime::I32Input::new(w.clone(), &[k, n]),
                ],
            )
            .unwrap();
        // compare against the rust multistage simulator
        use axe::accum::simulator::{dot_multistage, AccumSpec};
        let inner = AccumSpec::wraparound(p_inner);
        let outer = AccumSpec::wraparound(p_outer);
        for row in 0..m {
            for col in 0..n {
                let xr: Vec<i64> = (0..k).map(|i| x[row * k + i] as i64).collect();
                let wc: Vec<i64> = (0..k).map(|i| w[i * n + col] as i64).collect();
                let expect = dot_multistage(&xr, &wc, tile, inner, outer).value;
                let got = outs[0][row * n + col] as i64;
                assert_eq!(got, expect, "{name} [{row},{col}]");
            }
        }

        // The same artifact driven through the backend adapter must
        // agree bit-for-bit with the fused Rust GEMM — the very oracle
        // that gates the explicit-SIMD safe-tile path — through the
        // Rust calling convention (w channel-major [c,k]).
        let xi: Vec<i64> = x.iter().map(|&v| v as i64).collect();
        let mut wck = vec![0i32; n * k];
        for ch in 0..n {
            for i in 0..k {
                wck[ch * k + i] = w[i * n + ch];
            }
        }
        let mut fused = vec![0i64; m * n];
        let mut row_ovf = vec![0u64; m];
        axe::linalg::qgemm_multistage(
            &xi, m, &wck, n, k, tile, inner, outer, &mut fused, &mut row_ovf,
        );
        let adapted = axe::runtime::qgemm_pjrt(&rt, name, &xi, m, &wck, n, k).unwrap();
        assert_eq!(adapted, fused, "{name}: PJRT backend vs fused rust GEMM");
        eprintln!(
            "[pjrt] {name} bit-exact against the rust simulator and fused GEMM ({m}x{k}x{n})"
        );
    }
}
