//! Integration parity: the fused qgemm kernel against the scalar
//! per-MAC accumulator simulator, through every layer that routes dot
//! products — raw kernel, QuantLinear, and the batched prefill path.

use axe::accum::simulator::{dot_multistage, AccumSpec, OverflowMode};
use axe::coordinator::{quantize_transformer, DatapathMode, PipelineConfig};
use axe::eval::synth_corpus;
use axe::linalg::{qgemm_multistage, qgemm_multistage_scalar, simd_enabled};
use axe::model::{
    random_transformer, Activation, Datapath, KvCache, Linear, TransformerConfig,
};
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::util::rng::Rng;

fn lm_fixture(seed: u64) -> (axe::model::Transformer, Vec<u16>) {
    let cfg = TransformerConfig {
        name: "qgemm-itest".into(),
        vocab: 48,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        max_seq: 16,
        act: Activation::Gelu,
        parallel_residual: false,
    };
    (random_transformer(cfg, seed), synth_corpus(16 * 16, 48, seed + 1))
}

/// Raw kernel vs simulator on a serving-sized problem, wrap + saturate.
#[test]
fn kernel_matches_simulator_at_depth() {
    let mut rng = Rng::new(7001);
    let (rows, k, c, tile) = (3usize, 1024usize, 24usize, 64usize);
    for mode in [OverflowMode::Wraparound, OverflowMode::Saturate] {
        let inner = AccumSpec::new(14, mode); // narrow enough to overflow sometimes
        let outer = AccumSpec::new(18, mode);
        let x: Vec<i64> = (0..rows * k).map(|_| rng.int_in(0, 255)).collect();
        let w: Vec<i32> = (0..c * k).map(|_| rng.int_in(-7, 7) as i32).collect();
        let mut out = vec![0i64; rows * c];
        let mut ovf = vec![0u64; rows];
        qgemm_multistage(&x, rows, &w, c, k, tile, inner, outer, &mut out, &mut ovf);
        let mut want_ovf = vec![0u64; rows];
        for r in 0..rows {
            for ch in 0..c {
                let w64: Vec<i64> = w[ch * k..(ch + 1) * k].iter().map(|&v| v as i64).collect();
                let o = dot_multistage(&x[r * k..(r + 1) * k], &w64, tile, inner, outer);
                assert_eq!(out[r * c + ch], o.value, "mode {mode:?} [{r},{ch}]");
                want_ovf[r] += o.overflows as u64;
            }
        }
        assert_eq!(ovf, want_ovf, "mode {mode:?} per-row overflow counts");
    }
}

/// The explicit-SIMD safe-tile path against its forced-scalar oracle:
/// values AND per-row overflow counts must be bit-identical in both
/// overflow modes, across SIMD-eligible shapes (codes inside the
/// vector envelope, tile ≥ the SIMD floor) and ineligible ones (codes
/// outside the envelope → per-tile scalar fallback; ragged tails).
/// When the host dispatches scalar anyway (no AVX2, or `AXE_SIMD=off`
/// in the CI matrix leg) the two paths are trivially identical and the
/// test still pins the dispatcher's determinism.
#[test]
fn simd_dispatch_matches_forced_scalar_oracle() {
    let mut rng = Rng::new(7005);
    eprintln!("[simd] runtime dispatch: {}", if simd_enabled() { "vector" } else { "scalar" });
    // (rows, k, c, tile, xmax): in-envelope tiles, a sub-floor tile
    // (forced scalar per-tile), a ragged tail (k % tile != 0), and
    // out-of-envelope activation codes (tile_in_range rejects)
    for &(rows, k, c, tile, xmax) in &[
        (3usize, 1024usize, 24usize, 64usize, 255i64),
        (2, 768, 16, 128, 255),
        (3, 1024, 24, 8, 255),
        (2, 500, 12, 64, 255),
        (2, 512, 12, 64, 1 << 12),
    ] {
        for mode in [OverflowMode::Wraparound, OverflowMode::Saturate] {
            let inner = AccumSpec::new(14, mode); // overflows sometimes
            let outer = AccumSpec::new(18, mode);
            let x: Vec<i64> = (0..rows * k).map(|_| rng.int_in(0, xmax)).collect();
            let w: Vec<i32> = (0..c * k).map(|_| rng.int_in(-7, 7) as i32).collect();
            let (mut out, mut ovf) = (vec![0i64; rows * c], vec![0u64; rows]);
            let (mut out_s, mut ovf_s) = (vec![0i64; rows * c], vec![0u64; rows]);
            qgemm_multistage(&x, rows, &w, c, k, tile, inner, outer, &mut out, &mut ovf);
            qgemm_multistage_scalar(
                &x, rows, &w, c, k, tile, inner, outer, &mut out_s, &mut ovf_s,
            );
            let label = format!("{rows}x{k}x{c} tile={tile} xmax={xmax} mode={mode:?}");
            assert_eq!(out, out_s, "{label}: values");
            assert_eq!(ovf, ovf_s, "{label}: per-row overflow counts");
        }
    }
}

/// The quantized pipeline on the faithful datapath must produce a model
/// whose every linear runs the kernel, and whose logits match the
/// exact datapath while the guarantee holds.
#[test]
fn faithful_pipeline_runs_on_kernel_and_matches_exact() {
    let (base, toks) = lm_fixture(7010);
    let calib: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 14, tile: 8 };

    let mut m_exact = base.clone();
    quantize_transformer(&mut m_exact, &calib, &cfg).unwrap();

    let mut cfg_f = cfg.clone();
    cfg_f.datapath = DatapathMode::Faithful;
    let mut m_faith = base.clone();
    let report = quantize_transformer(&mut m_faith, &calib, &cfg_f).unwrap();
    assert!(report.guaranteed_safe());
    for name in m_faith.linear_names() {
        let Some(Linear::Quant(q)) = m_faith.get_linear(&name) else {
            panic!("{name} not quantized")
        };
        assert!(matches!(q.datapath, Datapath::Simulated { .. }), "{name}");
    }

    let la = m_exact.forward(&toks[..16], None);
    let lb = m_faith.forward(&toks[..16], None);
    for (a, b) in la.iter().zip(lb.iter()) {
        assert!((a - b).abs() < 1e-5, "exact vs faithful kernel diverged: {a} {b}");
    }
    assert_eq!(m_faith.overflow_events(), 0, "guaranteed-safe model must not overflow");
}

/// Batched prefill (kernel path) must agree with full-sequence forward
/// and with token-by-token decode on a quantized model.
#[test]
fn batched_prefill_matches_forward_and_decode() {
    let (base, toks) = lm_fixture(7020);
    let calib: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Gpfq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 14, tile: 8 };
    cfg.datapath = DatapathMode::Faithful;
    let mut m = base.clone();
    quantize_transformer(&mut m, &calib, &cfg).unwrap();

    let prompt = &toks[..10];
    let vocab = m.cfg.vocab;

    // full-sequence forward: last row of logits
    let full = m.forward(prompt, None);
    let want = &full[(prompt.len() - 1) * vocab..prompt.len() * vocab];

    // batched prefill
    let mut cache = KvCache::new(&m);
    let got = m.prefill(prompt, &mut cache);
    assert_eq!(cache.len(), prompt.len());
    for (a, b) in got.iter().zip(want.iter()) {
        assert!((a - b).abs() < 1e-4, "prefill vs forward: {a} {b}");
    }

    // token-by-token decode
    let mut cache2 = KvCache::new(&m);
    let mut step = Vec::new();
    for &t in prompt {
        step = m.decode_step(t, &mut cache2);
    }
    for (a, b) in got.iter().zip(step.iter()) {
        assert!((a - b).abs() < 1e-4, "prefill vs decode: {a} {b}");
    }
}

/// Continuous-batched serving on the faithful (fused-kernel) datapath
/// must emit, for every request, exactly the tokens sequential greedy
/// decode emits — the end-to-end guarantee the step scheduler rests on
/// (ragged batching, mid-flight admissions and window slides included)
/// — and the serve report must surface overflow accounting.
#[test]
fn continuous_batched_serving_is_token_exact_on_quantized_model() {
    use axe::coordinator::serve::{serve, Request, ServeQueue, ServeStats};
    use std::time::Instant;

    let (base, toks) = lm_fixture(7030);
    let calib: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 14, tile: 8 };
    cfg.datapath = DatapathMode::Faithful;
    let mut m = base.clone();
    let report = quantize_transformer(&mut m, &calib, &cfg).unwrap();
    assert!(report.guaranteed_safe());

    // mixed prompt lengths and generation lengths past the window so
    // slots slide and requests join/leave mid-flight (6 reqs, 3 slots)
    let reqs: Vec<Request> = (0..6u64)
        .map(|id| {
            let plen = 2 + ((id as usize * 3) % 9);
            Request {
                id,
                prompt: toks[id as usize * 16..id as usize * 16 + plen].to_vec(),
                max_new_tokens: 6 + ((id as usize * 9) % 20),
                ..Request::default()
            }
        })
        .collect();
    let q = ServeQueue::new();
    for r in &reqs {
        q.submit(r.clone()).unwrap();
    }
    q.close();
    let ovf_before = m.overflow_events();
    let t0 = Instant::now();
    serve(&m, &q, 1, 3);
    let responses = q.drain();
    let stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
    assert_eq!(stats.requests, reqs.len());
    assert_eq!(stats.overflow_events, 0, "guaranteed-safe model must not overflow");
    assert_eq!(m.overflow_events(), ovf_before, "model-wide counters agree");
    for (resp, req) in responses.iter().zip(reqs.iter()) {
        assert_eq!(resp.id, req.id);
        let want = m.generate_greedy(&req.prompt, req.max_new_tokens);
        assert_eq!(
            resp.tokens,
            want[req.prompt.len()..],
            "request {} diverged from sequential greedy decode",
            req.id
        );
    }
}
