//! Serving-level harness for **batch-invariant seeded sampling**: a
//! sampled token must be a pure function of the logits and the
//! `(seed, request id, position)` key, never of batch composition. So
//! under randomized admission schedules, every request's sampled
//! stream must equal the solo sequential sampled reference
//! ([`Transformer::generate_sampled_with`]) token for token — at every
//! prefill chunk size, at every slot count (max_batch = 1 IS
//! sequential service, so sequential ≡ batched ≡ ragged falls out of
//! one equality), on both KV backends — and two runs of the same
//! config must replay bit-identically, overflow attribution included.

use axe::coordinator::serve::{Request, Response, ServeConfig, StepEngine};
use axe::model::{
    random_transformer, Activation, KvCacheKind, KvQuantSpec, SampleSpec, Transformer,
    TransformerConfig,
};
use axe::util::rng::Rng;
use std::time::Instant;

fn model(seed: u64) -> Transformer {
    random_transformer(
        TransformerConfig {
            name: "sampling".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 16,
            act: Activation::Gelu,
            parallel_residual: false,
        },
        seed,
    )
}

/// Drive a [`StepEngine`] through an admission schedule (request `i`
/// admitted at tick `arrivals[i]`, deferred FCFS while no slot is
/// free), returning id-sorted responses.
fn run_schedule(
    m: &Transformer,
    cfg: ServeConfig,
    reqs: &[Request],
    arrivals: &[usize],
) -> Vec<Response> {
    let mut eng = StepEngine::new(m, cfg);
    let mut done: Vec<Response> = Vec::new();
    let mut next = 0usize;
    let mut tick = 0usize;
    loop {
        while next < reqs.len() && arrivals[next] <= tick && eng.free_slots() > 0 {
            eng.admit(reqs[next].clone(), Instant::now());
            next += 1;
        }
        eng.step();
        done.extend(eng.take_finished());
        tick += 1;
        if next == reqs.len() && !eng.has_work() {
            break;
        }
        assert!(tick < 100_000, "schedule did not converge");
    }
    done.sort_by_key(|r| r.id);
    done
}

/// Random schedule: prompts 1..=22 tokens (several past max_seq=16 →
/// clipped), generations 1..=28 (several past the window → slides),
/// arrivals spread over the first 12 ticks.
fn random_schedule(rng: &mut Rng, n_req: usize) -> (Vec<Request>, Vec<usize>) {
    let mut reqs = Vec::new();
    let mut arrivals: Vec<usize> = (0..n_req).map(|_| rng.int_in(0, 12) as usize).collect();
    arrivals.sort_unstable();
    for id in 0..n_req as u64 {
        let plen = rng.int_in(1, 22) as usize;
        let prompt: Vec<u16> = (0..plen).map(|_| rng.int_in(0, 31) as u16).collect();
        let max_new_tokens = rng.int_in(1, 28) as usize;
        reqs.push(Request { id, prompt, max_new_tokens, ..Request::default() });
    }
    (reqs, arrivals)
}

/// Solo sequential sampled reference for one request: the engine keys
/// each draw by (request id, emitted count), so the reference stream
/// is `generate_sampled_with` at stream = id.
fn sampled_reference(
    m: &Transformer,
    req: &Request,
    kind: KvCacheKind,
    spec: &SampleSpec,
) -> Vec<u16> {
    let clipped = m.clip_to_window(&req.prompt);
    m.generate_sampled_with(&clipped, req.max_new_tokens, kind, spec, req.id)[clipped.len()..]
        .to_vec()
}

/// THE sampling property: for every spec (plain temperature, top-k,
/// top-p, all three), every chunk size and both KV backends, batched
/// sampled serving reproduces the solo sequential sampled stream token
/// for token — the draw depends on the `(seed, id, position)` key and
/// the logits, never on what else shares the step.
#[test]
fn sampled_schedules_match_sequential_reference() {
    let m = model(61);
    let specs = [
        SampleSpec::temperature(0.8, 1234).with_top_k(12).with_top_p(0.95),
        SampleSpec::temperature(1.3, 7),
        SampleSpec::temperature(0.6, 99).with_top_k(3),
        SampleSpec::temperature(1.0, 2718).with_top_p(0.7),
    ];
    let mut rng = Rng::new(8001);
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
        let (reqs, arrivals) = random_schedule(&mut rng, 7);
        for spec in &specs {
            for &chunk in &[2usize, usize::MAX] {
                let label = format!("kind={kind:?} spec={spec:?} chunk={chunk}");
                let cfg = ServeConfig::new(3, kind).with_prefill_chunk(chunk).with_sampling(*spec);
                let responses = run_schedule(&m, cfg, &reqs, &arrivals);
                assert_eq!(responses.len(), reqs.len(), "{label}: lost responses");
                for (resp, req) in responses.iter().zip(reqs.iter()) {
                    assert_eq!(resp.id, req.id);
                    assert_eq!(
                        resp.tokens,
                        sampled_reference(&m, req, kind, spec),
                        "{label}: request {} sampled stream depends on batching",
                        req.id
                    );
                }
            }
        }
    }
}

/// Slot count is invisible: the same schedule served with 1, 3 and 7
/// slots emits identical sampled tokens AND identical per-request
/// overflow attribution. `max_batch = 1` is literal sequential service
/// (one request at a time, no ragged batching), so this is the
/// sequential ≡ batched ≡ ragged chain at the serving level.
#[test]
fn batch_composition_is_invisible_to_sampling() {
    let m = model(62);
    let spec = SampleSpec::temperature(0.9, 4242).with_top_k(8).with_top_p(0.9);
    let mut rng = Rng::new(8002);
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)))] {
        let (reqs, arrivals) = random_schedule(&mut rng, 7);
        let label = format!("kind={kind:?}");
        let run = |slots: usize| {
            let cfg = ServeConfig::new(slots, kind).with_prefill_chunk(5).with_sampling(spec);
            run_schedule(&m, cfg, &reqs, &arrivals)
        };
        let sequential = run(1);
        assert_eq!(sequential.len(), reqs.len(), "{label}: lost responses");
        for slots in [3usize, 7] {
            let batched = run(slots);
            for (a, b) in batched.iter().zip(sequential.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.tokens, b.tokens,
                    "{label}: request {} tokens depend on max_batch={slots}",
                    a.id
                );
                assert_eq!(
                    a.overflow_events, b.overflow_events,
                    "{label}: request {} attribution depends on max_batch={slots}",
                    a.id
                );
            }
        }
    }
}

/// Degenerate cuts collapse to greedy end to end: `top_k = 1` and
/// `top_p = 0.0` both keep exactly the first maximum, so a hot-running
/// sampled engine must emit the greedy engine's exact streams — the
/// tie-break (logit descending, index ascending) is one total order
/// shared with `argmax`.
#[test]
fn degenerate_cuts_reduce_to_greedy_serving() {
    let m = model(63);
    let mut rng = Rng::new(8003);
    let (reqs, arrivals) = random_schedule(&mut rng, 6);
    let kind = KvCacheKind::F32;
    let greedy =
        run_schedule(&m, ServeConfig::new(3, kind).with_prefill_chunk(4), &reqs, &arrivals);
    for spec in [
        SampleSpec::temperature(0.9, 42).with_top_k(1),
        SampleSpec::temperature(1.0, 5).with_top_p(0.0),
    ] {
        let cfg = ServeConfig::new(3, kind).with_prefill_chunk(4).with_sampling(spec);
        let sampled = run_schedule(&m, cfg, &reqs, &arrivals);
        for ((a, b), req) in sampled.iter().zip(greedy.iter()).zip(reqs.iter()) {
            assert_eq!(a.id, req.id);
            assert_eq!(a.tokens, b.tokens, "spec={spec:?}: request {} is not greedy", req.id);
            let clipped = m.clip_to_window(&req.prompt);
            let direct = m.generate_greedy_with(&clipped, req.max_new_tokens, kind);
            assert_eq!(
                a.tokens,
                direct[clipped.len()..],
                "spec={spec:?}: request {} vs direct greedy",
                req.id
            );
        }
    }
}

/// Replay determinism and seed sensitivity: the same config replays
/// bit-identically (tokens and overflow events), and changing only the
/// root seed moves at least one request's stream — the randomness is
/// real, and all of it lives in the seed.
#[test]
fn replay_is_deterministic_and_seeded() {
    let m = model(64);
    let mut rng = Rng::new(8004);
    let (reqs, arrivals) = random_schedule(&mut rng, 6);
    let run = |seed: u64| {
        let spec = SampleSpec::temperature(1.1, seed).with_top_p(0.92);
        let cfg = ServeConfig::new(3, KvCacheKind::F32).with_prefill_chunk(3).with_sampling(spec);
        run_schedule(&m, cfg, &reqs, &arrivals)
    };
    let a = run(1001);
    let b = run(1001);
    assert_eq!(a.len(), reqs.len(), "lost responses");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "request {} does not replay", x.id);
        assert_eq!(x.overflow_events, y.overflow_events, "request {} attribution drifts", x.id);
    }
    let c = run(2002);
    let moved = a.iter().zip(c.iter()).any(|(x, y)| x.tokens != y.tokens);
    assert!(moved, "changing the root seed must move at least one stream");
}
