//! Failure injection: corrupted artifacts, hostile inputs and parser
//! fuzz. A release-quality loader must fail loudly and safely, never
//! panic or silently mis-load.

use axe::model::{load_model, write_f32_bin};
use axe::util::json::Json;
use axe::util::rng::Rng;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("axe_fail_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn minimal_img_manifest() -> Json {
    let mut tensors = Json::obj();
    tensors.set("l0.w", vec![3usize, 4].into());
    tensors.set("l0.b", vec![3usize].into());
    tensors.set("head.w", vec![2usize, 3].into());
    tensors.set("head.b", vec![2usize].into());
    let mut arch = Json::obj();
    arch.set("input_dim", 4usize.into())
        .set("hidden", vec![3usize].into())
        .set("classes", 2usize.into())
        .set("act", "relu".into());
    let mut m = Json::obj();
    m.set("name", "x".into()).set("family", "img".into()).set("img", arch).set("tensors", tensors);
    m
}

#[test]
fn corrupt_manifest_is_error_not_panic() {
    let d = tmpdir("manifest");
    std::fs::write(d.join("manifest.json"), "{ not json !!").unwrap();
    assert!(load_model(&d).is_err());
    std::fs::write(d.join("manifest.json"), "null").unwrap();
    assert!(load_model(&d).is_err());
    std::fs::write(d.join("manifest.json"), r#"{"family": 42}"#).unwrap();
    assert!(load_model(&d).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn unknown_family_rejected() {
    let d = tmpdir("family");
    let mut m = minimal_img_manifest();
    m.set("family", "bert".into());
    std::fs::write(d.join("manifest.json"), m.to_pretty()).unwrap();
    let err = match load_model(&d) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("must fail"),
    };
    assert!(err.contains("unknown model family"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn truncated_tensor_is_error() {
    let d = tmpdir("trunc");
    std::fs::write(d.join("manifest.json"), minimal_img_manifest().to_pretty()).unwrap();
    write_f32_bin(&d.join("l0.w.bin"), &[0.1; 7]).unwrap(); // should be 12
    write_f32_bin(&d.join("l0.b.bin"), &[0.0; 3]).unwrap();
    write_f32_bin(&d.join("head.w.bin"), &[0.2; 6]).unwrap();
    write_f32_bin(&d.join("head.b.bin"), &[0.0; 2]).unwrap();
    let err = match load_model(&d) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("must fail"),
    };
    assert!(err.contains("expected"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn missing_tensor_file_is_error() {
    let d = tmpdir("missing");
    std::fs::write(d.join("manifest.json"), minimal_img_manifest().to_pretty()).unwrap();
    assert!(load_model(&d).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn nan_weights_do_not_crash_inference() {
    use axe::model::{random_mlp, Activation, MlpConfig};
    let mut m = random_mlp(
        MlpConfig {
            name: "nan".into(),
            input_dim: 8,
            hidden: vec![8],
            classes: 3,
            act: Activation::Relu,
            residual: false,
        },
        1,
    );
    if let axe::model::Linear::Float(fl) = &mut m.layers[0] {
        fl.w_mut()[3] = f32::NAN;
    }
    let y = m.forward(&[1.0; 8], None);
    assert_eq!(y.len(), 3); // NaNs propagate, no panic
}

#[test]
fn nan_activations_do_not_crash_quantizer() {
    let q = axe::quant::ActQuantizer::unit(8);
    let code = q.to_code(f64::NAN);
    assert!((0..=255).contains(&code), "NaN must map into the alphabet, got {code}");
    let _ = q.to_code(f64::INFINITY);
    let _ = q.to_code(f64::NEG_INFINITY);
}

#[test]
fn json_parser_fuzz_never_panics() {
    let mut rng = Rng::new(0xF422);
    for _ in 0..2000 {
        let len = rng.below(64);
        let bytes: Vec<u8> = (0..len)
            .map(|_| b" {}[]\",:0123456789truefalsenul.eE+-\\"[rng.below(36)])
            .collect();
        let s = String::from_utf8_lossy(&bytes).to_string();
        let _ = Json::parse(&s); // must never panic
    }
}

#[test]
fn json_parser_fuzz_roundtrip_valid_docs() {
    // generate random *valid* JSON and require parse(to_string(x)) == x
    let mut rng = Rng::new(0x1234);
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.int_in(-100000, 100000) as f64) / 8.0),
            3 => Json::Str((0..rng.below(8)).map(|_| (b'a' + rng.below(26) as u8) as char).collect()),
            4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth + 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(4) {
                    o.set(&format!("k{i}"), gen(rng, depth + 1));
                }
                o
            }
        }
    }
    for _ in 0..300 {
        let doc = gen(&mut rng, 0);
        let re = Json::parse(&doc.to_string()).expect("roundtrip parse");
        assert_eq!(doc, re);
        let re2 = Json::parse(&doc.to_pretty()).expect("pretty roundtrip parse");
        assert_eq!(doc, re2);
    }
}

#[test]
fn pipeline_rejects_already_quantized_layer() {
    use axe::coordinator::{quantize_mlp, PipelineConfig};
    use axe::eval::synth_glyphs;
    use axe::model::{random_mlp, Activation, MlpConfig};
    use axe::quant::{Algorithm, Method};
    let set = synth_glyphs(64, 4, 4, 9);
    let mut m = random_mlp(
        MlpConfig {
            name: "q2".into(),
            input_dim: 16,
            hidden: vec![8],
            classes: 4,
            act: Activation::Relu,
            residual: false,
        },
        2,
    );
    let calib: Vec<&[f32]> = (0..16).map(|i| set.row(i)).collect();
    let cfg = PipelineConfig::new(Algorithm::Optq, Method::Naive, 8, 8);
    quantize_mlp(&mut m, &calib, &cfg).unwrap();
    // second quantization over already-quantized layers must error cleanly
    let err = quantize_mlp(&mut m, &calib, &cfg).unwrap_err().to_string();
    assert!(err.contains("already quantized"), "{err}");
}

#[test]
fn empty_calibration_set_is_error_not_panic() {
    use axe::coordinator::{quantize_transformer, PipelineConfig};
    use axe::model::{random_transformer, Activation, TransformerConfig};
    use axe::quant::{Algorithm, Method};
    let mut m = random_transformer(
        TransformerConfig {
            name: "e".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            d_ff: 16,
            max_seq: 8,
            act: Activation::Gelu,
            parallel_residual: false,
        },
        3,
    );
    let calib: Vec<&[u16]> = vec![];
    let cfg = PipelineConfig::new(Algorithm::Optq, Method::Naive, 8, 8);
    assert!(quantize_transformer(&mut m, &calib, &cfg).is_err());
}
