//! End-to-end coverage for the accumulator-aware quantized KV cache:
//! batched-vs-sequential decode parity on the integer attention
//! datapath, slot reuse, window-slide semantics (codes + scales move
//! verbatim), bounded divergence against the f32 arena, memory
//! accounting, and exact per-request overflow attribution under
//! continuous batching.

use axe::coordinator::serve::{serve_with, Request, ServeQueue, ServeStats};
use axe::coordinator::{quantize_transformer, DatapathMode, PipelineConfig};
use axe::eval::synth_corpus;
use axe::model::{
    random_transformer, Activation, KvArena, KvCache, KvCacheKind, KvQuantSpec, Transformer,
    TransformerConfig, DEFAULT_KV_PAGE,
};
use axe::quant::{AccumTarget, Algorithm, Method};

fn lm(seed: u64, d_model: usize, n_heads: usize, max_seq: usize) -> Transformer {
    random_transformer(
        TransformerConfig {
            name: "kvq-itest".into(),
            vocab: 48,
            d_model,
            n_layers: 2,
            n_heads,
            d_ff: 2 * d_model,
            max_seq,
            act: Activation::Gelu,
            parallel_residual: false,
        },
        seed,
    )
}

const KV8: KvCacheKind = KvCacheKind::Quant(KvQuantSpec {
    kv_bits: 8,
    op_bits: 8,
    tile: 64,
    inner_bits: 23, // attention_inner_bits(64, 8, 8) — data-type safe
    mode: axe::accum::OverflowMode::Wraparound,
});

/// Batched decode on the quantized arena is bit-exact vs decoding each
/// sequence alone, slots are reusable after release, and the reused
/// slot behaves like a fresh cache.
#[test]
fn quant_arena_batched_decode_and_slot_reuse_are_bit_exact() {
    let m = lm(901, 16, 2, 16);
    let vocab = m.cfg.vocab;
    let seqs: Vec<Vec<u16>> = vec![vec![3, 1, 4, 1, 5], vec![9, 2, 6, 5, 3]];
    let mut want: Vec<Vec<f32>> = Vec::new();
    for s in &seqs {
        let mut cache = KvCache::with_kind(&m, KV8);
        let mut last = Vec::new();
        for &t in s {
            last = m.decode_step(t, &mut cache);
        }
        want.push(last);
    }
    let mut arena = KvArena::with_kind(&m, 2, KV8);
    let s0 = arena.alloc().unwrap();
    let s1 = arena.alloc().unwrap();
    let mut got = Vec::new();
    for pos in 0..seqs[0].len() {
        got = m.decode_step_batch(&[seqs[0][pos], seqs[1][pos]], &[s0, s1], &mut arena);
    }
    for (b, w) in want.iter().enumerate() {
        assert_eq!(&got[b * vocab..(b + 1) * vocab], &w[..], "seq {b} diverged under batching");
    }
    // release + reuse: the recycled slot must equal a fresh cache
    arena.release(s0);
    let s2 = arena.alloc().unwrap();
    assert_eq!(s2, s0, "LIFO free list must reuse the retired slot");
    let fresh = m.decode_step_batch(&[7], &[s2], &mut arena);
    let mut cache = KvCache::with_kind(&m, KV8);
    let alone = m.decode_step(7, &mut cache);
    assert_eq!(fresh, alone, "reused quant slot must behave like a fresh cache");
    // the surviving slot's cached rows were untouched
    assert_eq!(arena.len(s1), seqs[1].len());
}

/// `truncate_front` on the paged quantized arena re-bases the slot's
/// head offset (whole head pages are dropped, never memmoved): every
/// kept position dequantizes bit-identically after the slide, across
/// all layers.
#[test]
fn quant_truncate_front_slides_codes_and_scales_without_drift() {
    let m = lm(902, 16, 2, 16);
    let mut arena = KvArena::with_kind(&m, 1, KV8);
    let slot = arena.alloc().unwrap();
    for t in 0..8u16 {
        m.decode_step_batch(&[t], &[slot], &mut arena);
    }
    let mut snapshot: Vec<Vec<(Vec<f32>, Vec<f32>)>> = Vec::new();
    for layer in 0..m.cfg.n_layers {
        snapshot.push((3..8).map(|pos| arena.kv_row(layer, slot, pos)).collect());
    }
    arena.truncate_front(slot, 3);
    assert_eq!(arena.len(slot), 5);
    for (layer, rows) in snapshot.iter().enumerate() {
        for (pos, want) in rows.iter().enumerate() {
            assert_eq!(
                &arena.kv_row(layer, slot, pos),
                want,
                "layer {layer} pos {pos} drifted across the slide"
            );
        }
    }
    // sliding everything empties the slot
    arena.truncate_front(slot, 99);
    assert_eq!(arena.len(slot), 0);
}

/// Teacher-forced bounded divergence: feeding the SAME token sequence
/// through the f32 and the i8 KV backends keeps every step's logits
/// within quantization-error distance — the accuracy half of the
/// memory/accuracy trade-off.
#[test]
fn quant_vs_f32_logits_divergence_is_bounded() {
    let m = lm(903, 16, 2, 16);
    let toks = synth_corpus(12, m.cfg.vocab, 904);
    let mut f32_cache = KvCache::new(&m);
    let mut q_cache = KvCache::with_kind(&m, KV8);
    let mut worst = 0.0f32;
    let mut total = 0.0f32;
    let mut n = 0usize;
    for &t in &toks {
        let lf = m.decode_step(t, &mut f32_cache);
        let lq = m.decode_step(t, &mut q_cache);
        for (a, b) in lf.iter().zip(lq.iter()) {
            let d = (a - b).abs();
            worst = worst.max(d);
            total += d;
            n += 1;
        }
    }
    assert!(worst < 0.5, "worst logit divergence {worst} exceeds the quantization envelope");
    assert!(total / n as f32 < 0.1, "mean logit divergence {} too large", total / n as f32);
}

/// The i8 arena reserves ≤ 30% of the f32 arena's bytes at equal
/// slots/seq-len once heads are reasonably wide (scale overhead is
/// 1/head_dim); `footprint_paged` matches the page-pool geometry
/// including page-table/refcount metadata; and `bytes()` reports
/// **resident** (allocated-pages-only) memory, so a fresh arena is
/// metadata-only and filling a slot grows it page by page.
#[test]
fn quant_arena_memory_is_about_a_quarter_of_f32() {
    let m = lm(905, 64, 2, 32); // head dim 32
    let f32_bytes = KvArena::footprint(&m.cfg, 4, KvCacheKind::F32);
    let q8 = KvCacheKind::Quant(KvQuantSpec::int8());
    let q8_bytes = KvArena::footprint(&m.cfg, 4, q8);
    // reserved capacity = pool pages × per-page payload + pool
    // bookkeeping (refcount + free-list word + overflow counter per
    // page) + per-slot page tables and head/len words
    let ps = DEFAULT_KV_PAGE.min(m.cfg.max_seq);
    let pps = (m.cfg.max_seq + ps - 1) / ps + 1; // +1: head-offset headroom
    let n_pages = 4 * pps;
    let per_page_f32 = 2 * m.cfg.n_layers * ps * m.cfg.d_model * 4;
    let meta = n_pages * (4 + 4 + 8) + 4 * (pps * 4 + 2 * 8);
    assert_eq!(f32_bytes, n_pages * per_page_f32 + meta);
    assert!(
        (q8_bytes as f64) <= 0.30 * f32_bytes as f64,
        "i8 arena {q8_bytes} B exceeds 30% of f32 {f32_bytes} B"
    );
    let mut arena = KvArena::with_kind(&m, 4, q8);
    assert_eq!(arena.capacity_bytes(), q8_bytes, "footprint formula disagrees with the arena");
    // resident bytes: fresh arena holds no pages — metadata only
    let empty = arena.bytes();
    assert_eq!(empty, meta, "fresh arena must not charge unallocated pages");
    let slot = arena.alloc().unwrap();
    for t in 0..(ps as u16 + 1) {
        m.decode_step_batch(&[t], &[slot], &mut arena);
    }
    // ps+1 cached rows span exactly two pages
    let per_page_q8 = (q8_bytes - meta) / n_pages;
    assert_eq!(arena.resident_pages(), 2);
    assert_eq!(arena.bytes(), empty + 2 * per_page_q8);
    assert_eq!(arena.peak_bytes(), arena.bytes());
    arena.release(slot);
    assert_eq!(arena.bytes(), empty, "released pages must leave resident memory");
    assert_eq!(arena.peak_bytes(), empty + 2 * per_page_q8, "peak is a high-water mark");
    // 16-bit codes halve instead of quarter
    let q16_bytes = KvArena::footprint(&m.cfg, 4, KvCacheKind::Quant(KvQuantSpec::int16()));
    assert!(q16_bytes > q8_bytes && q16_bytes < f32_bytes);
}

/// THE attribution property: per-request overflow counts are exact —
/// invariant to batch composition — on a model whose narrow registers
/// overflow in both the linear layers (forced narrow eval width) and
/// the attention matmuls (narrow KV inner width).
#[test]
fn per_request_overflow_attribution_is_batch_invariant() {
    let m0 = lm(906, 16, 2, 16);
    let toks = synth_corpus(16 * 8, m0.cfg.vocab, 907);
    let calib: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 14, tile: 8 };
    cfg.datapath = DatapathMode::Faithful;
    cfg.force_eval_bits = Some(9); // deliberately too narrow → overflows
    let mut m = m0.clone();
    quantize_transformer(&mut m, &calib, &cfg).unwrap();
    // narrow attention registers too, so attention events join the count
    let kv = KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(8)));

    let reqs: Vec<Request> = (0..5u64)
        .map(|id| Request {
            id,
            prompt: toks[id as usize * 7..id as usize * 7 + 3 + id as usize].to_vec(),
            max_new_tokens: 4 + (id as usize * 5) % 14,
            ..Request::default()
        })
        .collect();
    let run = |max_batch: usize| {
        let q = ServeQueue::new();
        for r in &reqs {
            q.submit(r.clone()).unwrap();
        }
        q.close();
        serve_with(&m, &q, 1, max_batch, kv);
        q.drain()
    };
    let solo = run(1);
    let batched = run(3);
    assert_eq!(solo.len(), batched.len());
    let mut total = 0u64;
    for (a, b) in solo.iter().zip(batched.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} tokens depend on batching", a.id);
        assert_eq!(
            a.overflow_events, b.overflow_events,
            "request {} overflow attribution depends on batch composition",
            a.id
        );
        total += a.overflow_events;
    }
    assert!(total > 0, "the narrow-register fixture must actually overflow");
    let stats = ServeStats::from_responses(&batched, 1.0);
    assert_eq!(stats.overflow_events, total, "stats total must equal the per-request sum");
}

/// Acceptance path: an AXE-quantized model served end to end over the
/// quantized KV arena — token-exact vs sequential decode on the same
/// backend, zero overflow events (linear guarantee from AXE, attention
/// guarantee from the data-type-safe inner width), and a shrunken
/// arena.
#[test]
fn quantized_model_serves_end_to_end_on_quant_kv() {
    let m0 = lm(908, 16, 2, 16);
    let toks = synth_corpus(16 * 8, m0.cfg.vocab, 909);
    let calib: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 14, tile: 8 };
    cfg.datapath = DatapathMode::Faithful;
    let mut m = m0.clone();
    let report = quantize_transformer(&mut m, &calib, &cfg).unwrap();
    assert!(report.guaranteed_safe());

    let reqs: Vec<Request> = (0..6u64)
        .map(|id| {
            let plen = 2 + ((id as usize * 3) % 9);
            Request {
                id,
                prompt: toks[id as usize * 16..id as usize * 16 + plen].to_vec(),
                max_new_tokens: 6 + ((id as usize * 9) % 20), // some past the window → slides
                ..Request::default()
            }
        })
        .collect();
    let q = ServeQueue::new();
    for r in &reqs {
        q.submit(r.clone()).unwrap();
    }
    q.close();
    let t0 = std::time::Instant::now();
    serve_with(&m, &q, 1, 3, KV8);
    let responses = q.drain();
    let mut stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
    stats.arena_bytes = KvArena::footprint(&m.cfg, 3, KV8);
    assert_eq!(stats.requests, reqs.len());
    assert_eq!(stats.overflow_events, 0, "both guarantees hold → zero events");
    assert!(stats.arena_bytes < KvArena::footprint(&m.cfg, 3, KvCacheKind::F32) / 2);
    for (resp, req) in responses.iter().zip(reqs.iter()) {
        assert_eq!(resp.id, req.id);
        let want = m.generate_greedy_with(&req.prompt, req.max_new_tokens, KV8);
        assert_eq!(
            resp.tokens,
            want[req.prompt.len()..],
            "request {} diverged from sequential quant-KV greedy decode",
            req.id
        );
    }
}
