//! Steady-state allocation audit of the decode hot path.
//!
//! A counting global allocator wraps `System`; after a short warmup
//! (which grows the [`DecodeScratch`] buffers to their high-water
//! shape), a batched decode step must perform **zero** heap
//! allocations — on the quantized model + quantized-KV backend (the
//! serving configuration the scratch plan exists for), on the float
//! model + f32 arena, on ragged steps carrying a prefill chunk, and on
//! full speculative draft/verify/rollback cycles on both backends.
//! Telemetry recording rides inside every measured window: each step
//! builds a [`StepRecord`] and pushes it through a [`SharedMetrics`]
//! ring sized to wrap, so the record/observe/overwrite path is held to
//! the same zero-allocation bar as the kernels it measures.
//!
//! The fixture is deliberately sized below the kernels' band-threading
//! work threshold (rows·c·k < 64³ everywhere): the zero-allocation
//! guarantee is scoped to inline kernel calls — a call large enough to
//! fan out across scoped threads allocates for the spawns by design,
//! and that path is exercised elsewhere (qgemm threaded-band tests).
//!
//! This file contains exactly one `#[test]` on purpose: the allocation
//! counter is process-global, and a concurrently running sibling test
//! would pollute the measured window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use axe::coordinator::telemetry::{SharedMetrics, StepRecord};
use axe::coordinator::{quantize_transformer, DatapathMode, PipelineConfig};
use axe::eval::synth_corpus;
use axe::model::{
    random_transformer, Activation, DecodeScratch, KvArena, KvCacheKind, KvQuantSpec, RaggedOpts,
    RowGroup, Transformer, TransformerConfig,
};
use axe::quant::{AccumTarget, Algorithm, Method};

/// `System`, with every allocation counted (deallocations are free:
/// the property under test is "no allocations per step", and a
/// dealloc without a matching alloc cannot exist).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

fn lm_fixture(seed: u64) -> (Transformer, Vec<u16>) {
    let cfg = TransformerConfig {
        name: "zeroalloc".into(),
        vocab: 48,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        max_seq: 16,
        act: Activation::Gelu,
        parallel_residual: false,
    };
    (random_transformer(cfg, seed), synth_corpus(16 * 16, 48, seed + 1))
}

/// Drive `steps` batched decode steps over 4 slots and return how many
/// heap allocations they performed. Tokens/slots/counters live in
/// stack arrays; logits are read from the workspace — nothing in the
/// loop should touch the allocator once the workspace is warm.
fn run_steps(
    model: &Transformer,
    arena: &mut KvArena,
    slots: &[usize; 4],
    scratch: &mut DecodeScratch,
    metrics: &SharedMetrics,
    steps: usize,
    phase: u16,
) -> u64 {
    let vocab = model.cfg.vocab as u16;
    let mut tokens = [0u16; 4];
    let mut row_ovf = [0u64; 4];
    let before = allocations();
    for s in 0..steps {
        for (b, t) in tokens.iter_mut().enumerate() {
            *t = ((phase as usize + s * 7 + b * 3) % vocab as usize) as u16;
        }
        row_ovf.iter_mut().for_each(|v| *v = 0);
        model.decode_step_batch_scratch(&tokens, slots, arena, &mut row_ovf[..], scratch);
        // touch the result so the read can't be optimized away
        assert!(scratch.step.logits[..4 * vocab as usize].iter().all(|v| v.is_finite()));
        // telemetry rides in the measured window: a full StepRecord
        // plus a TTFT observation through the shared ring, per step,
        // must not allocate either (the ring is preallocated and a
        // std Mutex lock is allocation-free).
        let attn = scratch.last_attn_overflows();
        let rec = StepRecord {
            step: phase as u64 * 64 + s as u64,
            wall_ns: 1 + s as u64,
            decode_rows: 4,
            tokens: 4,
            overflow_linear: row_ovf.iter().sum::<u64>().saturating_sub(attn),
            overflow_attn: attn,
            attn_bands: scratch.last_attn_bands() as u32,
            arena_resident_bytes: arena.bytes() as u64,
            arena_capacity_bytes: arena.capacity_bytes() as u64,
            ..StepRecord::default()
        };
        metrics.with(|m| {
            m.record(rec);
            m.record_ttft(1 + s as u64);
        });
    }
    allocations() - before
}

#[test]
fn steady_state_decode_steps_allocate_nothing() {
    // -- phase 1: AXE-quantized model (faithful fused kernel) over the
    // quantized KV arena — the serving configuration.
    let (base, toks) = lm_fixture(7010);
    let calib: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 14, tile: 8 };
    cfg.datapath = DatapathMode::Faithful;
    let mut qmodel = base.clone();
    let report = quantize_transformer(&mut qmodel, &calib, &cfg).unwrap();
    // The guarantee matters for the allocation property too: an unsafe
    // tile would fall back to the per-MAC simulator, which buffers one
    // widened tile per event.
    assert!(report.guaranteed_safe(), "fixture must carry the overflow guarantee");

    let kind = KvCacheKind::Quant(KvQuantSpec::new(8, 64, None)); // data-type-safe width
    let mut arena = KvArena::with_kind(&qmodel, 4, kind);
    let mut slots = [0usize; 4];
    for s in slots.iter_mut() {
        *s = arena.alloc().expect("4-slot arena");
    }
    let mut scratch = DecodeScratch::for_model(&qmodel.cfg, 4);
    let mut ovf = 0u64;
    for (i, &s) in slots.iter().enumerate() {
        qmodel.prefill_slot_scratch(&toks[i * 3..i * 3 + 3], s, &mut arena, &mut ovf, &mut scratch);
    }
    // one telemetry ring for the whole test, sized to WRAP (capacity 8,
    // 45 records by the end): overwrite + drop accounting run inside
    // the measured windows, not just the happy path.
    let metrics = SharedMetrics::new(8);
    // warmup: first steps may still grow buffers / free-list internals
    run_steps(&qmodel, &mut arena, &slots, &mut scratch, &metrics, 3, 100);
    let quant_allocs = run_steps(&qmodel, &mut arena, &slots, &mut scratch, &metrics, 6, 200);
    assert_eq!(
        quant_allocs, 0,
        "quantized-model + quant-KV decode steps must not allocate after warmup \
         ({quant_allocs} allocations across 6 steps)"
    );

    // -- phase 2: float model over the f32 arena (banded f64 GEMM path).
    let mut arena_f = KvArena::new(&base, 4);
    let mut slots_f = [0usize; 4];
    for s in slots_f.iter_mut() {
        *s = arena_f.alloc().expect("4-slot arena");
    }
    let mut scratch_f = DecodeScratch::for_model(&base.cfg, 4);
    for (i, &s) in slots_f.iter().enumerate() {
        let prompt = &toks[i * 3..i * 3 + 3];
        base.prefill_slot_scratch(prompt, s, &mut arena_f, &mut ovf, &mut scratch_f);
    }
    run_steps(&base, &mut arena_f, &slots_f, &mut scratch_f, &metrics, 3, 300);
    let float_allocs = run_steps(&base, &mut arena_f, &slots_f, &mut scratch_f, &metrics, 6, 400);
    assert_eq!(
        float_allocs, 0,
        "float-model decode steps must not allocate after warmup \
         ({float_allocs} allocations across 6 steps)"
    );

    // -- phase 3: ragged steps that INCLUDE a prefill chunk (the
    // chunked-admission serving shape): 3 decode rows + a 5-token
    // chunk re-prefilling a recycled slot, every step. The workspace is
    // pre-sized to the ragged-step high-water mark (for_serve), so
    // steady-state chunked steps must be allocation-free too.
    let chunk_len = 5usize;
    let mut arena_r = KvArena::with_kind(&qmodel, 4, kind);
    let mut dec_slots = [0usize; 3];
    for s in dec_slots.iter_mut() {
        *s = arena_r.alloc().expect("4-slot arena");
    }
    let chunk_slot = arena_r.alloc().expect("4th slot");
    let mut scratch_r = DecodeScratch::for_serve(&qmodel.cfg, 4, chunk_len);
    // Configure a banded attention sweep. The per-thread AttnScratch
    // pool is presized here (grow-only), and this fixture sits far
    // below the default PAR_ATTN_MIN_WORK threshold, so every step
    // still runs the serial oracle — pinning that merely *enabling*
    // attention threads costs nothing on small steps and keeps the
    // inline path allocation-free. (A step big enough to actually fan
    // out allocates for the scoped spawns by design; see the module
    // docs above.)
    scratch_r.set_attn_threads(&qmodel.cfg, 8);
    let mut ovf_r = 0u64;
    for (i, &s) in dec_slots.iter().enumerate() {
        qmodel.prefill_slot_scratch(
            &toks[i * 3..i * 3 + 3],
            s,
            &mut arena_r,
            &mut ovf_r,
            &mut scratch_r,
        );
    }
    // step-composition buffers built once, reused every iteration
    let mut groups: Vec<RowGroup> = Vec::with_capacity(4);
    let mut tokens = [0u16; 8]; // 3 decode rows + 5 chunk rows
    let mut group_ovf = [0u64; 4];
    let vocab = qmodel.cfg.vocab as u16;
    let mut ragged_step = |arena: &mut KvArena,
                           scratch: &mut DecodeScratch,
                           groups: &mut Vec<RowGroup>,
                           phase: u16| {
        arena.reset_slot(chunk_slot); // recycle: chunk prefills it afresh
        for (b, t) in tokens.iter_mut().enumerate() {
            *t = ((phase as usize + b * 5) % vocab as usize) as u16;
        }
        groups.clear();
        for (g, &s) in dec_slots.iter().enumerate() {
            groups.push(RowGroup { slot: s, start: g, len: 1 });
        }
        groups.push(RowGroup { slot: chunk_slot, start: 3, len: chunk_len });
        group_ovf.iter_mut().for_each(|v| *v = 0);
        qmodel.decode_step_ragged_scratch(&tokens, groups, arena, &mut group_ovf, scratch);
        assert!(scratch.step.logits[..4 * vocab as usize].iter().all(|v| v.is_finite()));
        // chunked serving shape → the serve loop's record shape: the
        // overflow split reads the kernel's attention share back out of
        // the scratch, exactly as StepEngine::step does.
        let attn = scratch.last_attn_overflows();
        let total: u64 = group_ovf.iter().sum();
        metrics.with(|m| {
            m.record(StepRecord {
                step: phase as u64,
                wall_ns: 1 + phase as u64,
                decode_rows: 3,
                prefill_rows: chunk_len as u32,
                prefill_chunks: 1,
                tokens: 8,
                overflow_linear: total.saturating_sub(attn),
                overflow_attn: attn,
                attn_bands: scratch.last_attn_bands() as u32,
                arena_resident_bytes: arena.bytes() as u64,
                arena_capacity_bytes: arena.capacity_bytes() as u64,
                ..StepRecord::default()
            });
        });
    };
    for i in 0..3u16 {
        ragged_step(&mut arena_r, &mut scratch_r, &mut groups, 500 + i); // warmup
    }
    let before = allocations();
    for i in 0..6u16 {
        ragged_step(&mut arena_r, &mut scratch_r, &mut groups, 600 + i);
    }
    let ragged_allocs = allocations() - before;
    assert_eq!(
        ragged_allocs, 0,
        "ragged steps with a prefill chunk must not allocate after warmup \
         ({ragged_allocs} allocations across 6 steps)"
    );

    // -- phases 4 and 5: full speculative decoding cycles (the
    // self-speculative serving shape) on both backends. Per step, k-1
    // single-row draft rounds on a second scratch (page ledgers off),
    // a draft rollback, one k-row chunk-causal verify group per
    // sequence with per-row logits, and an acceptance rollback — the
    // exact call sequence StepEngine runs per speculative step. The
    // draft runs the stored register widths here (the exact-draft
    // configuration; a width-narrowed draft drives the same buffers,
    // just hotter overflow counters — and this fixture's phase-1 width
    // is chosen event-free on purpose, see above). Draft and verify
    // scratches, the All-layout logits plane, and the page pops /
    // free-list pushes from both rollbacks must all be warm after one
    // cycle.
    const K: usize = 4;
    let mut draft_tokens = [0u16; 3];
    let mut verify_tokens = [0u16; 3 * K];
    let mut spec_ovf = [0u64; 3];
    let mut spec_step = |model: &Transformer,
                         arena: &mut KvArena,
                         verify: &mut DecodeScratch,
                         draft: &mut DecodeScratch,
                         groups: &mut Vec<RowGroup>,
                         slots: &[usize; 3],
                         phase: u16| {
        for r in 0..K - 1 {
            for (b, t) in draft_tokens.iter_mut().enumerate() {
                *t = ((phase as usize + r * 11 + b * 5) % vocab as usize) as u16;
            }
            groups.clear();
            for (g, &s) in slots.iter().enumerate() {
                groups.push(RowGroup { slot: s, start: g, len: 1 });
            }
            spec_ovf.iter_mut().for_each(|v| *v = 0);
            model.decode_step_ragged_opts(
                &draft_tokens,
                groups,
                arena,
                &mut spec_ovf,
                draft,
                RaggedOpts::draft(None),
            );
        }
        // roll every draft append back, then score the whole chunk
        // full-width with one logits row per position
        for &s in slots.iter() {
            arena.truncate_tail(s, K - 1);
        }
        for (b, t) in verify_tokens.iter_mut().enumerate() {
            *t = ((phase as usize + b * 7) % vocab as usize) as u16;
        }
        groups.clear();
        for (g, &s) in slots.iter().enumerate() {
            groups.push(RowGroup { slot: s, start: g * K, len: K });
        }
        spec_ovf.iter_mut().for_each(|v| *v = 0);
        model.decode_step_ragged_opts(
            &verify_tokens,
            groups,
            arena,
            &mut spec_ovf,
            verify,
            RaggedOpts::verify(),
        );
        assert!(verify.step.logits[..3 * K * vocab as usize].iter().all(|v| v.is_finite()));
        // acceptance rollback, sized so steady-state net growth is zero
        for &s in slots.iter() {
            arena.truncate_tail(s, K);
        }
        let attn = verify.last_attn_overflows();
        let total: u64 = spec_ovf.iter().sum();
        metrics.with(|m| {
            m.record(StepRecord {
                step: phase as u64,
                wall_ns: 1 + phase as u64,
                decode_rows: (3 * K) as u32,
                tokens: (3 * K) as u32,
                overflow_linear: total.saturating_sub(attn),
                overflow_attn: attn,
                spec_proposed: (3 * (K - 1)) as u32,
                spec_accepted: (3 * (K - 1)) as u32,
                draft_rows: (3 * (K - 1)) as u32,
                arena_resident_bytes: arena.bytes() as u64,
                arena_capacity_bytes: arena.capacity_bytes() as u64,
                ..StepRecord::default()
            });
        });
    };
    for (model, akind, name) in
        [(&qmodel, Some(kind), "quantized model + quant KV"), (&base, None, "float model + f32 KV")]
    {
        let mut arena_s = match akind {
            Some(k) => KvArena::with_kind(model, 3, k),
            None => KvArena::new(model, 3),
        };
        let mut slots_s = [0usize; 3];
        for s in slots_s.iter_mut() {
            *s = arena_s.alloc().expect("3-slot arena");
        }
        let mut verify_s = DecodeScratch::for_model(&model.cfg, 4);
        let mut draft_s = DecodeScratch::for_model(&model.cfg, 4);
        let mut groups_s: Vec<RowGroup> = Vec::with_capacity(3);
        let mut ovf_s = 0u64;
        for (i, &s) in slots_s.iter().enumerate() {
            model.prefill_slot_scratch(
                &toks[i * 3..i * 3 + 3],
                s,
                &mut arena_s,
                &mut ovf_s,
                &mut draft_s,
            );
        }
        for i in 0..3u16 {
            let p = 700 + i; // warmup
            spec_step(model, &mut arena_s, &mut verify_s, &mut draft_s, &mut groups_s, &slots_s, p);
        }
        let before = allocations();
        for i in 0..6u16 {
            let p = 800 + i;
            spec_step(model, &mut arena_s, &mut verify_s, &mut draft_s, &mut groups_s, &slots_s, p);
        }
        let spec_allocs = allocations() - before;
        assert_eq!(
            spec_allocs, 0,
            "speculative draft/verify/rollback steps on the {name} must not allocate \
             after warmup ({spec_allocs} allocations across 6 steps)"
        );
    }

    // every step of every phase recorded; the capacity-8 ring wrapped
    // and drop-counted the overflow — all inside the audited windows.
    let sum = metrics.summary();
    assert_eq!(sum.steps, 45, "all 45 steps must be telemetry-recorded");
    assert_eq!(sum.records_dropped, 45 - 8, "ring wraparound must drop-count exactly");
    assert_eq!(
        sum.tokens,
        18 * 4 + 9 * 8 + 18 * 12,
        "recorded row totals must match the driven steps"
    );
}
