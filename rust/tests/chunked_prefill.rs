//! Property harness for **chunked interleaved prefill**: under
//! randomized admission schedules (arrival step, prompt length, chunk
//! size), the step scheduler must emit, for every request, exactly the
//! token stream sequential greedy decode emits AND exactly the
//! overflow events that request triggers when served alone — on both
//! KV backends, through mid-chunk window slides and slot reuse.
//!
//! The scheduler under test is the deterministic [`StepEngine`] the
//! engine threads drive, so schedules replay bit-for-bit: requests are
//! admitted at prescribed steps (deferred FCFS when no slot is free),
//! and every step interleaves prefill chunks with the in-flight decode
//! rows in one ragged kernel call.

use axe::accum::OverflowMode;
use axe::coordinator::serve::{Request, Response, ServeConfig, StepEngine};
use axe::coordinator::telemetry::SharedMetrics;
use axe::coordinator::{quantize_transformer, DatapathMode, PipelineConfig};
use axe::eval::synth_corpus;
use axe::model::{
    argmax, random_transformer, Activation, Datapath, KvArena, KvCacheKind, KvQuantSpec, Linear,
    Transformer, TransformerConfig,
};
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::util::rng::Rng;
use std::time::Instant;

fn model(seed: u64) -> Transformer {
    random_transformer(
        TransformerConfig {
            name: "chunked".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 16,
            act: Activation::Gelu,
            parallel_residual: false,
        },
        seed,
    )
}

/// Sequential single-request reference: the tokens AND the exact
/// overflow events this request costs when served alone. Mirrors
/// `generate_greedy_with` (prefill → sample → decode, slide on a full
/// window) but, like the engine, never decodes past the final sample —
/// so its event count is exactly what the engine must attribute.
fn sequential_reference(
    m: &Transformer,
    prompt: &[u16],
    n: usize,
    kind: KvCacheKind,
) -> (Vec<u16>, u64) {
    let clipped = m.clip_to_window(prompt);
    let mut arena = KvArena::with_kind(m, 1, kind);
    let slot = arena.alloc().unwrap();
    let mut ovf = 0u64;
    let mut logits = m.prefill_slot_counted(&clipped, slot, &mut arena, &mut ovf);
    let mut context = clipped.clone();
    let mut out: Vec<u16> = Vec::new();
    let mut row = [0u64; 1];
    for i in 0..n {
        if arena.is_full(slot) {
            let keep = m.slide_keep();
            let tail = context[context.len() - keep..].to_vec();
            arena.reset_slot(slot);
            logits = m.prefill_slot_counted(&tail, slot, &mut arena, &mut ovf);
            context = tail;
        }
        let next = argmax(&logits) as u16;
        out.push(next);
        context.push(next);
        if i + 1 < n {
            row[0] = 0;
            logits = m.decode_step_batch_counted(&[next], &[slot], &mut arena, &mut row);
            ovf += row[0];
        }
    }
    // self-check: the manual loop reproduces generate_greedy_with
    let direct = m.generate_greedy_with(&clipped, n, kind);
    assert_eq!(out, direct[clipped.len()..], "reference loop diverged from generate_greedy");
    (out, ovf)
}

/// Drive a [`StepEngine`] through an admission schedule: request `i` is
/// admitted at `arrivals[i]` (deferred, in order, while no slot is
/// free), one `step()` per scheduler tick, until everything drains.
fn run_schedule(
    m: &Transformer,
    cfg: ServeConfig,
    reqs: &[Request],
    arrivals: &[usize],
) -> Vec<Response> {
    let mut eng = StepEngine::new(m, cfg);
    let mut done: Vec<Response> = Vec::new();
    let mut next = 0usize;
    let mut tick = 0usize;
    loop {
        while next < reqs.len() && arrivals[next] <= tick && eng.free_slots() > 0 {
            eng.admit(reqs[next].clone(), Instant::now());
            next += 1;
        }
        eng.step();
        done.extend(eng.take_finished());
        tick += 1;
        if next == reqs.len() && !eng.has_work() {
            break;
        }
        assert!(tick < 100_000, "schedule did not converge");
    }
    done.sort_by_key(|r| r.id);
    done
}

/// [`run_schedule`], returning the engine's telemetry ring alongside
/// the responses so properties can compare per-step records against
/// response-level totals.
fn run_with_telemetry(
    m: &Transformer,
    cfg: ServeConfig,
    reqs: &[Request],
    arrivals: &[usize],
) -> (Vec<Response>, SharedMetrics) {
    let mut eng = StepEngine::new(m, cfg);
    let mut done: Vec<Response> = Vec::new();
    let mut next = 0usize;
    let mut tick = 0usize;
    loop {
        while next < reqs.len() && arrivals[next] <= tick && eng.free_slots() > 0 {
            eng.admit(reqs[next].clone(), Instant::now());
            next += 1;
        }
        eng.step();
        done.extend(eng.take_finished());
        tick += 1;
        if next == reqs.len() && !eng.has_work() {
            break;
        }
        assert!(tick < 100_000, "schedule did not converge");
    }
    let metrics = eng.metrics().expect("telemetry is on by default").clone();
    done.sort_by_key(|r| r.id);
    (done, metrics)
}

/// Random schedule: prompts 1..=22 tokens (several past max_seq=16 →
/// clipped), generations 1..=28 (several past the window → slides,
/// some mid-chunk at small chunk sizes), arrivals spread over the
/// first 12 ticks, 3 slots for 7 requests → deferred admissions and
/// slot reuse.
fn random_schedule(rng: &mut Rng, n_req: usize) -> (Vec<Request>, Vec<usize>) {
    let mut reqs = Vec::new();
    let mut arrivals: Vec<usize> = (0..n_req).map(|_| rng.int_in(0, 12) as usize).collect();
    arrivals.sort_unstable();
    for id in 0..n_req as u64 {
        let plen = rng.int_in(1, 22) as usize;
        let prompt: Vec<u16> = (0..plen).map(|_| rng.int_in(0, 31) as u16).collect();
        let max_new_tokens = rng.int_in(1, 28) as usize;
        reqs.push(Request { id, prompt, max_new_tokens, ..Request::default() });
    }
    (reqs, arrivals)
}

fn assert_schedule_exact(
    m: &Transformer,
    kind: KvCacheKind,
    chunk: usize,
    reqs: &[Request],
    arrivals: &[usize],
    label: &str,
) {
    let cfg = ServeConfig::new(3, kind).with_prefill_chunk(chunk);
    let responses = run_schedule(m, cfg, reqs, arrivals);
    assert_eq!(responses.len(), reqs.len(), "{label}: lost responses");
    for (resp, req) in responses.iter().zip(reqs.iter()) {
        assert_eq!(resp.id, req.id);
        let (want_tokens, want_ovf) =
            sequential_reference(m, &req.prompt, req.max_new_tokens, kind);
        assert_eq!(
            resp.tokens, want_tokens,
            "{label}: request {} token stream diverged from sequential decode",
            req.id
        );
        assert_eq!(
            resp.overflow_events, want_ovf,
            "{label}: request {} overflow attribution diverged from solo serving",
            req.id
        );
        assert!(resp.ttft_s >= resp.queued_s && resp.ttft_s <= resp.queued_s + resp.gen_s + 1e-9);
    }
}

/// THE chunked-serving property on the float model: every chunk size —
/// 1-token trickle, prime 7, the default 64 (≥ every prompt here), and
/// unchunked — over both KV backends, against randomized schedules.
#[test]
fn randomized_schedules_are_bit_exact_on_both_backends() {
    let m = model(42);
    let mut rng = Rng::new(9001);
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
        for &chunk in &[1usize, 7, 64, usize::MAX] {
            let (reqs, arrivals) = random_schedule(&mut rng, 7);
            assert_schedule_exact(
                &m,
                kind,
                chunk,
                &reqs,
                &arrivals,
                &format!("kind={kind:?} chunk={chunk}"),
            );
        }
    }
}

/// Overflow exactness with **live attention events**: a deliberately
/// narrow attention register (6-bit inner at tile 8) overflows
/// constantly, and every request's count must still match its solo
/// reference for every chunk size — i.e. attribution is
/// batch-composition- and chunking-invariant, not merely zero.
#[test]
fn narrow_attention_overflow_attribution_is_chunking_invariant() {
    let m = model(43);
    let kind = KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)));
    let mut rng = Rng::new(9002);
    let (reqs, arrivals) = random_schedule(&mut rng, 6);
    // the fixture must actually overflow, otherwise this test is vacuous
    let (_, probe_ovf) = sequential_reference(&m, &reqs[0].prompt, reqs[0].max_new_tokens, kind);
    assert!(probe_ovf > 0, "narrow attention register must overflow in this fixture");
    for &chunk in &[1usize, 5, usize::MAX] {
        assert_schedule_exact(&m, kind, chunk, &reqs, &arrivals, &format!("narrow chunk={chunk}"));
    }
}

/// The full serving configuration: an AXE-quantized model (fused
/// integer kernel) with deliberately narrowed linear registers (live
/// linear overflow events) over both KV backends — chunked serving
/// stays token- and attribution-exact end to end.
#[test]
fn quantized_model_chunked_serving_is_exact() {
    let base = model(44);
    let toks = synth_corpus(16 * 16, 32, 45);
    let calib: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 14, tile: 8 };
    cfg.datapath = DatapathMode::Faithful;
    let mut qmodel = base;
    quantize_transformer(&mut qmodel, &calib, &cfg).unwrap();
    // narrow every quantized linear's register so overflow events are
    // live (wraparound is deterministic and row-independent, so
    // exactness must survive)
    for name in qmodel.linear_names() {
        if let Some(Linear::Quant(q)) = qmodel.get_linear_mut(&name) {
            q.datapath = Datapath::Simulated {
                tile: 8,
                inner_bits: 11,
                outer_bits: 14,
                mode: OverflowMode::Wraparound,
            };
        }
    }
    let mut rng = Rng::new(9003);
    let (reqs, arrivals) = random_schedule(&mut rng, 5);
    let (_, probe_ovf) =
        sequential_reference(&qmodel, &reqs[0].prompt, reqs[0].max_new_tokens, KvCacheKind::F32);
    assert!(probe_ovf > 0, "narrowed linear registers must overflow in this fixture");
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
        for &chunk in &[1usize, 4, usize::MAX] {
            assert_schedule_exact(
                &qmodel,
                kind,
                chunk,
                &reqs,
                &arrivals,
                &format!("qmodel kind={kind:?} chunk={chunk}"),
            );
        }
    }
}

/// Overlapping-prefix workloads under the randomized-admission
/// harness: every request opens with the same system prompt, tails
/// diverge, 7 requests ride 3 slots (reuse waves), and generations run
/// past the window (mid-chunk slides → re-prefills that adopt again).
/// Token streams and per-request overflow counts must be bit-identical
/// with prefix sharing ON vs OFF — and both equal the solo sequential
/// reference — on both backends at every chunk size, with 4-token
/// pages so several full pages are actually shared.
#[test]
fn shared_prefix_schedules_match_sharing_off_exactly() {
    let m = model(47);
    let system: Vec<u16> = (0..10u16).map(|i| (i * 7 + 3) % 32).collect();
    let mut rng = Rng::new(9004);
    // narrow attention register on the quant backend → live overflow
    // events whose attribution must survive page adoption
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)))] {
        for &chunk in &[1usize, 5, usize::MAX] {
            let mut arrivals: Vec<usize> =
                (0..7).map(|_| rng.int_in(0, 10) as usize).collect();
            arrivals.sort_unstable();
            let reqs: Vec<Request> = (0..7u64)
                .map(|id| {
                    let tail = rng.int_in(0, 5) as usize;
                    let mut prompt = system.clone();
                    prompt.extend((0..tail).map(|_| rng.int_in(0, 31) as u16));
                    Request {
                        id,
                        prompt,
                        max_new_tokens: rng.int_in(1, 24) as usize,
                        ..Request::default()
                    }
                })
                .collect();
            let run = |sharing: bool| {
                let cfg = ServeConfig::new(3, kind)
                    .with_prefill_chunk(chunk)
                    .with_kv_page(4)
                    .with_prefix_cache(sharing);
                run_schedule(&m, cfg, &reqs, &arrivals)
            };
            let on = run(true);
            let off = run(false);
            let label = format!("kind={kind:?} chunk={chunk}");
            assert_eq!(on.len(), reqs.len(), "{label}: lost responses");
            for ((a, b), req) in on.iter().zip(off.iter()).zip(reqs.iter()) {
                assert_eq!(a.id, req.id);
                assert_eq!(
                    a.tokens, b.tokens,
                    "{label}: request {} tokens depend on prefix sharing",
                    req.id
                );
                assert_eq!(
                    a.overflow_events, b.overflow_events,
                    "{label}: request {} overflow attribution depends on prefix sharing",
                    req.id
                );
                assert_eq!(b.prefill_tokens_skipped, 0, "{label}: sharing off must skip nothing");
                let (want_tokens, want_ovf) =
                    sequential_reference(&m, &req.prompt, req.max_new_tokens, kind);
                assert_eq!(a.tokens, want_tokens, "{label}: request {} vs solo", req.id);
                assert_eq!(a.overflow_events, want_ovf, "{label}: request {} ovf vs solo", req.id);
            }
            // 7 requests on 3 slots: deferred admissions land after the
            // leader registered the system pages, so sharing must fire
            let skipped: usize = on.iter().map(|r| r.prefill_tokens_skipped).sum();
            assert!(skipped > 0, "{label}: no admission ever hit the prefix cache");
        }
    }
}

/// ISSUE acceptance bar: a **64-token shared prefix across 8 admitted
/// sequences**. After the leader serves, every follower's admission
/// maps the four full 16-token system pages read-only and prefills
/// only its 3-token private tail (`prefill_tokens_skipped == 64`) —
/// and tokens plus per-request overflow counts stay bit-identical with
/// sharing on vs off, on both backends, for every prefill chunk.
#[test]
fn sixty_four_token_shared_prefix_across_eight_sequences() {
    let m = random_transformer(
        TransformerConfig {
            name: "chunked-wide".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 96,
            act: Activation::Gelu,
            parallel_residual: false,
        },
        48,
    );
    let system: Vec<u16> = (0..64u16).map(|i| (i * 11 + 5) % 32).collect();
    let reqs: Vec<Request> = (0..8u64)
        .map(|id| {
            let mut prompt = system.clone();
            let id = id as u16;
            prompt.extend([id % 32, (id * 7 + 2) % 32, (id * 13 + 1) % 32]);
            Request { id: id as u64, prompt, max_new_tokens: 4, ..Request::default() }
        })
        .collect();
    // leader at tick 0; followers arrive once it has retired, so the
    // cache holds all four system pages before any of them admits
    let mut arrivals = vec![90usize; reqs.len()];
    arrivals[0] = 0;
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)))] {
        for &chunk in &[1usize, 7, usize::MAX] {
            let label = format!("kind={kind:?} chunk={chunk}");
            let run = |sharing: bool| {
                let cfg = ServeConfig::new(4, kind)
                    .with_prefill_chunk(chunk)
                    .with_kv_page(16)
                    .with_prefix_cache(sharing);
                let mut eng = StepEngine::new(&m, cfg);
                let mut done: Vec<Response> = Vec::new();
                let mut next = 0usize;
                let mut tick = 0usize;
                loop {
                    while next < reqs.len() && arrivals[next] <= tick && eng.free_slots() > 0 {
                        eng.admit(reqs[next].clone(), Instant::now());
                        next += 1;
                    }
                    eng.step();
                    done.extend(eng.take_finished());
                    tick += 1;
                    if next == reqs.len() && !eng.has_work() {
                        break;
                    }
                    assert!(tick < 100_000, "schedule did not converge");
                }
                let shared = eng.arena().pages_shared();
                done.sort_by_key(|r| r.id);
                (done, shared)
            };
            let (on, pages_shared) = run(true);
            let (off, pages_off) = run(false);
            assert_eq!(on.len(), 8, "{label}: lost responses");
            assert_eq!(pages_off, 0, "{label}: sharing off must not adopt pages");
            // 7 followers × 4 system pages each
            assert_eq!(pages_shared, 28, "{label}: follower admissions must map system pages");
            for ((a, b), req) in on.iter().zip(off.iter()).zip(reqs.iter()) {
                assert_eq!(a.id, req.id);
                let want = if a.id == 0 { 0 } else { 64 };
                assert_eq!(
                    a.prefill_tokens_skipped, want,
                    "{label}: request {} must prefill only its unshared tail",
                    req.id
                );
                assert_eq!(b.prefill_tokens_skipped, 0);
                assert_eq!(a.tokens, b.tokens, "{label}: request {} tokens", req.id);
                assert_eq!(
                    a.overflow_events, b.overflow_events,
                    "{label}: request {} overflow attribution",
                    req.id
                );
            }
            // spot-check one follower against solo sequential decode
            let (want_tokens, want_ovf) =
                sequential_reference(&m, &reqs[5].prompt, reqs[5].max_new_tokens, kind);
            assert_eq!(on[5].tokens, want_tokens, "{label}: follower vs solo tokens");
            assert_eq!(on[5].overflow_events, want_ovf, "{label}: follower vs solo ovf");
        }
    }
}

/// Tentpole parity gate for the band-parallel ragged-attention sweep:
/// the thread count must be invisible. Token streams AND per-request
/// overflow attribution are bit-identical at attention thread counts
/// {1, 2, 8} — with the banding work threshold zeroed so even this
/// tiny fixture actually fans out — and all of them equal the solo
/// sequential reference. `threads = 1` is the serial oracle (the exact
/// pre-banding code path); the narrow quant spec keeps attention
/// overflow events live so attribution-folding across bands is
/// genuinely exercised.
#[test]
fn attention_thread_count_never_changes_tokens_or_attribution() {
    let m = model(49);
    let mut rng = Rng::new(9005);
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)))] {
        for &chunk in &[1usize, 7, usize::MAX] {
            let (reqs, arrivals) = random_schedule(&mut rng, 7);
            let label = format!("kind={kind:?} chunk={chunk}");
            let run_at = |threads: usize| {
                let cfg = ServeConfig::new(3, kind)
                    .with_prefill_chunk(chunk)
                    .with_attn_threads(threads)
                    .with_attn_par_min_work(0);
                run_schedule(&m, cfg, &reqs, &arrivals)
            };
            let serial = run_at(1);
            assert_eq!(serial.len(), reqs.len(), "{label}: lost responses");
            for (resp, req) in serial.iter().zip(reqs.iter()) {
                let (want_tokens, want_ovf) =
                    sequential_reference(&m, &req.prompt, req.max_new_tokens, kind);
                assert_eq!(resp.tokens, want_tokens, "{label}: serial vs solo tokens");
                assert_eq!(resp.overflow_events, want_ovf, "{label}: serial vs solo ovf");
            }
            if matches!(kind, KvCacheKind::Quant(_)) {
                let live: u64 = serial.iter().map(|r| r.overflow_events).sum();
                assert!(live > 0, "{label}: attention overflow must be live in this fixture");
            }
            for threads in [2usize, 8] {
                let par = run_at(threads);
                for (a, b) in par.iter().zip(serial.iter()) {
                    assert_eq!(a.id, b.id);
                    assert_eq!(
                        a.tokens, b.tokens,
                        "{label}: request {} tokens depend on attn threads={threads}",
                        a.id
                    );
                    assert_eq!(
                        a.overflow_events, b.overflow_events,
                        "{label}: request {} attribution depends on attn threads={threads}",
                        a.id
                    );
                }
            }
        }
    }
}

/// Slot-reuse stress: back-to-back waves through a 2-slot arena — every
/// retirement hands its slot to a deferred request whose chunked
/// prefill then shares steps with the survivor's decode rows.
#[test]
fn slot_reuse_across_waves_stays_exact() {
    let m = model(46);
    let reqs: Vec<Request> = (0..8u64)
        .map(|id| Request {
            id,
            prompt: vec![(id as u16 * 3) % 32, (id as u16 * 5 + 1) % 32],
            max_new_tokens: 4 + (id as usize % 3),
            ..Request::default()
        })
        .collect();
    let arrivals = vec![0usize; reqs.len()]; // all at once, 2 slots
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
        let cfg = ServeConfig::new(2, kind).with_prefill_chunk(1);
        let responses = run_schedule(&m, cfg, &reqs, &arrivals);
        for (resp, req) in responses.iter().zip(reqs.iter()) {
            let (want, _) = sequential_reference(&m, &req.prompt, req.max_new_tokens, kind);
            assert_eq!(resp.tokens, want, "kind={kind:?} request {} diverged", req.id);
        }
    }
}

/// Telemetry conservation: the per-step records in the ring must SUM to
/// the run's response-level totals — rows, overflow events (live via a
/// narrow attention register), prefill work — with consecutive step
/// numbering and `tokens == decode_rows + prefill_rows` per record.
/// The schedule is slide-free (prompt+gen ≤ 13 < max_seq) so the
/// decode-row identity `Σ decode_rows == Σ generated − n_requests` is
/// exact, and the prefix cache stays off so no adoption credit lands
/// in a response without a matching executed row. A second run with a
/// 4-record ring checks wraparound: only the newest 4 records survive,
/// in order, and every overwrite is drop-counted.
#[test]
fn telemetry_step_records_conserve_serve_totals() {
    let m = model(50);
    let kind = KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)));
    let reqs: Vec<Request> = (0..6usize)
        .map(|i| {
            let plen = 1 + (i % 7);
            let prompt: Vec<u16> = (0..plen).map(|p| ((p * 5 + i * 3 + 1) % 32) as u16).collect();
            Request { id: i as u64, prompt, max_new_tokens: 1 + (i % 6), ..Request::default() }
        })
        .collect();
    let arrivals: Vec<usize> = (0..reqs.len()).map(|i| i / 2).collect();
    let cfg = ServeConfig::new(3, kind).with_prefill_chunk(3);
    let (responses, sm) = run_with_telemetry(&m, cfg, &reqs, &arrivals);
    let (records, recorded, dropped) = sm.with(|mm| {
        let mut v = Vec::new();
        mm.take_buffered(&mut v);
        (v, mm.recorded(), mm.dropped())
    });
    assert_eq!(responses.len(), reqs.len(), "lost responses");
    assert_eq!(dropped, 0, "the default ring must not drop at this scale");
    assert_eq!(recorded as usize, records.len(), "every record must still be buffered");

    for (i, r) in records.iter().enumerate() {
        assert_eq!(r.step, i as u64, "executed steps must be numbered consecutively");
        assert_eq!(
            r.tokens,
            r.decode_rows + r.prefill_rows,
            "step {} rows must decompose into decode + prefill",
            r.step
        );
        assert!(r.wall_ns > 0, "step {} wall clock must be measured", r.step);
    }

    let total_generated: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let total_prompt: usize = reqs.iter().map(|r| r.prompt.len()).sum();
    let rec_decode: u64 = records.iter().map(|r| u64::from(r.decode_rows)).sum();
    let rec_prefill: u64 = records.iter().map(|r| u64::from(r.prefill_rows)).sum();
    let rec_chunks: u64 = records.iter().map(|r| u64::from(r.prefill_chunks)).sum();
    let rec_ovf: u64 = records.iter().map(|r| r.overflow_linear + r.overflow_attn).sum();
    let resp_ovf: u64 = responses.iter().map(|r| r.overflow_events).sum();
    assert_eq!(rec_decode as usize, total_generated - reqs.len(), "decode-row conservation");
    assert_eq!(rec_prefill as usize, total_prompt, "prefill-row conservation");
    assert!(rec_chunks as usize >= reqs.len(), "each admission needs at least one chunk");
    assert!(resp_ovf > 0, "narrow attention register must overflow in this fixture");
    assert_eq!(rec_ovf, resp_ovf, "overflow events must conserve between ring and responses");

    let sum = sm.summary();
    assert_eq!(sum.ttft_ns.count() as usize, reqs.len(), "one TTFT observation per request");
    assert_eq!(
        sum.tpot_ns.count() as usize,
        total_generated - reqs.len(),
        "one TPOT observation per decode row"
    );

    // ring wraparound: a 4-record ring over the same deterministic
    // schedule keeps exactly the newest 4 records and drop-counts the
    // rest.
    let cfg4 = ServeConfig::new(3, kind).with_prefill_chunk(3).with_metrics_ring(4);
    let (_, sm4) = run_with_telemetry(&m, cfg4, &reqs, &arrivals);
    let (rec4, n4, d4) = sm4.with(|mm| {
        let mut v = Vec::new();
        mm.take_buffered(&mut v);
        (v, mm.recorded(), mm.dropped())
    });
    assert_eq!(n4, recorded, "the schedule replays to the same step count");
    assert_eq!(rec4.len(), 4, "a full ring holds exactly its capacity");
    assert_eq!(d4, n4 - 4, "every overwritten record must be drop-counted");
    for (i, r) in rec4.iter().enumerate() {
        assert_eq!(r.step, n4 - 4 + i as u64, "survivors must be the newest records, in order");
    }
}
