//! Overload-safety integration tests: seeded storms through the
//! bounded admission queue must stay bounded (queue depth, step
//! latency proxy via per-step token caps), conserve every submitted
//! request into exactly one typed response, replay bit-identically
//! per seed, and never perturb the token streams of the requests
//! that survive.

use std::time::Instant;

use axe::bench_support::load::{run_load, schedule, solo_reference, FaultSpec, LoadSpec};
use axe::coordinator::serve::{CancelToken, Request, ServeConfig, ShedPolicy, Status, StepEngine};
use axe::model::{
    random_transformer, Activation, KvCacheKind, KvQuantSpec, Transformer, TransformerConfig,
};

fn model() -> Transformer {
    random_transformer(
        TransformerConfig {
            name: "overload".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            max_seq: 16,
            act: Activation::Gelu,
            parallel_residual: false,
        },
        5,
    )
}

/// Burst storm against a small cap: depth stays ≤ cap, per-step work
/// stays ≤ max(prefill_chunk, max_batch) under the fair budget, every
/// request resolves, the whole run replays bit-identically for the
/// seed, shed accounting agrees between responses and the step-record
/// stream, and every surviving stream matches the solo oracle.
#[test]
fn bursty_storm_is_bounded_conserved_and_replayable() {
    let m = model();
    let cfg = ServeConfig::new(3, KvCacheKind::F32)
        .with_prefill_chunk(4)
        .with_kv_page(4)
        .with_prefix_cache(true);
    let spec = LoadSpec::bursty(24, 8, 2);
    let events = schedule(&spec, 7);
    let a = run_load(&m, cfg, 4, ShedPolicy::RejectNewest, &events, FaultSpec::default());
    let b = run_load(&m, cfg, 4, ShedPolicy::RejectNewest, &events, FaultSpec::default());

    assert!(a.conserved(), "submitted {} != responses {}", a.submitted, a.responses.len());
    assert_eq!(a.submitted, 24);
    assert!(a.shed > 0, "an 8-wide burst into cap 4 must shed");
    assert!(a.depth_hwm <= 4, "bounded queue leaked past its cap: {}", a.depth_hwm);

    // bit-exact replay: same seed → same shed decisions, same tokens
    let key = |r: &axe::coordinator::serve::Response| (r.id, r.status as u8, r.tokens.clone());
    let mut ka: Vec<_> = a.responses.iter().map(key).collect();
    let mut kb: Vec<_> = b.responses.iter().map(key).collect();
    ka.sort();
    kb.sort();
    assert_eq!(ka, kb, "same seed must replay the same outcomes");

    // fair budget bounds per-step work even mid-storm
    for rec in &a.records {
        assert!(rec.tokens <= 4, "step {} ran {} tokens (> chunk)", rec.step, rec.tokens);
        assert_eq!(rec.tokens, rec.decode_rows + rec.prefill_rows);
    }
    // queue_hwm is a running maximum → nondecreasing along the stream
    let mut hwm = 0u32;
    for rec in &a.records {
        assert!(rec.queue_hwm >= hwm, "queue_hwm regressed at step {}", rec.step);
        hwm = rec.queue_hwm;
    }
    // record-stream admission counters agree with the typed responses
    let (ok, shed, miss, cancelled) = a.status_counts();
    assert_eq!(a.records.iter().map(|r| r.shed as u64).sum::<u64>(), shed as u64);
    assert_eq!(a.records.iter().map(|r| r.deadline_miss).sum::<u32>(), miss as u32);
    assert_eq!(a.records.iter().map(|r| r.cancelled).sum::<u32>(), cancelled as u32);
    assert_eq!(shed as u64, a.shed);
    assert_eq!(ok + shed + miss + cancelled, a.responses.len());
    let s = a.summary.expect("telemetry is on by default");
    assert_eq!(s.shed, a.shed);
    // the engine folds depths observed at its admission polls, which
    // can miss the instantaneous peak the queue itself saw
    assert!(s.queue_hwm <= a.depth_hwm as u64);

    // survivors are bit-identical to running alone
    for r in a.responses.iter().filter(|r| r.status == Status::Ok) {
        let ev = &events[r.id as usize];
        let solo = solo_reference(&m, cfg, &ev.req);
        assert_eq!(r.tokens, solo.tokens, "overload changed request {}'s tokens", r.id);
        assert_eq!(r.overflow_events, solo.overflow_events);
    }
}

/// Open-loop Poisson arrivals across several seeds: conservation and
/// survivor exactness hold for every trace, not just the bursty one.
#[test]
fn poisson_arrivals_conserve_across_seeds() {
    let m = model();
    let cfg = ServeConfig::new(2, KvCacheKind::F32).with_prefill_chunk(3).with_kv_page(4);
    for seed in [1u64, 2, 3] {
        let events = schedule(&LoadSpec::poisson(16, 1.5), seed);
        let rep =
            run_load(&m, cfg, 3, ShedPolicy::RejectLargestPrompt, &events, FaultSpec::default());
        assert!(
            rep.conserved(),
            "seed {seed}: {} submitted, {} resolved",
            rep.submitted,
            rep.responses.len()
        );
        assert!(rep.depth_hwm <= 3, "seed {seed}: hwm {}", rep.depth_hwm);
        for r in rep.responses.iter().filter(|r| r.status == Status::Ok) {
            let solo = solo_reference(&m, cfg, &events[r.id as usize].req);
            assert_eq!(r.tokens, solo.tokens, "seed {seed} request {}", r.id);
        }
    }
}

/// Cancelling mid-prefill must release the slot and every unshared
/// page (shared prefix pages stay exactly while cached), on both KV
/// backends, and the freed slot must serve the next request
/// bit-identically to a cold engine.
#[test]
fn cancellation_mid_prefill_releases_slot_and_pages() {
    let m = model();
    for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
        for cache in [false, true] {
            let cfg = ServeConfig::new(2, kind)
                .with_prefill_chunk(2)
                .with_kv_page(4)
                .with_prefix_cache(cache);
            let mut eng = StepEngine::new(&m, cfg);
            let free0 = eng.arena().free_pages();
            let tok = CancelToken::new();
            eng.admit(
                Request {
                    id: 0,
                    prompt: (0..10u16).collect(),
                    max_new_tokens: 2,
                    cancel: Some(tok.clone()),
                    ..Request::default()
                },
                Instant::now(),
            );
            eng.step();
            assert_eq!(eng.prefilling(), 1, "10-token prompt at chunk 2 is still prefilling");
            tok.cancel();
            eng.step();
            let done = eng.take_finished();
            assert_eq!(done.len(), 1);
            assert_eq!(done[0].status, Status::Cancelled);
            assert!(done[0].tokens.is_empty(), "no token sampled mid-prefill");
            assert_eq!(eng.free_slots(), 2, "cancellation must free the slot ({kind:?})");
            let cached = eng.arena().prefix_cache_pages();
            if cache {
                assert_eq!(eng.arena().resident_pages(), cached);
            } else {
                assert_eq!(cached, 0);
                assert_eq!(eng.arena().resident_pages(), 0);
            }
            let msg = format!("pages leaked ({kind:?}, cache {cache})");
            assert_eq!(eng.arena().free_pages(), free0 - cached, "{msg}");

            // the recycled slot serves the next request exactly
            let req = Request {
                id: 1,
                prompt: vec![3, 1, 4, 1, 5],
                max_new_tokens: 3,
                ..Request::default()
            };
            eng.admit(req.clone(), Instant::now());
            while eng.has_work() {
                eng.step();
            }
            let done = eng.take_finished();
            assert_eq!(done.len(), 1);
            assert_eq!(done[0].status, Status::Ok);
            let solo = solo_reference(&m, cfg, &req);
            let msg = format!("slot reuse after cancel ({kind:?}, cache {cache})");
            assert_eq!(done[0].tokens, solo.tokens, "{msg}");
            assert_eq!(done[0].overflow_events, solo.overflow_events);
        }
    }
}

/// A request whose deadline already passed is refused at admission:
/// typed response, no tokens, no slot or page spent.
#[test]
fn expired_deadline_is_refused_without_spending_a_slot() {
    let m = model();
    let cfg = ServeConfig::new(2, KvCacheKind::F32).with_kv_page(4);
    let mut eng = StepEngine::new(&m, cfg);
    let d = Instant::now();
    eng.admit(
        Request {
            id: 9,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            deadline: Some(d),
            ..Request::default()
        },
        d,
    );
    let done = eng.take_finished();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].status, Status::DeadlineMiss);
    assert!(done[0].tokens.is_empty());
    assert_eq!(eng.free_slots(), 2, "dead-on-arrival must not consume a slot");
    assert_eq!(eng.arena().resident_pages(), 0);
    assert!(!eng.has_work());
}

/// Slow-step fault injection: with every step slowed past the
/// deadline, an admitted request misses mid-flight — and the run
/// still conserves and reports the miss through telemetry.
#[test]
fn slow_steps_force_mid_flight_deadline_miss() {
    let m = model();
    let cfg = ServeConfig::new(2, KvCacheKind::F32).with_prefill_chunk(1).with_kv_page(4);
    let mut spec = LoadSpec::poisson(1, 1.0);
    spec.prompt_lens = (8, 8);
    spec.output_lens = (4, 4);
    spec.deadline_ms = 10;
    let events = schedule(&spec, 11);
    let faults = FaultSpec { slow_every: 1, slow_ms: 25 };
    let rep = run_load(&m, cfg, 4, ShedPolicy::RejectNewest, &events, faults);
    assert!(rep.conserved());
    let (ok, shed, miss, cancelled) = rep.status_counts();
    assert_eq!((ok, shed, miss, cancelled), (0, 0, 1, 0), "25ms steps must blow a 10ms deadline");
    let s = rep.summary.expect("telemetry is on by default");
    assert_eq!(s.deadline_miss, 1);
    assert_eq!(rep.records.iter().map(|r| r.deadline_miss).sum::<u32>(), 1);
}
