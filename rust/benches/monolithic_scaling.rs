//! Bench: regenerate the paper's Table 3 — a monolithic 16-bit
//! accumulator (P_O = 16) across the ladder, the ablation showing that
//! *without* multi-stage tiling the constraint tightens as models grow
//! wider and quality collapses (contrast with Table 1 / multistage_llm).

use axe::coordinator::experiments::run_lm_config;
use axe::coordinator::PipelineConfig;
use axe::eval::{load_corpus_split_or_synth, perplexity};
use axe::model::{load_named, Model};
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::util::Table;

fn main() -> anyhow::Result<()> {
    let models = ["pico-70k", "pico-160k", "pico-410k", "pico-1m", "pico-2m"];
    // The paper uses P_O=16 at K ~ 2k-16k (budget/width ~ 0.02); our zoo
    // is 10-30x narrower, so P=13 (budget 32) matches that severity ratio.
    let p = 13u32;
    println!("### Table 3 analog — W4A8, monolithic P_O = {p} (no tiling)\n");
    let mut table = Table::new(&["Algorithm", "70k", "160k", "410k", "1m", "2m"]);
    for algo in [Algorithm::Gpfq, Algorithm::Optq] {
        let mut cells = vec![algo.name().to_string()];
        for name in &models {
            let Ok(Model::Lm(base)) = load_named(name) else {
                cells.push("-".into());
                continue;
            };
            let seq = base.cfg.max_seq;
            let train = load_corpus_split_or_synth("train", base.cfg.vocab);
            let val = load_corpus_split_or_synth("val", base.cfg.vocab);
            let calib: Vec<&[u16]> = train.chunks_exact(seq).take(10).collect();
            let mut cfg = PipelineConfig::new(algo, Method::Axe, 4, 8);
            cfg.target = AccumTarget::Monolithic { p_bits: p };
            let pt = run_lm_config(&base, &calib, &val, seq, 16, &cfg)?;
            cells.push(format!("{:.0}", pt.metric));
        }
        table.row(&cells);
    }
    println!("{}", table.render());
    // context row: float perplexities
    let mut floats = Vec::new();
    for name in &models {
        if let Ok(Model::Lm(base)) = load_named(name) {
            let val = load_corpus_split_or_synth("val", base.cfg.vocab);
            floats.push(format!("{:.1}", perplexity(&base, &val, base.cfg.max_seq, 16).ppl));
        }
    }
    println!("(float PPLs: {})", floats.join(", "));
    println!(
        "Expected shape (paper Table 3): severe degradation that WORSENS as\n\
         the ladder widens — the ℓ1 budget is fixed while the natural norm\n\
         grows with K. Compare against multistage_llm where fixing T and\n\
         P_I instead keeps the constraint width-independent."
    );
    Ok(())
}
