//! Micro-benchmarks of the hot paths (feeds EXPERIMENTS.md §Perf):
//! - f64 GEMM (calibration / gram construction)
//! - integer quantized-linear forward: exact vs fused-kernel datapaths
//! - fused multi-stage qgemm vs the scalar per-MAC simulator (the
//!   acceptance bench: the kernel must beat the simulator-backed path
//!   on a ≥1024-deep multi-stage matmul)
//! - GPFQ / GPFQ* / OPTQ per-layer quantization throughput
//! - transformer forward / perplexity evaluation throughput
//! - PJRT qmatmul kernel dispatch (when artifacts exist)

use axe::accum::simulator::dot_multistage;
use axe::accum::AccumSpec;
use axe::bench_support::{bench, throughput};
use axe::linalg::{qgemm_multistage, Mat};
use axe::model::{Datapath, QuantLinear};
use axe::quant::{
    gpfq_quantize, gpfq_quantize_grams, optq_quantize, ActQuantizer, GpfqParams, OptqParams,
};
use axe::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);

    // ---- GEMM
    for &n in &[128usize, 256, 512] {
        let a = Mat::random_normal(n, n, &mut rng, 1.0);
        let b = Mat::random_normal(n, n, &mut rng, 1.0);
        let flops = 2.0 * (n * n * n) as f64;
        let s = bench(&format!("gemm f64 {n}x{n}x{n}"), 2, 5, || {
            std::hint::black_box(a.matmul(&b));
        });
        println!("    -> {:.2} GFLOP/s", flops / s.median / 1e9);
    }

    // ---- quantized linear forward (exact vs simulated)
    let (k, c) = (512usize, 512usize);
    let w = Mat::random_normal(k, c, &mut rng, 0.3);
    let x_cal = Mat::random_normal(k, 64, &mut rng, 1.0);
    let result = gpfq_quantize(&w, &x_cal, &x_cal, &GpfqParams::base(4, 8));
    let act = ActQuantizer::calibrate(&x_cal.data().to_vec(), 8, 0.999);
    let mk = |dp: Datapath| QuantLinear::from_result(&result, vec![0.0; c], act, dp);
    let x_row: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
    let mut y = vec![0.0f32; c];
    let mut scratch = vec![0i64; k];

    let ql = mk(Datapath::Exact);
    let s = bench("qlinear 512x512 exact", 3, 20, || {
        ql.forward_row(&x_row, &mut y, &mut scratch);
    });
    println!("    -> {:.1} M MAC/s", (k * c) as f64 / s.median / 1e6);

    let ql_sim = mk(Datapath::Simulated {
        tile: 64,
        inner_bits: 16,
        outer_bits: 19,
        mode: axe::accum::OverflowMode::Wraparound,
    });
    let s = bench("qlinear 512x512 simulated 64x16b", 3, 20, || {
        ql_sim.forward_row(&x_row, &mut y, &mut scratch);
    });
    println!("    -> {:.1} M MAC/s", (k * c) as f64 / s.median / 1e6);

    // ---- fused multi-stage qgemm vs the scalar per-MAC simulator.
    // 2048-deep contraction (≥1024 per the acceptance bar), W4A8-ish
    // codes, 64x16b tiles with the Eq. 22 outer width.
    let (bq, kq, cq, tile_q) = (16usize, 2048usize, 256usize, 64usize);
    let inner = AccumSpec::wraparound(16);
    let outer = AccumSpec::wraparound(axe::quant::outer_bits(16, kq, tile_q));
    let xq: Vec<i64> = (0..bq * kq).map(|_| rng.int_in(0, 255)).collect();
    let wq_codes: Vec<i32> = (0..cq * kq).map(|_| rng.int_in(-7, 7) as i32).collect();
    let mut out_q = vec![0i64; bq * cq];
    let mut row_ovf = vec![0u64; bq];
    let macs = (bq * kq * cq) as f64;
    let s_fused = bench("qgemm fused 16x2048x256 (64x16b)", 2, 10, || {
        qgemm_multistage(
            &xq, bq, &wq_codes, cq, kq, tile_q, inner, outer, &mut out_q, &mut row_ovf,
        );
        std::hint::black_box((&out_q, &row_ovf));
    });
    println!("    -> {:.1} M MAC/s", macs / s_fused.median / 1e6);
    let w64: Vec<i64> = wq_codes.iter().map(|&v| v as i64).collect();
    let s_sim = bench("scalar simulator 16x2048x256 (64x16b)", 1, 3, || {
        let mut total = 0i64;
        for r in 0..bq {
            let xr = &xq[r * kq..(r + 1) * kq];
            for ch in 0..cq {
                let wr = &w64[ch * kq..(ch + 1) * kq];
                total = total.wrapping_add(dot_multistage(xr, wr, tile_q, inner, outer).value);
            }
        }
        std::hint::black_box(total);
    });
    println!(
        "    -> {:.1} M MAC/s ({:.1}x speedup for the fused kernel)",
        macs / s_sim.median / 1e6,
        s_sim.median / s_fused.median
    );

    // ---- PTQ algorithm throughput (one layer, K=C=256, D=256)
    let (k2, c2, d2) = (256usize, 256usize, 256usize);
    let w2 = Mat::random_normal(k2, c2, &mut rng, 0.3);
    let x2 = Mat::random_normal(k2, d2, &mut rng, 1.0);
    let gram = x2.gram();
    let g = x2.gram(); // X == X̃ here
    bench("gpfq layer 256x256 (D=256)", 1, 3, || {
        std::hint::black_box(gpfq_quantize(&w2, &x2, &x2, &GpfqParams::base(4, 8)));
    });
    bench("gpfq* (mem-eff) layer 256x256", 1, 3, || {
        std::hint::black_box(
            gpfq_quantize_grams(&w2, &g, &gram, &GpfqParams::base(4, 8), 0.01).unwrap(),
        );
    });
    bench("optq layer 256x256", 1, 3, || {
        std::hint::black_box(optq_quantize(&w2, &gram, &OptqParams::base(4, 8)).unwrap());
    });

    // ---- end-to-end eval throughput on a real model if present
    if let Ok(axe::model::Model::Lm(m)) = axe::model::load_named("pico-160k") {
        let val = axe::eval::load_corpus_split_or_synth("val", m.cfg.vocab);
        let seq = m.cfg.max_seq;
        let s = bench("perplexity pico-160k (16 seqs)", 1, 3, || {
            std::hint::black_box(axe::eval::perplexity(&m, &val, seq, 16));
        });
        println!("    -> {:.0} tok/s", throughput(16 * seq, s.median));
    }

    // ---- PJRT kernel dispatch
    if let Ok(rt) = axe::runtime::Runtime::new() {
        if rt.list_artifacts().iter().any(|a| a == "qmatmul_t64_p16") {
            let x: Vec<i32> = (0..32 * 256).map(|i| (i % 255) as i32).collect();
            let wq: Vec<i32> = (0..256 * 64).map(|i| (i % 15) as i32 - 7).collect();
            let xi = axe::runtime::I32Input::new(x, &[32, 256]);
            let wi = axe::runtime::I32Input::new(wq, &[256, 64]);
            let s = bench("pjrt qmatmul_t64_p16 (32x256x64)", 2, 10, || {
                std::hint::black_box(rt.run_i32("qmatmul_t64_p16", &[xi_clone(&xi), wi_clone(&wi)]).unwrap());
            });
            println!("    -> {:.1} µs/dispatch", s.median * 1e6);
        }
    }
}

fn xi_clone(x: &axe::runtime::I32Input) -> axe::runtime::I32Input {
    axe::runtime::I32Input::new(x.data.clone(), &x.dims)
}
fn wi_clone(x: &axe::runtime::I32Input) -> axe::runtime::I32Input {
    axe::runtime::I32Input::new(x.data.clone(), &x.dims)
}
