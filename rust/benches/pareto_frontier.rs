//! Bench: regenerate the paper's Figures 1 & 3 / Tables 4-7 — the
//! Pareto frontier of accumulator width P vs model quality for
//! naive bit-width manipulation, EP-init and AXE, on both GPFQ and
//! OPTQ, for one LM and one image classifier.
//!
//! A reduced design-space grid keeps `cargo bench` under a few minutes;
//! the full grid lives in `examples/pareto_sweep.rs`. Set
//! AXE_BENCH_FULL=1 for the complete (M, N) space.

use axe::coordinator::experiments::{
    pareto_frontier, render_frontier, run_img_config, run_lm_config, MetricKind,
};
use axe::coordinator::PipelineConfig;
use axe::eval::{load_corpus_split_or_synth, load_glyphs, synth_glyphs};
use axe::model::{load_named, random_mlp, random_transformer, Activation, Model};
use axe::quant::{AccumTarget, Algorithm, Method};

fn main() -> anyhow::Result<()> {
    let full = std::env::var("AXE_BENCH_FULL").is_ok();
    let grid: Vec<(u32, u32)> = if full {
        axe::coordinator::experiments::design_space(3, 8)
    } else {
        vec![(3, 3), (3, 6), (4, 6), (4, 8), (5, 8), (6, 8), (8, 8)]
    };
    // Naive bit-width manipulation bottoms out at P* = 14-15 here (Eq. 3
    // at K = 224-256 with M = N = 3), so the discriminating regime — the
    // paper's Fig. 1 left side — is P below that floor.
    let p_values: Vec<u32> = if full {
        (9..=20).collect()
    } else {
        vec![9, 10, 11, 12, 13, 14, 16, 20]
    };

    // ---- LM track (Fig. 1/3 bottom; Tables 5/7)
    let lm = match load_named("pico-160k") {
        Ok(Model::Lm(m)) => m,
        _ => {
            eprintln!("[pareto_frontier] artifacts missing; using a random pico model");
            random_transformer(
                axe::model::TransformerConfig {
                    name: "pico-rand".into(),
                    vocab: 64,
                    d_model: 56,
                    n_layers: 4,
                    n_heads: 7,
                    d_ff: 224,
                    max_seq: 64,
                    act: Activation::Gelu,
                    parallel_residual: true,
                },
                1,
            )
        }
    };
    let seq = lm.cfg.max_seq;
    let train = load_corpus_split_or_synth("train", lm.cfg.vocab);
    let val = load_corpus_split_or_synth("val", lm.cfg.vocab);
    let calib: Vec<&[u16]> = train.chunks_exact(seq).take(10).collect();

    for algo in [Algorithm::Gpfq, Algorithm::Optq] {
        for (method, label) in axe::coordinator::experiments::methods() {
            let t0 = std::time::Instant::now();
            let mut points = Vec::new();
            for &(m, n) in &grid {
                if method == Method::Naive {
                    let cfg = PipelineConfig::new(algo, method, m, n);
                    points.push(run_lm_config(&lm, &calib, &val, seq, 16, &cfg)?);
                } else {
                    for &p in &p_values {
                        let mut cfg = PipelineConfig::new(algo, method, m, n);
                        cfg.target = AccumTarget::Monolithic { p_bits: p };
                        points.push(run_lm_config(&lm, &calib, &val, seq, 16, &cfg)?);
                    }
                }
            }
            let f = pareto_frontier(&points, MetricKind::Perplexity);
            println!(
                "{}\n({} configs in {:.1}s)\n",
                render_frontier(
                    &format!("LM {} + {label}", algo.name()),
                    MetricKind::Perplexity,
                    &f
                ),
                points.len(),
                t0.elapsed().as_secs_f64()
            );
        }
    }

    // ---- image track (Fig. 1/3 top; Tables 4/6)
    let img = match load_named("glyph-mlp") {
        Ok(Model::Img(m)) => m,
        _ => random_mlp(
            axe::model::MlpConfig {
                name: "glyph-rand".into(),
                input_dim: 256,
                hidden: vec![128, 128],
                classes: 10,
                act: Activation::Relu,
                residual: false,
            },
            2,
        ),
    };
    let gtrain = load_glyphs("train").unwrap_or_else(|_| synth_glyphs(1000, 16, 10, 1));
    let gtest = load_glyphs("test").unwrap_or_else(|_| synth_glyphs(400, 16, 10, 2));
    let gcalib: Vec<&[f32]> = (0..128.min(gtrain.len())).map(|i| gtrain.row(i)).collect();
    for algo in [Algorithm::Gpfq, Algorithm::Optq] {
        for (method, label) in axe::coordinator::experiments::methods() {
            let mut points = Vec::new();
            for &(m, n) in &grid {
                if method == Method::Naive {
                    let cfg = PipelineConfig::new(algo, method, m, n);
                    points.push(run_img_config(&img, &gcalib, &gtest, &cfg)?);
                } else {
                    for &p in &p_values {
                        let mut cfg = PipelineConfig::new(algo, method, m, n);
                        cfg.target = AccumTarget::Monolithic { p_bits: p };
                        points.push(run_img_config(&img, &gcalib, &gtest, &cfg)?);
                    }
                }
            }
            let f = pareto_frontier(&points, MetricKind::Accuracy);
            println!(
                "{}\n",
                render_frontier(
                    &format!("IMG {} + {label}", algo.name()),
                    MetricKind::Accuracy,
                    &f
                )
            );
        }
    }
    Ok(())
}
