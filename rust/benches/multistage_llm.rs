//! Bench: regenerate the paper's Table 1 — multi-stage accumulation on
//! the LM ladder (W4A8, 16-bit inner accumulators, T ∈ {64, 128}),
//! for both the memory-efficient GPFQ* and OPTQ, against the
//! unconstrained base and the float model.
//!
//! AXE_BENCH_FULL=1 includes the larger ladder rungs.

use axe::coordinator::experiments::run_lm_config;
use axe::coordinator::PipelineConfig;
use axe::eval::{load_corpus_split_or_synth, perplexity};
use axe::model::{load_named, Model};
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::util::Table;

fn main() -> anyhow::Result<()> {
    let full = std::env::var("AXE_BENCH_FULL").is_ok();
    let models: Vec<&str> = if full {
        vec!["pico-70k", "pico-160k", "pico-410k", "pico-1m", "pico-2m"]
    } else {
        vec!["pico-70k", "pico-160k", "pico-410k"]
    };
    // (tile, P_I) grid: the paper's 64x16b/128x16b (free at our widths,
    // like their 64x16b at Pythia widths) plus the binding 14-bit tier
    // that exposes the tile-size trade at this zoo's K.
    let configs: [(usize, u32); 4] = [(64, 16), (128, 16), (64, 14), (128, 14)];

    for algo in [Algorithm::GpfqMemEff, Algorithm::Optq] {
        println!("\n### Table 1 analog — {} (W4A8)\n", algo.name());
        let mut table = Table::new(&[
            "model", "params", "K_max", "float", "base", "64x16b", "128x16b", "64x14b", "128x14b",
        ]);
        for name in &models {
            let Ok(Model::Lm(base)) = load_named(name) else {
                eprintln!("[multistage_llm] {name} missing — run `make artifacts`");
                continue;
            };
            let k_max = base.cfg.d_ff;
            let seq = base.cfg.max_seq;
            let train = load_corpus_split_or_synth("train", base.cfg.vocab);
            let val = load_corpus_split_or_synth("val", base.cfg.vocab);
            let calib: Vec<&[u16]> = train.chunks_exact(seq).take(10).collect();
            let float_ppl = perplexity(&base, &val, seq, 16).ppl;
            let base_cfg = PipelineConfig::new(algo, Method::Naive, 4, 8);
            let t0 = std::time::Instant::now();
            let base_pt = run_lm_config(&base, &calib, &val, seq, 16, &base_cfg)?;
            let mut row = vec![
                name.to_string(),
                format!("{}", base.cfg.param_count()),
                format!("{k_max}"),
                format!("{float_ppl:.1}"),
                format!("{:.1}", base_pt.metric),
            ];
            for &(t, p_inner) in &configs {
                let mut cfg = PipelineConfig::new(algo, Method::Axe, 4, 8);
                cfg.target = AccumTarget::MultiStage { p_inner, tile: t };
                let pt = run_lm_config(&base, &calib, &val, seq, 16, &cfg)?;
                row.push(format!("{:.1}", pt.metric));
            }
            table.row(&row);
            eprintln!("  [{name}] done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        println!("{}", table.render());
    }
    println!(
        "Expected shape: constrained columns approach `base` as width grows\n\
         (T fixed while K grows — the A2Q scaling hypothesis, paper §4.2)."
    );
    Ok(())
}
