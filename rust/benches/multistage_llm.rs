//! Bench: regenerate the paper's Table 1 — multi-stage accumulation on
//! the LM ladder (W4A8, 16-bit inner accumulators, T ∈ {64, 128}),
//! for both the memory-efficient GPFQ* and OPTQ, against the
//! unconstrained base and the float model — plus an end-to-end timing of
//! the faithful (fused-kernel) integer datapath and the decode-
//! throughput trajectory (sequential vs continuous batching, f32 vs
//! quantized KV).
//!
//! Runs against the trained zoo when `make artifacts` has been built;
//! otherwise falls back to one synthetic pico model so the bench always
//! produces numbers. AXE_BENCH_FULL=1 includes the larger ladder rungs.
//!
//! `--quick` (the CI mode) skips the Table 1 PTQ sweep, always runs on
//! the synthetic model, and — like every run — writes machine-readable
//! results to `BENCH_decode.json` (override with AXE_BENCH_OUT):
//! tokens/s per (kv backend, in-flight) configuration, the sequential
//! baseline, the telemetry ring's step-latency/occupancy histograms
//! per configuration (`"step_histograms"`) with a same-run
//! telemetry-off vs on+JSONL-sink cost probe (`"telemetry_overhead"`),
//! and an in-run before/after of the attention hot loop
//! (`attend_one_query_quant_ref`, the PR 3 per-element-gather +
//! per-call-alloc implementation, vs the scratch/bulk-gather fast
//! path), and a self-speculative decoding probe (`"speculative"`):
//! tokens/s and accept rate vs draft depth k × draft accumulator
//! width on the int8 KV backend, bit-exactness vs the k = 1 run
//! asserted in-run. If `BENCH_decode.baseline.json` exists (override with
//! AXE_BENCH_BASELINE), its content is embedded verbatim under
//! `"baseline"` so the perf trajectory can be tracked across PRs; CI
//! uploads the JSON as an artifact on every run.

use axe::bench_support::time_once;
use axe::coordinator::experiments::run_lm_config;
use axe::coordinator::serve::{
    serve_config, serve_telemetry, Request, ServeConfig, ServeQueue, ServeStats, StepEngine,
};
use axe::coordinator::telemetry::{MetricsSummary, SinkSpec, DEFAULT_FLUSH_EVERY};
use axe::coordinator::{quantize_transformer, DatapathMode, PipelineConfig};
use axe::eval::{load_corpus_split_or_synth, perplexity};
use axe::model::{
    attend_one_query_quant, attend_one_query_quant_ref, load_named, random_transformer,
    Activation, AttnScratch, KvArena, KvCacheKind, KvQuantSpec, Model, PageMap, Transformer,
    TransformerConfig,
};
use axe::model::kvquant::QuantKv;
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::util::rng::Rng;
use axe::util::Table;

fn synth_model() -> (String, Transformer) {
    let cfg = TransformerConfig {
        name: "pico-synth".into(),
        vocab: 64,
        d_model: 56,
        n_layers: 4,
        n_heads: 7,
        d_ff: 224,
        max_seq: 64,
        act: Activation::Gelu,
        parallel_residual: true,
    };
    ("pico-synth".to_string(), random_transformer(cfg, 1))
}

/// The trained zoo, or one synthetic stand-in model when artifacts are
/// absent (keeps the bench runnable on a fresh checkout).
fn zoo_or_synth(names: &[&str]) -> Vec<(String, Transformer)> {
    let mut out = Vec::new();
    for name in names {
        match load_named(name) {
            Ok(Model::Lm(m)) => out.push((name.to_string(), m)),
            _ => eprintln!("[multistage_llm] {name} missing — run `make artifacts`"),
        }
    }
    if out.is_empty() {
        eprintln!(
            "[multistage_llm] zoo missing — benching a synthetic pico model \
             (run `make artifacts` for the real ladder)"
        );
        out.push(synth_model());
    }
    out
}

/// One measured decode-throughput configuration (a BENCH_decode.json row).
struct DecodePoint {
    kv: &'static str,
    in_flight: usize,
    tokens_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    overflow_events: u64,
    arena_bytes: usize,
}

/// Per-(kv, in-flight) merged telemetry summary — the step-latency /
/// occupancy histograms behind a [`DecodePoint`] row, read out of the
/// same serve run's telemetry ring.
struct StepHistPoint {
    kv: &'static str,
    in_flight: usize,
    summary: MetricsSummary,
}

/// Same-run cost of the telemetry path: the 16-in-flight config served
/// with telemetry disabled vs recording every step AND streaming JSONL
/// to a sink file (acceptance: < 2% throughput regression).
struct TelemetryOverhead {
    in_flight: usize,
    off_tok_s: f64,
    on_tok_s: f64,
}

impl TelemetryOverhead {
    fn overhead_pct(&self) -> f64 {
        (self.off_tok_s / self.on_tok_s - 1.0) * 100.0
    }
}

/// In-run before/after of the attention hot loop.
struct AttnMicro {
    t_len: usize,
    d: usize,
    heads: usize,
    iters: usize,
    ref_us_per_call: f64,
    scratch_us_per_call: f64,
}

/// One measured chunked-prefill latency configuration: a long prompt
/// admitted against a loaded decode batch at one `--prefill-chunk`
/// setting (`prefill_chunk` 0 = unchunked whole-prompt admission).
struct TtftPoint {
    prefill_chunk: usize,
    /// Submission → first token of the long request.
    ttft_ms: f64,
    /// Longest single scheduler step during its admission — the worst
    /// inter-token stall any co-scheduled decoder experiences
    /// (head-of-line blocking, the number chunking exists to cut).
    max_step_ms: f64,
}

/// TTFT under load: admit a window-length prompt against `decoders`
/// already-decoding sequences and measure, per chunk setting, the long
/// request's time-to-first-token and the worst step stall its
/// admission inflicts on the batch. Token streams are bit-identical
/// across settings (property-tested in tests/chunked_prefill.rs); this
/// probe measures the latency trade only.
struct TtftProbe {
    prompt_len: usize,
    decoders: usize,
    points: Vec<TtftPoint>,
}

/// Shared-prefix serving: one sharing-on/off measurement row.
struct SharedPrefixPoint {
    prefix_cache: bool,
    mean_follower_ttft_ms: f64,
    resident_bytes: usize,
    pages_shared: u64,
    prefill_tokens_skipped: usize,
}

/// N sequences over one system prompt, served with the prefix cache on
/// vs off: follower TTFT (the cache skips the shared pages' prefill)
/// and resident arena bytes with every follower in flight (shared
/// pages are deduplicated). Token streams are bit-identical either way
/// (property-tested in tests/chunked_prefill.rs); this probe measures
/// the latency/memory trade only.
struct SharedPrefixProbe {
    prefix_len: usize,
    n_seqs: usize,
    points: Vec<SharedPrefixPoint>,
}

fn shared_prefix_probe(model: &Transformer, val: &[u16], kind: KvCacheKind) -> SharedPrefixProbe {
    use std::time::Instant;
    let n_seqs = 8usize;
    let prefix_len = model.cfg.max_seq * 3 / 4; // several full 16-token pages
    let system: Vec<u16> = val[..prefix_len].to_vec();
    let reqs: Vec<Request> = (0..n_seqs as u64)
        .map(|id| {
            let mut prompt = system.clone();
            let at = (7 + id as usize * 11) % (val.len() - 4);
            prompt.extend_from_slice(&val[at..at + 3]); // divergent tail
            Request { id, prompt, max_new_tokens: 4, ..Request::default() }
        })
        .collect();
    let mut points = Vec::new();
    for sharing in [true, false] {
        let cfg = ServeConfig::new(n_seqs + 1, kind).with_prefix_cache(sharing);
        let mut eng = StepEngine::new(model, cfg);
        // leader populates the cache, then retires
        eng.admit(
            Request {
                id: 999,
                prompt: reqs[0].prompt.clone(),
                max_new_tokens: 2,
                ..Request::default()
            },
            Instant::now(),
        );
        while eng.take_finished().is_empty() {
            eng.step();
        }
        // all followers in flight at once: cache-hit admissions prefill
        // only the unshared tail
        for r in &reqs {
            eng.admit(r.clone(), Instant::now());
        }
        while eng.prefilling() > 0 {
            eng.step();
        }
        let resident_bytes = eng.arena().bytes();
        let mut done = Vec::new();
        while done.len() < n_seqs {
            eng.step();
            done.extend(eng.take_finished());
        }
        let mean_ttft_s =
            done.iter().map(|r| r.ttft_s).sum::<f64>() / done.len().max(1) as f64;
        points.push(SharedPrefixPoint {
            prefix_cache: sharing,
            mean_follower_ttft_ms: mean_ttft_s * 1e3,
            resident_bytes,
            pages_shared: eng.arena().pages_shared(),
            prefill_tokens_skipped: done.iter().map(|r| r.prefill_tokens_skipped).sum(),
        });
    }
    SharedPrefixProbe { prefix_len, n_seqs, points }
}

/// Banded ragged-attention before/after: one (in-flight, chunk) corner,
/// serial sweep vs band-parallel sweep.
struct RaggedAttnPoint {
    in_flight: usize,
    prefill_chunk: usize,
    serial_tok_s: f64,
    parallel_tok_s: f64,
}

/// The tentpole's measured before/after: the same chunked serving
/// workload with the ragged-attention sweep serial (`attn_threads = 1`,
/// the oracle path) vs band-parallel (`attn_threads = 0` → auto, with
/// the work threshold zeroed so the pico fixture actually fans out),
/// at 4 and 16 in-flight slots × prefill chunk 16 and 64 on the int8
/// KV backend. Token streams are bit-identical across thread counts
/// (property-tested in tests/chunked_prefill.rs); this probe measures
/// the wall-clock trade only.
struct RaggedAttnProbe {
    attn_threads: usize,
    gen_tokens: usize,
    points: Vec<RaggedAttnPoint>,
}

fn ragged_attn_probe(model: &Transformer, val: &[u16], kind: KvCacheKind) -> RaggedAttnProbe {
    use std::time::Instant;
    let seq = model.cfg.max_seq;
    let gen_tokens = 24usize;
    let mut points = Vec::new();
    for &in_flight in &[4usize, 16] {
        for &chunk in &[16usize, 64] {
            let run = |attn_threads: usize, par_min: usize| -> f64 {
                let n_req = in_flight * 2; // one slot-reuse wave
                let reqs: Vec<Request> = (0..n_req as u64)
                    .map(|id| {
                        let at = (id as usize * 13) % (val.len() - seq);
                        Request {
                            id,
                            prompt: val[at..at + seq / 2].to_vec(),
                            max_new_tokens: gen_tokens,
                            ..Request::default()
                        }
                    })
                    .collect();
                let cfg = ServeConfig::new(in_flight, kind)
                    .with_prefill_chunk(chunk)
                    .with_attn_threads(attn_threads)
                    .with_attn_par_min_work(par_min);
                let mut eng = StepEngine::new(model, cfg);
                let mut next = 0usize;
                let mut tokens = 0usize;
                let t0 = Instant::now();
                loop {
                    while next < reqs.len() && eng.free_slots() > 0 {
                        eng.admit(reqs[next].clone(), Instant::now());
                        next += 1;
                    }
                    eng.step();
                    for r in eng.take_finished() {
                        tokens += r.tokens.len();
                    }
                    if next == reqs.len() && !eng.has_work() {
                        break;
                    }
                }
                tokens as f64 / t0.elapsed().as_secs_f64()
            };
            points.push(RaggedAttnPoint {
                in_flight,
                prefill_chunk: chunk,
                serial_tok_s: run(1, usize::MAX),
                parallel_tok_s: run(0, 0),
            });
        }
    }
    RaggedAttnProbe { attn_threads: axe::linalg::num_threads(), gen_tokens, points }
}

/// Self-speculative decoding: one (draft depth, draft width) row.
struct SpeculativePoint {
    k: usize,
    /// Draft inner-register width in bits; 0 = full width (exact draft).
    draft_bits: u32,
    tokens_per_s: f64,
    accept_rate: f64,
    proposed: u64,
    accepted: u64,
    draft_rows: u64,
}

/// Tokens/s and acceptance vs draft depth × draft accumulator width on
/// the int8 KV backend, against the non-speculative (k = 1) run of the
/// same workload. Token streams are bit-identical at every setting
/// (asserted in-run; property-tested in tests/speculative.rs) — the
/// probe measures the draft-work-vs-accepted-tokens trade only.
struct SpeculativeProbe {
    in_flight: usize,
    baseline_tok_s: f64,
    points: Vec<SpeculativePoint>,
}

fn speculative_probe(
    model: &Transformer,
    make_requests: &dyn Fn() -> Vec<Request>,
    kind: KvCacheKind,
) -> SpeculativeProbe {
    let in_flight = 16usize;
    type Served = Vec<axe::coordinator::serve::Response>;
    let run = |k: usize, bits: Option<u32>| -> (f64, MetricsSummary, Served) {
        let queue = ServeQueue::new();
        for r in make_requests() {
            queue.submit(r).expect("unbounded queue accepts every submit");
        }
        queue.close();
        let t0 = std::time::Instant::now();
        let engines = serve_config(
            model,
            &queue,
            1,
            ServeConfig::new(in_flight, kind).with_speculate(k, bits),
        );
        let responses = queue.drain();
        let tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let tok_s = tokens as f64 / t0.elapsed().as_secs_f64();
        (tok_s, engines[0].telemetry.expect("telemetry on by default"), responses)
    };
    let (baseline_tok_s, _, want) = run(1, None);
    let mut points = Vec::new();
    for &k in &[2usize, 4, 8] {
        for &bits in &[0u32, 8] {
            let (tok_s, t, resp) = run(k, if bits == 0 { None } else { Some(bits) });
            for (a, b) in resp.iter().zip(want.iter()) {
                assert_eq!(
                    a.tokens, b.tokens,
                    "speculative serving must stay bit-exact (k {k}, draft bits {bits})"
                );
            }
            points.push(SpeculativePoint {
                k,
                draft_bits: bits,
                tokens_per_s: tok_s,
                accept_rate: t.spec_accepted as f64 / t.spec_proposed.max(1) as f64,
                proposed: t.spec_proposed,
                accepted: t.spec_accepted,
                draft_rows: t.draft_rows,
            });
        }
    }
    SpeculativeProbe { in_flight, baseline_tok_s, points }
}

fn ttft_probe(model: &Transformer, val: &[u16]) -> TtftProbe {
    use std::time::Instant;
    let seq = model.cfg.max_seq;
    let decoders = 15usize;
    let prompt_len = seq - 1; // the longest servable prompt
    let long_prompt: Vec<u16> = val[..prompt_len].to_vec();
    let mut points = Vec::new();
    for &chunk in &[0usize, 64, 16, 8] {
        let cfg = ServeConfig::new(decoders + 1, KvCacheKind::F32)
            .with_prefill_chunk(if chunk == 0 { usize::MAX } else { chunk });
        let mut eng = StepEngine::new(model, cfg);
        for id in 0..decoders as u64 {
            let at = (id as usize * 7) % (val.len() - 4);
            // effectively endless decoders: the probe ends when the
            // long request finishes
            eng.admit(
                Request {
                    id,
                    prompt: val[at..at + 4].to_vec(),
                    max_new_tokens: 1 << 20,
                    ..Request::default()
                },
                Instant::now(),
            );
        }
        while eng.prefilling() > 0 {
            eng.step();
        }
        for _ in 0..3 {
            eng.step(); // a few hot steady-state steps
        }
        let t0 = Instant::now();
        eng.admit(
            Request {
                id: 999,
                prompt: long_prompt.clone(),
                max_new_tokens: 2,
                ..Request::default()
            },
            t0,
        );
        let mut max_step_ms = 0f64;
        let ttft_ms = loop {
            let s0 = Instant::now();
            eng.step();
            max_step_ms = max_step_ms.max(s0.elapsed().as_secs_f64() * 1e3);
            if let Some(r) = eng.take_finished().into_iter().find(|r| r.id == 999) {
                break r.ttft_s * 1e3;
            }
        };
        points.push(TtftPoint { prefill_chunk: chunk, ttft_ms, max_step_ms });
    }
    TtftProbe { prompt_len, decoders, points }
}

/// Serve the same workload twice on one engine thread — telemetry
/// disabled vs telemetry on with a JSONL sink streaming every step
/// record to a temp file — and report both throughputs. Run in this
/// order (off first) so the on-run sees the warmer caches: any bias
/// favors finding overhead, not hiding it.
fn telemetry_overhead_probe(
    model: &Transformer,
    reqs: &[Request],
    kind: KvCacheKind,
    in_flight: usize,
) -> TelemetryOverhead {
    let sink_path = std::env::temp_dir().join("axe_bench_overhead_metrics.jsonl");
    let run = |spec: &SinkSpec| -> f64 {
        let queue = ServeQueue::new();
        for r in reqs {
            queue.submit(r.clone()).expect("unbounded queue accepts every submit");
        }
        queue.close();
        let cfg = ServeConfig::new(in_flight, kind).with_telemetry(*spec != SinkSpec::None);
        let t0 = std::time::Instant::now();
        serve_telemetry(model, &queue, 1, cfg, spec, DEFAULT_FLUSH_EVERY)
            .expect("jsonl sink in temp dir must be constructible");
        let tokens: usize = queue.drain().iter().map(|r| r.tokens.len()).sum();
        tokens as f64 / t0.elapsed().as_secs_f64()
    };
    let off_tok_s = run(&SinkSpec::None);
    let on_tok_s = run(&SinkSpec::Jsonl(sink_path.clone()));
    let _ = std::fs::remove_file(&sink_path);
    TelemetryOverhead { in_flight, off_tok_s, on_tok_s }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let full = std::env::var("AXE_BENCH_FULL").is_ok();

    let zoo = if quick {
        eprintln!("[multistage_llm] --quick: decode trajectory only, synthetic model");
        vec![synth_model()]
    } else {
        let model_names: Vec<&str> = if full {
            vec!["pico-70k", "pico-160k", "pico-410k", "pico-1m", "pico-2m"]
        } else {
            vec!["pico-70k", "pico-160k", "pico-410k"]
        };
        zoo_or_synth(&model_names)
    };

    if !quick {
        // (tile, P_I) grid: the paper's 64x16b/128x16b (free at our
        // widths, like their 64x16b at Pythia widths) plus the binding
        // 14-bit tier that exposes the tile-size trade at this zoo's K.
        let configs: [(usize, u32); 4] = [(64, 16), (128, 16), (64, 14), (128, 14)];
        for algo in [Algorithm::GpfqMemEff, Algorithm::Optq] {
            println!("\n### Table 1 analog — {} (W4A8)\n", algo.name());
            let mut table = Table::new(&[
                "model", "params", "K_max", "float", "base", "64x16b", "128x16b", "64x14b",
                "128x14b",
            ]);
            for (name, base) in &zoo {
                let k_max = base.cfg.d_ff;
                let seq = base.cfg.max_seq;
                let train = load_corpus_split_or_synth("train", base.cfg.vocab);
                let val = load_corpus_split_or_synth("val", base.cfg.vocab);
                let calib: Vec<&[u16]> = train.chunks_exact(seq).take(10).collect();
                let float_ppl = perplexity(base, &val, seq, 16).ppl;
                let base_cfg = PipelineConfig::new(algo, Method::Naive, 4, 8);
                let t0 = std::time::Instant::now();
                let base_pt = run_lm_config(base, &calib, &val, seq, 16, &base_cfg)?;
                let mut row = vec![
                    name.to_string(),
                    format!("{}", base.cfg.param_count()),
                    format!("{k_max}"),
                    format!("{float_ppl:.1}"),
                    format!("{:.1}", base_pt.metric),
                ];
                for &(t, p_inner) in &configs {
                    let mut cfg = PipelineConfig::new(algo, Method::Axe, 4, 8);
                    cfg.target = AccumTarget::MultiStage { p_inner, tile: t };
                    let pt = run_lm_config(base, &calib, &val, seq, 16, &cfg)?;
                    row.push(format!("{:.1}", pt.metric));
                }
                table.row(&row);
                eprintln!("  [{name}] done in {:.1}s", t0.elapsed().as_secs_f64());
            }
            println!("{}", table.render());
        }
    }

    // ---- quantize the timing model: DatapathMode::Faithful executes
    // on the fused qgemm kernel (bit-for-bit equal to the scalar
    // simulator, which remains the audit oracle).
    let (name, base) = &zoo[0];
    let seq = base.cfg.max_seq;
    let train = load_corpus_split_or_synth("train", base.cfg.vocab);
    let val = load_corpus_split_or_synth("val", base.cfg.vocab);
    let calib_n = if quick { 4 } else { 8 };
    let calib: Vec<&[u16]> = train.chunks_exact(seq).take(calib_n).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 16, tile: 64 };
    cfg.datapath = DatapathMode::Faithful;
    let mut qmodel = base.clone();
    quantize_transformer(&mut qmodel, &calib, &cfg)?;

    if !quick {
        let (report, secs) = time_once(|| perplexity(&qmodel, &val, seq, 16));
        println!(
            "\nfaithful-datapath eval on {name} (fused 64x16b kernel): \
             {:.0} tok/s, PPL {:.1}, overflow events {}",
            report.tokens as f64 / secs,
            report.ppl,
            report.overflows
        );
    }

    // ---- decode throughput: per-request sequential decode vs the
    // continuous-batching step scheduler. Each serve run uses ONE
    // engine thread; what scales is the number of in-flight slots the
    // scheduler stacks into every decode step / fused qgemm call.
    let n_requests = 16usize;
    let gen_tokens = 32usize;
    let make_requests = || -> Vec<Request> {
        (0..n_requests as u64)
            .map(|id| {
                let start = (id as usize * 31) % (val.len() - seq);
                Request {
                    id,
                    prompt: val[start..start + seq / 2].to_vec(),
                    max_new_tokens: gen_tokens,
                    ..Request::default()
                }
            })
            .collect()
    };
    let mut points: Vec<DecodePoint> = Vec::new();
    let mut hist_points: Vec<StepHistPoint> = Vec::new();

    // sequential baseline: one request at a time through the KV cache
    let reqs = make_requests();
    let (seq_out, seq_s) = time_once(|| {
        reqs.iter()
            .map(|r| qmodel.generate_greedy(&r.prompt, r.max_new_tokens))
            .collect::<Vec<_>>()
    });
    let sequential_tok_s = (n_requests * gen_tokens) as f64 / seq_s;
    println!(
        "\ndecode throughput on {name} ({} reqs × {} tokens, W4A8 64x16b faithful):",
        n_requests, gen_tokens
    );
    println!("  per-request sequential : {sequential_tok_s:>7.1} tok/s");

    for max_batch in [1usize, 4, 16] {
        let queue = ServeQueue::new();
        for r in make_requests() {
            queue.submit(r).expect("unbounded queue accepts every submit");
        }
        queue.close();
        let t0 = std::time::Instant::now();
        let engines =
            serve_config(&qmodel, &queue, 1, ServeConfig::new(max_batch, KvCacheKind::F32));
        let responses = queue.drain();
        let mut stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
        stats.fill_telemetry(&engines);
        println!(
            "  continuous batch @ {max_batch:>2}  : {:>7.1} tok/s  \
             (p50 {:>6.1} ms, p99 {:>6.1} ms, overflow {})",
            stats.tokens_per_s,
            stats.p50_latency_s * 1e3,
            stats.p99_latency_s * 1e3,
            stats.overflow_events
        );
        // batched serving stays token-exact vs the sequential baseline
        for (resp, want) in responses.iter().zip(seq_out.iter()) {
            assert_eq!(
                resp.tokens[..],
                want[want.len() - gen_tokens..],
                "batched decode must be token-exact"
            );
        }
        points.push(DecodePoint {
            kv: "f32",
            in_flight: max_batch,
            tokens_per_s: stats.tokens_per_s,
            p50_ms: stats.p50_latency_s * 1e3,
            p99_ms: stats.p99_latency_s * 1e3,
            overflow_events: stats.overflow_events,
            arena_bytes: KvArena::footprint(&qmodel.cfg, max_batch, KvCacheKind::F32),
        });
        if let Some(t) = stats.telemetry {
            hist_points.push(StepHistPoint { kv: "f32", in_flight: max_batch, summary: t });
        }
    }

    // ---- quantized-KV decode throughput: same scheduler, but the
    // arena stores i8 codes + per-(slot, position, head) bf16 scales
    // and the attention score/value matmuls run on the multi-stage
    // integer datapath. Token-exact vs sequential decode on the SAME
    // backend (vs the f32 arena it trades bounded divergence for ~4x
    // memory).
    let kv_kind = KvCacheKind::Quant(KvQuantSpec::int8());
    let f32_bytes = KvArena::footprint(&qmodel.cfg, 16, KvCacheKind::F32);
    let q_bytes = KvArena::footprint(&qmodel.cfg, 16, kv_kind);
    println!(
        "\nquantized-KV decode throughput (i8 arena @16 slots: {} B, {:.1}% of f32 {} B):",
        q_bytes,
        100.0 * q_bytes as f64 / f32_bytes as f64,
        f32_bytes
    );
    let reqs = make_requests();
    let want_q: Vec<Vec<u16>> = reqs
        .iter()
        .map(|r| qmodel.generate_greedy_with(&r.prompt, r.max_new_tokens, kv_kind))
        .collect();
    for max_batch in [1usize, 4, 16] {
        let queue = ServeQueue::new();
        for r in make_requests() {
            queue.submit(r).expect("unbounded queue accepts every submit");
        }
        queue.close();
        let t0 = std::time::Instant::now();
        let engines = serve_config(&qmodel, &queue, 1, ServeConfig::new(max_batch, kv_kind));
        let responses = queue.drain();
        let mut stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
        stats.fill_telemetry(&engines);
        stats.arena_bytes = KvArena::footprint(&qmodel.cfg, max_batch, kv_kind);
        println!(
            "  quant-kv batch @ {max_batch:>2}    : {:>7.1} tok/s  \
             (p50 {:>6.1} ms, p99 {:>6.1} ms, overflow {}, arena {} B)",
            stats.tokens_per_s,
            stats.p50_latency_s * 1e3,
            stats.p99_latency_s * 1e3,
            stats.overflow_events,
            stats.arena_bytes
        );
        for (resp, want) in responses.iter().zip(want_q.iter()) {
            assert_eq!(
                resp.tokens[..],
                want[want.len() - gen_tokens..],
                "quant-KV batched decode must be token-exact vs quant-KV sequential"
            );
        }
        points.push(DecodePoint {
            kv: "int8",
            in_flight: max_batch,
            tokens_per_s: stats.tokens_per_s,
            p50_ms: stats.p50_latency_s * 1e3,
            p99_ms: stats.p99_latency_s * 1e3,
            overflow_events: stats.overflow_events,
            arena_bytes: stats.arena_bytes,
        });
        if let Some(t) = stats.telemetry {
            hist_points.push(StepHistPoint { kv: "int8", in_flight: max_batch, summary: t });
        }
    }

    // ---- step histograms + telemetry cost: the telemetry ring's view
    // of the serve runs above (merged per config), then the same int8
    // @16 workload served telemetry-off vs telemetry-on-with-JSONL-sink
    // to price the observability path itself.
    println!("\nstep histograms from the telemetry ring (per serve config):");
    for h in &hist_points {
        let t = &h.summary;
        println!(
            "  {:>4} @ {:>2} : step p50 {:>7.3} ms p99 {:>7.3} ms, occupancy p50 {:>2} \
             max {:>2}, {} steps ({} dropped)",
            h.kv,
            h.in_flight,
            t.step_ns.quantile(0.50) as f64 / 1e6,
            t.step_ns.quantile(0.99) as f64 / 1e6,
            t.occupancy.quantile(0.50),
            t.occupancy.max_value(),
            t.steps,
            t.records_dropped
        );
    }
    let overhead = telemetry_overhead_probe(&qmodel, &make_requests(), kv_kind, 16);
    println!(
        "telemetry overhead (int8 @ {} in-flight): off {:.1} tok/s, on+jsonl {:.1} tok/s \
         ({:+.2}% cost; acceptance < 2%)",
        overhead.in_flight,
        overhead.off_tok_s,
        overhead.on_tok_s,
        overhead.overhead_pct()
    );

    // ---- attention hot-loop micro: the PR 3 reference (per-element
    // gathers + per-call allocations) vs the scratch/bulk-gather fast
    // path, identical arithmetic (asserted) — the tentpole's measured
    // before/after inside one run.
    let attn = attention_micro(&qmodel.cfg, if quick { 400 } else { 1500 });
    println!(
        "\nattention hot loop (t_len {}, d {}, {} heads, {} iters):\n  \
         ref (PR 3 gathers+allocs): {:>7.2} µs/call\n  \
         scratch + bulk gathers   : {:>7.2} µs/call  ({:.2}x)",
        attn.t_len,
        attn.d,
        attn.heads,
        attn.iters,
        attn.ref_us_per_call,
        attn.scratch_us_per_call,
        attn.ref_us_per_call / attn.scratch_us_per_call
    );

    // ---- chunked-prefill TTFT under load: a window-length prompt
    // admitted against 15 in-flight decoders, per --prefill-chunk
    // setting (0 = unchunked). max_step is the worst inter-token stall
    // the admission inflicts on the batch.
    let ttft = ttft_probe(&qmodel, &val);
    println!(
        "\nttft under load ({}-token prompt vs {} in-flight decoders):",
        ttft.prompt_len, ttft.decoders
    );
    for p in &ttft.points {
        let label = if p.prefill_chunk == 0 {
            "unchunked".to_string()
        } else {
            format!("chunk {:>3}", p.prefill_chunk)
        };
        println!(
            "  {label:>10} : ttft {:>7.2} ms, worst co-batch stall {:>7.2} ms/step",
            p.ttft_ms, p.max_step_ms
        );
    }

    // ---- shared-prefix serving: N sequences over one system prompt,
    // prefix cache on vs off — follower TTFT and resident arena bytes
    // (deduplicated shared pages) are the win; tokens are bit-identical
    // either way.
    let shared = shared_prefix_probe(&qmodel, &val, kv_kind);
    println!(
        "\nshared-prefix serving ({}-token system prompt, {} sequences, int8 KV):",
        shared.prefix_len, shared.n_seqs
    );
    for p in &shared.points {
        println!(
            "  prefix cache {:>3} : mean follower ttft {:>7.2} ms, resident {:>9} B, \
             {} pages shared, {} prefill tokens skipped",
            if p.prefix_cache { "on" } else { "off" },
            p.mean_follower_ttft_ms,
            p.resident_bytes,
            p.pages_shared,
            p.prefill_tokens_skipped
        );
    }

    // ---- banded ragged-attention before/after: serial sweep vs the
    // band-parallel sweep (threshold zeroed so this pico model fans
    // out) across batch-size × chunk corners. Tokens are bit-identical
    // across thread counts; only wall clock moves.
    let ragged = ragged_attn_probe(&qmodel, &val, kv_kind);
    println!(
        "\nragged-attention banding ({} attn threads, {} gen tokens/req, int8 KV):",
        ragged.attn_threads, ragged.gen_tokens
    );
    for p in &ragged.points {
        println!(
            "  in-flight {:>2}, chunk {:>2} : serial {:>7.1} tok/s, banded {:>7.1} tok/s  \
             ({:.2}x)",
            p.in_flight,
            p.prefill_chunk,
            p.serial_tok_s,
            p.parallel_tok_s,
            p.parallel_tok_s / p.serial_tok_s
        );
    }

    // ---- self-speculative decoding: draft k tokens on a narrowed
    // accumulator, verify in one full-width ragged step. Tokens are
    // bit-identical to k = 1 at every setting (asserted in-run); the
    // probe prices the draft-work-vs-accepted-tokens trade.
    let spec = speculative_probe(&qmodel, &make_requests, kv_kind);
    println!(
        "\nself-speculative decoding (int8 KV @ {} in-flight, non-speculative {:.1} tok/s):",
        spec.in_flight, spec.baseline_tok_s
    );
    for p in &spec.points {
        let width = if p.draft_bits == 0 {
            "full".to_string()
        } else {
            format!("{:>2}b", p.draft_bits)
        };
        println!(
            "  k {:>2}, draft {:>4} : {:>7.1} tok/s ({:.2}x), accepted {}/{} ({:.0}%), \
             {} draft rows",
            p.k,
            width,
            p.tokens_per_s,
            p.tokens_per_s / spec.baseline_tok_s,
            p.accepted,
            p.proposed,
            100.0 * p.accept_rate,
            p.draft_rows
        );
    }

    // ---- machine-readable results (CI uploads this as an artifact).
    // Default paths anchor at the workspace root (one level above this
    // package's manifest), independent of the bench's CWD.
    let out_path = std::env::var("AXE_BENCH_OUT").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.json").to_string()
    });
    let baseline_path = std::env::var("AXE_BENCH_BASELINE").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.baseline.json").to_string()
    });
    let json = render_json(
        name,
        quick,
        n_requests,
        gen_tokens,
        sequential_tok_s,
        &points,
        &hist_points,
        &overhead,
        &attn,
        &ttft,
        &shared,
        &ragged,
        &spec,
        &baseline_path,
    );
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {out_path} ({} bytes)", json.len());

    if !quick {
        println!(
            "\nExpected shape: constrained columns approach `base` as width grows\n\
             (T fixed while K grows — the A2Q scaling hypothesis, paper §4.2);\n\
             continuous-batch decode throughput grows with in-flight slots,\n\
             and the i8 KV arena roughly quarters serving memory."
        );
    }
    Ok(())
}

/// Time `attend_one_query_quant_ref` vs the scratch fast path over one
/// quantized KV fixture, asserting bit-identical outputs first.
fn attention_micro(cfg: &TransformerConfig, iters: usize) -> AttnMicro {
    let (d, heads) = (cfg.d_model, cfg.n_heads);
    let t_len = (cfg.max_seq * 3 / 4).max(1);
    let spec = KvQuantSpec::int8();
    let mut rng = Rng::new(42);
    // one t_len-sized page so the micro times the same contiguous
    // gathers as before the paged-arena refactor
    let mut kv = QuantKv::new(spec, 1, 1, t_len, d, heads);
    let table = [0u32];
    let map = PageMap::new(&table, 0, t_len);
    for pos in 0..t_len {
        let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        kv.append_row(0, &map, pos, &k, &v);
    }
    let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let view = kv.slot_view(0, map);
    let mut scratch = AttnScratch::new();
    let mut out_ref = vec![0.0f32; d];
    let mut out_fast = vec![0.0f32; d];
    let ovf_r = attend_one_query_quant_ref(&q, &view, t_len, d, heads, &spec, &mut out_ref);
    let ovf_f =
        attend_one_query_quant(&q, &view, t_len, d, heads, &spec, &mut scratch, &mut out_fast);
    assert_eq!(out_ref, out_fast, "ref and fast attention paths must be bit-identical");
    assert_eq!(ovf_r, ovf_f, "ref and fast overflow counts must agree");

    let (_, ref_s) = time_once(|| {
        for _ in 0..iters {
            std::hint::black_box(attend_one_query_quant_ref(
                &q, &view, t_len, d, heads, &spec, &mut out_ref,
            ));
        }
    });
    let (_, fast_s) = time_once(|| {
        for _ in 0..iters {
            std::hint::black_box(attend_one_query_quant(
                &q,
                &view,
                t_len,
                d,
                heads,
                &spec,
                &mut scratch,
                &mut out_fast,
            ));
        }
    });
    AttnMicro {
        t_len,
        d,
        heads,
        iters,
        ref_us_per_call: ref_s * 1e6 / iters as f64,
        scratch_us_per_call: fast_s * 1e6 / iters as f64,
    }
}

/// Hand-rolled JSON (no serde offline). `baseline` embeds the previous
/// snapshot verbatim when the file exists and looks like JSON.
#[allow(clippy::too_many_arguments)]
fn render_json(
    model: &str,
    quick: bool,
    n_requests: usize,
    gen_tokens: usize,
    sequential_tok_s: f64,
    points: &[DecodePoint],
    hist: &[StepHistPoint],
    overhead: &TelemetryOverhead,
    attn: &AttnMicro,
    ttft: &TtftProbe,
    shared: &SharedPrefixProbe,
    ragged: &RaggedAttnProbe,
    spec: &SpeculativeProbe,
    baseline_path: &str,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"axe-bench-decode/v1\",\n");
    s.push_str(&format!("  \"model\": \"{model}\",\n"));
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str(&format!("  \"n_requests\": {n_requests},\n"));
    s.push_str(&format!("  \"gen_tokens\": {gen_tokens},\n"));
    s.push_str(&format!("  \"sequential_tok_s\": {sequential_tok_s:.1},\n"));
    s.push_str("  \"configs\": [\n");
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"kv\": \"{}\", \"in_flight\": {}, \"tokens_per_s\": {:.1}, \
             \"p50_ms\": {:.2}, \"p99_ms\": {:.2}, \"overflow_events\": {}, \
             \"arena_bytes\": {}}}{}\n",
            p.kv,
            p.in_flight,
            p.tokens_per_s,
            p.p50_ms,
            p.p99_ms,
            p.overflow_events,
            p.arena_bytes,
            if i + 1 < points.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    // step_histograms mirrors "configs" row-for-row: the same serve
    // runs seen through the telemetry ring (ns quantiles are log2
    // bucket upper bounds; buckets are the raw step-latency counts).
    s.push_str("  \"step_histograms\": [\n");
    for (i, h) in hist.iter().enumerate() {
        let t = &h.summary;
        let buckets: Vec<String> =
            t.step_ns.bucket_counts().iter().map(|c| c.to_string()).collect();
        s.push_str(&format!(
            "    {{\"kv\": \"{}\", \"in_flight\": {}, \"steps\": {}, \"records_dropped\": {}, \
             \"step_ns_p50\": {}, \"step_ns_p99\": {}, \"ttft_ns_p50\": {}, \
             \"tpot_ns_p50\": {}, \"occupancy_p50\": {}, \"occupancy_max\": {}, \
             \"step_ns_buckets\": [{}]}}{}\n",
            h.kv,
            h.in_flight,
            t.steps,
            t.records_dropped,
            t.step_ns.quantile(0.50),
            t.step_ns.quantile(0.99),
            t.ttft_ns.quantile(0.50),
            t.tpot_ns.quantile(0.50),
            t.occupancy.quantile(0.50),
            t.occupancy.max_value(),
            buckets.join(", "),
            if i + 1 < hist.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"telemetry_overhead\": {{\"kv\": \"int8\", \"in_flight\": {}, \"off_tok_s\": {:.1}, \
         \"on_tok_s\": {:.1}, \"overhead_pct\": {:.2}}},\n",
        overhead.in_flight,
        overhead.off_tok_s,
        overhead.on_tok_s,
        overhead.overhead_pct()
    ));
    s.push_str(&format!(
        "  \"attention_hot_loop\": {{\"t_len\": {}, \"d\": {}, \"heads\": {}, \"iters\": {}, \
         \"ref_us_per_call\": {:.3}, \"scratch_us_per_call\": {:.3}, \"speedup\": {:.2}}},\n",
        attn.t_len,
        attn.d,
        attn.heads,
        attn.iters,
        attn.ref_us_per_call,
        attn.scratch_us_per_call,
        attn.ref_us_per_call / attn.scratch_us_per_call
    ));
    // prefill_chunk 0 = unchunked whole-prompt admission
    s.push_str(&format!(
        "  \"ttft_under_load\": {{\"prompt_len\": {}, \"decoders\": {}, \"configs\": [\n",
        ttft.prompt_len, ttft.decoders
    ));
    for (i, p) in ttft.points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"prefill_chunk\": {}, \"ttft_ms\": {:.3}, \"max_step_ms\": {:.3}}}{}\n",
            p.prefill_chunk,
            p.ttft_ms,
            p.max_step_ms,
            if i + 1 < ttft.points.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]},\n");
    s.push_str(&format!(
        "  \"shared_prefix\": {{\"prefix_len\": {}, \"n_seqs\": {}, \"kv\": \"int8\", \
         \"configs\": [\n",
        shared.prefix_len, shared.n_seqs
    ));
    for (i, p) in shared.points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"prefix_cache\": {}, \"mean_follower_ttft_ms\": {:.3}, \
             \"resident_bytes\": {}, \"pages_shared\": {}, \"prefill_tokens_skipped\": {}}}{}\n",
            p.prefix_cache,
            p.mean_follower_ttft_ms,
            p.resident_bytes,
            p.pages_shared,
            p.prefill_tokens_skipped,
            if i + 1 < shared.points.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]},\n");
    s.push_str(&format!(
        "  \"ragged_attention\": {{\"attn_threads\": {}, \"gen_tokens\": {}, \"kv\": \"int8\", \
         \"configs\": [\n",
        ragged.attn_threads, ragged.gen_tokens
    ));
    for (i, p) in ragged.points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"in_flight\": {}, \"prefill_chunk\": {}, \"serial_tok_s\": {:.1}, \
             \"parallel_tok_s\": {:.1}, \"speedup\": {:.3}}}{}\n",
            p.in_flight,
            p.prefill_chunk,
            p.serial_tok_s,
            p.parallel_tok_s,
            p.parallel_tok_s / p.serial_tok_s,
            if i + 1 < ragged.points.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]},\n");
    // draft_bits 0 = full-width (exact) draft
    s.push_str(&format!(
        "  \"speculative\": {{\"in_flight\": {}, \"kv\": \"int8\", \
         \"baseline_tok_s\": {:.1}, \"configs\": [\n",
        spec.in_flight, spec.baseline_tok_s
    ));
    for (i, p) in spec.points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"k\": {}, \"draft_bits\": {}, \"tokens_per_s\": {:.1}, \
             \"accept_rate\": {:.4}, \"proposed\": {}, \"accepted\": {}, \
             \"draft_rows\": {}}}{}\n",
            p.k,
            p.draft_bits,
            p.tokens_per_s,
            p.accept_rate,
            p.proposed,
            p.accepted,
            p.draft_rows,
            if i + 1 < spec.points.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]},\n");
    match std::fs::read_to_string(baseline_path) {
        Ok(b) if b.trim_start().starts_with('{') => {
            s.push_str("  \"baseline\": ");
            s.push_str(b.trim());
            s.push('\n');
        }
        _ => s.push_str("  \"baseline\": null\n"),
    }
    s.push_str("}\n");
    s
}
