//! Bench: regenerate the paper's Table 1 — multi-stage accumulation on
//! the LM ladder (W4A8, 16-bit inner accumulators, T ∈ {64, 128}),
//! for both the memory-efficient GPFQ* and OPTQ, against the
//! unconstrained base and the float model — plus an end-to-end timing of
//! the faithful (fused-kernel) integer datapath.
//!
//! Runs against the trained zoo when `make artifacts` has been built;
//! otherwise falls back to one synthetic pico model so the bench always
//! produces numbers. AXE_BENCH_FULL=1 includes the larger ladder rungs.

use axe::bench_support::time_once;
use axe::coordinator::experiments::run_lm_config;
use axe::coordinator::{quantize_transformer, DatapathMode, PipelineConfig};
use axe::eval::{load_corpus_split_or_synth, perplexity};
use axe::model::{
    load_named, random_transformer, Activation, Model, Transformer, TransformerConfig,
};
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::util::Table;

/// The trained zoo, or one synthetic stand-in model when artifacts are
/// absent (keeps the bench runnable on a fresh checkout).
fn zoo_or_synth(names: &[&str]) -> Vec<(String, Transformer)> {
    let mut out = Vec::new();
    for name in names {
        match load_named(name) {
            Ok(Model::Lm(m)) => out.push((name.to_string(), m)),
            _ => eprintln!("[multistage_llm] {name} missing — run `make artifacts`"),
        }
    }
    if out.is_empty() {
        eprintln!(
            "[multistage_llm] zoo missing — benching a synthetic pico model \
             (run `make artifacts` for the real ladder)"
        );
        let cfg = TransformerConfig {
            name: "pico-synth".into(),
            vocab: 64,
            d_model: 56,
            n_layers: 4,
            n_heads: 7,
            d_ff: 224,
            max_seq: 64,
            act: Activation::Gelu,
            parallel_residual: true,
        };
        out.push(("pico-synth".to_string(), random_transformer(cfg, 1)));
    }
    out
}

fn main() -> anyhow::Result<()> {
    let full = std::env::var("AXE_BENCH_FULL").is_ok();
    let model_names: Vec<&str> = if full {
        vec!["pico-70k", "pico-160k", "pico-410k", "pico-1m", "pico-2m"]
    } else {
        vec!["pico-70k", "pico-160k", "pico-410k"]
    };
    let zoo = zoo_or_synth(&model_names);
    // (tile, P_I) grid: the paper's 64x16b/128x16b (free at our widths,
    // like their 64x16b at Pythia widths) plus the binding 14-bit tier
    // that exposes the tile-size trade at this zoo's K.
    let configs: [(usize, u32); 4] = [(64, 16), (128, 16), (64, 14), (128, 14)];

    for algo in [Algorithm::GpfqMemEff, Algorithm::Optq] {
        println!("\n### Table 1 analog — {} (W4A8)\n", algo.name());
        let mut table = Table::new(&[
            "model", "params", "K_max", "float", "base", "64x16b", "128x16b", "64x14b", "128x14b",
        ]);
        for (name, base) in &zoo {
            let k_max = base.cfg.d_ff;
            let seq = base.cfg.max_seq;
            let train = load_corpus_split_or_synth("train", base.cfg.vocab);
            let val = load_corpus_split_or_synth("val", base.cfg.vocab);
            let calib: Vec<&[u16]> = train.chunks_exact(seq).take(10).collect();
            let float_ppl = perplexity(base, &val, seq, 16).ppl;
            let base_cfg = PipelineConfig::new(algo, Method::Naive, 4, 8);
            let t0 = std::time::Instant::now();
            let base_pt = run_lm_config(base, &calib, &val, seq, 16, &base_cfg)?;
            let mut row = vec![
                name.to_string(),
                format!("{}", base.cfg.param_count()),
                format!("{k_max}"),
                format!("{float_ppl:.1}"),
                format!("{:.1}", base_pt.metric),
            ];
            for &(t, p_inner) in &configs {
                let mut cfg = PipelineConfig::new(algo, Method::Axe, 4, 8);
                cfg.target = AccumTarget::MultiStage { p_inner, tile: t };
                let pt = run_lm_config(base, &calib, &val, seq, 16, &cfg)?;
                row.push(format!("{:.1}", pt.metric));
            }
            table.row(&row);
            eprintln!("  [{name}] done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        println!("{}", table.render());
    }

    // ---- faithful-datapath serving throughput. DatapathMode::Faithful
    // now executes on the fused qgemm kernel (bit-for-bit equal to the
    // scalar simulator, which remains the audit oracle) — this times the
    // end-to-end integer-datapath eval the serve path runs on.
    let (name, base) = &zoo[0];
    let seq = base.cfg.max_seq;
    let train = load_corpus_split_or_synth("train", base.cfg.vocab);
    let val = load_corpus_split_or_synth("val", base.cfg.vocab);
    let calib: Vec<&[u16]> = train.chunks_exact(seq).take(8).collect();
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 16, tile: 64 };
    cfg.datapath = DatapathMode::Faithful;
    let mut qmodel = base.clone();
    quantize_transformer(&mut qmodel, &calib, &cfg)?;
    let (report, secs) = time_once(|| perplexity(&qmodel, &val, seq, 16));
    println!(
        "\nfaithful-datapath eval on {name} (fused 64x16b kernel): \
         {:.0} tok/s, PPL {:.1}, overflow events {}",
        report.tokens as f64 / secs,
        report.ppl,
        report.overflows
    );

    // ---- decode throughput: per-request sequential decode vs the
    // continuous-batching step scheduler. Each serve run uses ONE
    // engine thread; what scales is the number of in-flight slots the
    // scheduler stacks into every decode_step_batch / fused qgemm call.
    use axe::coordinator::serve::{serve, serve_with, Request, ServeQueue, ServeStats};
    use axe::model::{KvArena, KvCacheKind, KvQuantSpec};

    let n_requests = 16usize;
    let gen_tokens = 32usize;
    let make_requests = || -> Vec<Request> {
        (0..n_requests as u64)
            .map(|id| {
                let start = (id as usize * 31) % (val.len() - seq);
                Request {
                    id,
                    prompt: val[start..start + seq / 2].to_vec(),
                    max_new_tokens: gen_tokens,
                }
            })
            .collect()
    };

    // sequential baseline: one request at a time through the KV cache
    let reqs = make_requests();
    let (seq_out, seq_s) = time_once(|| {
        reqs.iter()
            .map(|r| qmodel.generate_greedy(&r.prompt, r.max_new_tokens))
            .collect::<Vec<_>>()
    });
    println!(
        "\ndecode throughput on {name} ({} reqs × {} tokens, W4A8 64x16b faithful):",
        n_requests, gen_tokens
    );
    println!(
        "  per-request sequential : {:>7.1} tok/s",
        (n_requests * gen_tokens) as f64 / seq_s
    );

    for max_batch in [1usize, 4, 16] {
        let queue = ServeQueue::new();
        for r in make_requests() {
            queue.submit(r);
        }
        queue.close();
        let t0 = std::time::Instant::now();
        serve(&qmodel, &queue, 1, max_batch);
        let responses = queue.drain();
        let stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
        println!(
            "  continuous batch @ {max_batch:>2}  : {:>7.1} tok/s  \
             (p50 {:>6.1} ms, p99 {:>6.1} ms, overflow {})",
            stats.tokens_per_s,
            stats.p50_latency_s * 1e3,
            stats.p99_latency_s * 1e3,
            stats.overflow_events
        );
        // batched serving stays token-exact vs the sequential baseline
        for (resp, want) in responses.iter().zip(seq_out.iter()) {
            assert_eq!(
                resp.tokens[..],
                want[want.len() - gen_tokens..],
                "batched decode must be token-exact"
            );
        }
    }

    // ---- quantized-KV decode throughput: same scheduler, but the
    // arena stores i8 codes + per-(slot, position, head) scales and the
    // attention score/value matmuls run on the multi-stage integer
    // datapath. Token-exact vs sequential decode on the SAME backend
    // (vs the f32 arena it trades bounded divergence for ~4x memory).
    let kv_kind = KvCacheKind::Quant(KvQuantSpec::int8());
    let f32_bytes = KvArena::footprint(&qmodel.cfg, 16, KvCacheKind::F32);
    let q_bytes = KvArena::footprint(&qmodel.cfg, 16, kv_kind);
    println!(
        "\nquantized-KV decode throughput (i8 arena @16 slots: {} B, {:.1}% of f32 {} B):",
        q_bytes,
        100.0 * q_bytes as f64 / f32_bytes as f64,
        f32_bytes
    );
    let reqs = make_requests();
    let want_q: Vec<Vec<u16>> = reqs
        .iter()
        .map(|r| qmodel.generate_greedy_with(&r.prompt, r.max_new_tokens, kv_kind))
        .collect();
    for max_batch in [1usize, 4, 16] {
        let queue = ServeQueue::new();
        for r in make_requests() {
            queue.submit(r);
        }
        queue.close();
        let t0 = std::time::Instant::now();
        serve_with(&qmodel, &queue, 1, max_batch, kv_kind);
        let responses = queue.drain();
        let mut stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
        stats.arena_bytes = KvArena::footprint(&qmodel.cfg, max_batch, kv_kind);
        println!(
            "  quant-kv batch @ {max_batch:>2}    : {:>7.1} tok/s  \
             (p50 {:>6.1} ms, p99 {:>6.1} ms, overflow {}, arena {} B)",
            stats.tokens_per_s,
            stats.p50_latency_s * 1e3,
            stats.p99_latency_s * 1e3,
            stats.overflow_events,
            stats.arena_bytes
        );
        for (resp, want) in responses.iter().zip(want_q.iter()) {
            assert_eq!(
                resp.tokens[..],
                want[want.len() - gen_tokens..],
                "quant-KV batched decode must be token-exact vs quant-KV sequential"
            );
        }
    }

    println!(
        "\nExpected shape: constrained columns approach `base` as width grows\n\
         (T fixed while K grows — the A2Q scaling hypothesis, paper §4.2);\n\
         continuous-batch decode throughput grows with in-flight slots,\n\
         and the i8 KV arena roughly quarters serving memory."
    );
    Ok(())
}
