//! Bench: regenerate the paper's Table 2 — the ablation isolating
//! (a) error correction (EP-init vs AXE-RTZ), (b) rounding function
//! (AXE-RTZ vs AXE-RTN), and (c) the soft ℓ1 constraint (AXE-RTN vs
//! AXE-HCO), at W4A8 with a 20-bit monolithic accumulator on two LM
//! variants.

use axe::coordinator::experiments::run_lm_config;
use axe::coordinator::PipelineConfig;
use axe::eval::load_corpus_split_or_synth;
use axe::model::{load_named, Model};
use axe::quant::{AccumTarget, Algorithm, Method, Rounding};
use axe::util::Table;

fn main() -> anyhow::Result<()> {
    let p = 16u32; // binding regime for K <= 224 (paper used 20 at K ~ 3k)
    let models = ["pico-160k-opt", "pico-160k"];
    println!("### Table 2 analog — W4A8, monolithic {p}-bit accumulator (scaled to this zoo's width)\n");
    let mut table = Table::new(&["Algorithm", "Model", "EP-init", "AXE-RTZ", "AXE-RTN", "AXE-HCO"]);
    for algo in [Algorithm::Gpfq, Algorithm::Optq] {
        for name in &models {
            let Ok(Model::Lm(base)) = load_named(name) else {
                eprintln!("[ablation] {name} missing — run `make artifacts`");
                continue;
            };
            let seq = base.cfg.max_seq;
            let train = load_corpus_split_or_synth("train", base.cfg.vocab);
            let val = load_corpus_split_or_synth("val", base.cfg.vocab);
            let calib: Vec<&[u16]> = train.chunks_exact(seq).take(10).collect();
            let mut cells = vec![algo.name().to_string(), name.to_string()];
            for variant in ["ep", "rtz", "rtn", "hco"] {
                let mut cfg = PipelineConfig::new(
                    algo,
                    if variant == "ep" { Method::EpInit } else { Method::Axe },
                    4,
                    8,
                );
                cfg.target = AccumTarget::Monolithic { p_bits: p };
                match variant {
                    "rtz" => cfg.rounding = Rounding::Zero,
                    "hco" => cfg.soft = false,
                    _ => {}
                }
                let pt = run_lm_config(&base, &calib, &val, seq, 16, &cfg)?;
                assert!(pt.safe, "all four variants must be provably safe");
                cells.push(format!("{:.1}", pt.metric));
            }
            table.row(&cells);
        }
    }
    println!("{}", table.render());
    println!(
        "Expected ordering (paper Table 2): EP-init ≫ AXE-RTZ > AXE-HCO ≥ AXE-RTN\n\
         — the EP-init→RTZ gap is error correction, RTZ→RTN is the rounding\n\
         function, RTN→HCO is the soft ℓ1 penalty."
    );
    Ok(())
}
