//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! (HLO text emitted by `python/compile/aot.py`) on the XLA CPU client.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto` — jax ≥0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README).
//!
//! Python runs only at build time; this module is the entire inference
//! dependency on the artifacts.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A compiled-executable cache over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    hlo_dir: PathBuf,
}

impl Runtime {
    /// Create a runtime rooted at `<artifacts>/hlo`.
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            hlo_dir: crate::artifacts_dir().join("hlo"),
        })
    }

    pub fn with_dir(dir: &Path) -> Result<Runtime> {
        let mut rt = Runtime::new()?;
        rt.hlo_dir = dir.to_path_buf();
        Ok(rt)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of available HLO artifacts (without extension).
    pub fn list_artifacts(&self) -> Vec<String> {
        let mut v = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.hlo_dir) {
            for e in rd.flatten() {
                if let Some(name) = e.file_name().to_str() {
                    if let Some(stem) = name.strip_suffix(".hlo.txt") {
                        v.push(stem.to_string());
                    }
                }
            }
        }
        v.sort();
        v
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(name) {
                return Ok(exe.clone());
            }
        }
        let path = self.hlo_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on f32 inputs, returning all f32 outputs.
    /// The AOT path lowers with `return_tuple=True`, so the single result
    /// literal is a tuple.
    pub fn run_f32(&self, name: &str, inputs: &[F32Input]) -> Result<Vec<Vec<f32>>> {
        let exe = self.load(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| {
                let lit = xla::Literal::vec1(&inp.data);
                let dims: Vec<i64> = inp.dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let mut out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let tuple = out.decompose_tuple().map_err(|e| anyhow!("decompose: {e:?}"))?;
        tuple
            .into_iter()
            .map(|lit| {
                // outputs may be f32 or i32; convert i32 to f32 for a
                // uniform return type
                lit.to_vec::<f32>().or_else(|_| {
                    lit.to_vec::<i32>()
                        .map(|v| v.into_iter().map(|x| x as f32).collect())
                })
                .map_err(|e| anyhow!("to_vec: {e:?}"))
            })
            .collect()
    }

    /// Execute an artifact whose inputs are i32 tensors.
    pub fn run_i32(&self, name: &str, inputs: &[I32Input]) -> Result<Vec<Vec<i32>>> {
        let exe = self.load(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| {
                let lit = xla::Literal::vec1(&inp.data);
                let dims: Vec<i64> = inp.dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let mut out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let tuple = out.decompose_tuple().map_err(|e| anyhow!("decompose: {e:?}"))?;
        tuple
            .into_iter()
            .map(|lit| lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// A shaped f32 input.
pub struct F32Input {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl F32Input {
    pub fn new(data: Vec<f32>, dims: &[usize]) -> F32Input {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        F32Input { data, dims: dims.to_vec() }
    }
}

/// A shaped i32 input.
pub struct I32Input {
    pub data: Vec<i32>,
    pub dims: Vec<usize>,
}

impl I32Input {
    pub fn new(data: Vec<i32>, dims: &[usize]) -> I32Input {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        I32Input { data, dims: dims.to_vec() }
    }
}

/// A manifest describing the AOT artifacts (written by aot.py).
pub fn load_manifest() -> Result<crate::util::json::Json> {
    let path = crate::artifacts_dir().join("hlo").join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    crate::util::json::Json::parse(&text).map_err(|e| anyhow!("bad hlo manifest: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/ and skip
    // gracefully when `make artifacts` has not run. Here we only test
    // the input containers.

    #[test]
    fn input_shapes_validated() {
        let i = F32Input::new(vec![1.0; 6], &[2, 3]);
        assert_eq!(i.dims, vec![2, 3]);
    }

    #[test]
    #[should_panic]
    fn input_shape_mismatch_panics() {
        F32Input::new(vec![1.0; 5], &[2, 3]);
    }
}
