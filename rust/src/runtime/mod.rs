//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! (HLO text emitted by `python/compile/aot.py`) on the XLA CPU client.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto` — jax ≥0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README).
//!
//! Python runs only at build time; this module is the entire inference
//! dependency on the artifacts.
//!
//! The `xla` bindings crate is not available in the offline registry, so
//! the PJRT-backed implementation is gated behind the off-by-default
//! `pjrt` feature (see `rust/Cargo.toml` for how to enable it). Without
//! the feature this module compiles a stub with the same API whose
//! constructors return a descriptive error, keeping every caller —
//! CLI, benches, examples — buildable offline.

use anyhow::Result;
use std::path::Path;

/// A shaped f32 input.
pub struct F32Input {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl F32Input {
    pub fn new(data: Vec<f32>, dims: &[usize]) -> F32Input {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        F32Input { data, dims: dims.to_vec() }
    }
}

/// A shaped i32 input.
pub struct I32Input {
    pub data: Vec<i32>,
    pub dims: Vec<usize>,
}

impl I32Input {
    pub fn new(data: Vec<i32>, dims: &[usize]) -> I32Input {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        I32Input { data, dims: dims.to_vec() }
    }
}

/// A manifest describing the AOT artifacts (written by aot.py).
pub fn load_manifest() -> Result<crate::util::json::Json> {
    use anyhow::Context;
    let path = crate::artifacts_dir().join("hlo").join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    crate::util::json::Json::parse(&text).map_err(|e| anyhow::anyhow!("bad hlo manifest: {e}"))
}

/// Names of the HLO artifacts (without extension) under `dir`.
fn scan_artifacts(dir: &Path) -> Vec<String> {
    let mut v = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            if let Some(name) = e.file_name().to_str() {
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    v.push(stem.to_string());
                }
            }
        }
    }
    v.sort();
    v
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::{scan_artifacts, F32Input, I32Input};
    use anyhow::{anyhow, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::{Arc, Mutex};

    /// Element types the execution path is generic over.
    trait PjrtElem: Copy {
        fn to_literal(data: &[Self]) -> xla::Literal;
        fn from_literal(lit: &xla::Literal) -> Result<Vec<Self>>;
    }

    impl PjrtElem for f32 {
        fn to_literal(data: &[f32]) -> xla::Literal {
            xla::Literal::vec1(data)
        }
        fn from_literal(lit: &xla::Literal) -> Result<Vec<f32>> {
            // outputs may be f32 or i32; convert i32 to f32 for a
            // uniform return type
            lit.to_vec::<f32>()
                .or_else(|_| {
                    lit.to_vec::<i32>().map(|v| v.into_iter().map(|x| x as f32).collect())
                })
                .map_err(|e| anyhow!("to_vec: {e:?}"))
        }
    }

    impl PjrtElem for i32 {
        fn to_literal(data: &[i32]) -> xla::Literal {
            xla::Literal::vec1(data)
        }
        fn from_literal(lit: &xla::Literal) -> Result<Vec<i32>> {
            lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))
        }
    }

    /// A compiled-executable cache over one PJRT CPU client.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
        hlo_dir: PathBuf,
    }

    impl Runtime {
        /// Create a runtime rooted at `<artifacts>/hlo`.
        pub fn new() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Runtime {
                client,
                cache: Mutex::new(HashMap::new()),
                hlo_dir: crate::artifacts_dir().join("hlo"),
            })
        }

        pub fn with_dir(dir: &Path) -> Result<Runtime> {
            let mut rt = Runtime::new()?;
            rt.hlo_dir = dir.to_path_buf();
            Ok(rt)
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Names of available HLO artifacts (without extension).
        pub fn list_artifacts(&self) -> Vec<String> {
            scan_artifacts(&self.hlo_dir)
        }

        /// Load + compile an artifact by name (cached).
        pub fn load(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
            {
                let cache = self.cache.lock().unwrap();
                if let Some(exe) = cache.get(name) {
                    return Ok(exe.clone());
                }
            }
            let path = self.hlo_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            let exe = Arc::new(exe);
            self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
            Ok(exe)
        }

        /// Shared execute path: reshape inputs into literals, run the
        /// executable, and decode the result literal(s). The AOT path
        /// lowers with `return_tuple=True`, so the single result is
        /// normally a tuple — but a single-output executable that was
        /// lowered without tupling is tolerated and treated as a
        /// one-element result list.
        fn execute_raw<T: PjrtElem>(
            &self,
            name: &str,
            inputs: &[(&[T], &[usize])],
        ) -> Result<Vec<xla::Literal>> {
            let exe = self.load(name)?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let lit = T::to_literal(data);
                    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
                })
                .collect::<Result<_>>()?;
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
            let mut out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            match out.decompose_tuple() {
                Ok(parts) => Ok(parts),
                // Non-tuple single output: hand the literal back as-is.
                Err(_) => Ok(vec![out]),
            }
        }

        /// Execute an artifact on f32 inputs, returning all f32 outputs.
        pub fn run_f32(&self, name: &str, inputs: &[F32Input]) -> Result<Vec<Vec<f32>>> {
            let raw: Vec<(&[f32], &[usize])> =
                inputs.iter().map(|i| (i.data.as_slice(), i.dims.as_slice())).collect();
            let parts = self.execute_raw::<f32>(name, &raw)?;
            parts.iter().map(f32::from_literal).collect()
        }

        /// Execute an artifact whose inputs are i32 tensors.
        pub fn run_i32(&self, name: &str, inputs: &[I32Input]) -> Result<Vec<Vec<i32>>> {
            let raw: Vec<(&[i32], &[usize])> =
                inputs.iter().map(|i| (i.data.as_slice(), i.dims.as_slice())).collect();
            let parts = self.execute_raw::<i32>(name, &raw)?;
            parts.iter().map(i32::from_literal).collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::{scan_artifacts, F32Input, I32Input};
    use anyhow::{anyhow, Result};
    use std::path::{Path, PathBuf};

    fn unavailable<T>(what: &str) -> Result<T> {
        Err(anyhow!(
            "{what}: this build has no PJRT runtime — rebuild with `--features pjrt` \
             (requires the `xla` bindings crate, see rust/Cargo.toml)"
        ))
    }

    /// Stub runtime used when the `pjrt` feature is off. Both
    /// constructors fail with a descriptive error, so the instance
    /// methods are unreachable — they exist (with the hlo_dir the real
    /// runtime carries) purely so every caller of the PJRT API keeps
    /// compiling unchanged against either implementation.
    pub struct Runtime {
        hlo_dir: PathBuf,
    }

    impl Runtime {
        pub fn new() -> Result<Runtime> {
            unavailable("creating PJRT client")
        }

        pub fn with_dir(_dir: &Path) -> Result<Runtime> {
            Runtime::new()
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        pub fn list_artifacts(&self) -> Vec<String> {
            scan_artifacts(&self.hlo_dir)
        }

        pub fn load(&self, name: &str) -> Result<()> {
            unavailable(&format!("compiling {name}"))
        }

        pub fn run_f32(&self, name: &str, _inputs: &[F32Input]) -> Result<Vec<Vec<f32>>> {
            unavailable(&format!("executing {name}"))
        }

        pub fn run_i32(&self, name: &str, _inputs: &[I32Input]) -> Result<Vec<Vec<i32>>> {
            unavailable(&format!("executing {name}"))
        }
    }
}

pub use imp::Runtime;

/// Execute a `qmatmul` Pallas artifact as an alternate integer-GEMM
/// backend behind the calling convention of
/// [`crate::linalg::qgemm_multistage`]: `x` is `rows*k` activation
/// codes (row-major), `w` is `c*k` weight codes in the Rust
/// channel-major layout. The artifact wants `w` feature-major
/// (`[k, n]`, `n = c`), so this transposes on the way in, narrows the
/// codes to the artifact's i32 interchange type, and widens the
/// `rows*c` row-major outputs back to i64 on the way out.
///
/// The kernel performs the same tiled two-stage accumulation the fused
/// Rust GEMM simulates, so its outputs are gated bit-exactly against
/// `qgemm_multistage` (the same oracle that gates the explicit-SIMD
/// path) in `tests/integration_artifacts.rs`. Codes always fit i32:
/// the quantizers emit at most 16-bit codes.
pub fn qgemm_pjrt(
    rt: &Runtime,
    name: &str,
    x: &[i64],
    rows: usize,
    w: &[i32],
    c: usize,
    k: usize,
) -> Result<Vec<i64>> {
    assert_eq!(x.len(), rows * k, "x must be rows*k");
    assert_eq!(w.len(), c * k, "w must be c*k");
    let xi: Vec<i32> = x
        .iter()
        .map(|&v| i32::try_from(v).expect("activation code exceeds i32"))
        .collect();
    let mut wt = vec![0i32; k * c];
    for ch in 0..c {
        for i in 0..k {
            wt[i * c + ch] = w[ch * k + i];
        }
    }
    let outs =
        rt.run_i32(name, &[I32Input::new(xi, &[rows, k]), I32Input::new(wt, &[k, c])])?;
    Ok(outs[0].iter().map(|&v| v as i64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/ and skip
    // gracefully when `make artifacts` has not run. Here we only test
    // the input containers.

    #[test]
    fn input_shapes_validated() {
        let i = F32Input::new(vec![1.0; 6], &[2, 3]);
        assert_eq!(i.dims, vec![2, 3]);
    }

    #[test]
    #[should_panic]
    fn input_shape_mismatch_panics() {
        F32Input::new(vec![1.0; 5], &[2, 3]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_errors_descriptively() {
        let e = Runtime::new().err().expect("stub must not construct");
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
