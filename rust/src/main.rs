//! `axe` — the command-line front end of the AXE reproduction.
//!
//! Subcommands map onto the paper's experiments:
//!   quantize — run one PTQ configuration on a model and evaluate it
//!   pareto   — sweep the (M, N, P) design space (Figs. 1/3, Tables 4-7)
//!   scaling  — multi-stage accumulation across the LM ladder (Table 1)
//!   ablation — EP-init / AXE-RTZ / AXE-RTN / AXE-HCO (Table 2)
//!   audit    — overflow audit of a quantized configuration (Eq. 6)
//!   zoo      — list available models and artifacts
//!   runtime  — smoke-test the PJRT runtime against the AOT artifacts

use anyhow::{anyhow, Result};
use axe::coordinator::experiments::{
    design_space, pareto_frontier, render_frontier, run_lm_config, MetricKind,
};
use axe::coordinator::{quantize_transformer, PipelineConfig};
use axe::eval::load_corpus_split_or_synth;
use axe::eval::perplexity;
use axe::model::{load_named, Model};
use axe::quant::{AccumTarget, Algorithm, Method, Rounding};
use axe::util::argparse::{usage, Args};
use axe::util::Table;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("quantize") => cmd_quantize(args),
        Some("pareto") => cmd_pareto(args),
        Some("scaling") => cmd_scaling(args),
        Some("ablation") => cmd_ablation(args),
        Some("audit") => cmd_audit(args),
        Some("serve") => cmd_serve(args),
        Some("sensitivity") => cmd_sensitivity(args),
        Some("zoo") => cmd_zoo(),
        Some("runtime") => cmd_runtime(),
        _ => {
            println!(
                "{}",
                usage(
                    "axe",
                    "accumulator-aware post-training quantization",
                    &[
                        ("quantize", "quantize one model with one configuration"),
                        ("pareto", "P-vs-accuracy Pareto sweep (Figs. 1/3)"),
                        ("scaling", "multi-stage accumulation across the LM ladder (Table 1)"),
                        ("ablation", "rounding/soft-constraint ablation (Table 2)"),
                        ("audit", "worst-case + fuzz overflow audit"),
                        ("serve", "serve batched generation from a quantized model"),
                        ("sensitivity", "per-layer + pipeline-stage sensitivity analysis"),
                        ("zoo", "list trained models and artifacts"),
                        ("runtime", "PJRT runtime smoke test"),
                    ],
                    &[],
                )
            );
            Ok(())
        }
    }
}

fn parse_target(args: &Args, default_tile: Option<usize>) -> AccumTarget {
    let p = args.u32_or("acc-bits", 0);
    if p == 0 {
        return AccumTarget::None;
    }
    match args.get("tile").map(|t| t.parse::<usize>().unwrap_or(0)).or(default_tile) {
        Some(t) if t > 0 => AccumTarget::MultiStage { p_inner: p, tile: t },
        _ => AccumTarget::Monolithic { p_bits: p },
    }
}

fn load_lm(name: &str) -> Result<axe::model::Transformer> {
    match load_named(name)? {
        Model::Lm(m) => Ok(m),
        _ => Err(anyhow!("{name} is not an LM")),
    }
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let model_name = args.str_or("model", "pico-160k");
    let algorithm = Algorithm::parse(&args.str_or("algo", "optq"))
        .ok_or_else(|| anyhow!("bad --algo"))?;
    let method =
        Method::parse(&args.str_or("method", "axe")).ok_or_else(|| anyhow!("bad --method"))?;
    let m = args.u32_or("weight-bits", 4);
    let n = args.u32_or("act-bits", 8);
    let mut cfg = PipelineConfig::new(algorithm, method, m, n);
    cfg.target = parse_target(args, None);
    if args.flag("rtz") {
        cfg.rounding = Rounding::Zero;
    }
    if args.flag("no-soft") {
        cfg.soft = false;
    }
    if args.flag("faithful") {
        cfg.datapath = axe::coordinator::DatapathMode::Faithful;
    }

    let mut model = load_lm(&model_name)?;
    let seq = model.cfg.max_seq;
    let train = load_corpus_split_or_synth("train", model.cfg.vocab);
    let val = load_corpus_split_or_synth("val", model.cfg.vocab);
    let calib: Vec<&[u16]> =
        train.chunks_exact(seq).take(args.usize_or("calib-seqs", 16)).collect();
    let float_ppl = perplexity(&model, &val, seq, args.usize_or("eval-seqs", 32)).ppl;

    let report = quantize_transformer(&mut model, &calib, &cfg)?;
    let q = perplexity(&model, &val, seq, args.usize_or("eval-seqs", 32));
    println!("model            : {model_name} ({} params)", model.cfg.param_count());
    println!("config           : {}", report.config);
    let k_max = model
        .linear_names()
        .iter()
        .filter_map(|n| model.get_linear(n))
        .map(|l| l.in_dim())
        .max()
        .unwrap_or(1);
    println!("deploy target    : {}", cfg.effective_target(k_max).describe());
    println!("float PPL        : {float_ppl:.2}");
    println!("quantized PPL    : {:.2}", q.ppl);
    println!("weight sparsity  : {:.1}%", report.sparsity() * 100.0);
    println!("guaranteed safe  : {}", report.guaranteed_safe());
    println!("worst utilization: {:.3}", report.audit.worst_utilization);
    println!("overflow events  : {}", q.overflows);
    println!("quantization time: {:.2}s", report.total_seconds);
    Ok(())
}

fn cmd_pareto(args: &Args) -> Result<()> {
    let model_name = args.str_or("model", "pico-160k");
    let algorithm = Algorithm::parse(&args.str_or("algo", "gpfq"))
        .ok_or_else(|| anyhow!("bad --algo"))?;
    let base = load_lm(&model_name)?;
    let seq = base.cfg.max_seq;
    let train = load_corpus_split_or_synth("train", base.cfg.vocab);
    let val = load_corpus_split_or_synth("val", base.cfg.vocab);
    let calib: Vec<&[u16]> =
        train.chunks_exact(seq).take(args.usize_or("calib-seqs", 12)).collect();
    let eval_seqs = args.usize_or("eval-seqs", 24);
    let min_bits = args.u32_or("min-bits", 3);
    let max_bits = args.u32_or("max-bits", 8);
    let p_values = args.usize_list_or("p-bits", &[9, 10, 11, 12, 13, 14, 16, 20]);

    for (method, label) in axe::coordinator::experiments::methods() {
        let mut points = Vec::new();
        for (m, n) in design_space(min_bits, max_bits) {
            match method {
                Method::Naive => {
                    let cfg = PipelineConfig::new(algorithm, method, m, n);
                    points.push(run_lm_config(&base, &calib, &val, seq, eval_seqs, &cfg)?);
                }
                _ => {
                    for &p in &p_values {
                        let mut cfg = PipelineConfig::new(algorithm, method, m, n);
                        cfg.target = AccumTarget::Monolithic { p_bits: p as u32 };
                        points.push(run_lm_config(&base, &calib, &val, seq, eval_seqs, &cfg)?);
                    }
                }
            }
        }
        let frontier = pareto_frontier(&points, MetricKind::Perplexity);
        println!(
            "{}",
            render_frontier(
                &format!("{model_name} {} + {label}", algorithm.name()),
                MetricKind::Perplexity,
                &frontier
            )
        );
    }
    Ok(())
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let models = args.str_list_or(
        "models",
        &["pico-70k", "pico-160k", "pico-410k", "pico-1m", "pico-2m"],
    );
    let tiles = args.usize_list_or("tiles", &[64, 128]);
    let p_inner = args.u32_or("acc-bits", 16);
    let algorithm = Algorithm::parse(&args.str_or("algo", "optq")).unwrap();
    let mut table = Table::new(&["model", "params", "float", "base", "64x16b", "128x16b"]);
    for name in &models {
        let base = load_lm(name)?;
        let seq = base.cfg.max_seq;
        let train = load_corpus_split_or_synth("train", base.cfg.vocab);
        let val = load_corpus_split_or_synth("val", base.cfg.vocab);
        let calib: Vec<&[u16]> = train.chunks_exact(seq).take(12).collect();
        let float_ppl = perplexity(&base, &val, seq, 24).ppl;
        let base_cfg = PipelineConfig::new(algorithm, Method::Naive, 4, 8);
        let base_ppl = run_lm_config(&base, &calib, &val, seq, 24, &base_cfg)?.metric;
        let mut row = vec![
            name.clone(),
            format!("{}", base.cfg.param_count()),
            format!("{float_ppl:.1}"),
            format!("{base_ppl:.1}"),
        ];
        for &t in &tiles {
            let mut cfg = PipelineConfig::new(algorithm, Method::Axe, 4, 8);
            cfg.target = AccumTarget::MultiStage { p_inner, tile: t };
            let p = run_lm_config(&base, &calib, &val, seq, 24, &cfg)?;
            row.push(format!("{:.1}{}", p.metric, if p.safe { "" } else { "!" }));
        }
        while row.len() < 6 {
            row.push("-".into());
        }
        table.row(&row);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let models = args.str_list_or("models", &["pico-160k", "pico-160k-opt"]);
    let p = args.u32_or("acc-bits", 16);
    let mut table = Table::new(&["algo", "model", "EP-init", "AXE-RTZ", "AXE-RTN", "AXE-HCO"]);
    for algo in [Algorithm::Gpfq, Algorithm::Optq] {
        for name in &models {
            let base = load_lm(name)?;
            let seq = base.cfg.max_seq;
            let train = load_corpus_split_or_synth("train", base.cfg.vocab);
            let val = load_corpus_split_or_synth("val", base.cfg.vocab);
            let calib: Vec<&[u16]> = train.chunks_exact(seq).take(12).collect();
            let mut cells = vec![algo.name().to_string(), name.clone()];
            for variant in ["ep", "rtz", "rtn", "hco"] {
                let mut cfg = PipelineConfig::new(
                    algo,
                    if variant == "ep" { Method::EpInit } else { Method::Axe },
                    4,
                    8,
                );
                cfg.target = AccumTarget::Monolithic { p_bits: p };
                match variant {
                    "rtz" => cfg.rounding = Rounding::Zero,
                    "hco" => cfg.soft = false,
                    _ => {}
                }
                let pt = run_lm_config(&base, &calib, &val, seq, 24, &cfg)?;
                cells.push(format!("{:.1}", pt.metric));
            }
            table.row(&cells);
        }
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_audit(args: &Args) -> Result<()> {
    let model_name = args.str_or("model", "pico-160k");
    let mut cfg = PipelineConfig::new(
        Algorithm::parse(&args.str_or("algo", "optq")).unwrap(),
        Method::parse(&args.str_or("method", "axe")).unwrap(),
        args.u32_or("weight-bits", 4),
        args.u32_or("act-bits", 8),
    );
    cfg.target = parse_target(args, Some(64));
    let mut model = load_lm(&model_name)?;
    let train = load_corpus_split_or_synth("train", model.cfg.vocab);
    let seq = model.cfg.max_seq;
    let calib: Vec<&[u16]> = train.chunks_exact(seq).take(8).collect();
    let report = quantize_transformer(&mut model, &calib, &cfg)?;
    println!("config           : {}", report.config);
    println!("audited cases    : {}", report.audit.cases);
    println!("violations       : {}", report.audit.violations);
    println!("worst utilization: {:.4}", report.audit.worst_utilization);
    println!("verdict          : {}", if report.guaranteed_safe() { "SAFE" } else { "UNSAFE" });
    Ok(())
}

fn cmd_sensitivity(args: &Args) -> Result<()> {
    use axe::coordinator::sensitivity::{per_layer_sensitivity, render_sensitivity, stage_ablation};
    let model_name = args.str_or("model", "pico-160k");
    let model = load_lm(&model_name)?;
    let seq = model.cfg.max_seq;
    let train = load_corpus_split_or_synth("train", model.cfg.vocab);
    let val = load_corpus_split_or_synth("val", model.cfg.vocab);
    let calib: Vec<&[u16]> = train.chunks_exact(seq).take(args.usize_or("calib-seqs", 12)).collect();
    let mut cfg = PipelineConfig::new(
        Algorithm::parse(&args.str_or("algo", "optq")).unwrap(),
        Method::Axe,
        args.u32_or("weight-bits", 4),
        args.u32_or("act-bits", 8),
    );
    cfg.target = match parse_target(args, None) {
        AccumTarget::None => AccumTarget::Monolithic { p_bits: 16 },
        t => t,
    };
    let eval_seqs = args.usize_or("eval-seqs", 16);
    let layers = per_layer_sensitivity(&model, &calib, &val, eval_seqs, &cfg)?;
    let stages = stage_ablation(&model, &calib, &val, eval_seqs, &cfg)?;
    println!("model: {model_name}, config: {}", cfg.describe());
    println!("{}", render_sensitivity(&layers, &stages));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use axe::coordinator::report::render_telemetry_report;
    use axe::coordinator::serve::{
        serve_telemetry, Request, ServeConfig, ServeQueue, ServeStats, ShedPolicy,
        DEFAULT_PREFILL_CHUNK,
    };
    use axe::coordinator::telemetry::{SinkSpec, DEFAULT_FLUSH_EVERY, DEFAULT_RING_CAPACITY};
    use axe::model::{KvArena, KvCacheKind, KvQuantSpec, SampleSpec, DEFAULT_KV_PAGE};
    let model_name = args.str_or("model", "pico-160k");
    // --model synthetic: a seeded random transformer served on the
    // float weight datapath with PTQ skipped — the serve loop, the KV
    // backends and the telemetry stream all run without trained
    // artifacts (the CI telemetry-smoke path)
    let synthetic = model_name == "synthetic";
    let mut model = if synthetic {
        use axe::model::{random_transformer, Activation, TransformerConfig};
        random_transformer(
            TransformerConfig {
                name: "synthetic".into(),
                vocab: 64,
                d_model: 32,
                n_layers: 2,
                n_heads: 4,
                d_ff: 64,
                max_seq: 32,
                act: Activation::Gelu,
                parallel_residual: false,
            },
            7,
        )
    } else {
        load_lm(&model_name)?
    };
    let seq = model.cfg.max_seq;
    let train = load_corpus_split_or_synth("train", model.cfg.vocab);
    let val = load_corpus_split_or_synth("val", model.cfg.vocab);
    let calib: Vec<&[u16]> = train.chunks_exact(seq).take(12).collect();

    let mut cfg = PipelineConfig::new(
        Algorithm::parse(&args.str_or("algo", "optq")).unwrap(),
        Method::parse(&args.str_or("method", "axe")).unwrap(),
        args.u32_or("weight-bits", 4),
        args.u32_or("act-bits", 8),
    );
    cfg.target = parse_target(args, Some(64));
    if cfg.target == AccumTarget::None {
        cfg.target = AccumTarget::MultiStage { p_inner: 16, tile: 64 };
        cfg.method = Method::Axe;
    }
    // --kv-bits 8|16|off: quantize the KV arena and run the attention
    // score/value matmuls on the multi-stage integer datapath
    let kind = match args.str_or("kv-bits", "off").as_str() {
        "off" | "f32" => KvCacheKind::F32,
        s => {
            let bits: u32 =
                s.parse().map_err(|_| anyhow!("--kv-bits must be 8, 16 or off (got {s})"))?;
            if bits != 8 && bits != 16 {
                return Err(anyhow!("--kv-bits must be 8, 16 or off (got {bits})"));
            }
            let inner = match args.u32_or("kv-acc-bits", 0) {
                0 => None, // data-type-safe width (guaranteed overflow-free)
                b => Some(b),
            };
            KvCacheKind::Quant(KvQuantSpec::new(bits, args.usize_or("kv-tile", 64), inner))
        }
    };
    if synthetic {
        println!("serving {model_name} (random weights, float linear datapath, PTQ skipped)");
    } else {
        let report = quantize_transformer(&mut model, &calib, &cfg)?;
        println!("serving {} ({}, safe={})", model_name, report.config, report.guaranteed_safe());
    }

    let n_requests = args.usize_or("requests", 16);
    let new_tokens = args.usize_or("tokens", 24);
    let workers = args.usize_or("workers", 1);
    let max_batch = args.usize_or("max-batch", 4);
    // --prefill-chunk N: per-step prefill chunk size / shared token
    // budget (0 = unchunked whole-prompt admission). Token streams are
    // bit-identical for every value; small chunks cut time-to-first-
    // token under load at the cost of more steps per prompt.
    let prefill_chunk = match args.usize_or("prefill-chunk", DEFAULT_PREFILL_CHUNK) {
        0 => usize::MAX,
        c => c,
    };
    // --kv-page N: positions per KV page (clamped to the window);
    // --prefix-cache on|off: shared-prefix page adoption at admission.
    // Tokens and per-request overflow counts are bit-identical either
    // way — the switch trades admission prefill work and resident
    // bytes only.
    let kv_page = args.usize_or("kv-page", DEFAULT_KV_PAGE).max(1);
    let prefix_cache = match args.str_or("prefix-cache", "on").as_str() {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        s => return Err(anyhow!("--prefix-cache must be on or off (got {s})")),
    };
    // --attn-threads N: threads for the banded ragged-attention sweep
    // per engine (0 = auto-detect; 1 = serial oracle). Token streams
    // and per-request overflow counts are bit-identical at every value.
    let attn_threads = args.usize_or("attn-threads", 0);
    // --speculate-k K: self-speculative decoding — draft K tokens per
    // decoding sequence on a narrowed accumulator (--draft-acc-bits,
    // 0 = full width) and verify them in one full-width ragged step.
    // Greedy acceptance keeps token streams bit-identical to K=1; the
    // knobs trade draft work against accepted tokens per step only.
    let speculate_k = args.usize_or("speculate-k", 1).max(1);
    let draft_bits = match args.u32_or("draft-acc-bits", 0) {
        0 => None, // draft on the full-width datapath (exact draft)
        b => Some(b),
    };
    // --temperature/--top-k/--top-p/--seed: seeded batch-invariant
    // sampling (temperature 0 = greedy). Draws are keyed per (seed,
    // request, position), so sampled streams are identical across
    // batch compositions and replay exactly under the same seed.
    let sample = SampleSpec {
        temperature: args.f64_or("temperature", 0.0) as f32,
        top_k: args.usize_or("top-k", 0),
        top_p: args.f64_or("top-p", 1.0) as f32,
        seed: args.u64_or("seed", 0),
    };
    if speculate_k > 1 && !sample.is_greedy() {
        return Err(anyhow!(
            "--speculate-k {speculate_k} requires greedy sampling (--temperature 0) — \
             the acceptance rule is the argmax"
        ));
    }
    // --metrics <path|->: stream one JSON object per executed ragged
    // step (schema v3) to a JSONL file — `<path>.<i>` per engine at
    // --workers > 1 — or to stdout with `-`. Off by default; the
    // in-memory histograms below are on either way.
    // --metrics-flush-every N: buffered records per off-thread drain;
    // --metrics-ring N: ring capacity before oldest records drop.
    let sink = args.get("metrics").map(SinkSpec::parse).unwrap_or_default();
    let flush_every = args.usize_or("metrics-flush-every", DEFAULT_FLUSH_EVERY);
    let metrics_ring = args.usize_or("metrics-ring", DEFAULT_RING_CAPACITY);
    // --queue-cap N: bound the pending queue at N requests (0 =
    // unbounded); overflow is shed per --shed-policy and every shed
    // request still resolves to a typed response. --deadline-ms N
    // attaches a wall-clock deadline to every request (0 = off);
    // expired work is dropped at admission or mid-step. --fair-budget
    // scales the shared prefill budget by live decode rows (default
    // on). Tokens of accepted-and-finished requests are bit-identical
    // under every setting.
    let queue_cap = args.usize_or("queue-cap", 0);
    let shed_policy = match args.str_or("shed-policy", "newest").as_str() {
        "newest" => ShedPolicy::RejectNewest,
        "largest" => ShedPolicy::RejectLargestPrompt,
        s => return Err(anyhow!("--shed-policy must be newest or largest (got {s})")),
    };
    let deadline_ms = args.u64_or("deadline-ms", 0);
    let fair_budget = match args.str_or("fair-budget", "on").as_str() {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        s => return Err(anyhow!("--fair-budget must be on or off (got {s})")),
    };
    let queue = if queue_cap == 0 {
        ServeQueue::new()
    } else {
        ServeQueue::bounded(queue_cap, shed_policy)
    };
    for id in 0..n_requests as u64 {
        let start = (id as usize * 37) % (val.len() - seq);
        let deadline = (deadline_ms > 0)
            .then(|| std::time::Instant::now() + std::time::Duration::from_millis(deadline_ms));
        // a full queue sheds by design: the queue files the typed
        // Shed response, so a rejected submit needs no handling here
        let _ = queue.submit(Request {
            id,
            prompt: val[start..start + seq / 2].to_vec(),
            max_new_tokens: new_tokens,
            deadline,
            ..Request::default()
        });
    }
    queue.close();
    let ovf_before = model.overflow_events();
    let t0 = std::time::Instant::now();
    let engine_stats = serve_telemetry(
        &model,
        &queue,
        workers,
        ServeConfig::new(max_batch, kind)
            .with_prefill_chunk(prefill_chunk)
            .with_kv_page(kv_page)
            .with_prefix_cache(prefix_cache)
            .with_attn_threads(attn_threads)
            .with_fair_budget(fair_budget)
            .with_metrics_ring(metrics_ring)
            .with_speculate(speculate_k, draft_bits)
            .with_sampling(sample),
        &sink,
        flush_every,
    )?;
    let responses = queue.drain();
    let mut stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
    stats.arena_bytes = KvArena::footprint_paged(&model.cfg, max_batch, kind, kv_page);
    stats.pages_shared = engine_stats.iter().map(|e| e.pages_shared).sum();
    stats.cache_evictions = engine_stats.iter().map(|e| e.cache_evictions).sum();
    stats.fill_telemetry(&engine_stats);
    let f32_bytes = KvArena::footprint_paged(&model.cfg, max_batch, KvCacheKind::F32, kv_page);
    println!("requests      : {}", stats.requests);
    println!(
        "admission     : {} completed / {} shed / {} deadline-missed / {} cancelled \
         (queue cap {}, hwm {}, policy {:?})",
        stats.completed,
        stats.shed,
        stats.deadline_miss,
        stats.cancelled,
        if queue_cap == 0 { "off".to_string() } else { queue_cap.to_string() },
        queue.depth_hwm(),
        shed_policy,
    );
    // conservation is the overload-safety contract: every submitted
    // request resolved to exactly one typed response
    if !stats.conserved(queue.submitted_count()) {
        return Err(anyhow!(
            "conservation violated: {} submitted != {} completed + {} shed + {} missed + {} cancelled",
            queue.submitted_count(),
            stats.completed,
            stats.shed,
            stats.deadline_miss,
            stats.cancelled
        ));
    }
    println!("generated     : {} tokens in {:.2}s", stats.total_tokens, stats.wall_s);
    println!("throughput    : {:.1} tok/s", stats.tokens_per_s);
    println!("latency p50   : {:.1} ms", stats.p50_latency_s * 1e3);
    println!("latency p99   : {:.1} ms", stats.p99_latency_s * 1e3);
    println!(
        "ttft p50/p99  : {:.1} / {:.1} ms (prefill chunk {})",
        stats.p50_ttft_s * 1e3,
        stats.p99_ttft_s * 1e3,
        if prefill_chunk == usize::MAX { "off".to_string() } else { prefill_chunk.to_string() }
    );
    println!("mean queue    : {:.1} ms", stats.mean_queue_s * 1e3);
    println!(
        "kv arena      : {} B per engine ({:.1}% of the {} B f32 arena), page size {}",
        stats.arena_bytes,
        100.0 * stats.arena_bytes as f64 / f32_bytes.max(1) as f64,
        f32_bytes,
        kv_page.min(model.cfg.max_seq),
    );
    let peak: usize = engine_stats.iter().map(|e| e.peak_bytes).max().unwrap_or(0);
    println!(
        "kv resident   : peak {} B across engines (deduplicated pages; \
         capacity {} B per engine)",
        peak,
        engine_stats.first().map(|e| e.capacity_bytes).unwrap_or(0)
    );
    println!(
        "prefix cache  : {} — hits {}/{} ({:.0}%), {} prefill tokens skipped, \
         {} pages shared, ttft p50 shared/cold {:.1}/{:.1} ms, {} flushes, \
         {} evictions, {} pages deduped",
        if prefix_cache { "on" } else { "off" },
        stats.prefix_hits,
        stats.requests,
        100.0 * stats.prefix_hit_rate,
        stats.prefill_tokens_skipped,
        stats.pages_shared,
        stats.p50_ttft_shared_s * 1e3,
        stats.p50_ttft_cold_s * 1e3,
        engine_stats.iter().map(|e| e.cache_flushes).sum::<u64>(),
        stats.cache_evictions,
        engine_stats.iter().map(|e| e.pages_deduped).sum::<u64>()
    );
    println!(
        "attn threads  : {} per engine (banded ragged-attention sweep; \
         0 = auto, 1 = serial oracle)",
        if attn_threads == 0 { "auto".to_string() } else { attn_threads.to_string() }
    );
    println!(
        "overflow evts : {} total across requests ({:.3} per generated token; \
         exact per-request attribution)",
        stats.overflow_events,
        stats.overflow_events as f64 / stats.total_tokens.max(1) as f64
    );
    // the unified model-wide counter (quantized linears + attention
    // matmuls) must agree with the per-request sum — one number for
    // eval and serve
    println!(
        "                of which attention: {}; unified model counter delta: {}",
        model.attention_overflow_events(),
        model.overflow_events() - ovf_before
    );
    // merged per-step histograms — continuous signals (latency tails,
    // occupancy, overflow rate) next to the end-of-run aggregates
    if let Some(t) = &stats.telemetry {
        println!("{}", render_telemetry_report(t));
    }
    if let SinkSpec::Jsonl(path) = &sink {
        println!(
            "metrics       : step records streamed to {} (schema v3{})",
            path.display(),
            if workers > 1 { ", one file per engine" } else { "" }
        );
    }
    Ok(())
}

fn cmd_zoo() -> Result<()> {
    let names = axe::model::list_models();
    if names.is_empty() {
        println!("no models found — run `make artifacts` first");
        return Ok(());
    }
    let mut t = Table::new(&["model", "family", "params"]);
    for n in names {
        match load_named(&n) {
            Ok(m) => {
                let fam = match &m {
                    Model::Lm(_) => "lm",
                    Model::Img(_) => "img",
                };
                t.row(&[n.clone(), fam.into(), format!("{}", m.param_count())]);
            }
            Err(e) => t.row(&[n.clone(), "error".into(), format!("{e}")]),
        }
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_runtime() -> Result<()> {
    let rt = axe::runtime::Runtime::new()?;
    println!("platform : {}", rt.platform());
    let artifacts = rt.list_artifacts();
    println!("artifacts: {artifacts:?}");
    for name in &artifacts {
        match rt.load(name) {
            Ok(_) => println!("  {name}: compiled OK"),
            Err(e) => println!("  {name}: FAILED ({e})"),
        }
    }
    Ok(())
}
