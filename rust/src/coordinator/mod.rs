//! The L3 coordinator: layer-by-layer PTQ pipeline and experiment
//! harness.
//!
//! Pipeline order follows the paper (App. C.1): load → graph
//! equalization → quantizer calibration → GPFQ/OPTQ (± AXE / EP-init) →
//! bias correction — traversing the network so each layer is quantized
//! against the activations of the already-quantized prefix (X̃) while
//! reconstructing the float activations (X).

pub mod experiments;
pub mod pipeline;
pub mod report;
pub mod sensitivity;
pub mod serve;
pub mod telemetry;

pub use pipeline::{
    quantize_mlp, quantize_transformer, DatapathMode, PipelineConfig, PipelineReport,
};
pub use report::LayerReport;
