//! The PTQ pipeline: equalize → calibrate → quantize layer-by-layer
//! (against quantized-prefix activations) → bias-correct → audit.

use super::report::LayerReport;
use crate::accum::audit::{audit_channel, AuditReport};
use crate::calib;
use crate::linalg::Mat;
use crate::model::{
    Capture, Datapath, Linear, Mlp, QuantLinear, Transformer,
};
use crate::quant::{
    datatype_min_bits, ep_init, gpfq_quantize, gpfq_quantize_grams, optq_quantize, AccumTarget,
    ActQuantizer, Algorithm, AxeConfig, GpfqParams, Method, OptqParams, QuantResult, Rounding,
};
use anyhow::Result;

/// How quantized linears execute after the pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatapathMode {
    /// Exact i64 integer arithmetic — bit-identical to the simulated
    /// datapath whenever the audit proves zero overflow (the fast path
    /// used for sweeps).
    Exact,
    /// Faithful per-MAC two's-complement wraparound simulation.
    Faithful,
}

/// Full pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub algorithm: Algorithm,
    pub method: Method,
    /// Weight bits M.
    pub weight_bits: u32,
    /// Activation bits N.
    pub act_bits: u32,
    /// Accumulator target for EP-init / AXE (ignored for Naive).
    pub target: AccumTarget,
    pub rounding: Rounding,
    /// AXE soft ℓ1 penalty (HCO ablation turns this off).
    pub soft: bool,
    pub act_order: bool,
    pub equalize: bool,
    pub bias_correction: bool,
    /// Two-sided percentile for activation range calibration.
    pub percentile: f64,
    pub datapath: DatapathMode,
    /// Damping for the memory-efficient GPFQ gram matrices.
    pub gram_damp: f64,
    /// Override the evaluation accumulator width (used by the overflow
    /// demonstration to run an unconstrained model on a too-small
    /// register). Does not affect the quantization itself.
    pub force_eval_bits: Option<u32>,
    /// QuaRot/SpinQuant-style randomized block-Hadamard rotation of each
    /// layer's input space before quantization (the paper's §5 future
    /// work). Exact in float arithmetic; the online transform is folded
    /// into the quantized layer.
    pub rotate: bool,
}

impl PipelineConfig {
    pub fn new(algorithm: Algorithm, method: Method, m: u32, n: u32) -> PipelineConfig {
        PipelineConfig {
            algorithm,
            method,
            weight_bits: m,
            act_bits: n,
            target: AccumTarget::None,
            rounding: Rounding::Nearest,
            soft: true,
            act_order: true,
            equalize: true,
            bias_correction: true,
            percentile: 0.999,
            datapath: DatapathMode::Exact,
            gram_damp: 0.01,
            force_eval_bits: None,
            rotate: false,
        }
    }

    /// AXE config handed to the base algorithm.
    fn axe(&self) -> AxeConfig {
        match self.method {
            Method::Axe => AxeConfig {
                target: self.target,
                soft: self.soft,
                rounding: self.rounding,
                act_bits: self.act_bits,
            },
            _ => AxeConfig::unconstrained(self.rounding, self.act_bits),
        }
    }

    /// The accumulator the deployed layer must run on: the constrained
    /// target for AXE/EP-init, the Eq. 3 data-type bound for Naive.
    pub fn effective_target(&self, k: usize) -> AccumTarget {
        match self.method {
            Method::Naive => AccumTarget::Monolithic {
                p_bits: datatype_min_bits(k, self.act_bits, self.weight_bits, false),
            },
            _ => self.target,
        }
    }

    /// Label like "OPTQ+axe W4A8 64x16b".
    pub fn describe(&self) -> String {
        format!(
            "{}+{} W{}A{} {}",
            self.algorithm.name(),
            self.method.name(),
            self.weight_bits,
            self.act_bits,
            self.target.describe()
        )
    }
}

/// Outcome of a pipeline run.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub config: String,
    pub layers: Vec<LayerReport>,
    pub audit: AuditReport,
    pub total_seconds: f64,
}

impl PipelineReport {
    pub fn sparsity(&self) -> f64 {
        super::report::total_sparsity(&self.layers)
    }

    /// True when every audited dot product is provably overflow-free.
    pub fn guaranteed_safe(&self) -> bool {
        self.audit.clean()
    }
}

/// Quantize every linear layer of a transformer in place.
pub fn quantize_transformer(
    model: &mut Transformer,
    calib_seqs: &[&[u16]],
    cfg: &PipelineConfig,
) -> Result<PipelineReport> {
    let start = std::time::Instant::now();
    let names = model.linear_names();
    let groups = model.block_groups();

    // --- Step A: graph equalization (SmoothQuant at LN boundaries).
    if cfg.equalize {
        let eq_layers: Vec<String> = (0..model.cfg.n_layers)
            .flat_map(|b| [format!("b{b}.wq"), format!("b{b}.fc1")])
            .collect();
        let mut pre = Capture::for_layers(&eq_layers);
        for s in calib_seqs {
            model.forward(s, Some(&mut pre));
        }
        for b in 0..model.cfg.n_layers {
            let attn_max = pre
                .matrix_kd(&format!("b{b}.wq"))
                .map(|m| calib::channel_abs_max(&m))
                .unwrap_or_default();
            let mlp_max = pre
                .matrix_kd(&format!("b{b}.fc1"))
                .map(|m| calib::channel_abs_max(&m))
                .unwrap_or_default();
            let blk = &mut model.blocks[b];
            if !attn_max.is_empty() {
                let (ln1, wq, wk, wv) = (&mut blk.ln1, &mut blk.wq, &mut blk.wk, &mut blk.wv);
                calib::smoothquant_fold(ln1, &mut [wq, wk, wv], &attn_max, 0.5);
            }
            if !mlp_max.is_empty() {
                calib::smoothquant_fold(&mut blk.ln2, &mut [&mut blk.fc1], &mlp_max, 0.5);
            }
        }
    }

    // --- Step B: float capture of every linear input (post-equalization).
    let mut float_cap = Capture::for_layers(&names);
    for s in calib_seqs {
        model.forward(s, Some(&mut float_cap));
    }

    // --- Step C: per block, refresh quantized-prefix activations and
    // quantize the block's layers. Layers within a group share the same
    // frozen prefix capture and read only their own float weights, so
    // their greedy channel paths are mutually independent — fan the
    // group across scoped threads (each worker further parallelizes its
    // GPFQ/OPTQ channels internally; at group sizes ≤ 6 the resulting
    // oversubscription costs less than leaving the narrow layers'
    // channel loops unable to fill the machine). Installs happen
    // afterwards, in group order, so reports and model state match the
    // sequential run exactly.
    let mut layer_reports = Vec::new();
    let mut audit_total = AuditReport::default();
    for group in &groups {
        let mut prefix_cap = Capture::for_layers(group);
        for s in calib_seqs {
            model.forward(s, Some(&mut prefix_cap));
        }
        let staged_group: Vec<Result<StagedLayer>> = {
            let model_ref: &Transformer = model;
            let float_ref = &float_cap;
            let prefix_ref = &prefix_cap;
            std::thread::scope(|scope| {
                let handles: Vec<_> = group
                    .iter()
                    .map(|name| {
                        scope.spawn(move || {
                            quantize_one_layer(
                                cfg,
                                float_ref,
                                prefix_ref,
                                |n| model_ref.get_linear(n),
                                name,
                            )
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("layer quantization worker panicked"))
                    .collect()
            })
        };
        for staged in staged_group {
            let staged = staged?;
            let slot = model.get_linear_mut(&staged.name).expect("layer exists");
            let (report, audit) = staged.install(slot);
            audit_total.merge(&audit);
            layer_reports.push(report);
        }
    }
    Ok(PipelineReport {
        config: cfg.describe(),
        layers: layer_reports,
        audit: audit_total,
        total_seconds: start.elapsed().as_secs_f64(),
    })
}

/// Quantize every hidden layer of an MLP in place.
pub fn quantize_mlp(model: &mut Mlp, calib: &[&[f32]], cfg: &PipelineConfig) -> Result<PipelineReport> {
    let start = std::time::Instant::now();
    let names = model.linear_names();

    // --- Step A: weight equalization between consecutive ReLU linears.
    if cfg.equalize && model.cfg.act == crate::model::Activation::Relu && !model.cfg.residual {
        for i in 0..model.layers.len().saturating_sub(1) {
            let (a, b) = model.layers.split_at_mut(i + 1);
            if let (Linear::Float(l1), Linear::Float(l2)) = (&mut a[i], &mut b[0]) {
                calib::equalize_pair(l1, l2);
            }
        }
    }

    // --- Step B: float capture.
    let mut float_cap = Capture::for_layers(&names);
    for x in calib {
        model.forward(x, Some(&mut float_cap));
    }

    // --- Step C: sequential layer quantization with prefix refresh.
    let mut layer_reports = Vec::new();
    let mut audit_total = AuditReport::default();
    for name in &names {
        let mut prefix_cap = Capture::for_layers(std::slice::from_ref(name));
        for x in calib {
            model.forward(x, Some(&mut prefix_cap));
        }
        let staged =
            quantize_one_layer(cfg, &float_cap, &prefix_cap, |n| model.get_linear(n), name)?;
        let (report, audit) = staged.install(model.get_linear_mut(name).expect("layer exists"));
        audit_total.merge(&audit);
        layer_reports.push(report);
    }
    Ok(PipelineReport {
        config: cfg.describe(),
        layers: layer_reports,
        audit: audit_total,
        total_seconds: start.elapsed().as_secs_f64(),
    })
}

/// Staged result for one layer: everything needed to install it.
struct StagedLayer {
    name: String,
    new_linear: QuantLinear,
    w_float: Mat,
    x: Mat,
    xt: Mat,
    bias_correction: bool,
    seconds: f64,
    audit: AuditReport,
    sparsity: f64,
}

impl StagedLayer {
    /// Install into the model slot, applying bias correction.
    fn install(mut self, slot: &mut Linear) -> (LayerReport, AuditReport) {
        if self.bias_correction {
            calib::bias_correct(&mut self.new_linear, &self.w_float, &self.x, &self.xt);
        }
        let report = LayerReport {
            name: self.name.clone(),
            k: self.w_float.rows(),
            c: self.w_float.cols(),
            sparsity: self.sparsity,
            worst_utilization: self.audit.worst_utilization,
            audit_violations: self.audit.violations,
            seconds: self.seconds,
        };
        *slot = Linear::Quant(self.new_linear);
        (report, self.audit)
    }
}

/// Run the configured algorithm on one layer.
fn quantize_one_layer<'m>(
    cfg: &PipelineConfig,
    float_cap: &Capture,
    prefix_cap: &Capture,
    get: impl Fn(&str) -> Option<&'m Linear>,
    name: &str,
) -> Result<StagedLayer> {
    let t0 = std::time::Instant::now();
    let layer = get(name).ok_or_else(|| anyhow::anyhow!("layer {name} not found"))?;
    let fl = layer
        .as_float()
        .ok_or_else(|| anyhow::anyhow!("layer {name} already quantized"))?;
    let mut w = fl.weights_kc();
    let mut x = float_cap
        .matrix_kd(name)
        .ok_or_else(|| anyhow::anyhow!("no float capture for {name}"))?;
    let mut xt = prefix_cap
        .matrix_kd(name)
        .ok_or_else(|| anyhow::anyhow!("no prefix capture for {name}"))?;
    anyhow::ensure!(x.cols() == xt.cols(), "capture sample mismatch for {name}");

    // Optional incoherence rotation: rotate the layer's whole input
    // space (weights + both captures); dot products are unchanged in
    // float arithmetic but activation outliers flatten.
    let rotation = if cfg.rotate {
        let seed = name.bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
        let rot = crate::quant::rotation::Rotation::new(w.rows(), seed);
        rot.apply_weights_kc(&mut w);
        rot.apply_capture_kd(&mut x);
        rot.apply_capture_kd(&mut xt);
        Some(rot)
    } else {
        None
    };

    // Activation quantizer calibrated on the quantized-prefix samples
    // (what the layer will actually see at inference, post-rotation).
    let samples: Vec<f64> = if rotation.is_some() {
        xt.data().to_vec()
    } else {
        prefix_cap.samples(name).unwrap().iter().map(|&v| v as f64).collect()
    };
    let act = ActQuantizer::calibrate(&samples, cfg.act_bits, cfg.percentile);

    // The PTQ algorithms correct error against real-valued X̃; feed them
    // the fake-quantized prefix activations so the integer datapath sees
    // exactly what the algorithm optimized for.
    let xt_q = Mat::from_fn(xt.rows(), xt.cols(), |i, j| act.fake(xt.get(i, j)));

    let axe = cfg.axe();
    let mut result: QuantResult = match cfg.algorithm {
        Algorithm::Gpfq => {
            let p = GpfqParams { weight_bits: cfg.weight_bits, axe, act_order: cfg.act_order };
            gpfq_quantize(&w, &x, &xt_q, &p)
        }
        Algorithm::GpfqMemEff => {
            let p = GpfqParams { weight_bits: cfg.weight_bits, axe, act_order: cfg.act_order };
            let g = x.matmul_bt(&xt_q);
            let a = xt_q.gram();
            gpfq_quantize_grams(&w, &g, &a, &p, cfg.gram_damp)?
        }
        Algorithm::Optq => {
            let p = OptqParams {
                weight_bits: cfg.weight_bits,
                axe,
                act_order: cfg.act_order,
                damp: 0.01,
            };
            let gram = xt_q.gram();
            optq_quantize(&w, &gram, &p)?
        }
    };
    if cfg.method == Method::EpInit {
        result = ep_init(&result, cfg.target, cfg.act_bits);
    }

    // Audit against the effective deployment target.
    let k = w.rows();
    let target = cfg.effective_target(k);
    let mut audit = AuditReport::default();
    if let Some((p_inner, tile)) = target.tile_plan(k) {
        for ch in 0..result.c {
            audit.merge(&audit_channel(&result.channel_codes(ch), cfg.act_bits, p_inner, tile));
        }
    }

    // Deployment datapath.
    let datapath = match (cfg.datapath, target.tile_plan(k)) {
        (DatapathMode::Exact, _) | (_, None) => Datapath::Exact,
        (DatapathMode::Faithful, Some((p_inner, tile))) => {
            let inner = cfg.force_eval_bits.unwrap_or(p_inner);
            let outer = match cfg.force_eval_bits {
                Some(p) => crate::quant::outer_bits(p, k, tile),
                None => target.outer_bits(k).unwrap_or(p_inner),
            };
            Datapath::Simulated {
                tile,
                inner_bits: inner,
                outer_bits: outer,
                mode: crate::accum::OverflowMode::Wraparound,
            }
        }
    };
    let sparsity = result.sparsity();
    let mut new_linear = QuantLinear::from_result(&result, fl.b.clone(), act, datapath);
    new_linear.rotation = rotation;
    Ok(StagedLayer {
        name: name.to_string(),
        new_linear,
        w_float: w,
        x,
        xt: xt_q,
        bias_correction: cfg.bias_correction,
        seconds: t0.elapsed().as_secs_f64(),
        audit,
        sparsity,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::dataset::{synth_corpus, synth_glyphs};
    use crate::eval::{perplexity, top1_accuracy};
    use crate::model::{random_mlp, random_transformer, Activation, MlpConfig, TransformerConfig};

    fn lm_fixture() -> (Transformer, Vec<u16>) {
        let cfg = TransformerConfig {
            name: "t".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 16,
            act: Activation::Gelu,
            parallel_residual: false,
        };
        (random_transformer(cfg, 7), synth_corpus(16 * 24, 64, 8))
    }

    #[test]
    fn transformer_pipeline_quantizes_all_layers() {
        let (mut m, toks) = lm_fixture();
        let seqs: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
        let cfg = PipelineConfig::new(Algorithm::Optq, Method::Naive, 8, 8);
        let report = quantize_transformer(&mut m, &seqs, &cfg).unwrap();
        assert_eq!(report.layers.len(), 12);
        for name in m.linear_names() {
            assert!(m.get_linear(&name).unwrap().is_quantized(), "{name}");
        }
        assert!(report.guaranteed_safe(), "naive P* target must audit clean");
    }

    #[test]
    fn eight_bit_quantization_preserves_ppl() {
        let (mut m, toks) = lm_fixture();
        let float_ppl = {
            let r = perplexity(&m, &toks, 16, 8);
            r.ppl
        };
        let seqs: Vec<&[u16]> = toks.chunks_exact(16).take(6).collect();
        let cfg = PipelineConfig::new(Algorithm::Optq, Method::Naive, 8, 8);
        quantize_transformer(&mut m, &seqs, &cfg).unwrap();
        let q_ppl = perplexity(&m, &toks, 16, 8).ppl;
        assert!(
            (q_ppl - float_ppl).abs() / float_ppl < 0.10,
            "W8A8 should be near-lossless: float={float_ppl} quant={q_ppl}"
        );
    }

    #[test]
    fn axe_pipeline_is_guaranteed_safe() {
        let (mut m, toks) = lm_fixture();
        let seqs: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
        let mut cfg = PipelineConfig::new(Algorithm::Gpfq, Method::Axe, 4, 8);
        cfg.target = AccumTarget::MultiStage { p_inner: 14, tile: 8 };
        let report = quantize_transformer(&mut m, &seqs, &cfg).unwrap();
        assert!(report.guaranteed_safe());
        assert!(report.audit.worst_utilization <= 1.0);
        // a forward pass must produce finite logits
        let logits = m.forward(&toks[..16], None);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn ep_init_pipeline_is_guaranteed_safe() {
        let (mut m, toks) = lm_fixture();
        let seqs: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
        let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::EpInit, 4, 8);
        cfg.target = AccumTarget::Monolithic { p_bits: 16 };
        let report = quantize_transformer(&mut m, &seqs, &cfg).unwrap();
        assert!(report.guaranteed_safe());
    }

    #[test]
    fn mlp_pipeline_end_to_end() {
        let set = synth_glyphs(160, 6, 4, 30);
        let mcfg = MlpConfig {
            name: "t".into(),
            input_dim: 36,
            hidden: vec![32, 32],
            classes: 4,
            act: Activation::Relu,
            residual: false,
        };
        let mut m = random_mlp(mcfg, 31);
        let acc_before = top1_accuracy(&m, &set);
        let calib: Vec<&[f32]> = (0..32).map(|i| set.row(i)).collect();
        let cfg = PipelineConfig::new(Algorithm::Gpfq, Method::Naive, 8, 8);
        let report = quantize_mlp(&mut m, &calib, &cfg).unwrap();
        assert_eq!(report.layers.len(), 2);
        let acc_after = top1_accuracy(&m, &set);
        // random net ≈ chance either way; just require it still runs and
        // stays in a sane band
        assert!(acc_after >= acc_before - 30.0);
        assert!(m.layers.iter().all(|l| l.is_quantized()));
    }

    #[test]
    fn rotation_pipeline_stays_accurate_and_safe() {
        let (m0, toks) = lm_fixture();
        let seqs: Vec<&[u16]> = toks.chunks_exact(16).take(6).collect();
        let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
        cfg.target = AccumTarget::Monolithic { p_bits: 18 };
        cfg.rotate = true;
        let mut m = m0.clone();
        let report = quantize_transformer(&mut m, &seqs, &cfg).unwrap();
        assert!(report.guaranteed_safe());
        let rotated_ppl = perplexity(&m, &toks, 16, 8).ppl;
        let mut cfg_plain = cfg.clone();
        cfg_plain.rotate = false;
        let mut m2 = m0.clone();
        quantize_transformer(&mut m2, &seqs, &cfg_plain).unwrap();
        let plain_ppl = perplexity(&m2, &toks, 16, 8).ppl;
        // rotation must not break anything (and often helps with outliers)
        assert!(
            rotated_ppl < plain_ppl * 1.5,
            "rotated {rotated_ppl} vs plain {plain_ppl}"
        );
    }

    #[test]
    fn faithful_datapath_matches_exact_when_safe() {
        let (m0, toks) = lm_fixture();
        let seqs: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
        let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
        cfg.target = AccumTarget::Monolithic { p_bits: 16 };
        let mut m_exact = m0.clone();
        quantize_transformer(&mut m_exact, &seqs, &cfg).unwrap();
        let mut cfg_f = cfg.clone();
        cfg_f.datapath = DatapathMode::Faithful;
        let mut m_faith = m0.clone();
        quantize_transformer(&mut m_faith, &seqs, &cfg_f).unwrap();
        let la = m_exact.forward(&toks[..16], None);
        let lb = m_faith.forward(&toks[..16], None);
        for (a, b) in la.iter().zip(lb.iter()) {
            assert!((a - b).abs() < 1e-5, "exact vs faithful diverged: {a} {b}");
        }
        assert_eq!(m_faith.overflow_events(), 0);
    }
}
