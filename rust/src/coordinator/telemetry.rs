//! Per-step serving telemetry: a preallocated record ring, log2
//! latency histograms, and pluggable structured event sinks.
//!
//! The serve report prints end-of-run aggregates; a production engine
//! needs *continuous* signals — step-latency tails, batch occupancy,
//! and the overflow-event **rate** as load shifts (the paper's exact
//! per-accumulator-width overflow accounting, as a live stream rather
//! than a final count). This module provides the three pieces:
//!
//! - [`StepMetrics`] — a fixed-capacity, preallocated ring of
//!   [`StepRecord`]s plus [`LatHist`] histograms, filled by the engine
//!   at the end of every ragged step with **zero hot-path allocation**
//!   (asserted by `tests/zero_alloc_decode.rs`). When the off-thread
//!   drainer falls behind, the oldest buffered record is overwritten
//!   and the `dropped` counter advances — the histograms and running
//!   sums still see every step, so aggregates stay exact even when the
//!   raw stream is lossy.
//! - [`EventSink`] — the pluggable structured-output trait
//!   ([`JsonlSink`], [`StdoutSink`], [`NullSink`]), drained off the
//!   engine thread by [`spawn_drainer`] on a flush interval; one sink
//!   per engine thread, selected via `axe serve --metrics <path|->`
//!   ([`SinkSpec`]).
//! - [`LatHist`] — fixed-bucket log2 histograms (48 buckets, so any
//!   u64 nanosecond value lands somewhere) for step latency, TTFT,
//!   TPOT and occupancy, mergeable across engines into one
//!   [`MetricsSummary`] for the serve report and the bench trajectory
//!   (`BENCH_decode.json` `"step_histograms"`).

use crate::util::json::Json;
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Version tag stamped on every emitted record; bump on any
/// field-set change so downstream consumers can dispatch. v2 added the
/// overload-control counters (`shed`, `deadline_miss`, `cancelled`,
/// `queue_hwm`); v3 adds the speculative-decoding counters
/// (`spec_proposed`, `spec_accepted`, `draft_rows`, `overflow_draft`).
/// Consumers (`check_jsonl.py`, `metrics_report.py`) still accept v1
/// and v2 streams.
pub const SCHEMA_VERSION: u32 = 3;

/// Default ring capacity (records buffered between drains) — the
/// `--metrics-ring` default. At one record per ragged step, 4096 steps
/// of slack before the drainer has to keep up.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Default drain threshold (buffered records before the drainer writes
/// a batch) — the `--metrics-flush-every` default.
pub const DEFAULT_FLUSH_EVERY: usize = 64;

/// One per-step telemetry record. Plain `Copy` data so ring writes are
/// a memcpy and the drainer can batch-copy records out under the lock
/// and format them outside it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepRecord {
    /// Engine-local step index (consecutive over *executed* ragged
    /// steps — empty scheduler iterations record nothing).
    pub step: u64,
    /// Wall time of the full scheduler iteration (sample/slide/retire
    /// + compose + ragged kernel call + routing), nanoseconds.
    pub wall_ns: u64,
    /// Decode rows in this step (one per generating sequence).
    pub decode_rows: u32,
    /// Prompt (and slide-tail) tokens prefetched this step across all
    /// admitting sequences.
    pub prefill_rows: u32,
    /// Prefill chunks (groups) those rows arrived in.
    pub prefill_chunks: u32,
    /// Total rows executed: `decode_rows + prefill_rows` — the step's
    /// batch occupancy.
    pub tokens: u32,
    /// Overflow events from the quantized **linear** layers this step
    /// (per-group kernel attribution, attention share subtracted).
    pub overflow_linear: u64,
    /// Overflow events from the quantized-KV **attention** matmuls
    /// this step (0 on the f32 backend).
    pub overflow_attn: u64,
    /// Resident (deduplicated) KV arena bytes after the step.
    pub arena_resident_bytes: u64,
    /// Reserved KV arena bytes (every page backed).
    pub arena_capacity_bytes: u64,
    /// Prefix-cache pages adopted since the previous record.
    pub prefix_hits: u32,
    /// Private pages deduplicated onto cached twins since the previous
    /// record.
    pub prefix_dedups: u32,
    /// Prefix-cache entries evicted under pressure since the previous
    /// record.
    pub prefix_evictions: u32,
    /// Threads the banded attention sweep actually fanned out across
    /// (1 = the serial path).
    pub attn_bands: u32,
    /// Pending (unadmitted) queue depth sampled at this step's
    /// admission poll.
    pub queue_depth: u32,
    /// Running high-water mark of the sampled queue depth — monotone
    /// non-decreasing within one engine's record stream (v2).
    pub queue_hwm: u32,
    /// Requests shed by the bounded queue's capacity policy since the
    /// previous record (credited to exactly one engine's stream) (v2).
    pub shed: u32,
    /// Requests dropped on an expired deadline since the previous
    /// record — at admission or mid-flight (v2).
    pub deadline_miss: u32,
    /// Requests dropped via their cancel token since the previous
    /// record (v2).
    pub cancelled: u32,
    /// Draft tokens proposed by the speculative scheduler this step
    /// (`speculate_k - 1` and window/remaining caps per decoding
    /// sequence; 0 with speculation off) (v3).
    pub spec_proposed: u32,
    /// Proposed draft tokens the full-width verify pass accepted this
    /// step (`spec_accepted <= spec_proposed` always) (v3).
    pub spec_accepted: u32,
    /// Narrow-register draft rows executed this step — the speculative
    /// overhead's work measure; **not** counted in `tokens`, which
    /// covers full-width rows only (v3).
    pub draft_rows: u32,
    /// Overflow events the narrowed draft rounds triggered this step.
    /// Work-done telemetry only: draft rows roll back, so these events
    /// never reach per-request attribution (v3).
    pub overflow_draft: u64,
}

impl StepRecord {
    /// The stable JSONL schema — one flat object, every field numeric,
    /// plus `schema_version`. Field *set* changes require a
    /// [`SCHEMA_VERSION`] bump (golden-tested below and validated in CI
    /// by `.github/scripts/check_jsonl.py`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("schema_version", SCHEMA_VERSION.into())
            .set("step", self.step.into())
            .set("wall_ns", self.wall_ns.into())
            .set("decode_rows", self.decode_rows.into())
            .set("prefill_rows", self.prefill_rows.into())
            .set("prefill_chunks", self.prefill_chunks.into())
            .set("tokens", self.tokens.into())
            .set("overflow_linear", self.overflow_linear.into())
            .set("overflow_attn", self.overflow_attn.into())
            .set("arena_resident_bytes", self.arena_resident_bytes.into())
            .set("arena_capacity_bytes", self.arena_capacity_bytes.into())
            .set("prefix_hits", self.prefix_hits.into())
            .set("prefix_dedups", self.prefix_dedups.into())
            .set("prefix_evictions", self.prefix_evictions.into())
            .set("attn_bands", self.attn_bands.into())
            .set("queue_depth", self.queue_depth.into())
            .set("queue_hwm", self.queue_hwm.into())
            .set("shed", self.shed.into())
            .set("deadline_miss", self.deadline_miss.into())
            .set("cancelled", self.cancelled.into())
            .set("spec_proposed", self.spec_proposed.into())
            .set("spec_accepted", self.spec_accepted.into())
            .set("draft_rows", self.draft_rows.into())
            .set("overflow_draft", self.overflow_draft.into());
        o
    }
}

/// Log2 bucket count: bucket `i` holds values in `[2^i, 2^(i+1))`
/// (bucket 0 additionally holds 0), bucket 47 holds everything from
/// `2^47` up — so any u64 lands somewhere and observation can never
/// fail or allocate.
pub const HIST_BUCKETS: usize = 48;

/// Fixed-bucket log2 histogram — `Copy`, allocation-free to observe,
/// associative to merge. Quantiles return the **inclusive upper bound**
/// of the bucket holding the rank-`q` observation (clamped to the true
/// maximum), so a log2 histogram quantile is exact to within one
/// bucket of the sorted-sample quantile by construction.
#[derive(Clone, Copy, Debug)]
pub struct LatHist {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    max: u64,
}

// [T; 48] has no Default impl (std stops at 32) — spell it out.
impl Default for LatHist {
    fn default() -> LatHist {
        LatHist { buckets: [0; HIST_BUCKETS], count: 0, max: 0 }
    }
}

impl LatHist {
    pub fn new() -> LatHist {
        LatHist::default()
    }

    /// Bucket index of `v`: floor(log2(v)) clamped to the bucket
    /// range; 0 and 1 both land in bucket 0.
    pub fn bucket_of(v: u64) -> usize {
        ((63 - (v | 1).leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i`.
    pub fn bucket_upper(i: usize) -> u64 {
        if i + 1 >= 64 {
            u64::MAX
        } else {
            (1u64 << (i + 1)) - 1
        }
    }

    pub fn observe(&mut self, v: u64) {
        self.observe_n(v, 1);
    }

    /// Record `n` observations of `v` at once (TPOT: one per decode
    /// row of a step, all sharing the step's wall time).
    pub fn observe_n(&mut self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.buckets[LatHist::bucket_of(v)] += n;
        self.count += n;
        self.max = self.max.max(v);
    }

    /// Element-wise merge — commutative and associative, so per-engine
    /// histograms fold into one in any order.
    pub fn merge(&mut self, other: &LatHist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_value(&self) -> u64 {
        self.max
    }

    pub fn bucket_counts(&self) -> &[u64; HIST_BUCKETS] {
        &self.buckets
    }

    /// Rank-based quantile (`q` in [0, 1]): the inclusive upper bound
    /// of the bucket holding the `ceil(count * q)`-th observation,
    /// clamped to the observed maximum. 0 when empty. The rank formula
    /// matches the sorted-vector percentile in
    /// `ServeStats::from_responses`, so both select the same
    /// observation and the histogram answer is exact to within its
    /// bucket.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count as f64 * q).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= rank {
                return LatHist::bucket_upper(i).min(self.max);
            }
        }
        self.max
    }
}

/// Mergeable cross-engine aggregate of one engine's telemetry —
/// everything the serve report and the bench `"step_histograms"`
/// section need, and nothing that refers back into the ring.
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSummary {
    /// Ragged steps recorded (includes records later dropped from the
    /// ring — histograms and sums saw them all).
    pub steps: u64,
    /// Records overwritten before the drainer took them.
    pub records_dropped: u64,
    /// Total rows executed across steps (decode + prefill).
    pub tokens: u64,
    /// Total quantized-linear overflow events.
    pub overflow_linear: u64,
    /// Total quantized-KV attention overflow events.
    pub overflow_attn: u64,
    /// Total requests shed by the bounded queue (v2).
    pub shed: u64,
    /// Total requests dropped on an expired deadline (v2).
    pub deadline_miss: u64,
    /// Total requests dropped via their cancel token (v2).
    pub cancelled: u64,
    /// Queue-depth high-water mark (max over records; max-merged
    /// across engines) (v2).
    pub queue_hwm: u64,
    /// Total speculative draft tokens proposed (v3).
    pub spec_proposed: u64,
    /// Total draft tokens the verify passes accepted (v3).
    pub spec_accepted: u64,
    /// Total narrow-register draft rows executed (v3).
    pub draft_rows: u64,
    /// Total overflow events from the narrowed draft rounds (v3).
    pub overflow_draft: u64,
    /// Step wall-time histogram, nanoseconds.
    pub step_ns: LatHist,
    /// Time-to-first-token histogram, nanoseconds (requests that
    /// generate ≥ 1 token).
    pub ttft_ns: LatHist,
    /// Time-per-output-token histogram, nanoseconds: each decode row
    /// observes its step's wall time.
    pub tpot_ns: LatHist,
    /// Batch-occupancy histogram (rows per executed step).
    pub occupancy: LatHist,
}

impl MetricsSummary {
    pub fn merge(&mut self, other: &MetricsSummary) {
        self.steps += other.steps;
        self.records_dropped += other.records_dropped;
        self.tokens += other.tokens;
        self.overflow_linear += other.overflow_linear;
        self.overflow_attn += other.overflow_attn;
        self.shed += other.shed;
        self.deadline_miss += other.deadline_miss;
        self.cancelled += other.cancelled;
        self.queue_hwm = self.queue_hwm.max(other.queue_hwm);
        self.spec_proposed += other.spec_proposed;
        self.spec_accepted += other.spec_accepted;
        self.draft_rows += other.draft_rows;
        self.overflow_draft += other.overflow_draft;
        self.step_ns.merge(&other.step_ns);
        self.ttft_ns.merge(&other.ttft_ns);
        self.tpot_ns.merge(&other.tpot_ns);
        self.occupancy.merge(&other.occupancy);
    }
}

/// Fixed-capacity step-record ring + histograms. All storage is
/// preallocated at construction; [`StepMetrics::record`] and
/// [`StepMetrics::record_ttft`] touch only owned arrays — no heap
/// traffic, ever (the zero-allocation decode bar covers them).
#[derive(Debug)]
pub struct StepMetrics {
    ring: Vec<StepRecord>,
    /// Index of the oldest undrained record.
    head: usize,
    /// Undrained records buffered in the ring.
    len: usize,
    recorded: u64,
    dropped: u64,
    tokens: u64,
    overflow_linear: u64,
    overflow_attn: u64,
    shed: u64,
    deadline_miss: u64,
    cancelled: u64,
    queue_hwm: u64,
    spec_proposed: u64,
    spec_accepted: u64,
    draft_rows: u64,
    overflow_draft: u64,
    step_ns: LatHist,
    ttft_ns: LatHist,
    tpot_ns: LatHist,
    occupancy: LatHist,
}

impl StepMetrics {
    pub fn new(capacity: usize) -> StepMetrics {
        StepMetrics {
            ring: vec![StepRecord::default(); capacity.max(1)],
            head: 0,
            len: 0,
            recorded: 0,
            dropped: 0,
            tokens: 0,
            overflow_linear: 0,
            overflow_attn: 0,
            shed: 0,
            deadline_miss: 0,
            cancelled: 0,
            queue_hwm: 0,
            spec_proposed: 0,
            spec_accepted: 0,
            draft_rows: 0,
            overflow_draft: 0,
            step_ns: LatHist::new(),
            ttft_ns: LatHist::new(),
            tpot_ns: LatHist::new(),
            occupancy: LatHist::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.ring.len()
    }

    /// Append one step record. Histograms and running sums always see
    /// it; if the ring is full (drainer behind), the **oldest** buffered
    /// record is overwritten and `dropped` advances — newest data wins,
    /// aggregates stay exact.
    pub fn record(&mut self, rec: StepRecord) {
        self.step_ns.observe(rec.wall_ns);
        self.occupancy.observe(rec.tokens as u64);
        self.tpot_ns.observe_n(rec.wall_ns, rec.decode_rows as u64);
        self.tokens += rec.tokens as u64;
        self.overflow_linear += rec.overflow_linear;
        self.overflow_attn += rec.overflow_attn;
        self.shed += rec.shed as u64;
        self.deadline_miss += rec.deadline_miss as u64;
        self.cancelled += rec.cancelled as u64;
        self.queue_hwm = self.queue_hwm.max(rec.queue_hwm as u64);
        self.spec_proposed += rec.spec_proposed as u64;
        self.spec_accepted += rec.spec_accepted as u64;
        self.draft_rows += rec.draft_rows as u64;
        self.overflow_draft += rec.overflow_draft;
        let cap = self.ring.len();
        if self.len == cap {
            self.ring[self.head] = rec;
            self.head = (self.head + 1) % cap;
            self.dropped += 1;
        } else {
            self.ring[(self.head + self.len) % cap] = rec;
            self.len += 1;
        }
        self.recorded += 1;
    }

    /// Record one request's time-to-first-token (nanoseconds,
    /// submission → first sampled token).
    pub fn record_ttft(&mut self, ns: u64) {
        self.ttft_ns.observe(ns);
    }

    /// Undrained records currently buffered.
    pub fn buffered(&self) -> usize {
        self.len
    }

    /// Records ever recorded (drained, buffered, or dropped).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Records overwritten before being drained.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Copy every buffered record into `out` in step order and reset
    /// the buffer. The drainer calls this under the shared lock (a
    /// bounded memcpy) and formats/writes *outside* it.
    pub fn take_buffered(&mut self, out: &mut Vec<StepRecord>) {
        out.clear();
        let cap = self.ring.len();
        for i in 0..self.len {
            out.push(self.ring[(self.head + i) % cap]);
        }
        self.head = (self.head + self.len) % cap;
        self.len = 0;
    }

    /// Snapshot the mergeable aggregate (histograms + sums).
    pub fn summary(&self) -> MetricsSummary {
        MetricsSummary {
            steps: self.recorded,
            records_dropped: self.dropped,
            tokens: self.tokens,
            overflow_linear: self.overflow_linear,
            overflow_attn: self.overflow_attn,
            shed: self.shed,
            deadline_miss: self.deadline_miss,
            cancelled: self.cancelled,
            queue_hwm: self.queue_hwm,
            spec_proposed: self.spec_proposed,
            spec_accepted: self.spec_accepted,
            draft_rows: self.draft_rows,
            overflow_draft: self.overflow_draft,
            step_ns: self.step_ns,
            ttft_ns: self.ttft_ns,
            tpot_ns: self.tpot_ns,
            occupancy: self.occupancy,
        }
    }
}

/// Handle shared between one engine thread (recording) and its drainer
/// (draining). The mutex is uncontended in steady state — the engine
/// takes it once per step for a memcpy-sized critical section, the
/// drainer once per flush interval.
#[derive(Clone, Debug)]
pub struct SharedMetrics {
    inner: Arc<Mutex<StepMetrics>>,
}

impl SharedMetrics {
    pub fn new(ring_capacity: usize) -> SharedMetrics {
        SharedMetrics { inner: Arc::new(Mutex::new(StepMetrics::new(ring_capacity))) }
    }

    /// Run `f` under the lock. Locking an uncontended std mutex does
    /// not allocate, so recording through this keeps the zero-alloc
    /// decode bar.
    pub fn with<R>(&self, f: impl FnOnce(&mut StepMetrics) -> R) -> R {
        f(&mut self.inner.lock().unwrap())
    }

    /// Snapshot the mergeable aggregate.
    pub fn summary(&self) -> MetricsSummary {
        self.with(|m| m.summary())
    }
}

/// Pluggable structured event sink — one per engine thread, driven off
/// the engine thread by [`spawn_drainer`]. Writes are best-effort:
/// telemetry must never take the serving path down, so I/O errors are
/// swallowed (the JSONL consumer sees a truncated stream, the in-memory
/// aggregates are unaffected).
pub trait EventSink: Send {
    /// Emit one step record.
    fn record_step(&mut self, rec: &StepRecord);
    /// Flush buffered output (end of a drain batch, and at shutdown).
    fn flush(&mut self);
}

/// Discards everything — telemetry aggregates without a stream.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl EventSink for NullSink {
    fn record_step(&mut self, _rec: &StepRecord) {}
    fn flush(&mut self) {}
}

/// One JSON object per line to stdout (`--metrics -`).
#[derive(Clone, Copy, Debug, Default)]
pub struct StdoutSink;

impl EventSink for StdoutSink {
    fn record_step(&mut self, rec: &StepRecord) {
        println!("{}", rec.to_json().to_string());
    }
    fn flush(&mut self) {
        let _ = io::stdout().flush();
    }
}

/// Buffered JSON-lines sink: one object per step, stable schema
/// ([`StepRecord::to_json`]), flushed on the drain interval.
#[derive(Debug)]
pub struct JsonlSink<W: Write + Send> {
    w: W,
}

impl JsonlSink<BufWriter<std::fs::File>> {
    /// Create (truncate) `path` and wrap it in a buffered writer.
    pub fn create(path: &Path) -> io::Result<JsonlSink<BufWriter<std::fs::File>>> {
        Ok(JsonlSink::new(BufWriter::new(std::fs::File::create(path)?)))
    }
}

impl<W: Write + Send> JsonlSink<W> {
    pub fn new(w: W) -> JsonlSink<W> {
        JsonlSink { w }
    }

    /// Unwrap the writer (tests inspect the bytes).
    pub fn into_inner(self) -> W {
        self.w
    }
}

impl<W: Write + Send> EventSink for JsonlSink<W> {
    fn record_step(&mut self, rec: &StepRecord) {
        let _ = writeln!(self.w, "{}", rec.to_json().to_string());
    }
    fn flush(&mut self) {
        let _ = self.w.flush();
    }
}

/// CLI-level sink selection (`axe serve --metrics <path|->`): how each
/// engine thread's sink is built.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum SinkSpec {
    /// No stream — in-memory aggregates only.
    #[default]
    None,
    /// JSON lines to stdout.
    Stdout,
    /// JSON lines to a file; with several engines, engine `i` writes
    /// `<path>.<i>` (sinks are per-thread, streams stay ordered).
    Jsonl(PathBuf),
}

impl SinkSpec {
    /// `-` selects stdout, anything else is a file path.
    pub fn parse(arg: &str) -> SinkSpec {
        if arg == "-" {
            SinkSpec::Stdout
        } else {
            SinkSpec::Jsonl(PathBuf::from(arg))
        }
    }

    /// Build engine `engine`'s sink (of `engines` total). `Ok(None)`
    /// means telemetry streaming is off for this run.
    pub fn build(&self, engine: usize, engines: usize) -> io::Result<Option<Box<dyn EventSink>>> {
        Ok(match self {
            SinkSpec::None => None,
            SinkSpec::Stdout => Some(Box::new(StdoutSink)),
            SinkSpec::Jsonl(path) => {
                let p = if engines <= 1 {
                    path.clone()
                } else {
                    PathBuf::from(format!("{}.{engine}", path.display()))
                };
                Some(Box::new(JsonlSink::create(&p)?))
            }
        })
    }
}

/// Off-thread drainer handle: stop + join via [`Drainer::finish`]
/// (drains whatever is still buffered, flushes, returns the records
/// written). Dropping without `finish` stops and joins too.
#[derive(Debug)]
pub struct Drainer {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<u64>>,
}

/// Spawn the drain thread for one engine's metrics: every tick it
/// checks the buffer and, once `flush_every` records are waiting (or
/// at shutdown), copies them out under the lock and writes them to the
/// sink outside it. The engine must have stopped stepping before
/// [`Drainer::finish`] for the final drain to be complete.
pub fn spawn_drainer(
    metrics: SharedMetrics,
    mut sink: Box<dyn EventSink>,
    flush_every: usize,
) -> Drainer {
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = Arc::clone(&stop);
    let flush_every = flush_every.max(1);
    let handle = std::thread::spawn(move || {
        let mut batch: Vec<StepRecord> = Vec::with_capacity(flush_every.max(64));
        let mut written = 0u64;
        loop {
            let stopping = stop_flag.load(Ordering::Acquire);
            if stopping || metrics.with(|m| m.buffered()) >= flush_every {
                metrics.with(|m| m.take_buffered(&mut batch));
                for rec in &batch {
                    sink.record_step(rec);
                }
                written += batch.len() as u64;
                sink.flush();
                if stopping {
                    return written;
                }
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    });
    Drainer { stop, handle: Some(handle) }
}

impl Drainer {
    /// Stop, final-drain, flush, join; returns total records written.
    pub fn finish(mut self) -> u64 {
        self.stop.store(true, Ordering::Release);
        self.handle.take().map(|h| h.join().expect("drainer panicked")).unwrap_or(0)
    }
}

impl Drop for Drainer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64) -> StepRecord {
        StepRecord {
            step,
            wall_ns: 1000 + step,
            decode_rows: 2,
            prefill_rows: 1,
            prefill_chunks: 1,
            tokens: 3,
            ..StepRecord::default()
        }
    }

    #[test]
    fn ring_wraparound_and_drop_accounting() {
        let mut m = StepMetrics::new(4);
        for i in 0..10 {
            m.record(rec(i));
        }
        assert_eq!(m.recorded(), 10);
        assert_eq!(m.dropped(), 6, "capacity 4, 10 records → 6 overwritten");
        assert_eq!(m.buffered(), 4);
        let mut out = Vec::new();
        m.take_buffered(&mut out);
        // newest-wins: the surviving records are the last 4, in order
        assert_eq!(out.iter().map(|r| r.step).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert_eq!(m.buffered(), 0);
        // a drain resets the buffer but not the lifetime counters …
        for i in 10..13 {
            m.record(rec(i));
        }
        assert_eq!(m.dropped(), 6, "room after the drain — no new drops");
        let mut out2 = Vec::new();
        m.take_buffered(&mut out2);
        assert_eq!(out2.iter().map(|r| r.step).collect::<Vec<_>>(), vec![10, 11, 12]);
        // … and the aggregates saw every record, dropped or not
        let s = m.summary();
        assert_eq!(s.steps, 13);
        assert_eq!(s.records_dropped, 6);
        assert_eq!(s.tokens, 13 * 3);
        assert_eq!(s.step_ns.count(), 13);
        assert_eq!(s.tpot_ns.count(), 13 * 2, "one TPOT observation per decode row");
        assert_eq!(s.occupancy.count(), 13);
    }

    #[test]
    fn lathist_bucket_boundaries() {
        assert_eq!(LatHist::bucket_of(0), 0);
        assert_eq!(LatHist::bucket_of(1), 0);
        assert_eq!(LatHist::bucket_of(2), 1);
        assert_eq!(LatHist::bucket_of(3), 1);
        assert_eq!(LatHist::bucket_of(4), 2);
        assert_eq!(LatHist::bucket_of(1023), 9);
        assert_eq!(LatHist::bucket_of(1024), 10);
        assert_eq!(LatHist::bucket_of(u64::MAX), HIST_BUCKETS - 1, "tail bucket is open");
        assert_eq!(LatHist::bucket_upper(0), 1);
        assert_eq!(LatHist::bucket_upper(9), 1023);
        assert_eq!(LatHist::bucket_upper(HIST_BUCKETS - 1), u64::MAX);
        // every boundary value buckets consistently with its upper bound
        for i in 0..HIST_BUCKETS - 1 {
            assert_eq!(LatHist::bucket_of(LatHist::bucket_upper(i)), i);
            assert_eq!(LatHist::bucket_of(LatHist::bucket_upper(i) + 1), i + 1);
        }
    }

    #[test]
    fn lathist_quantiles_and_merge_associativity() {
        let mut h = LatHist::new();
        for v in 1..=100u64 {
            h.observe(v);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.max_value(), 100);
        // rank 50 is value 50 → bucket 5 ([32, 64)) → upper bound 63
        assert_eq!(h.quantile(0.50), 63);
        // rank 100 is value 100 → bucket 6, upper bound 127 clamps to max
        assert_eq!(h.quantile(1.0), 100);
        assert_eq!(LatHist::new().quantile(0.5), 0, "empty histogram");

        // merge associativity: (a ∪ b) ∪ c == a ∪ (b ∪ c)
        let mk = |seed: u64, n: u64| {
            let mut h = LatHist::new();
            let mut x = seed;
            for _ in 0..n {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                h.observe(x >> 40);
            }
            h
        };
        let (a, b, c) = (mk(1, 37), mk(2, 53), mk(3, 71));
        let mut left = a;
        left.merge(&b);
        left.merge(&c);
        let mut bc = b;
        bc.merge(&c);
        let mut right = a;
        right.merge(&bc);
        assert_eq!(left.bucket_counts(), right.bucket_counts());
        assert_eq!(left.count(), right.count());
        assert_eq!(left.max_value(), right.max_value());
        assert_eq!(left.count(), 37 + 53 + 71);
    }

    /// The JSONL schema is a stable contract: the exact field set and
    /// the schema_version below. Changing either requires bumping
    /// [`SCHEMA_VERSION`] and updating `.github/scripts/check_jsonl.py`.
    #[test]
    fn jsonl_golden_schema() {
        let golden = [
            "arena_capacity_bytes",
            "arena_resident_bytes",
            "attn_bands",
            "cancelled",
            "deadline_miss",
            "decode_rows",
            "draft_rows",
            "overflow_attn",
            "overflow_draft",
            "overflow_linear",
            "prefill_chunks",
            "prefill_rows",
            "prefix_dedups",
            "prefix_evictions",
            "prefix_hits",
            "queue_depth",
            "queue_hwm",
            "schema_version",
            "shed",
            "spec_accepted",
            "spec_proposed",
            "step",
            "tokens",
            "wall_ns",
        ];
        let mut sink = JsonlSink::new(Vec::<u8>::new());
        sink.record_step(&rec(7));
        sink.record_step(&rec(8));
        sink.flush();
        let text = String::from_utf8(sink.into_inner()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "one object per line");
        for line in &lines {
            let v = Json::parse(line).expect("every line parses");
            let keys: Vec<&str> = v.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
            assert_eq!(keys, golden, "field set drifted without a schema bump");
            assert_eq!(v.get("schema_version").unwrap().as_usize(), Some(3));
        }
        assert_eq!(Json::parse(lines[0]).unwrap().get("step").unwrap().as_usize(), Some(7));
    }

    /// Test sink capturing records through a shared handle (the drainer
    /// boxes its sink, so a Vec can't be recovered by unboxing).
    struct CaptureSink {
        out: Arc<Mutex<Vec<StepRecord>>>,
        flushes: Arc<Mutex<usize>>,
    }

    impl EventSink for CaptureSink {
        fn record_step(&mut self, rec: &StepRecord) {
            self.out.lock().unwrap().push(*rec);
        }
        fn flush(&mut self) {
            *self.flushes.lock().unwrap() += 1;
        }
    }

    #[test]
    fn drainer_drains_everything_in_order() {
        let sm = SharedMetrics::new(64);
        let out = Arc::new(Mutex::new(Vec::new()));
        let flushes = Arc::new(Mutex::new(0usize));
        let sink = CaptureSink { out: Arc::clone(&out), flushes: Arc::clone(&flushes) };
        let drainer = spawn_drainer(sm.clone(), Box::new(sink), 8);
        for i in 0..30 {
            sm.with(|m| m.record(rec(i)));
        }
        // the engine has stopped recording; finish must drain the tail
        let written = drainer.finish();
        assert_eq!(written, 30);
        let got = out.lock().unwrap();
        assert_eq!(got.len(), 30);
        assert!(got.windows(2).all(|w| w[0].step + 1 == w[1].step), "records stay ordered");
        assert!(*flushes.lock().unwrap() >= 1, "shutdown always flushes");
        assert_eq!(sm.with(|m| m.dropped()), 0, "ring never overflowed");
    }

    #[test]
    fn sink_spec_parse_and_multi_engine_paths() {
        assert_eq!(SinkSpec::parse("-"), SinkSpec::Stdout);
        assert_eq!(SinkSpec::parse("m.jsonl"), SinkSpec::Jsonl(PathBuf::from("m.jsonl")));
        assert_eq!(SinkSpec::default(), SinkSpec::None);
        assert!(SinkSpec::None.build(0, 1).unwrap().is_none());
        let dir = std::env::temp_dir().join(format!("axe_sinkspec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let spec = SinkSpec::Jsonl(dir.join("m.jsonl"));
        {
            let mut s = spec.build(0, 1).unwrap().unwrap();
            s.record_step(&rec(0));
            s.flush();
        }
        assert!(dir.join("m.jsonl").is_file(), "single engine writes the path verbatim");
        {
            let mut s = spec.build(1, 2).unwrap().unwrap();
            s.record_step(&rec(0));
            s.flush();
        }
        assert!(dir.join("m.jsonl.1").is_file(), "engine 1 of 2 writes a suffixed path");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn summary_merge_folds_engines() {
        let mut a = StepMetrics::new(8);
        let mut b = StepMetrics::new(8);
        for i in 0..5 {
            a.record(StepRecord {
                shed: 1,
                queue_hwm: 10 + i as u32,
                spec_proposed: 3,
                spec_accepted: 2,
                draft_rows: 3,
                overflow_draft: 7,
                ..rec(i)
            });
            a.record_ttft(500 + i);
        }
        for i in 0..3 {
            b.record(StepRecord { deadline_miss: 2, cancelled: 1, queue_hwm: 40, ..rec(i) });
        }
        let mut s = a.summary();
        s.merge(&b.summary());
        assert_eq!(s.steps, 8);
        assert_eq!(s.tokens, 8 * 3);
        assert_eq!(s.step_ns.count(), 8);
        assert_eq!(s.ttft_ns.count(), 5);
        assert_eq!(s.tpot_ns.count(), 8 * 2);
        // v2 overload counters: terminal events sum, the high-water
        // mark max-merges
        assert_eq!(s.shed, 5);
        assert_eq!(s.deadline_miss, 6);
        assert_eq!(s.cancelled, 3);
        assert_eq!(s.queue_hwm, 40);
        // v3 speculation counters sum across records and engines
        assert_eq!(s.spec_proposed, 15);
        assert_eq!(s.spec_accepted, 10);
        assert_eq!(s.draft_rows, 15);
        assert_eq!(s.overflow_draft, 35);
    }
}
