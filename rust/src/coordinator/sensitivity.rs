//! Sensitivity analyses:
//!
//! 1. **Per-layer**: quantize one layer at a time (leaving the rest
//!    float) and measure the metric impact — identifies which layers
//!    consume the accumulator budget hardest (the per-layer analog of
//!    the paper's App. D sparsity tables).
//! 2. **Pipeline-stage ablation**: toggle the design choices the paper
//!    fixes (graph equalization, bias correction, act-order, and this
//!    repo's rotation extension) one at a time against the default
//!    pipeline.

use super::pipeline::{quantize_transformer, PipelineConfig};
use crate::eval::perplexity;
use crate::model::Transformer;
use crate::util::Table;
use anyhow::Result;

/// Per-layer sensitivity result.
#[derive(Clone, Debug)]
pub struct LayerSensitivity {
    pub name: String,
    pub k: usize,
    pub ppl: f64,
    pub delta: f64,
    pub sparsity: f64,
}

/// Quantize each linear layer in isolation and measure perplexity.
pub fn per_layer_sensitivity(
    base: &Transformer,
    calib: &[&[u16]],
    eval_tokens: &[u16],
    eval_seqs: usize,
    cfg: &PipelineConfig,
) -> Result<Vec<LayerSensitivity>> {
    let seq = base.cfg.max_seq;
    let float_ppl = perplexity(base, eval_tokens, seq, eval_seqs).ppl;
    let mut out = Vec::new();
    for name in base.linear_names() {
        let mut model = base.clone();
        let report = quantize_one(&mut model, calib, cfg, &name)?;
        let ppl = perplexity(&model, eval_tokens, seq, eval_seqs).ppl;
        out.push(LayerSensitivity {
            name: name.clone(),
            k: model.get_linear(&name).map(|l| l.in_dim()).unwrap_or(0),
            ppl,
            delta: ppl - float_ppl,
            sparsity: report,
        });
    }
    Ok(out)
}

/// Quantize only `target_name` (helper for the sensitivity loop).
fn quantize_one(
    model: &mut Transformer,
    calib: &[&[u16]],
    cfg: &PipelineConfig,
    target_name: &str,
) -> Result<f64> {
    // run the standard pipeline but restricted to one layer by cloning
    // the model and reverting every other layer afterwards.
    let original = model.clone();
    let report = quantize_transformer(model, calib, cfg)?;
    let mut sparsity = 0.0;
    for l in &report.layers {
        if l.name == target_name {
            sparsity = l.sparsity;
        }
    }
    for name in original.linear_names() {
        if name != target_name {
            let fresh = original.get_linear(&name).unwrap().clone();
            *model.get_linear_mut(&name).unwrap() = fresh;
        }
    }
    Ok(sparsity)
}

/// One row of the pipeline-stage ablation.
#[derive(Clone, Debug)]
pub struct StageAblation {
    pub label: String,
    pub ppl: f64,
}

/// Toggle pipeline stages one at a time against the default config.
pub fn stage_ablation(
    base: &Transformer,
    calib: &[&[u16]],
    eval_tokens: &[u16],
    eval_seqs: usize,
    cfg: &PipelineConfig,
) -> Result<Vec<StageAblation>> {
    let seq = base.cfg.max_seq;
    let mut rows = Vec::new();
    let mut run = |label: &str, cfg: PipelineConfig| -> Result<()> {
        let mut model = base.clone();
        quantize_transformer(&mut model, calib, &cfg)?;
        rows.push(StageAblation {
            label: label.to_string(),
            ppl: perplexity(&model, eval_tokens, seq, eval_seqs).ppl,
        });
        Ok(())
    };
    run("default", cfg.clone())?;
    let mut c = cfg.clone();
    c.equalize = false;
    run("- equalization", c)?;
    let mut c = cfg.clone();
    c.bias_correction = false;
    run("- bias correction", c)?;
    let mut c = cfg.clone();
    c.act_order = false;
    run("- act order", c)?;
    let mut c = cfg.clone();
    c.rotate = true;
    run("+ rotation (QuaRot-style)", c)?;
    Ok(rows)
}

/// Render both analyses as tables.
pub fn render_sensitivity(layers: &[LayerSensitivity], stages: &[StageAblation]) -> String {
    let mut t = Table::new(&["layer", "K", "PPL", "ΔPPL", "sparsity%"]);
    for l in layers {
        t.row(&[
            l.name.clone(),
            format!("{}", l.k),
            format!("{:.2}", l.ppl),
            format!("{:+.2}", l.delta),
            format!("{:.1}", l.sparsity * 100.0),
        ]);
    }
    let mut s = format!("## per-layer sensitivity\n{}", t.render());
    let mut t2 = Table::new(&["pipeline variant", "PPL"]);
    for r in stages {
        t2.row(&[r.label.clone(), format!("{:.2}", r.ppl)]);
    }
    s.push_str(&format!("\n## pipeline-stage ablation\n{}", t2.render()));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::synth_corpus;
    use crate::model::{random_transformer, Activation, TransformerConfig};
    use crate::quant::{AccumTarget, Algorithm, Method};

    fn fixture() -> (Transformer, Vec<u16>) {
        let cfg = TransformerConfig {
            name: "sens".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            max_seq: 16,
            act: Activation::Gelu,
            parallel_residual: false,
        };
        (random_transformer(cfg, 50), synth_corpus(16 * 16, 32, 51))
    }

    #[test]
    fn per_layer_quantizes_exactly_one_layer() {
        let (base, toks) = fixture();
        let calib: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
        let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
        cfg.target = AccumTarget::Monolithic { p_bits: 16 };
        let rows = per_layer_sensitivity(&base, &calib, &toks, 6, &cfg).unwrap();
        assert_eq!(rows.len(), 6);
        assert!(rows.iter().all(|r| r.ppl.is_finite()));
        // fc2 has K = d_ff
        let fc2 = rows.iter().find(|r| r.name == "b0.fc2").unwrap();
        assert_eq!(fc2.k, 32);
    }

    #[test]
    fn stage_ablation_rows_complete() {
        let (base, toks) = fixture();
        let calib: Vec<&[u16]> = toks.chunks_exact(16).take(4).collect();
        let mut cfg = PipelineConfig::new(Algorithm::Gpfq, Method::Axe, 4, 8);
        cfg.target = AccumTarget::Monolithic { p_bits: 18 };
        let rows = stage_ablation(&base, &calib, &toks, 6, &cfg).unwrap();
        assert_eq!(rows.len(), 5);
        assert!(rows.iter().all(|r| r.ppl.is_finite()));
        let s = render_sensitivity(&[], &rows);
        assert!(s.contains("- equalization"));
        assert!(s.contains("+ rotation"));
    }
}
