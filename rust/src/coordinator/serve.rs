//! Mini serving stack: a request queue, a batching scheduler and a
//! worker pool over KV-cached decode — the deployment surface for
//! AXE-quantized models (and the shape a vLLM-style router would take
//! around this engine).
//!
//! Requests are greedy-generation jobs (prompt → n tokens). The
//! scheduler drains the queue into batches of up to `max_batch`
//! requests, fans them across the worker pool, and records per-request
//! latency; a shared histogram feeds the throughput/latency report the
//! serve example prints.

use crate::model::{KvCache, Transformer};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
}

/// Completed response with timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u16>,
    /// Queue wait in seconds.
    pub queued_s: f64,
    /// Generation time in seconds.
    pub gen_s: f64,
}

struct QueueInner {
    pending: VecDeque<(Request, Instant)>,
    done: Vec<Response>,
    closed: bool,
    in_flight: usize,
}

/// Shared request queue with blocking pop.
pub struct ServeQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

impl ServeQueue {
    pub fn new() -> Arc<ServeQueue> {
        Arc::new(ServeQueue {
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                done: Vec::new(),
                closed: false,
                in_flight: 0,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn submit(&self, req: Request) {
        let mut g = self.inner.lock().unwrap();
        assert!(!g.closed, "queue closed");
        g.pending.push_back((req, Instant::now()));
        self.cv.notify_all();
    }

    /// Close the queue; workers drain and exit.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.cv.notify_all();
    }

    /// Pop up to `max_batch` requests, blocking until work or close.
    fn pop_batch(&self, max_batch: usize) -> Option<Vec<(Request, Instant)>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.pending.is_empty() {
                let take = g.pending.len().min(max_batch);
                let batch: Vec<_> = g.pending.drain(..take).collect();
                g.in_flight += batch.len();
                return Some(batch);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn complete(&self, resp: Vec<Response>) {
        let mut g = self.inner.lock().unwrap();
        g.in_flight -= resp.len();
        g.done.extend(resp);
        self.cv.notify_all();
    }

    /// Wait for all submitted work to finish, then return responses
    /// sorted by id.
    pub fn drain(&self) -> Vec<Response> {
        let mut g = self.inner.lock().unwrap();
        while !g.pending.is_empty() || g.in_flight > 0 {
            g = self.cv.wait(g).unwrap();
        }
        let mut out = std::mem::take(&mut g.done);
        out.sort_by_key(|r| r.id);
        out
    }
}

/// Serving statistics over a set of responses.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub requests: usize,
    pub total_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub mean_queue_s: f64,
}

impl ServeStats {
    pub fn from_responses(responses: &[Response], wall_s: f64) -> ServeStats {
        let mut latencies: Vec<f64> = responses.iter().map(|r| r.queued_s + r.gen_s).collect();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let pct = |p: f64| -> f64 {
            if latencies.is_empty() {
                return 0.0;
            }
            let idx = ((latencies.len() as f64 * p).ceil() as usize).clamp(1, latencies.len()) - 1;
            latencies[idx]
        };
        ServeStats {
            requests: responses.len(),
            total_tokens,
            wall_s,
            tokens_per_s: total_tokens as f64 / wall_s.max(1e-12),
            p50_latency_s: pct(0.50),
            p99_latency_s: pct(0.99),
            mean_queue_s: responses.iter().map(|r| r.queued_s).sum::<f64>()
                / responses.len().max(1) as f64,
        }
    }
}

/// Run a worker pool serving greedy generation off the queue. Returns
/// when the queue is closed and drained.
pub fn serve(model: &Transformer, queue: &ServeQueue, workers: usize, max_batch: usize) {
    std::thread::scope(|scope| {
        for _ in 0..workers.max(1) {
            scope.spawn(|| {
                while let Some(batch) = queue.pop_batch(max_batch) {
                    let mut responses = Vec::with_capacity(batch.len());
                    for (req, enqueued) in batch {
                        let started = Instant::now();
                        let queued_s = started.duration_since(enqueued).as_secs_f64();
                        let tokens = generate_within_window(model, &req);
                        responses.push(Response {
                            id: req.id,
                            tokens,
                            queued_s,
                            gen_s: started.elapsed().as_secs_f64(),
                        });
                    }
                    queue.complete(responses);
                }
            });
        }
    });
}

/// Greedy generation clipped to the model's context window.
///
/// The prompt goes through [`Transformer::prefill`], which runs every
/// linear batched over the whole window — quantized layers execute one
/// fused qgemm kernel call per layer instead of one simulated dot
/// product per (token, channel) pair. Decode steps then reuse the KV
/// cache.
fn generate_within_window(model: &Transformer, req: &Request) -> Vec<u16> {
    let max_seq = model.cfg.max_seq;
    let prompt: Vec<u16> = if req.prompt.len() >= max_seq {
        req.prompt[req.prompt.len() - (max_seq - 1)..].to_vec()
    } else {
        req.prompt.clone()
    };
    let mut cache = KvCache::new(model);
    let mut out: Vec<u16> = Vec::with_capacity(req.max_new_tokens);
    let mut logits = model.prefill(&prompt, &mut cache);
    let mut context = prompt;
    for _ in 0..req.max_new_tokens {
        if cache.is_full() {
            let keep = max_seq / 2;
            let tail = context[context.len() - keep..].to_vec();
            cache.clear();
            logits = model.prefill(&tail, &mut cache);
            context = tail;
        }
        let next = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u16)
            .unwrap_or(0);
        out.push(next);
        context.push(next);
        logits = model.decode_step(next, &mut cache);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{random_transformer, Activation, TransformerConfig};

    fn model() -> Transformer {
        random_transformer(
            TransformerConfig {
                name: "s".into(),
                vocab: 32,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                d_ff: 32,
                max_seq: 16,
                act: Activation::Gelu,
                parallel_residual: false,
            },
            5,
        )
    }

    #[test]
    fn serves_all_requests() {
        let m = model();
        let q = ServeQueue::new();
        for id in 0..12 {
            q.submit(Request { id, prompt: vec![1, 2, 3], max_new_tokens: 5 });
        }
        q.close();
        let t0 = Instant::now();
        serve(&m, &q, 3, 4);
        let responses = q.drain();
        assert_eq!(responses.len(), 12);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 5);
        }
        let stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
        assert_eq!(stats.requests, 12);
        assert_eq!(stats.total_tokens, 60);
        assert!(stats.p99_latency_s >= stats.p50_latency_s);
    }

    #[test]
    fn serving_matches_direct_generation() {
        let m = model();
        let q = ServeQueue::new();
        q.submit(Request { id: 0, prompt: vec![4, 5, 6], max_new_tokens: 8 });
        q.close();
        serve(&m, &q, 1, 1);
        let responses = q.drain();
        let direct = m.generate_greedy(&[4, 5, 6], 8);
        assert_eq!(responses[0].tokens, direct[3..]);
    }

    #[test]
    fn long_prompt_is_window_clipped() {
        let m = model();
        let q = ServeQueue::new();
        let long: Vec<u16> = (0..40).map(|i| i % 32).collect();
        q.submit(Request { id: 0, prompt: long, max_new_tokens: 4 });
        q.close();
        serve(&m, &q, 1, 1);
        let r = q.drain();
        assert_eq!(r[0].tokens.len(), 4);
    }

    #[test]
    fn generation_past_window_slides() {
        let m = model();
        let q = ServeQueue::new();
        q.submit(Request { id: 0, prompt: vec![1, 2], max_new_tokens: 30 });
        q.close();
        serve(&m, &q, 1, 1);
        let r = q.drain();
        assert_eq!(r[0].tokens.len(), 30, "generation must continue past max_seq");
    }

    #[test]
    fn stats_percentiles() {
        let resp: Vec<Response> = (0..100)
            .map(|i| Response {
                id: i,
                tokens: vec![0; 2],
                queued_s: 0.0,
                gen_s: (i + 1) as f64 / 100.0,
            })
            .collect();
        let s = ServeStats::from_responses(&resp, 1.0);
        assert!((s.p50_latency_s - 0.5).abs() < 0.02);
        assert!((s.p99_latency_s - 0.99).abs() < 0.02);
        assert_eq!(s.total_tokens, 200);
    }
}
