//! Continuous-batching serving engine — the deployment surface for
//! AXE-quantized models.
//!
//! Requests are greedy-generation jobs (prompt → n tokens) on a shared
//! queue. Each engine thread owns a [`KvArena`] of `max_batch` slots
//! and runs a vLLM-style **step scheduler**: every iteration it admits
//! queued requests into free slots, stacks the current token of every
//! in-flight sequence into one
//! [`Transformer::decode_step_batch_scratch`] call (one fused qgemm
//! dispatch per layer across the whole batch), samples greedily, and
//! retires finished sequences — requests join and leave the batch
//! mid-flight, so the accumulator-aware GEMM amortizes across whatever
//! traffic is live instead of idling between requests. Each engine
//! owns one [`DecodeScratch`] workspace reused across admissions,
//! steps and slides, so the steady-state step loop performs zero heap
//! allocations (`tests/zero_alloc_decode.rs`; scoped, to kernel calls
//! below the band-threading work threshold — past it, thread spawns
//! allocate by design).
//!
//! Scheduling is **token-exact**: admission prefill, per-slot window
//! slides, sampling order and tie-breaks replicate
//! [`Transformer::generate_greedy`] per sequence, and every batched
//! kernel row is computed independently of its batchmates, so each
//! response is bit-identical to serving that request alone (tested
//! below and in `tests/qgemm_parity.rs`). The same row independence
//! makes overflow accounting **exact**: the kernels report per-row
//! event counts, so each [`Response`] carries precisely the events its
//! own prefills, decode rows and (on the quantized-KV backend,
//! [`serve_with`]) attention matmuls produced — not a batch-window
//! bound.

use crate::model::{argmax, DecodeScratch, KvArena, KvCacheKind, Transformer};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
}

/// Completed response with timing and overflow accounting.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u16>,
    /// Queue wait in seconds (submission → admission into the batch).
    pub queued_s: f64,
    /// Generation time in seconds (admission → retirement).
    pub gen_s: f64,
    /// Integer-datapath overflow events attributed to **this request
    /// exactly**: its admission prefill and window-slide re-prefills,
    /// plus its own rows of every batched decode step it rode in
    /// (quantized linear layers and, on the quantized-KV backend, its
    /// attention matmuls). Per-row kernel attribution makes the counts
    /// disjoint across co-scheduled requests and invariant to batch
    /// composition.
    pub overflow_events: u64,
}

struct QueueInner {
    pending: VecDeque<(Request, Instant)>,
    done: Vec<Response>,
    closed: bool,
    in_flight: usize,
}

/// Shared request queue with blocking pop (idle engines) and
/// non-blocking poll (engines with work in flight).
pub struct ServeQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

impl ServeQueue {
    pub fn new() -> Arc<ServeQueue> {
        Arc::new(ServeQueue {
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                done: Vec::new(),
                closed: false,
                in_flight: 0,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn submit(&self, req: Request) {
        let mut g = self.inner.lock().unwrap();
        assert!(!g.closed, "queue closed");
        g.pending.push_back((req, Instant::now()));
        self.cv.notify_all();
    }

    /// Close the queue; engines drain and exit.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.cv.notify_all();
    }

    /// Pop up to `max` requests, blocking until work or close. `None`
    /// means closed and empty — the engine exits.
    fn pop_batch(&self, max: usize) -> Option<Vec<(Request, Instant)>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.pending.is_empty() {
                let take = g.pending.len().min(max);
                let batch: Vec<_> = g.pending.drain(..take).collect();
                g.in_flight += batch.len();
                return Some(batch);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking admission poll: up to `max` pending requests, empty
    /// when the queue has none — a busy engine never stalls its
    /// in-flight batch waiting for more traffic.
    fn poll(&self, max: usize) -> Vec<(Request, Instant)> {
        if max == 0 {
            return Vec::new();
        }
        let mut g = self.inner.lock().unwrap();
        let take = g.pending.len().min(max);
        let batch: Vec<_> = g.pending.drain(..take).collect();
        g.in_flight += batch.len();
        batch
    }

    fn complete(&self, resp: Vec<Response>) {
        if resp.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.in_flight -= resp.len();
        g.done.extend(resp);
        self.cv.notify_all();
    }

    /// Wait for all submitted work to finish, then return responses
    /// sorted by id.
    pub fn drain(&self) -> Vec<Response> {
        let mut g = self.inner.lock().unwrap();
        while !g.pending.is_empty() || g.in_flight > 0 {
            g = self.cv.wait(g).unwrap();
        }
        let mut out = std::mem::take(&mut g.done);
        out.sort_by_key(|r| r.id);
        out
    }
}

/// Serving statistics over a set of responses.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub requests: usize,
    pub total_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub mean_queue_s: f64,
    /// Total overflow events across the serve run — the sum of the
    /// exact per-request counts (attribution is disjoint, so the sum
    /// is the model-wide total for the run's forward work).
    pub overflow_events: u64,
    /// KV arena footprint in bytes per engine (0 when the caller did
    /// not fill it in; see [`crate::model::KvArena::footprint`]).
    pub arena_bytes: usize,
}

impl ServeStats {
    /// Aggregate responses; overflow events are summed from the exact
    /// per-request counters.
    pub fn from_responses(responses: &[Response], wall_s: f64) -> ServeStats {
        let mut latencies: Vec<f64> = responses.iter().map(|r| r.queued_s + r.gen_s).collect();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let pct = |p: f64| -> f64 {
            if latencies.is_empty() {
                return 0.0;
            }
            let idx = ((latencies.len() as f64 * p).ceil() as usize).clamp(1, latencies.len()) - 1;
            latencies[idx]
        };
        ServeStats {
            requests: responses.len(),
            total_tokens,
            wall_s,
            tokens_per_s: total_tokens as f64 / wall_s.max(1e-12),
            p50_latency_s: pct(0.50),
            p99_latency_s: pct(0.99),
            mean_queue_s: responses.iter().map(|r| r.queued_s).sum::<f64>()
                / responses.len().max(1) as f64,
            overflow_events: responses.iter().map(|r| r.overflow_events).sum(),
            arena_bytes: 0,
        }
    }
}

/// One in-flight sequence: its arena slot plus the state the step
/// scheduler threads from sample to sample.
struct InFlight {
    id: u64,
    slot: usize,
    /// Window-clipped prompt + generated tokens (the slide tail source).
    context: Vec<u16>,
    /// Generated tokens only.
    emitted: Vec<u16>,
    max_new: usize,
    /// Logits pending a sample (from prefill or the last batched step).
    logits: Vec<f32>,
    enqueued: Instant,
    admitted: Instant,
    /// Exact overflow events this request has triggered so far
    /// (prefills + its rows of every batched step).
    overflow: u64,
}

/// Run `engines` continuous-batching engine threads off the queue, each
/// with `max_batch` in-flight slots over an f32 KV arena. Returns when
/// the queue is closed and fully drained.
pub fn serve(model: &Transformer, queue: &ServeQueue, engines: usize, max_batch: usize) {
    serve_with(model, queue, engines, max_batch, KvCacheKind::F32);
}

/// [`serve`] with an explicit KV-cache backend: `KvCacheKind::Quant`
/// stores each engine's arena as narrow integer codes and runs the
/// attention score/value matmuls through the multi-stage integer
/// accumulator — the `--kv-bits` deployment path.
pub fn serve_with(
    model: &Transformer,
    queue: &ServeQueue,
    engines: usize,
    max_batch: usize,
    kind: KvCacheKind,
) {
    std::thread::scope(|scope| {
        for _ in 0..engines.max(1) {
            scope.spawn(move || run_engine(model, queue, max_batch.max(1), kind));
        }
    });
}

/// The step scheduler: admit → (slide | sample | retire) → one batched
/// decode step, until the queue closes and the batch drains.
///
/// The engine owns one [`DecodeScratch`] workspace plus reusable
/// step-composition vectors; the steady-state loop — poll-empty
/// admission, per-sequence sample, one batched
/// [`Transformer::decode_step_batch_scratch`] call — performs zero heap
/// allocations beyond the per-sequence `emitted`/`context`/`logits`
/// buffers, which reuse their retained capacity.
fn run_engine(model: &Transformer, queue: &ServeQueue, max_batch: usize, kind: KvCacheKind) {
    let vocab = model.cfg.vocab;
    let mut arena = KvArena::with_kind(model, max_batch, kind);
    let mut active: Vec<InFlight> = Vec::new();
    // one workspace per engine, shared by admissions, steps and slides
    let mut scratch = DecodeScratch::for_model(&model.cfg, max_batch);
    let mut step_tokens: Vec<u16> = Vec::with_capacity(max_batch);
    let mut step_slots: Vec<usize> = Vec::with_capacity(max_batch);
    let mut step_ovf: Vec<u64> = Vec::with_capacity(max_batch);
    loop {
        // -- admission: block when idle, poll when the batch has work
        let admissions = if active.is_empty() {
            match queue.pop_batch(max_batch) {
                Some(batch) => batch,
                None => return, // closed + drained
            }
        } else {
            queue.poll(arena.free_slots())
        };
        let mut finished: Vec<Response> = Vec::new();
        for (req, enqueued) in admissions {
            let admitted = Instant::now();
            if req.max_new_tokens == 0 {
                // nothing to generate: complete without spending a
                // prefill or an arena slot
                finished.push(Response {
                    id: req.id,
                    tokens: Vec::new(),
                    queued_s: admitted.duration_since(enqueued).as_secs_f64(),
                    gen_s: 0.0,
                    overflow_events: 0,
                });
                continue;
            }
            let slot = arena.alloc().expect("admission is bounded by free slots");
            let prompt = model.clip_to_window(&req.prompt);
            let mut prefill_ovf = 0u64;
            model.prefill_slot_scratch(&prompt, slot, &mut arena, &mut prefill_ovf, &mut scratch);
            active.push(InFlight {
                id: req.id,
                slot,
                context: prompt,
                emitted: Vec::with_capacity(req.max_new_tokens),
                max_new: req.max_new_tokens,
                logits: scratch.step.logits[..vocab].to_vec(),
                enqueued,
                admitted,
                overflow: prefill_ovf,
            });
        }

        // -- per-sequence: window-slide if needed, sample, retire
        let mut i = 0;
        while i < active.len() {
            let seq = &mut active[i];
            let done = {
                if arena.is_full(seq.slot) {
                    // slide: re-encode the tail at fresh absolute
                    // positions — identical to generate_greedy's slide
                    let keep = model.slide_keep();
                    let tail = seq.context[seq.context.len() - keep..].to_vec();
                    arena.reset_slot(seq.slot);
                    let mut slide_ovf = 0u64;
                    model.prefill_slot_scratch(
                        &tail,
                        seq.slot,
                        &mut arena,
                        &mut slide_ovf,
                        &mut scratch,
                    );
                    seq.logits.clear();
                    seq.logits.extend_from_slice(&scratch.step.logits[..vocab]);
                    seq.overflow += slide_ovf;
                    seq.context = tail;
                }
                let next = argmax(&seq.logits) as u16;
                seq.emitted.push(next);
                seq.context.push(next);
                seq.emitted.len() >= seq.max_new
            };
            if done {
                let seq = active.swap_remove(i);
                arena.release(seq.slot);
                finished.push(Response {
                    id: seq.id,
                    tokens: seq.emitted,
                    queued_s: seq.admitted.duration_since(seq.enqueued).as_secs_f64(),
                    gen_s: seq.admitted.elapsed().as_secs_f64(),
                    overflow_events: seq.overflow,
                });
            } else {
                i += 1;
            }
        }

        // -- one decode step for every sequence still in flight: the
        // whole batch goes through one forward_rows_scratch per linear;
        // the kernel's per-row overflow counts land on the requests
        // that produced them. Step vectors and the workspace are
        // reused, so the steady-state iteration is allocation-free.
        if !active.is_empty() {
            step_tokens.clear();
            step_tokens.extend(active.iter().map(|s| *s.context.last().unwrap()));
            step_slots.clear();
            step_slots.extend(active.iter().map(|s| s.slot));
            step_ovf.clear();
            step_ovf.resize(active.len(), 0);
            model.decode_step_batch_scratch(
                &step_tokens,
                &step_slots,
                &mut arena,
                &mut step_ovf,
                &mut scratch,
            );
            for (b, seq) in active.iter_mut().enumerate() {
                seq.overflow += step_ovf[b];
                seq.logits.clear();
                seq.logits.extend_from_slice(&scratch.step.logits[b * vocab..(b + 1) * vocab]);
            }
        }
        queue.complete(finished);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{random_transformer, Activation, TransformerConfig};

    fn model() -> Transformer {
        random_transformer(
            TransformerConfig {
                name: "s".into(),
                vocab: 32,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                d_ff: 32,
                max_seq: 16,
                act: Activation::Gelu,
                parallel_residual: false,
            },
            5,
        )
    }

    /// What the engine must reproduce for a request, bit for bit.
    fn direct(m: &Transformer, prompt: &[u16], n: usize) -> Vec<u16> {
        let clipped = m.clip_to_window(prompt);
        m.generate_greedy(&clipped, n)[clipped.len()..].to_vec()
    }

    #[test]
    fn serves_all_requests() {
        let m = model();
        let q = ServeQueue::new();
        for id in 0..12 {
            q.submit(Request { id, prompt: vec![1, 2, 3], max_new_tokens: 5 });
        }
        q.close();
        let t0 = Instant::now();
        serve(&m, &q, 3, 4);
        let responses = q.drain();
        assert_eq!(responses.len(), 12);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 5);
        }
        let stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
        assert_eq!(stats.requests, 12);
        assert_eq!(stats.total_tokens, 60);
        assert!(stats.p99_latency_s >= stats.p50_latency_s);
    }

    #[test]
    fn serving_matches_direct_generation() {
        let m = model();
        let q = ServeQueue::new();
        q.submit(Request { id: 0, prompt: vec![4, 5, 6], max_new_tokens: 8 });
        q.close();
        serve(&m, &q, 1, 1);
        let responses = q.drain();
        let direct = m.generate_greedy(&[4, 5, 6], 8);
        assert_eq!(responses[0].tokens, direct[3..]);
    }

    /// THE serving parity property: continuous batching with mid-flight
    /// admissions, mixed prompt lengths (including window-clipped ones),
    /// staggered retirements and per-slot window slides emits, for every
    /// request, exactly the tokens sequential greedy decode emits.
    #[test]
    fn continuous_batching_is_token_exact() {
        let m = model();
        let q = ServeQueue::new();
        // 10 requests, prompt lengths 1..=22 (some beyond max_seq=16 →
        // clipped), generation lengths 3..=27 (several past the window →
        // slides); staggered lengths force mid-flight joins and leaves.
        let mut reqs: Vec<Request> = Vec::new();
        for id in 0..10u64 {
            let off = id as usize;
            let plen = 1 + ((off * 5) % 22);
            let prompt: Vec<u16> = (0..plen).map(|i| ((i * 7 + off) % 32) as u16).collect();
            let max_new_tokens = 3 + ((off * 11) % 25);
            reqs.push(Request { id, prompt, max_new_tokens });
        }
        for r in &reqs {
            q.submit(r.clone());
        }
        q.close();
        // one engine, 3 slots, 10 requests → continuous mid-flight
        // admission pressure the whole run
        serve(&m, &q, 1, 3);
        let responses = q.drain();
        assert_eq!(responses.len(), reqs.len());
        for (resp, req) in responses.iter().zip(reqs.iter()) {
            assert_eq!(resp.id, req.id);
            let want = direct(&m, &req.prompt, req.max_new_tokens);
            assert_eq!(
                resp.tokens,
                want,
                "request {} diverged from sequential greedy decode",
                req.id
            );
        }
    }

    /// Continuous batching over the **quantized** KV arena must be
    /// token-exact versus sequential greedy decode on that same
    /// backend — the serving guarantee survives the integer attention
    /// datapath.
    #[test]
    fn quant_kv_serving_matches_quant_sequential() {
        use crate::model::KvQuantSpec;
        let m = model();
        let kind = KvCacheKind::Quant(KvQuantSpec::int8());
        let q = ServeQueue::new();
        let reqs: Vec<Request> = (0..6u64)
            .map(|id| {
                let off = id as usize;
                let plen = 1 + ((off * 5) % 12);
                Request {
                    id,
                    prompt: (0..plen).map(|i| ((i * 7 + off) % 32) as u16).collect(),
                    max_new_tokens: 3 + ((off * 11) % 22),
                }
            })
            .collect();
        for r in &reqs {
            q.submit(r.clone());
        }
        q.close();
        serve_with(&m, &q, 1, 3, kind);
        let responses = q.drain();
        assert_eq!(responses.len(), reqs.len());
        for (resp, req) in responses.iter().zip(reqs.iter()) {
            let clipped = m.clip_to_window(&req.prompt);
            let want = m.generate_greedy_with(&clipped, req.max_new_tokens, kind);
            assert_eq!(
                resp.tokens,
                want[clipped.len()..],
                "request {} diverged from sequential quant-KV decode",
                req.id
            );
        }
    }

    #[test]
    fn zero_token_request_completes_empty() {
        let m = model();
        let q = ServeQueue::new();
        q.submit(Request { id: 0, prompt: vec![1, 2], max_new_tokens: 0 });
        q.submit(Request { id: 1, prompt: vec![1, 2], max_new_tokens: 4 });
        q.close();
        serve(&m, &q, 1, 2);
        let r = q.drain();
        assert_eq!(r[0].tokens.len(), 0);
        assert_eq!(r[1].tokens, direct(&m, &[1, 2], 4));
    }

    #[test]
    fn long_prompt_is_window_clipped() {
        let m = model();
        let q = ServeQueue::new();
        let long: Vec<u16> = (0..40).map(|i| i % 32).collect();
        q.submit(Request { id: 0, prompt: long.clone(), max_new_tokens: 4 });
        q.close();
        serve(&m, &q, 1, 1);
        let r = q.drain();
        assert_eq!(r[0].tokens.len(), 4);
        assert_eq!(r[0].tokens, direct(&m, &long, 4));
    }

    #[test]
    fn generation_past_window_slides() {
        let m = model();
        let q = ServeQueue::new();
        q.submit(Request { id: 0, prompt: vec![1, 2], max_new_tokens: 30 });
        q.close();
        serve(&m, &q, 1, 1);
        let r = q.drain();
        assert_eq!(r[0].tokens.len(), 30, "generation must continue past max_seq");
        assert_eq!(r[0].tokens, direct(&m, &[1, 2], 30));
    }

    #[test]
    fn stats_percentiles() {
        let resp: Vec<Response> = (0..100)
            .map(|i| Response {
                id: i,
                tokens: vec![0; 2],
                queued_s: 0.0,
                gen_s: (i + 1) as f64 / 100.0,
                overflow_events: i % 5,
            })
            .collect();
        let s = ServeStats::from_responses(&resp, 1.0);
        assert!((s.p50_latency_s - 0.5).abs() < 0.02);
        assert!((s.p99_latency_s - 0.99).abs() < 0.02);
        assert_eq!(s.total_tokens, 200);
        // per-request counts are disjoint, so the total is their sum
        assert_eq!(s.overflow_events, (0..100u64).map(|i| i % 5).sum::<u64>());
        assert_eq!(s.arena_bytes, 0, "arena bytes are caller-filled");
    }
}
