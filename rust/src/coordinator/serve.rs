//! Continuous-batching serving engine — the deployment surface for
//! AXE-quantized models.
//!
//! Requests are greedy-generation jobs (prompt → n tokens) on a shared
//! queue. Each engine thread owns a [`KvArena`] of `max_batch` slots
//! and runs a vLLM-style **step scheduler** ([`StepEngine`]): every
//! iteration it admits queued requests into free slots, composes one
//! **ragged step** — a prefill chunk of up to `prefill_chunk` tokens
//! for each admitting sequence plus one decode row for every in-flight
//! sequence — and executes it as a single
//! [`Transformer::decode_step_ragged_scratch`] call (one fused qgemm
//! dispatch per layer across every row of the step), then samples
//! greedily and retires finished sequences. Prefill is therefore a
//! first-class citizen of the step loop: a long prompt no longer
//! blocks the in-flight batch head-of-line — it trickles in chunk by
//! chunk while decode rows keep flowing, and each chunk *amortizes*
//! the fused kernel across the live decode traffic. Each engine owns
//! one [`DecodeScratch`] workspace sized to the ragged-step high-water
//! mark ([`DecodeScratch::for_serve`]), so the steady-state step loop
//! — chunks included — performs zero heap allocations
//! (`tests/zero_alloc_decode.rs`; scoped, to kernel calls below the
//! band-threading work threshold — past it, thread spawns allocate by
//! design).
//!
//! **Overload control.** The serve path degrades by policy, never by
//! accident, mirroring the guarantee character of the kernel layer:
//!
//! - **Bounded admission.** [`ServeQueue::bounded`] caps the pending
//!   queue; [`ServeQueue::submit`] returns
//!   [`SubmitError::QueueFull`] instead of growing without bound, and
//!   the deterministic [`ShedPolicy`] decides *which* request is shed
//!   (reject-newest by default, or evict the largest pending prompt).
//!   Every shed request still resolves to a [`Response`] with
//!   [`Status::Shed`], and the queue keeps exact `submitted`/`shed`
//!   counters so `submitted == completed + shed + missed + cancelled`
//!   is checkable ([`ServeStats::conserved`]).
//! - **Deadlines + cancellation.** A [`Request`] may carry a
//!   `deadline` and/or a [`CancelToken`]. Doomed work is dropped at
//!   admission (no slot spent) and mid-flight — including mid-prefill
//!   — by a reaper that releases the arena slot and unrefs its pages
//!   (prefix-cache refcounts fall back to the cache's own holds).
//!   Dropped requests resolve to typed [`Status::DeadlineMiss`] /
//!   [`Status::Cancelled`] responses carrying whatever tokens they
//!   emitted, so callers never hang.
//! - **Fairness under storm.** With `fair_budget` on (default) the
//!   shared per-step prefill budget scales *down* with live decode
//!   rows, bounding step tokens — and hence step latency — by
//!   `max(prefill_chunk, max_batch)`; chunk grants round-robin across
//!   prefilling sequences so one giant prompt cannot starve the rest.
//!   Both knobs reorder *scheduling only*: tokens and per-request
//!   overflow attribution stay bit-identical (row independence).
//!
//! **Admission / fairness policy.** Decode rows always ride — an
//! admitting prompt can never stall sequences that are already
//! generating. The per-step prefill budget (`prefill_chunk` tokens,
//! shared) is handed out round-robin across prefilling sequences;
//! window-slide re-encodes run through the same chunked path and the
//! same budget. Per-request **time-to-first-token** is recorded on
//! every [`Response`] (`ttft_s`), making the latency effect of the
//! chunk size directly observable (`ServeStats::{p50,p99}_ttft_s`).
//!
//! Scheduling is **token-exact for every chunk size**: each row of a
//! ragged step is computed independently of how rows are grouped into
//! chunks or batched with other sequences, and sampling order,
//! tie-breaks and per-slot window slides replicate
//! [`Transformer::generate_greedy`] per sequence — so each response is
//! bit-identical to serving that request alone, whatever
//! `prefill_chunk` says (tested below and in
//! `tests/chunked_prefill.rs`). The same row independence makes
//! overflow accounting **exact**: the kernels report per-group event
//! counts, so each [`Response`] carries precisely the events its own
//! prefill chunks, decode rows and (on the quantized-KV backend,
//! [`serve_with`]) attention matmuls produced — not a batch-window
//! bound.
//!
//! **Self-speculative decoding** (`--speculate-k`). The narrow-register
//! integer datapath is a free draft model: with `speculate_k > 1` each
//! decoding sequence extends its committed sample into a depth-`k`
//! chunk by running extra 1-row rounds at a narrower inner accumulator
//! width ([`RaggedOpts::draft`] — same weights, codes and scales, so
//! the draft costs zero extra memory), rolls the draft K/V appends
//! back ([`KvArena::truncate_tail`]; draft rows never touch the page
//! fill ledgers), and re-encodes the whole chunk **full-width** as one
//! chunk-causal verify group with per-row logits
//! ([`RaggedOpts::verify`]). Greedy acceptance keeps the longest
//! matching prefix, so the emitted stream — and, because attribution
//! counts accepted verify rows only, each response's overflow count —
//! is **bit-identical to non-speculative decode by construction**
//! (`tests/speculative.rs`). Speculation trades step *composition*
//! only: more rows per step when drafts hit, wasted verify rows when
//! they miss (`spec_accepted / spec_proposed` in the step records).
//!
//! **Sampling** (`--temperature/--top-k/--top-p/--seed`). Decode
//! sampling is pluggable via [`SampleSpec`]: draws are keyed per
//! `(seed, request id, position)` so sampled streams are
//! batch-composition-invariant and replayable, exactly like the greedy
//! default (`tests/sampling.rs`). Speculative mode requires greedy —
//! its acceptance rule *is* the greedy argmax.

use crate::coordinator::telemetry::{
    spawn_drainer, EventSink, MetricsSummary, SharedMetrics, SinkSpec, StepRecord,
    DEFAULT_FLUSH_EVERY, DEFAULT_RING_CAPACITY,
};
use crate::model::{
    argmax, DecodeScratch, KvArena, KvCacheKind, RaggedOpts, RowGroup, SampleSpec, Transformer,
    DEFAULT_KV_PAGE,
};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Default per-step prefill chunk / budget (tokens) — the
/// `--prefill-chunk` default.
pub const DEFAULT_PREFILL_CHUNK: usize = 64;

/// Typed terminal status of a [`Response`]. Every request accepted by
/// [`ServeQueue::submit`] resolves to **exactly one** response with
/// exactly one of these — overloaded or cancelled work is answered,
/// never silently dropped, so callers can always stop waiting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Status {
    /// Ran to completion: `tokens` holds the full requested stream.
    #[default]
    Ok,
    /// Rejected by the bounded queue's [`ShedPolicy`] before admission;
    /// `tokens` is empty.
    Shed,
    /// Deadline expired — at admission (empty `tokens`) or mid-flight
    /// (partial `tokens`, a prefix of the uncontended stream).
    DeadlineMiss,
    /// [`CancelToken::cancel`] observed — at admission or mid-flight;
    /// `tokens` holds whatever was emitted before the drop.
    Cancelled,
}

/// Shared cancellation handle: clone it into a [`Request`], call
/// [`CancelToken::cancel`] from any thread, and the scheduler drops the
/// request at its next admission check or step (releasing its arena
/// slot and page refcounts), resolving it as [`Status::Cancelled`].
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// Typed [`ServeQueue::submit`] rejection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is at capacity and the [`ShedPolicy`] shed the
    /// submitted request (a [`Status::Shed`] response was filed for it
    /// — the submission is still *accounted*, not lost).
    QueueFull,
    /// [`ServeQueue::close`] already ran — the request was not
    /// enqueued, not counted, and gets no response.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue at capacity: request shed"),
            SubmitError::Closed => write!(f, "queue closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Deterministic decision of *which* request a full queue sheds. Both
/// policies are pure functions of queue contents + incoming request,
/// so shed decisions replay exactly from a seeded arrival schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Shed the incoming request (classic tail-drop) — pending work is
    /// never disturbed.
    #[default]
    RejectNewest,
    /// Evict the pending request with the largest prompt (ties →
    /// newest) if it is strictly larger than the incoming one,
    /// otherwise shed the incoming request — under storm, many small
    /// requests beat one giant one.
    RejectLargestPrompt,
}

/// One generation request.
#[derive(Clone, Debug, Default)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    /// Drop-dead time: work not finished by here is dropped at the
    /// scheduler's next admission check or step and resolved as
    /// [`Status::DeadlineMiss`]. `None` = no deadline.
    pub deadline: Option<Instant>,
    /// Caller-held cancellation handle (see [`CancelToken`]).
    pub cancel: Option<CancelToken>,
}

/// Completed response with timing and overflow accounting.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u16>,
    /// Queue wait in seconds (submission → admission into the batch).
    pub queued_s: f64,
    /// Generation time in seconds (admission → retirement).
    pub gen_s: f64,
    /// Time to first token in seconds (submission → first sampled
    /// token) — the latency the chunked-prefill admission path exists
    /// to cut. Equals `queued_s` for zero-token requests.
    pub ttft_s: f64,
    /// Integer-datapath overflow events attributed to **this request
    /// exactly**: its admission prefill chunks and window-slide
    /// re-prefill chunks, plus its own rows of every ragged step it
    /// rode in (quantized linear layers and, on the quantized-KV
    /// backend, its attention matmuls). Per-group kernel attribution
    /// makes the counts disjoint across co-scheduled requests and
    /// invariant to batch composition. Prefill positions skipped via
    /// prefix-page adoption contribute the events stored on the adopted
    /// pages at fill time, so this count is bit-identical with prefix
    /// sharing on or off.
    pub overflow_events: u64,
    /// Prompt (and slide-tail) positions this request did **not** have
    /// to prefill because already-encoded prefix pages were mapped into
    /// its slot from the prefix cache. 0 on a cold admission or with
    /// `--prefix-cache off`.
    pub prefill_tokens_skipped: usize,
    /// Typed terminal status; non-[`Status::Ok`] responses may carry a
    /// partial (prefix-exact) token stream.
    pub status: Status,
}

/// The response a shed request resolves to — empty tokens, zero model
/// work, `queued_s` = time spent pending before eviction (0 when the
/// incoming request itself was shed).
fn shed_response(req: Request, queued_s: f64) -> Response {
    Response {
        id: req.id,
        tokens: Vec::new(),
        queued_s,
        gen_s: 0.0,
        ttft_s: queued_s,
        overflow_events: 0,
        prefill_tokens_skipped: 0,
        status: Status::Shed,
    }
}

struct QueueInner {
    pending: VecDeque<(Request, Instant)>,
    done: Vec<Response>,
    closed: bool,
    in_flight: usize,
    /// Pending-queue capacity (`usize::MAX` = unbounded).
    cap: usize,
    policy: ShedPolicy,
    /// Requests accepted by `submit` (everything except
    /// [`SubmitError::Closed`]) — the conservation left-hand side.
    submitted: u64,
    /// Requests shed by the capacity policy (each filed a
    /// [`Status::Shed`] response).
    shed: u64,
    /// Prefix of `shed` already handed to an engine via
    /// [`ServeQueue::take_shed_delta`] — sheds reach telemetry
    /// exactly once even with multiple engines polling.
    shed_reported: u64,
    /// High-water pending depth — with a cap, provably ≤ cap.
    depth_hwm: usize,
}

/// Shared request queue with blocking pop (idle engines) and
/// non-blocking poll (engines with work in flight). Optionally bounded
/// ([`ServeQueue::bounded`]): at capacity, the [`ShedPolicy`] decides
/// deterministically which request is shed, and the shed request still
/// resolves to a [`Status::Shed`] response on [`ServeQueue::drain`].
pub struct ServeQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

impl ServeQueue {
    /// Unbounded queue (legacy behaviour — `submit` only errors after
    /// [`ServeQueue::close`]).
    pub fn new() -> Arc<ServeQueue> {
        ServeQueue::bounded(usize::MAX, ShedPolicy::RejectNewest)
    }

    /// Bounded queue: at most `cap` pending (unadmitted) requests;
    /// beyond that, `policy` sheds deterministically. `cap` is clamped
    /// to ≥ 1.
    pub fn bounded(cap: usize, policy: ShedPolicy) -> Arc<ServeQueue> {
        Arc::new(ServeQueue {
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                done: Vec::new(),
                closed: false,
                in_flight: 0,
                cap: cap.max(1),
                policy,
                submitted: 0,
                shed: 0,
                shed_reported: 0,
                depth_hwm: 0,
            }),
            cv: Condvar::new(),
        })
    }

    /// Submit a request. `Err(Closed)` after [`ServeQueue::close`]
    /// (not enqueued, not counted); `Err(QueueFull)` when the bounded
    /// queue shed the *incoming* request (it **is** counted and will
    /// resolve as a [`Status::Shed`] response). `Ok` means the request
    /// is pending — though a later over-capacity submit may still evict
    /// it under [`ShedPolicy::RejectLargestPrompt`].
    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(SubmitError::Closed);
        }
        g.submitted += 1;
        let now = Instant::now();
        if g.pending.len() >= g.cap {
            match g.policy {
                ShedPolicy::RejectNewest => {
                    g.shed += 1;
                    g.done.push(shed_response(req, 0.0));
                    self.cv.notify_all();
                    return Err(SubmitError::QueueFull);
                }
                ShedPolicy::RejectLargestPrompt => {
                    // victim = largest pending prompt, ties → newest
                    // (cap ≥ 1, so at capacity pending is non-empty)
                    let mut vi = 0;
                    for (i, (p, _)) in g.pending.iter().enumerate() {
                        if p.prompt.len() >= g.pending[vi].0.prompt.len() {
                            vi = i;
                        }
                    }
                    if g.pending[vi].0.prompt.len() > req.prompt.len() {
                        let (victim, venq) =
                            g.pending.remove(vi).expect("victim index is in bounds");
                        g.shed += 1;
                        let queued_s = now.duration_since(venq).as_secs_f64();
                        g.done.push(shed_response(victim, queued_s));
                        g.pending.push_back((req, now));
                        let depth = g.pending.len();
                        g.depth_hwm = g.depth_hwm.max(depth);
                        self.cv.notify_all();
                        return Ok(());
                    }
                    // incoming is itself the largest → shed it
                    g.shed += 1;
                    g.done.push(shed_response(req, 0.0));
                    self.cv.notify_all();
                    return Err(SubmitError::QueueFull);
                }
            }
        }
        g.pending.push_back((req, now));
        let depth = g.pending.len();
        g.depth_hwm = g.depth_hwm.max(depth);
        self.cv.notify_all();
        Ok(())
    }

    /// Close the queue; engines drain and exit. Later submits return
    /// [`SubmitError::Closed`].
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.cv.notify_all();
    }

    /// Pop up to `max` requests, blocking until work or close. `None`
    /// means closed and empty — the engine exits.
    fn pop_batch(&self, max: usize) -> Option<Vec<(Request, Instant)>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.pending.is_empty() {
                let take = g.pending.len().min(max);
                let batch: Vec<_> = g.pending.drain(..take).collect();
                g.in_flight += batch.len();
                return Some(batch);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking admission poll: up to `max` pending requests, empty
    /// when the queue has none — a busy engine never stalls its
    /// in-flight batch waiting for more traffic. Crate-visible so the
    /// load harness (`bench_support::load`) can drive the same
    /// admission seam tick by tick.
    pub(crate) fn poll(&self, max: usize) -> Vec<(Request, Instant)> {
        if max == 0 {
            return Vec::new();
        }
        let mut g = self.inner.lock().unwrap();
        let take = g.pending.len().min(max);
        let batch: Vec<_> = g.pending.drain(..take).collect();
        g.in_flight += batch.len();
        batch
    }

    pub(crate) fn complete(&self, resp: Vec<Response>) {
        if resp.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.in_flight -= resp.len();
        g.done.extend(resp);
        self.cv.notify_all();
    }

    /// Pending (unadmitted) requests right now — the queue depth an
    /// engine samples into its step records at each admission poll.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().pending.len()
    }

    /// High-water pending depth over the queue's lifetime — with
    /// [`ServeQueue::bounded`], provably ≤ the cap.
    pub fn depth_hwm(&self) -> usize {
        self.inner.lock().unwrap().depth_hwm
    }

    /// Requests accepted by `submit` (the conservation left-hand side:
    /// `submitted == completed + shed + deadline_miss + cancelled`
    /// after drain).
    pub fn submitted_count(&self) -> u64 {
        self.inner.lock().unwrap().submitted
    }

    /// Requests shed by the capacity policy so far.
    pub fn shed_count(&self) -> u64 {
        self.inner.lock().unwrap().shed
    }

    /// Sheds not yet handed to any engine's telemetry — each shed is
    /// reported exactly once across all engines polling this queue
    /// (pair with [`StepEngine::note_shed`]).
    pub fn take_shed_delta(&self) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let delta = g.shed - g.shed_reported;
        g.shed_reported = g.shed;
        delta
    }

    /// Wait for all submitted work to finish, then return responses
    /// sorted by id.
    pub fn drain(&self) -> Vec<Response> {
        let mut g = self.inner.lock().unwrap();
        while !g.pending.is_empty() || g.in_flight > 0 {
            g = self.cv.wait(g).unwrap();
        }
        let mut out = std::mem::take(&mut g.done);
        out.sort_by_key(|r| r.id);
        out
    }
}

/// Serving statistics over a set of responses. Latency/TTFT
/// percentiles, queue-wait means and the prefix-sharing partition are
/// computed over [`Status::Ok`] responses only (a shed request's
/// "latency" would poison the percentiles); token and overflow totals
/// count every response, including partial streams from reaped work.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub requests: usize,
    /// Responses that ran to completion ([`Status::Ok`]).
    pub completed: usize,
    /// Responses shed by the bounded queue's capacity policy.
    pub shed: usize,
    /// Responses dropped on an expired deadline (at admission or
    /// mid-flight).
    pub deadline_miss: usize,
    /// Responses dropped via their [`CancelToken`].
    pub cancelled: usize,
    pub total_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub mean_queue_s: f64,
    /// Time-to-first-token percentiles across completed responses —
    /// the metric the chunked-prefill admission path targets.
    pub p50_ttft_s: f64,
    pub p99_ttft_s: f64,
    /// Total overflow events across the serve run — the sum of the
    /// exact per-request counts (attribution is disjoint, so the sum
    /// is the model-wide total for the run's forward work).
    pub overflow_events: u64,
    /// KV arena footprint in bytes per engine (0 when the caller did
    /// not fill it in; see [`crate::model::KvArena::footprint`]).
    pub arena_bytes: usize,
    /// Completed requests whose admission hit the prefix cache
    /// (adopted ≥ 1 shared page).
    pub prefix_hits: usize,
    /// Prefix-cache hit rate across completed requests.
    pub prefix_hit_rate: f64,
    /// Total prefill positions skipped via shared-page adoption.
    pub prefill_tokens_skipped: usize,
    /// Median TTFT over cache-hit admissions only (0 when none) — with
    /// [`ServeStats::p50_ttft_cold_s`], the latency win sharing buys.
    pub p50_ttft_shared_s: f64,
    /// Median TTFT over cold (no pages adopted) admissions only.
    pub p50_ttft_cold_s: f64,
    /// Full pages mapped read-only from the prefix cache, summed over
    /// engines (0 when the caller did not fill it in; see
    /// [`crate::model::KvArena::pages_shared`]).
    pub pages_shared: u64,
    /// Unreferenced prefix-cache entries evicted under allocation
    /// pressure, summed over engines (0 when the caller did not fill it
    /// in; see [`crate::model::KvArena::cache_evictions`]).
    pub cache_evictions: u64,
    /// Per-step telemetry merged across engines (`None` until the
    /// caller runs [`ServeStats::fill_telemetry`], or when every
    /// engine ran with telemetry off) — step-latency / TTFT / TPOT /
    /// occupancy histograms and the per-step overflow split.
    pub telemetry: Option<MetricsSummary>,
}

impl ServeStats {
    /// Aggregate responses; overflow events are summed from the exact
    /// per-request counters.
    pub fn from_responses(responses: &[Response], wall_s: f64) -> ServeStats {
        let pct = |sorted: &[f64], p: f64| -> f64 {
            if sorted.is_empty() {
                return 0.0;
            }
            let idx = ((sorted.len() as f64 * p).ceil() as usize).clamp(1, sorted.len()) - 1;
            sorted[idx]
        };
        let (mut shed, mut miss, mut cancelled) = (0usize, 0usize, 0usize);
        for r in responses {
            match r.status {
                Status::Ok => {}
                Status::Shed => shed += 1,
                Status::DeadlineMiss => miss += 1,
                Status::Cancelled => cancelled += 1,
            }
        }
        let ok: Vec<&Response> = responses.iter().filter(|r| r.status == Status::Ok).collect();
        let mut latencies: Vec<f64> = ok.iter().map(|r| r.queued_s + r.gen_s).collect();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut ttfts: Vec<f64> = ok.iter().map(|r| r.ttft_s).collect();
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let mut shared_ttfts: Vec<f64> = Vec::new();
        let mut cold_ttfts: Vec<f64> = Vec::new();
        for r in &ok {
            if r.prefill_tokens_skipped > 0 {
                shared_ttfts.push(r.ttft_s);
            } else {
                cold_ttfts.push(r.ttft_s);
            }
        }
        shared_ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        cold_ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ServeStats {
            requests: responses.len(),
            completed: ok.len(),
            shed,
            deadline_miss: miss,
            cancelled,
            total_tokens,
            wall_s,
            tokens_per_s: total_tokens as f64 / wall_s.max(1e-12),
            p50_latency_s: pct(&latencies, 0.50),
            p99_latency_s: pct(&latencies, 0.99),
            mean_queue_s: ok.iter().map(|r| r.queued_s).sum::<f64>() / ok.len().max(1) as f64,
            p50_ttft_s: pct(&ttfts, 0.50),
            p99_ttft_s: pct(&ttfts, 0.99),
            overflow_events: responses.iter().map(|r| r.overflow_events).sum(),
            arena_bytes: 0,
            prefix_hits: shared_ttfts.len(),
            prefix_hit_rate: shared_ttfts.len() as f64 / ok.len().max(1) as f64,
            prefill_tokens_skipped: responses.iter().map(|r| r.prefill_tokens_skipped).sum(),
            p50_ttft_shared_s: pct(&shared_ttfts, 0.50),
            p50_ttft_cold_s: pct(&cold_ttfts, 0.50),
            pages_shared: 0,
            cache_evictions: 0,
            telemetry: None,
        }
    }

    /// The overload-conservation invariant: every accepted submission
    /// resolved to exactly one typed terminal response —
    /// `submitted == completed + shed + deadline_miss + cancelled`.
    /// `submitted` comes from [`ServeQueue::submitted_count`].
    pub fn conserved(&self, submitted: u64) -> bool {
        self.requests as u64 == submitted
            && self.completed + self.shed + self.deadline_miss + self.cancelled == self.requests
    }

    /// Merge the per-engine telemetry summaries (histograms are
    /// associative, so fold order is irrelevant) into this stats
    /// block for the serve report.
    pub fn fill_telemetry(&mut self, engines: &[EngineStats]) {
        let mut merged: Option<MetricsSummary> = None;
        for e in engines {
            if let Some(t) = &e.telemetry {
                match &mut merged {
                    Some(m) => m.merge(t),
                    None => merged = Some(*t),
                }
            }
        }
        self.telemetry = merged;
    }
}

/// Per-engine serving configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// In-flight slots per engine (the continuous-batching degree).
    pub max_batch: usize,
    /// KV arena backend.
    pub kind: KvCacheKind,
    /// Per-step prefill chunk size AND shared prefill token budget:
    /// each ragged step carries at most this many prompt tokens,
    /// handed out round-robin across admitting sequences. `usize::MAX`
    /// (or anything ≥ the longest servable prompt) degenerates to
    /// whole-prompt admission in a single ragged group. Token streams
    /// are bit-identical for every value — this knob trades
    /// time-to-first-token against per-step latency only.
    pub prefill_chunk: usize,
    /// Positions per KV page (`--kv-page`; clamped to the model window
    /// at arena construction). Smaller pages share shorter common
    /// prefixes at finer granularity but carry more table overhead.
    pub kv_page: usize,
    /// Shared-prefix page caching (`--prefix-cache`): admissions adopt
    /// already-encoded full prefix pages read-only and skip straight to
    /// the unshared tail. Token streams and per-request overflow counts
    /// are bit-identical on or off — the switch trades admission work
    /// and resident bytes only.
    pub prefix_cache: bool,
    /// Threads for the banded ragged-attention sweep (`--attn-threads`;
    /// `0` = auto: resolve to [`crate::linalg::num_threads`] at engine
    /// construction). `1` keeps the sweep serial — the parity oracle.
    /// Token streams and per-request overflow counts are bit-identical
    /// at every value.
    pub attn_threads: usize,
    /// Minimum estimated attention MACs in a step before it fans out
    /// across bands (below it the serial sweep is faster and stays
    /// allocation-free). Benches and parity tests set 0 to force
    /// banding on tiny fixtures.
    pub attn_par_min: usize,
    /// Scale the shared prefill budget down by the step's live decode
    /// rows (`--fair-budget`, default on): step tokens — and hence
    /// per-step latency — stay bounded by
    /// `max(prefill_chunk, max_batch)` under admission storms, at the
    /// cost of slower prefill when the batch is decode-heavy. Off
    /// restores the fixed budget. Bit-identical tokens either way.
    pub fair_budget: bool,
    /// Per-step telemetry (record ring + histograms). On by default:
    /// recording is allocation-free and adds one mutex round-trip per
    /// step. Turning it off removes the records, the histograms and
    /// the [`EngineStats::telemetry`] summary.
    pub telemetry: bool,
    /// Telemetry ring capacity in records (`--metrics-ring`) — the
    /// slack between the engine and its off-thread sink drainer before
    /// oldest records are overwritten (drop-counted).
    pub metrics_ring: usize,
    /// Self-speculative chunk depth (`--speculate-k`): each decoding
    /// sequence proposes up to `speculate_k - 1` draft tokens per step
    /// on the narrowed datapath and verifies the whole chunk in one
    /// full-width chunk-causal group. `≤ 1` disables speculation.
    /// Token streams and per-request overflow counts are bit-identical
    /// to non-speculative decode at every depth — the knob trades step
    /// composition (and wasted verify rows on draft misses) only.
    pub speculate_k: usize,
    /// Inner accumulator register width of the draft rounds
    /// (`--draft-acc-bits`; clamped to the datapath's own width, so
    /// `None` — or anything at least as wide — makes the draft exact
    /// and every proposal accept). Narrower drafts are cheaper models
    /// of the same weights: saturation skews their argmax, costing
    /// acceptance rate, never correctness.
    pub draft_bits: Option<u32>,
    /// Decode sampling spec (`--temperature/--top-k/--top-p/--seed`);
    /// greedy by default. Draws are keyed per (seed, request id,
    /// position), so sampled streams are batch-composition-invariant.
    /// Speculative mode (`speculate_k > 1`) requires greedy.
    pub sample: SampleSpec,
}

impl ServeConfig {
    pub fn new(max_batch: usize, kind: KvCacheKind) -> ServeConfig {
        ServeConfig {
            max_batch: max_batch.max(1),
            kind,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            kv_page: DEFAULT_KV_PAGE,
            prefix_cache: true,
            attn_threads: 1,
            attn_par_min: crate::model::PAR_ATTN_MIN_WORK,
            fair_budget: true,
            telemetry: true,
            metrics_ring: DEFAULT_RING_CAPACITY,
            speculate_k: 1,
            draft_bits: None,
            sample: SampleSpec::greedy(),
        }
    }

    pub fn with_prefill_chunk(mut self, chunk: usize) -> ServeConfig {
        self.prefill_chunk = chunk.max(1);
        self
    }

    pub fn with_kv_page(mut self, page: usize) -> ServeConfig {
        self.kv_page = page.max(1);
        self
    }

    pub fn with_prefix_cache(mut self, on: bool) -> ServeConfig {
        self.prefix_cache = on;
        self
    }

    /// Attention sweep thread count (`0` = auto).
    pub fn with_attn_threads(mut self, threads: usize) -> ServeConfig {
        self.attn_threads = threads;
        self
    }

    /// Banding threshold in estimated attention MACs (`0` forces the
    /// banded sweep whenever more than one group is scheduled).
    pub fn with_attn_par_min_work(mut self, macs: usize) -> ServeConfig {
        self.attn_par_min = macs;
        self
    }

    /// Decode-row-scaled prefill budget on/off (default on).
    pub fn with_fair_budget(mut self, on: bool) -> ServeConfig {
        self.fair_budget = on;
        self
    }

    /// Per-step telemetry on/off (default on).
    pub fn with_telemetry(mut self, on: bool) -> ServeConfig {
        self.telemetry = on;
        self
    }

    /// Telemetry ring capacity in records (clamped to ≥ 1).
    pub fn with_metrics_ring(mut self, records: usize) -> ServeConfig {
        self.metrics_ring = records.max(1);
        self
    }

    /// Speculative chunk depth and draft register width (`k ≤ 1`
    /// disables speculation; see the field docs).
    pub fn with_speculate(mut self, k: usize, draft_bits: Option<u32>) -> ServeConfig {
        self.speculate_k = k.max(1);
        self.draft_bits = draft_bits;
        self
    }

    /// Decode sampling spec (greedy by default). Speculative mode
    /// requires greedy — asserted at engine construction.
    pub fn with_sampling(mut self, sample: SampleSpec) -> ServeConfig {
        self.sample = sample;
        self
    }
}

/// Scheduler phase of an in-flight sequence.
enum Phase {
    /// `context[next_pos..]` still has prompt (or slide-tail) tokens to
    /// prefill in chunks; no logits are pending.
    Prefilling { next_pos: usize },
    /// Prefill complete: `logits` holds the last step's output, a
    /// sample is due.
    Decoding,
}

/// One in-flight sequence: its arena slot plus the state the step
/// scheduler threads from sample to sample.
struct InFlight {
    id: u64,
    slot: usize,
    /// Window-clipped prompt + generated tokens (the slide tail
    /// source). While `Prefilling`, the suffix from `next_pos` is what
    /// remains to be encoded.
    context: Vec<u16>,
    /// Generated tokens only.
    emitted: Vec<u16>,
    max_new: usize,
    /// Logits pending a sample (valid in `Decoding` only).
    logits: Vec<f32>,
    enqueued: Instant,
    admitted: Instant,
    /// When the first token was sampled (TTFT numerator).
    first_token: Option<Instant>,
    /// Exact overflow events this request has triggered so far (its
    /// prefill chunks + its rows of every ragged step, plus the
    /// fill-time events credited from any adopted prefix pages).
    overflow: u64,
    /// Prefill positions skipped via prefix-page adoption.
    skipped: usize,
    /// Deadline the reaper enforces (admission check + every step).
    deadline: Option<Instant>,
    /// Cancellation handle the reaper polls (admission + every step).
    cancel: Option<CancelToken>,
    phase: Phase,
}

/// Seal an in-flight sequence into its terminal [`Response`] — shared
/// by normal retirement ([`Status::Ok`], full stream) and the
/// deadline/cancel reaper (partial stream).
fn finish(seq: InFlight, status: Status) -> Response {
    let queued_s = seq.admitted.duration_since(seq.enqueued).as_secs_f64();
    Response {
        id: seq.id,
        tokens: seq.emitted,
        queued_s,
        gen_s: seq.admitted.elapsed().as_secs_f64(),
        ttft_s: seq
            .first_token
            .map(|t| t.duration_since(seq.enqueued).as_secs_f64())
            .unwrap_or(queued_s),
        overflow_events: seq.overflow,
        prefill_tokens_skipped: seq.skipped,
        status,
    }
}

/// The deterministic, single-threaded step scheduler one engine thread
/// drives — exposed so tests (`tests/chunked_prefill.rs`,
/// `tests/overload.rs`) and benches can run admission schedules step by
/// step without queues or threads.
///
/// Lifecycle: [`StepEngine::admit`] requests into free slots (they
/// start in the `Prefilling` phase — admission does **no** model
/// work), then call [`StepEngine::step`] repeatedly; completed
/// [`Response`]s accumulate until [`StepEngine::take_finished`].
pub struct StepEngine<'m> {
    model: &'m Transformer,
    cfg: ServeConfig,
    arena: KvArena,
    scratch: DecodeScratch,
    /// Draft-round workspace (speculative mode only): the narrowed
    /// passes run over their own scratch, so the verify pass's per-row
    /// overflow counters, logits and attention-share telemetry in
    /// `scratch` stay readable after the step.
    draft_scratch: Option<DecodeScratch>,
    /// Flat per-sequence draft chunks, stride `speculate_k`, indexed by
    /// position in `active` (stable within a step): entry 0 is the
    /// committed sample, the rest are narrow-register proposals.
    spec_chunk: Vec<u16>,
    /// Live chunk depth per `active` index (0 while not decoding).
    spec_len: Vec<usize>,
    /// Reused candidate buffer for sampled decode (presized to vocab,
    /// so sampling stays on the zero-allocation steady state).
    sample_buf: Vec<(f32, u32)>,
    active: Vec<InFlight>,
    finished: Vec<Response>,
    // reused ragged-step composition buffers (allocation-free loop)
    step_tokens: Vec<u16>,
    groups: Vec<RowGroup>,
    /// `group_seq[g]` = index into `active` of the sequence group `g`
    /// belongs to (a budget-starved prefill contributes no group).
    group_seq: Vec<usize>,
    group_ovf: Vec<u64>,
    /// Per-step telemetry (ring + histograms), shared with the sink
    /// drainer when one is attached. `None` with `cfg.telemetry` off.
    metrics: Option<SharedMetrics>,
    /// Index of the next *executed* ragged step (empty scheduler
    /// iterations don't advance it, so recorded steps are consecutive).
    step_idx: u64,
    /// Queue depth sampled at the latest admission poll
    /// ([`StepEngine::note_queue_depth`]).
    queue_depth: u32,
    /// Running max of every sampled queue depth — the step records'
    /// high-water mark (monotone per engine stream).
    queue_hwm: u32,
    /// Rotates the round-robin start of prefill chunk grants by one
    /// sequence per executed step.
    rr_cursor: usize,
    /// Terminal events (queue sheds / deadline misses / cancellations)
    /// observed since the last emitted step record — carried on the
    /// next record (a zero-token drain record if the engine is empty)
    /// so the record stream's sums equal the response-status counts.
    pending_shed: u64,
    pending_miss: u32,
    pending_cancel: u32,
    /// Speculation counters of the step being composed: draft tokens
    /// proposed / accepted, draft rows executed, draft-pass overflow
    /// events (work-done telemetry — per-request attribution counts
    /// accepted verify rows only).
    pending_proposed: u32,
    pending_accepted: u32,
    pending_draft_rows: u32,
    pending_draft_ovf: u64,
    /// Last recorded [pages_shared, pages_deduped, cache_evictions] —
    /// step records carry per-step deltas of the arena's lifetime
    /// counters.
    prefix_snap: [u64; 3],
}

impl<'m> StepEngine<'m> {
    pub fn new(model: &'m Transformer, cfg: ServeConfig) -> StepEngine<'m> {
        let max_batch = cfg.max_batch.max(1);
        let k = cfg.speculate_k.max(1);
        assert!(
            k <= 1 || cfg.sample.is_greedy(),
            "speculative decoding requires greedy sampling — its acceptance rule is the argmax"
        );
        // a speculative step stacks up to k verify rows per decoding
        // sequence, so the main workspace is presized to that wider
        // ragged high-water mark
        let mut scratch = DecodeScratch::for_serve(&model.cfg, max_batch * k, cfg.prefill_chunk);
        // resolve the thread count once and presize the per-thread
        // attention pool here, so the step loop never allocates scratch
        let threads =
            if cfg.attn_threads == 0 { crate::linalg::num_threads() } else { cfg.attn_threads };
        scratch.set_attn_threads(&model.cfg, threads);
        scratch.set_attn_par_min_work(cfg.attn_par_min);
        let draft_scratch = (k > 1).then(|| {
            // draft rounds are all-1-row-group steps: one row per
            // decoding sequence, no prefill chunks
            let mut s = DecodeScratch::for_serve(&model.cfg, max_batch, 1);
            s.set_attn_threads(&model.cfg, threads);
            s.set_attn_par_min_work(cfg.attn_par_min);
            s
        });
        StepEngine {
            model,
            cfg,
            arena: KvArena::with_kind_paged(model, max_batch, cfg.kind, cfg.kv_page),
            scratch,
            draft_scratch,
            spec_chunk: vec![0; max_batch * k],
            spec_len: vec![0; max_batch],
            sample_buf: if cfg.sample.is_greedy() {
                Vec::new()
            } else {
                Vec::with_capacity(model.cfg.vocab)
            },
            active: Vec::with_capacity(max_batch),
            finished: Vec::new(),
            step_tokens: Vec::new(),
            groups: Vec::with_capacity(max_batch),
            group_seq: Vec::with_capacity(max_batch),
            group_ovf: Vec::with_capacity(max_batch),
            metrics: cfg.telemetry.then(|| SharedMetrics::new(cfg.metrics_ring)),
            step_idx: 0,
            queue_depth: 0,
            queue_hwm: 0,
            rr_cursor: 0,
            pending_shed: 0,
            pending_miss: 0,
            pending_cancel: 0,
            pending_proposed: 0,
            pending_accepted: 0,
            pending_draft_rows: 0,
            pending_draft_ovf: 0,
            prefix_snap: [0; 3],
        }
    }

    pub fn free_slots(&self) -> usize {
        self.arena.free_slots()
    }

    /// Sequences currently in flight (any phase).
    pub fn in_flight(&self) -> usize {
        self.active.len()
    }

    /// In-flight sequences still prefilling their prompt or slide tail.
    pub fn prefilling(&self) -> usize {
        self.active
            .iter()
            .filter(|s| matches!(s.phase, Phase::Prefilling { .. }))
            .count()
    }

    pub fn has_work(&self) -> bool {
        !self.active.is_empty()
    }

    /// The engine's telemetry handle (`None` with telemetry off) —
    /// clone it to attach a sink drainer, or snapshot
    /// `.summary()` after the run.
    pub fn metrics(&self) -> Option<&SharedMetrics> {
        self.metrics.as_ref()
    }

    /// Record the pending-queue depth observed at this iteration's
    /// admission poll; the next step record carries it (and folds it
    /// into the high-water mark).
    pub fn note_queue_depth(&mut self, depth: usize) {
        self.queue_depth = depth.min(u32::MAX as usize) as u32;
        self.queue_hwm = self.queue_hwm.max(self.queue_depth);
    }

    /// Credit `n` queue sheds to this engine's telemetry stream (pair
    /// with [`ServeQueue::take_shed_delta`] for exactly-once reporting
    /// across engines).
    pub fn note_shed(&mut self, n: u64) {
        self.pending_shed += n;
    }

    /// Admit a request into a free slot. Costs no model work: the
    /// prompt is clipped to the window and queued for chunked prefill
    /// inside the step loop. Zero-token requests complete immediately;
    /// already-cancelled or deadline-expired requests resolve to their
    /// typed terminal response without spending a slot.
    pub fn admit(&mut self, req: Request, enqueued: Instant) {
        let admitted = Instant::now();
        let queued_s = admitted.duration_since(enqueued).as_secs_f64();
        let dead_on_arrival = if req.cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
            self.pending_cancel += 1;
            Some(Status::Cancelled)
        } else if req.deadline.is_some_and(|d| admitted >= d) {
            self.pending_miss += 1;
            Some(Status::DeadlineMiss)
        } else if req.max_new_tokens == 0 {
            // nothing to generate: complete without spending a prefill
            // or an arena slot
            Some(Status::Ok)
        } else {
            None
        };
        if let Some(status) = dead_on_arrival {
            self.finished.push(Response {
                id: req.id,
                tokens: Vec::new(),
                queued_s,
                gen_s: 0.0,
                ttft_s: queued_s,
                overflow_events: 0,
                prefill_tokens_skipped: 0,
                status,
            });
            return;
        }
        assert!(!req.prompt.is_empty(), "empty prompt");
        let slot = self.arena.alloc().expect("admission is bounded by free slots");
        let prompt = self.model.clip_to_window(&req.prompt);
        // prefix-cache hit: map already-encoded full prefix pages
        // read-only into the fresh slot (refcount bumps, no model
        // work) and start the chunked prefill at the unshared tail.
        // Adopted pages are bit-identical to what prefilling them
        // would produce, and their stored fill-time overflow events
        // are credited here — tokens and per-request overflow counts
        // are unchanged vs a cold admission.
        let (mapped, adopted_ovf) = if self.cfg.prefix_cache {
            self.arena.adopt_prefix(slot, &prompt)
        } else {
            (0, 0)
        };
        self.active.push(InFlight {
            id: req.id,
            slot,
            context: prompt,
            emitted: Vec::with_capacity(req.max_new_tokens),
            max_new: req.max_new_tokens,
            logits: Vec::new(),
            enqueued,
            admitted,
            first_token: None,
            overflow: adopted_ovf,
            skipped: mapped,
            deadline: req.deadline,
            cancel: req.cancel,
            phase: Phase::Prefilling { next_pos: mapped },
        });
    }

    /// One scheduler iteration: reap cancelled / deadline-expired
    /// sequences, sample / slide / retire every `Decoding` sequence,
    /// then compose and execute one ragged step ({prefill chunks +
    /// decode rows}) over everything still in flight. No-op when
    /// nothing is in flight (modulo flushing pending terminal events
    /// into a drain record).
    pub fn step(&mut self) {
        // telemetry clocks the full scheduler iteration (reap + sample/
        // slide/retire + compose + kernel + routing); gated so a
        // telemetry-off engine doesn't even read the clock
        let t0 = self.metrics.is_some().then(Instant::now);
        let vocab = self.model.cfg.vocab;

        // -- reap doomed work before spending any model time on it.
        // Mid-prefill drops release the slot and unref its pages —
        // private pages return to the pool, adopted/cached pages fall
        // back to the prefix cache's own refcount hold. The partial
        // token stream (a prefix of the uncontended stream, by row
        // independence) ships on the typed terminal response.
        if self.active.iter().any(|s| s.deadline.is_some() || s.cancel.is_some()) {
            let now = Instant::now();
            let mut i = 0;
            while i < self.active.len() {
                let seq = &self.active[i];
                let status = if seq.cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
                    Some(Status::Cancelled)
                } else if seq.deadline.is_some_and(|d| now >= d) {
                    Some(Status::DeadlineMiss)
                } else {
                    None
                };
                match status {
                    Some(status) => {
                        let seq = self.active.swap_remove(i);
                        self.arena.release(seq.slot);
                        match status {
                            Status::Cancelled => self.pending_cancel += 1,
                            _ => self.pending_miss += 1,
                        }
                        self.finished.push(finish(seq, status));
                    }
                    None => i += 1,
                }
            }
        }

        // -- sample, slide, retire (Decoding sequences only; a
        // Prefilling sequence has no logits to sample yet)
        let mut i = 0;
        while i < self.active.len() {
            let seq = &mut self.active[i];
            if !matches!(seq.phase, Phase::Decoding) {
                i += 1;
                continue;
            }
            if self.arena.is_full(seq.slot) {
                // window slide: drop to the kept tail and re-encode it
                // through the same chunked prefill path. The pending
                // logits are discarded and replaced by the tail
                // re-prefill's final logits — exactly generate_greedy's
                // slide, so the token stream cannot diverge.
                let keep = self.model.slide_keep();
                let cut = seq.context.len() - keep;
                seq.context.drain(..cut);
                self.arena.reset_slot(seq.slot);
                // a reset slot is fresh and position-0-aligned, so the
                // slide tail can adopt shared pages too (a divergent
                // tail simply misses)
                let mapped = if self.cfg.prefix_cache {
                    let (mapped, ovf) = self.arena.adopt_prefix(seq.slot, &seq.context);
                    seq.overflow += ovf;
                    seq.skipped += mapped;
                    mapped
                } else {
                    0
                };
                seq.phase = Phase::Prefilling { next_pos: mapped };
                i += 1;
                continue;
            }
            // seeded sampling is keyed per (request id, emitted count):
            // a pure function of per-request state, so the draw — and
            // hence the stream — is invariant to batch composition
            let next = self.cfg.sample.sample_with(
                &seq.logits,
                seq.id,
                seq.emitted.len() as u64,
                &mut self.sample_buf,
            ) as u16;
            if seq.first_token.is_none() {
                let now = Instant::now();
                seq.first_token = Some(now);
                // TTFT lands in the histogram the moment it is known —
                // the record stream stays per-step, per-request latency
                // still reaches the merged summary
                if let Some(m) = &self.metrics {
                    m.with(|mm| {
                        mm.record_ttft(now.duration_since(seq.enqueued).as_nanos() as u64)
                    });
                }
            }
            seq.emitted.push(next);
            seq.context.push(next);
            if seq.emitted.len() >= seq.max_new {
                let seq = self.active.swap_remove(i);
                self.arena.release(seq.slot);
                self.finished.push(finish(seq, Status::Ok));
            } else {
                i += 1;
            }
        }

        // -- speculative draft rounds: every decoding sequence extends
        // the sample it just committed into a depth-L chunk on the
        // narrowed datapath, batched as one 1-row group per sequence
        // per round. Draft rows append K/V like any step row but skip
        // the page fill ledgers; the rollback below restores the arena
        // byte for byte before the full-width verify re-encodes the
        // whole chunk at the same positions.
        let k = self.cfg.speculate_k;
        let speculating = k > 1;
        if speculating {
            let max_seq = self.model.cfg.max_seq;
            self.spec_len.iter_mut().for_each(|l| *l = 0);
            for (si, seq) in self.active.iter().enumerate() {
                if !matches!(seq.phase, Phase::Decoding) {
                    continue;
                }
                // chunk depth L = committed sample + up to k-1 drafts,
                // capped by the window and by remaining tokens so full
                // acceptance leaves at least one token for the next
                // sample pass (retirement stays in one place) and the
                // verify group never overflows the slot
                let remaining = seq.max_new - seq.emitted.len();
                let space = max_seq - self.arena.len(seq.slot);
                self.spec_len[si] = k.min(remaining).min(space);
                self.spec_chunk[si * k] = *seq.context.last().unwrap();
            }
            let draft =
                self.draft_scratch.as_mut().expect("speculating engine owns a draft workspace");
            for round in 1..k {
                self.step_tokens.clear();
                self.groups.clear();
                self.group_seq.clear();
                for (si, seq) in self.active.iter().enumerate() {
                    if self.spec_len[si] > round {
                        let start = self.step_tokens.len();
                        self.step_tokens.push(self.spec_chunk[si * k + round - 1]);
                        self.groups.push(RowGroup { slot: seq.slot, start, len: 1 });
                        self.group_seq.push(si);
                    }
                }
                if self.groups.is_empty() {
                    break;
                }
                self.group_ovf.clear();
                self.group_ovf.resize(self.groups.len(), 0);
                self.model.decode_step_ragged_opts(
                    &self.step_tokens,
                    &self.groups,
                    &mut self.arena,
                    &mut self.group_ovf,
                    draft,
                    RaggedOpts::draft(self.cfg.draft_bits),
                );
                self.pending_draft_rows += self.groups.len() as u32;
                self.pending_draft_ovf += self.group_ovf.iter().sum::<u64>();
                for (gi, &si) in self.group_seq.iter().enumerate() {
                    self.spec_chunk[si * k + round] =
                        argmax(&draft.step.logits[gi * vocab..(gi + 1) * vocab]) as u16;
                }
            }
            // roll every draft append back; the verify group re-encodes
            // chunk row 0 (the committed sample) onward full-width
            for (si, seq) in self.active.iter().enumerate() {
                if self.spec_len[si] > 1 {
                    self.arena.truncate_tail(seq.slot, self.spec_len[si] - 1);
                }
            }
        }

        // -- compose the ragged step. Pass 1: one decode group per
        // Decoding sequence, in active order (always — admissions can
        // never stall the batch): a single row normally, the whole
        // draft chunk as one chunk-causal verify group when
        // speculating.
        self.step_tokens.clear();
        self.groups.clear();
        self.group_seq.clear();
        let (mut decode_rows, mut prefill_rows, mut prefill_chunks) = (0u32, 0u32, 0u32);
        for (si, seq) in self.active.iter().enumerate() {
            if matches!(seq.phase, Phase::Decoding) {
                let start = self.step_tokens.len();
                if speculating {
                    let l = self.spec_len[si];
                    self.step_tokens.extend_from_slice(&self.spec_chunk[si * k..si * k + l]);
                    self.groups.push(RowGroup { slot: seq.slot, start, len: l });
                    decode_rows += l as u32;
                } else {
                    self.step_tokens.push(*seq.context.last().unwrap());
                    self.groups.push(RowGroup { slot: seq.slot, start, len: 1 });
                    decode_rows += 1;
                }
                self.group_seq.push(si);
            }
        }
        // fair budget: the decode rows above already claimed their
        // share of the step, so shrink the prefill budget by them —
        // step tokens (and step latency) stay bounded by
        // max(prefill_chunk, max_batch) however hard admissions storm
        let mut budget = if self.cfg.fair_budget {
            self.cfg.prefill_chunk.max(1).saturating_sub(decode_rows as usize).max(1)
        } else {
            self.cfg.prefill_chunk.max(1)
        };
        // Pass 2: hand prefill chunks out round-robin, rotating the
        // start by one sequence per executed step, so a giant prompt
        // shares the budget instead of monopolizing it. Grant order
        // only — every row is computed independently, so tokens and
        // attribution are unchanged by the rotation.
        let n = self.active.len();
        let start_at = if n == 0 { 0 } else { self.rr_cursor % n };
        for k in 0..n {
            if budget == 0 {
                break; // starved this step; next step's budget is fresh
            }
            let si = (start_at + k) % n;
            let seq = &self.active[si];
            if let Phase::Prefilling { next_pos } = seq.phase {
                let take = budget.min(seq.context.len() - next_pos);
                let start = self.step_tokens.len();
                self.step_tokens.extend_from_slice(&seq.context[next_pos..next_pos + take]);
                self.groups.push(RowGroup { slot: seq.slot, start, len: take });
                self.group_seq.push(si);
                budget -= take;
                prefill_rows += take as u32;
                prefill_chunks += 1;
            }
        }
        if self.groups.is_empty() {
            // nothing to execute — but terminal events observed since
            // the last record (sheds with an idle engine, a reap that
            // emptied the batch) must still reach the record stream:
            // emit a zero-token drain record so per-step sums stay
            // equal to the response-status counts
            if self.pending_shed != 0 || self.pending_miss != 0 || self.pending_cancel != 0 {
                if let Some(m) = &self.metrics {
                    let rec = StepRecord {
                        step: self.step_idx,
                        wall_ns: t0.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0),
                        arena_resident_bytes: self.arena.bytes() as u64,
                        arena_capacity_bytes: self.arena.capacity_bytes() as u64,
                        queue_depth: self.queue_depth,
                        queue_hwm: self.queue_hwm,
                        shed: self.pending_shed.min(u32::MAX as u64) as u32,
                        deadline_miss: self.pending_miss,
                        cancelled: self.pending_cancel,
                        ..StepRecord::default()
                    };
                    m.with(|mm| mm.record(rec));
                    self.step_idx += 1;
                }
                self.pending_shed = 0;
                self.pending_miss = 0;
                self.pending_cancel = 0;
            }
            return;
        }
        self.group_ovf.clear();
        self.group_ovf.resize(self.groups.len(), 0);
        // a speculative step needs per-row logits (acceptance compares
        // every chunk position), so the whole step runs in the
        // all-rows layout; otherwise the standard one-per-group shape
        self.model.decode_step_ragged_opts(
            &self.step_tokens,
            &self.groups,
            &mut self.arena,
            &mut self.group_ovf,
            &mut self.scratch,
            if speculating { RaggedOpts::verify() } else { RaggedOpts::standard() },
        );

        // -- route results: overflow attribution per group, logits to
        // every decode row and to each prefill that just completed. In
        // speculative mode decode groups additionally run acceptance:
        // draft position i stands iff the full-width argmax over verify
        // row i-1 (the logits after chunk[..i]) reproduces it — the
        // longest matching prefix is committed, the rejected tail rolls
        // back, and the row after the last accepted token seeds the
        // next sample with exactly the logits plain decode would hold.
        for (gi, &si) in self.group_seq.iter().enumerate() {
            let g = self.groups[gi];
            let seq = &mut self.active[si];
            if speculating && matches!(seq.phase, Phase::Decoding) {
                let mut acc = 1usize;
                while acc < g.len {
                    let row = g.start + acc - 1;
                    let t =
                        argmax(&self.scratch.step.logits[row * vocab..(row + 1) * vocab]) as u16;
                    if t != self.spec_chunk[si * k + acc] {
                        break;
                    }
                    seq.emitted.push(t);
                    seq.context.push(t);
                    acc += 1;
                }
                self.pending_proposed += (g.len - 1) as u32;
                self.pending_accepted += (acc - 1) as u32;
                self.arena.truncate_tail(seq.slot, g.len - acc);
                // per-request attribution counts the committed rows
                // only — exactly the rows non-speculative decode runs;
                // rejected verify rows are step-level work, folded into
                // the telemetry record's overflow totals instead
                seq.overflow +=
                    self.scratch.step.row_ovf[g.start..g.start + acc].iter().sum::<u64>();
                let row = g.start + acc - 1;
                seq.logits.clear();
                seq.logits
                    .extend_from_slice(&self.scratch.step.logits[row * vocab..(row + 1) * vocab]);
                continue;
            }
            seq.overflow += self.group_ovf[gi];
            let done_prefill = match &mut seq.phase {
                Phase::Decoding => true,
                Phase::Prefilling { next_pos } => {
                    *next_pos += g.len;
                    if self.cfg.prefix_cache {
                        // file the pages this chunk just completed in
                        // the prefix cache, so admissions sharing the
                        // prefix can adopt them (idempotent per page)
                        self.arena.register_prefix(seq.slot, &seq.context[..*next_pos]);
                    }
                    *next_pos == seq.context.len()
                }
            };
            if done_prefill {
                // logits row of this group: its own index in the
                // one-per-group layout, its final row when the
                // speculative step ran in the all-rows layout
                let row = if speculating { g.start + g.len - 1 } else { gi };
                seq.logits.clear();
                seq.logits
                    .extend_from_slice(&self.scratch.step.logits[row * vocab..(row + 1) * vocab]);
                seq.phase = Phase::Decoding;
            }
        }
        self.rr_cursor = self.rr_cursor.wrapping_add(1);

        // -- telemetry: one record per executed ragged step, built from
        // state the step already computed (per-group overflow fold, the
        // kernel's attention share, arena counters) — a handful of
        // reads, one memcpy into the preallocated ring, no allocation
        if let Some(m) = &self.metrics {
            let total_ovf: u64 = self.group_ovf.iter().sum();
            let attn_ovf = self.scratch.last_attn_overflows();
            let shared = self.arena.pages_shared();
            let deduped = self.arena.pages_deduped();
            let evicted = self.arena.cache_evictions();
            let rec = StepRecord {
                step: self.step_idx,
                wall_ns: t0.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0),
                decode_rows,
                prefill_rows,
                prefill_chunks,
                tokens: decode_rows + prefill_rows,
                // group_ovf counts linear AND attention events per row;
                // the kernel reports the attention share separately
                overflow_linear: total_ovf.saturating_sub(attn_ovf),
                overflow_attn: attn_ovf,
                arena_resident_bytes: self.arena.bytes() as u64,
                arena_capacity_bytes: self.arena.capacity_bytes() as u64,
                prefix_hits: (shared - self.prefix_snap[0]) as u32,
                prefix_dedups: (deduped - self.prefix_snap[1]) as u32,
                prefix_evictions: (evicted - self.prefix_snap[2]) as u32,
                attn_bands: self.scratch.last_attn_bands() as u32,
                queue_depth: self.queue_depth,
                queue_hwm: self.queue_hwm,
                shed: self.pending_shed.min(u32::MAX as u64) as u32,
                deadline_miss: self.pending_miss,
                cancelled: self.pending_cancel,
                spec_proposed: self.pending_proposed,
                spec_accepted: self.pending_accepted,
                draft_rows: self.pending_draft_rows,
                overflow_draft: self.pending_draft_ovf,
            };
            self.prefix_snap = [shared, deduped, evicted];
            m.with(|mm| mm.record(rec));
            self.step_idx += 1;
        }
        self.pending_shed = 0;
        self.pending_miss = 0;
        self.pending_cancel = 0;
        self.pending_proposed = 0;
        self.pending_accepted = 0;
        self.pending_draft_rows = 0;
        self.pending_draft_ovf = 0;
    }

    /// Drain completed responses (unordered; the queue sorts on drain).
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// The engine's KV arena — resident/capacity bytes, pages shared,
    /// prefix-cache size (tests, benches, and the serve report).
    pub fn arena(&self) -> &KvArena {
        &self.arena
    }
}

/// Per-engine arena/prefix-cache counters collected when an engine
/// thread exits — the serve report's sharing-effectiveness block.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Full pages mapped read-only from the prefix cache.
    pub pages_shared: u64,
    /// Entries (full pages) held by the prefix cache at exit.
    pub prefix_cache_pages: usize,
    /// Resident (deduplicated) arena bytes at exit.
    pub resident_bytes: usize,
    /// High-water resident arena bytes.
    pub peak_bytes: usize,
    /// Reserved arena bytes (every page backed).
    pub capacity_bytes: usize,
    /// Times the prefix cache was flushed outright (explicit
    /// invalidation; allocation pressure evicts instead).
    pub cache_flushes: u64,
    /// Unreferenced prefix-cache entries evicted oldest-first under
    /// allocation pressure.
    pub cache_evictions: u64,
    /// Private pages remapped onto an already-cached twin at
    /// registration (concurrent same-prefix admissions deduplicated).
    pub pages_deduped: u64,
    /// This engine's telemetry aggregate (histograms + per-step sums);
    /// `None` when the engine ran with telemetry off.
    pub telemetry: Option<MetricsSummary>,
}

impl EngineStats {
    fn of(arena: &KvArena) -> EngineStats {
        EngineStats {
            pages_shared: arena.pages_shared(),
            prefix_cache_pages: arena.prefix_cache_pages(),
            resident_bytes: arena.bytes(),
            peak_bytes: arena.peak_bytes(),
            capacity_bytes: arena.capacity_bytes(),
            cache_flushes: arena.cache_flushes(),
            cache_evictions: arena.cache_evictions(),
            pages_deduped: arena.pages_deduped(),
            telemetry: None,
        }
    }
}

/// Run `engines` continuous-batching engine threads off the queue, each
/// with `max_batch` in-flight slots over an f32 KV arena and the
/// default prefill chunk. Returns when the queue is closed and fully
/// drained.
pub fn serve(model: &Transformer, queue: &ServeQueue, engines: usize, max_batch: usize) {
    serve_with(model, queue, engines, max_batch, KvCacheKind::F32);
}

/// [`serve`] with an explicit KV-cache backend: `KvCacheKind::Quant`
/// stores each engine's arena as narrow integer codes and runs the
/// attention score/value matmuls through the multi-stage integer
/// accumulator — the `--kv-bits` deployment path.
pub fn serve_with(
    model: &Transformer,
    queue: &ServeQueue,
    engines: usize,
    max_batch: usize,
    kind: KvCacheKind,
) {
    serve_config(model, queue, engines, ServeConfig::new(max_batch, kind));
}

/// [`serve`] with the full per-engine configuration, including
/// `prefill_chunk`, `kv_page` and `prefix_cache` — the CLI deployment
/// path. Returns one [`EngineStats`] per engine thread (sharing
/// effectiveness and resident-byte accounting for the serve report).
pub fn serve_config(
    model: &Transformer,
    queue: &ServeQueue,
    engines: usize,
    cfg: ServeConfig,
) -> Vec<EngineStats> {
    serve_telemetry(model, queue, engines, cfg, &SinkSpec::None, DEFAULT_FLUSH_EVERY)
        .expect("SinkSpec::None cannot fail to build")
}

/// [`serve_config`] with a structured telemetry stream: each engine
/// thread gets its own [`EventSink`] built from `sink`
/// (`--metrics <path|->`) and an off-thread drainer that batches the
/// engine's step records to it every `flush_every` records
/// (`--metrics-flush-every`). Errors only on sink construction (e.g.
/// an unwritable metrics path) — sink I/O during the run is
/// best-effort by design.
pub fn serve_telemetry(
    model: &Transformer,
    queue: &ServeQueue,
    engines: usize,
    cfg: ServeConfig,
    sink: &SinkSpec,
    flush_every: usize,
) -> std::io::Result<Vec<EngineStats>> {
    let n = engines.max(1);
    let mut sinks = Vec::with_capacity(n);
    for i in 0..n {
        sinks.push(sink.build(i, n)?);
    }
    Ok(std::thread::scope(|scope| {
        let handles: Vec<_> = sinks
            .into_iter()
            .map(|s| scope.spawn(move || run_engine(model, queue, cfg, s, flush_every)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("engine thread panicked")).collect()
    }))
}

/// One engine thread: drive a [`StepEngine`] off the shared queue —
/// block when idle, poll admissions (bounded by free slots) when the
/// batch has work, one ragged step per iteration. Queue sheds are
/// credited to this engine's telemetry exactly once via
/// [`ServeQueue::take_shed_delta`]. With a sink attached (and
/// telemetry on), a drainer thread streams the step records; it is
/// finished — final drain + flush — after the engine stops stepping,
/// so the stream is complete before the stats return.
fn run_engine(
    model: &Transformer,
    queue: &ServeQueue,
    cfg: ServeConfig,
    sink: Option<Box<dyn EventSink>>,
    flush_every: usize,
) -> EngineStats {
    let mut engine = StepEngine::new(model, cfg);
    let drainer = match (sink, engine.metrics()) {
        (Some(s), Some(m)) => Some(spawn_drainer(m.clone(), s, flush_every)),
        _ => None,
    };
    loop {
        let admissions = if engine.has_work() {
            queue.poll(engine.free_slots())
        } else {
            match queue.pop_batch(cfg.max_batch.max(1)) {
                Some(batch) => batch,
                None => break, // closed + drained
            }
        };
        for (req, enqueued) in admissions {
            engine.admit(req, enqueued);
        }
        engine.note_queue_depth(queue.depth());
        engine.note_shed(queue.take_shed_delta());
        engine.step();
        queue.complete(engine.take_finished());
    }
    // sheds can land while this engine idles in pop_batch (a rejected
    // submit never enqueues, so no admission follows it) — take the
    // final delta and let an empty step flush it as a drain record
    engine.note_shed(queue.take_shed_delta());
    engine.step();
    let mut stats = EngineStats::of(engine.arena());
    if let Some(d) = drainer {
        d.finish();
    }
    stats.telemetry = engine.metrics().map(|m| m.summary());
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{random_transformer, Activation, TransformerConfig};

    fn model() -> Transformer {
        random_transformer(
            TransformerConfig {
                name: "s".into(),
                vocab: 32,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                d_ff: 32,
                max_seq: 16,
                act: Activation::Gelu,
                parallel_residual: false,
            },
            5,
        )
    }

    /// What the engine must reproduce for a request, bit for bit.
    fn direct(m: &Transformer, prompt: &[u16], n: usize) -> Vec<u16> {
        let clipped = m.clip_to_window(prompt);
        m.generate_greedy(&clipped, n)[clipped.len()..].to_vec()
    }

    #[test]
    fn serves_all_requests() {
        let m = model();
        let q = ServeQueue::new();
        for id in 0..12 {
            q.submit(Request { id, prompt: vec![1, 2, 3], max_new_tokens: 5, ..Request::default() })
                .unwrap();
        }
        q.close();
        let t0 = Instant::now();
        serve(&m, &q, 3, 4);
        let responses = q.drain();
        assert_eq!(responses.len(), 12);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.status, Status::Ok);
            assert_eq!(r.tokens.len(), 5);
            assert!(r.ttft_s >= r.queued_s, "ttft precedes admission");
            assert!(r.ttft_s <= r.queued_s + r.gen_s + 1e-9);
        }
        let stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
        assert_eq!(stats.requests, 12);
        assert_eq!(stats.completed, 12);
        assert_eq!((stats.shed, stats.deadline_miss, stats.cancelled), (0, 0, 0));
        assert!(stats.conserved(q.submitted_count()));
        assert_eq!(stats.total_tokens, 60);
        assert!(stats.p99_latency_s >= stats.p50_latency_s);
        assert!(stats.p99_ttft_s >= stats.p50_ttft_s);
    }

    #[test]
    fn serving_matches_direct_generation() {
        let m = model();
        let q = ServeQueue::new();
        q.submit(Request { id: 0, prompt: vec![4, 5, 6], max_new_tokens: 8, ..Request::default() })
            .unwrap();
        q.close();
        serve(&m, &q, 1, 1);
        let responses = q.drain();
        let direct = m.generate_greedy(&[4, 5, 6], 8);
        assert_eq!(responses[0].tokens, direct[3..]);
    }

    /// THE serving parity property: continuous batching with mid-flight
    /// admissions, mixed prompt lengths (including window-clipped ones),
    /// staggered retirements and per-slot window slides emits, for every
    /// request, exactly the tokens sequential greedy decode emits —
    /// whatever the prefill chunk size (whole-prompt, the default, or a
    /// pathological 1-token trickle), with the fair budget and the
    /// round-robin rotation live.
    #[test]
    fn continuous_batching_is_token_exact() {
        let m = model();
        // 10 requests, prompt lengths 1..=22 (some beyond max_seq=16 →
        // clipped), generation lengths 3..=27 (several past the window →
        // slides); staggered lengths force mid-flight joins and leaves.
        let mut reqs: Vec<Request> = Vec::new();
        for id in 0..10u64 {
            let off = id as usize;
            let plen = 1 + ((off * 5) % 22);
            let prompt: Vec<u16> = (0..plen).map(|i| ((i * 7 + off) % 32) as u16).collect();
            let max_new_tokens = 3 + ((off * 11) % 25);
            reqs.push(Request { id, prompt, max_new_tokens, ..Request::default() });
        }
        for chunk in [1usize, 3, DEFAULT_PREFILL_CHUNK, usize::MAX] {
            for fair in [true, false] {
                let q = ServeQueue::new();
                for r in &reqs {
                    q.submit(r.clone()).unwrap();
                }
                q.close();
                // one engine, 3 slots, 10 requests → continuous mid-flight
                // admission pressure the whole run
                serve_config(
                    &m,
                    &q,
                    1,
                    ServeConfig::new(3, KvCacheKind::F32)
                        .with_prefill_chunk(chunk)
                        .with_fair_budget(fair),
                );
                let responses = q.drain();
                assert_eq!(responses.len(), reqs.len());
                for (resp, req) in responses.iter().zip(reqs.iter()) {
                    assert_eq!(resp.id, req.id);
                    let want = direct(&m, &req.prompt, req.max_new_tokens);
                    assert_eq!(
                        resp.tokens, want,
                        "request {} diverged from sequential greedy decode at chunk {} fair {}",
                        req.id, chunk, fair
                    );
                }
            }
        }
    }

    /// Continuous batching over the **quantized** KV arena must be
    /// token-exact versus sequential greedy decode on that same
    /// backend — the serving guarantee survives the integer attention
    /// datapath and chunked admission.
    #[test]
    fn quant_kv_serving_matches_quant_sequential() {
        use crate::model::KvQuantSpec;
        let m = model();
        let kind = KvCacheKind::Quant(KvQuantSpec::int8());
        let reqs: Vec<Request> = (0..6u64)
            .map(|id| {
                let off = id as usize;
                let plen = 1 + ((off * 5) % 12);
                Request {
                    id,
                    prompt: (0..plen).map(|i| ((i * 7 + off) % 32) as u16).collect(),
                    max_new_tokens: 3 + ((off * 11) % 22),
                    ..Request::default()
                }
            })
            .collect();
        for chunk in [2usize, usize::MAX] {
            let q = ServeQueue::new();
            for r in &reqs {
                q.submit(r.clone()).unwrap();
            }
            q.close();
            serve_config(&m, &q, 1, ServeConfig::new(3, kind).with_prefill_chunk(chunk));
            let responses = q.drain();
            assert_eq!(responses.len(), reqs.len());
            for (resp, req) in responses.iter().zip(reqs.iter()) {
                let clipped = m.clip_to_window(&req.prompt);
                let want = m.generate_greedy_with(&clipped, req.max_new_tokens, kind);
                assert_eq!(
                    resp.tokens,
                    want[clipped.len()..],
                    "request {} diverged from sequential quant-KV decode at chunk {}",
                    req.id,
                    chunk
                );
            }
        }
    }

    /// The interleaving itself: while a long prompt is admitted with a
    /// small chunk, already-decoding sequences keep emitting — the
    /// admission can no longer block the batch head-of-line.
    #[test]
    fn prefill_chunks_interleave_with_decode() {
        let m = model();
        let cfg = ServeConfig::new(2, KvCacheKind::F32).with_prefill_chunk(2);
        let mut eng = StepEngine::new(&m, cfg);
        // sequence A: short prompt, decoding after 1 step
        eng.admit(
            Request { id: 0, prompt: vec![1, 2], max_new_tokens: 12, ..Request::default() },
            Instant::now(),
        );
        eng.step(); // A's whole prompt (2 ≤ chunk)
        assert_eq!(eng.prefilling(), 0);
        // sequence B: 15-token prompt → many chunked steps (the fair
        // budget shrinks the chunk to 1 while A decodes)
        let prompt_b: Vec<u16> = (0..15).map(|i| (i % 32) as u16).collect();
        eng.admit(
            Request { id: 1, prompt: prompt_b.clone(), max_new_tokens: 3, ..Request::default() },
            Instant::now(),
        );
        let mut a_tokens_during_b_prefill = 0usize;
        while eng.prefilling() > 0 {
            eng.step();
            // A may retire mid-prefill (12 tokens < B's chunked steps)
            if let Some(a) = eng.active.iter().find(|s| s.id == 0) {
                a_tokens_during_b_prefill = a_tokens_during_b_prefill.max(a.emitted.len());
            }
        }
        assert!(
            a_tokens_during_b_prefill >= 5,
            "decoder A must keep emitting while B's prompt trickles in \
             (got {a_tokens_during_b_prefill} tokens)"
        );
        // and both finish token-exact
        while eng.has_work() {
            eng.step();
        }
        let mut done = eng.take_finished();
        done.sort_by_key(|r| r.id);
        assert_eq!(done[0].tokens, direct(&m, &[1, 2], 12));
        assert_eq!(done[1].tokens, direct(&m, &prompt_b, 3));
    }

    /// Shared-prefix admissions: followers adopt the leader's full
    /// prefix pages (prefill work ∝ unshared tail only), and tokens AND
    /// per-request overflow counts are bit-identical with sharing on vs
    /// off — on both KV backends, with overflow events live.
    #[test]
    fn prefix_sharing_skips_prefill_and_stays_bit_exact() {
        use crate::model::KvQuantSpec;
        let m = model();
        let sys: Vec<u16> = (0..9).map(|i| ((i * 3 + 1) % 32) as u16).collect();
        for kind in [
            KvCacheKind::F32,
            KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6))), // overflow live
        ] {
            let mut runs: Vec<Vec<Response>> = Vec::new();
            for sharing in [true, false] {
                let cfg = ServeConfig::new(3, kind)
                    .with_prefill_chunk(4)
                    .with_kv_page(4)
                    .with_prefix_cache(sharing);
                let mut eng = StepEngine::new(&m, cfg);
                // leader: prefills + registers the shared prompt
                eng.admit(
                    Request { id: 0, prompt: sys.clone(), max_new_tokens: 4, ..Request::default() },
                    Instant::now(),
                );
                while eng.prefilling() > 0 {
                    eng.step();
                }
                // followers: same prompt → with sharing, admission maps
                // both full pages and prefill covers only the tail
                for id in 1..3u64 {
                    eng.admit(
                        Request {
                            id,
                            prompt: sys.clone(),
                            max_new_tokens: 4,
                            ..Request::default()
                        },
                        Instant::now(),
                    );
                }
                if sharing {
                    for seq in eng.active.iter().filter(|s| s.id > 0) {
                        assert_eq!(
                            seq.skipped, 8,
                            "kind={kind:?}: followers must adopt both full prefix pages"
                        );
                        assert!(
                            matches!(seq.phase, Phase::Prefilling { next_pos: 8 }),
                            "kind={kind:?}: prefill must start at the unshared tail"
                        );
                    }
                    assert_eq!(eng.arena().pages_shared(), 4, "2 followers × 2 pages");
                }
                while eng.has_work() {
                    eng.step();
                }
                let mut done = eng.take_finished();
                done.sort_by_key(|r| r.id);
                runs.push(done);
            }
            let (on, off) = (&runs[0], &runs[1]);
            for (a, b) in on.iter().zip(off.iter()) {
                assert_eq!(a.tokens, b.tokens, "kind={kind:?}: tokens diverge with sharing");
                assert_eq!(
                    a.overflow_events, b.overflow_events,
                    "kind={kind:?} request {}: overflow attribution diverges with sharing",
                    a.id
                );
                assert_eq!(b.prefill_tokens_skipped, 0, "sharing off must skip nothing");
            }
            assert_eq!(on[0].prefill_tokens_skipped, 0, "leader admission is cold");
            assert_eq!(on[1].prefill_tokens_skipped, 8);
            assert_eq!(on[2].prefill_tokens_skipped, 8);
            // and the sequential reference agrees
            for r in on {
                let want = m.generate_greedy_with(&sys, 4, kind);
                assert_eq!(r.tokens, want[sys.len()..], "kind={kind:?}");
            }
        }
    }

    #[test]
    fn zero_token_request_completes_empty() {
        let m = model();
        let q = ServeQueue::new();
        q.submit(Request { id: 0, prompt: vec![1, 2], max_new_tokens: 0, ..Request::default() })
            .unwrap();
        q.submit(Request { id: 1, prompt: vec![1, 2], max_new_tokens: 4, ..Request::default() })
            .unwrap();
        q.close();
        serve(&m, &q, 1, 2);
        let r = q.drain();
        assert_eq!(r[0].tokens.len(), 0);
        assert_eq!(r[0].status, Status::Ok);
        assert_eq!(r[1].tokens, direct(&m, &[1, 2], 4));
    }

    #[test]
    fn long_prompt_is_window_clipped() {
        let m = model();
        let q = ServeQueue::new();
        let long: Vec<u16> = (0..40).map(|i| i % 32).collect();
        q.submit(Request { id: 0, prompt: long.clone(), max_new_tokens: 4, ..Request::default() })
            .unwrap();
        q.close();
        serve(&m, &q, 1, 1);
        let r = q.drain();
        assert_eq!(r[0].tokens.len(), 4);
        assert_eq!(r[0].tokens, direct(&m, &long, 4));
    }

    #[test]
    fn generation_past_window_slides() {
        let m = model();
        for chunk in [3usize, usize::MAX] {
            let q = ServeQueue::new();
            q.submit(Request {
                id: 0,
                prompt: vec![1, 2],
                max_new_tokens: 30,
                ..Request::default()
            })
            .unwrap();
            q.close();
            serve_config(
                &m,
                &q,
                1,
                ServeConfig::new(1, KvCacheKind::F32).with_prefill_chunk(chunk),
            );
            let r = q.drain();
            assert_eq!(r[0].tokens.len(), 30, "generation must continue past max_seq");
            assert_eq!(r[0].tokens, direct(&m, &[1, 2], 30), "chunk {chunk}");
        }
    }

    /// Satellite fix: submitting after close is a typed error, not a
    /// panic and not a silent enqueue — the request is not counted and
    /// yields no response.
    #[test]
    fn submit_after_close_returns_typed_error() {
        let q = ServeQueue::new();
        q.submit(Request { id: 0, prompt: vec![1], max_new_tokens: 1, ..Request::default() })
            .unwrap();
        q.close();
        let err = q
            .submit(Request { id: 1, prompt: vec![1], max_new_tokens: 1, ..Request::default() })
            .unwrap_err();
        assert_eq!(err, SubmitError::Closed);
        assert_eq!(q.submitted_count(), 1, "closed submits are not counted");
        assert_eq!(q.depth(), 1, "closed submits are not enqueued");
    }

    /// Bounded admission, reject-newest: overflowing submits shed
    /// deterministically, every submission still resolves to exactly
    /// one typed response, and the conservation invariant holds.
    #[test]
    fn bounded_queue_sheds_newest_and_conserves() {
        let m = model();
        let q = ServeQueue::bounded(2, ShedPolicy::RejectNewest);
        let results: Vec<bool> = (0..5u64)
            .map(|id| {
                q.submit(Request {
                    id,
                    prompt: vec![1, 2],
                    max_new_tokens: 2,
                    ..Request::default()
                })
                .is_ok()
            })
            .collect();
        // no engine is draining yet: 2 fit, the 3 newest shed
        assert_eq!(results, [true, true, false, false, false]);
        assert_eq!(q.shed_count(), 3);
        assert_eq!(q.depth_hwm(), 2, "bounded depth never exceeds the cap");
        q.close();
        serve(&m, &q, 1, 2);
        let responses = q.drain();
        assert_eq!(responses.len(), 5, "every accepted submit yields a terminal response");
        let stats = ServeStats::from_responses(&responses, 1.0);
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.shed, 3);
        assert!(stats.conserved(q.submitted_count()));
        for r in &responses {
            match r.status {
                Status::Ok => assert_eq!(r.tokens, direct(&m, &[1, 2], 2)),
                Status::Shed => assert!(r.tokens.is_empty()),
                s => panic!("unexpected status {s:?}"),
            }
        }
    }

    /// Bounded admission, reject-largest-prompt: the pending giant is
    /// evicted for a smaller incoming request; an incoming giant sheds
    /// itself.
    #[test]
    fn reject_largest_prompt_evicts_the_pending_giant() {
        let q = ServeQueue::bounded(2, ShedPolicy::RejectLargestPrompt);
        q.submit(Request { id: 0, prompt: vec![0; 10], max_new_tokens: 1, ..Request::default() })
            .unwrap();
        q.submit(Request { id: 1, prompt: vec![0; 2], max_new_tokens: 1, ..Request::default() })
            .unwrap();
        // incoming len 3 < largest pending (id 0, len 10) → evict it
        assert!(q
            .submit(Request { id: 2, prompt: vec![0; 3], max_new_tokens: 1, ..Request::default() })
            .is_ok());
        // incoming len 50 is itself the largest → shed incoming
        assert_eq!(
            q.submit(Request {
                id: 3,
                prompt: vec![0; 50],
                max_new_tokens: 1,
                ..Request::default()
            }),
            Err(SubmitError::QueueFull)
        );
        assert_eq!(q.shed_count(), 2);
        assert_eq!(q.depth(), 2);
        q.close();
        let m = model();
        serve(&m, &q, 1, 2);
        let responses = q.drain();
        assert_eq!(responses.len(), 4);
        let statuses: Vec<Status> = responses.iter().map(|r| r.status).collect();
        assert_eq!(
            statuses,
            [Status::Shed, Status::Ok, Status::Ok, Status::Shed],
            "shed decisions are deterministic: the pending giant and the incoming giant"
        );
        assert!(ServeStats::from_responses(&responses, 1.0).conserved(q.submitted_count()));
    }

    /// Round-robin chunk grants: with a 1-token budget, a giant prompt
    /// and a small prompt admitted together alternate grants, so the
    /// small one reaches decoding in bounded steps instead of starving
    /// behind the giant — and both stay token-exact.
    #[test]
    fn round_robin_prefill_prevents_giant_prompt_starvation() {
        let m = model();
        let cfg = ServeConfig::new(2, KvCacheKind::F32).with_prefill_chunk(1);
        let mut eng = StepEngine::new(&m, cfg);
        let big: Vec<u16> = (0..15).map(|i| (i % 32) as u16).collect();
        let small: Vec<u16> = vec![3, 4, 5];
        eng.admit(
            Request { id: 0, prompt: big.clone(), max_new_tokens: 2, ..Request::default() },
            Instant::now(),
        );
        eng.admit(
            Request { id: 1, prompt: small.clone(), max_new_tokens: 2, ..Request::default() },
            Instant::now(),
        );
        let mut steps = 0;
        while eng
            .active
            .iter()
            .any(|s| s.id == 1 && matches!(s.phase, Phase::Prefilling { .. }))
        {
            eng.step();
            steps += 1;
            assert!(steps <= 6, "round-robin grants must reach the small prompt");
        }
        assert!(
            eng.active
                .iter()
                .any(|s| s.id == 0 && matches!(s.phase, Phase::Prefilling { .. })),
            "the giant prompt must still be mid-prefill — it did not monopolize the budget"
        );
        while eng.has_work() {
            eng.step();
        }
        let mut done = eng.take_finished();
        done.sort_by_key(|r| r.id);
        assert_eq!(done[0].tokens, direct(&m, &big, 2));
        assert_eq!(done[1].tokens, direct(&m, &small, 2));
    }

    /// Cancellation mid-decode: the reaper resolves the sequence with a
    /// partial, prefix-exact token stream and frees its slot.
    #[test]
    fn cancel_mid_decode_returns_partial_prefix_exact_tokens() {
        let m = model();
        let cfg = ServeConfig::new(1, KvCacheKind::F32).with_prefill_chunk(usize::MAX);
        let mut eng = StepEngine::new(&m, cfg);
        let tok = CancelToken::new();
        eng.admit(
            Request {
                id: 0,
                prompt: vec![1, 2],
                max_new_tokens: 10,
                cancel: Some(tok.clone()),
                ..Request::default()
            },
            Instant::now(),
        );
        eng.step(); // whole-prompt prefill
        eng.step(); // first decode sample
        eng.step(); // second decode sample
        tok.cancel();
        eng.step(); // reaper fires before any further sampling
        let done = eng.take_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].status, Status::Cancelled);
        assert_eq!(done[0].tokens.len(), 2, "two samples before the cancel");
        let want = direct(&m, &[1, 2], 10);
        assert_eq!(done[0].tokens[..], want[..2], "partial stream is prefix-exact");
        assert_eq!(eng.free_slots(), 1, "slot released on cancellation");
        assert!(!eng.has_work());
    }

    /// A request whose deadline already expired is refused at admission
    /// without spending an arena slot.
    #[test]
    fn expired_deadline_is_refused_at_admission() {
        let m = model();
        let mut eng = StepEngine::new(&m, ServeConfig::new(2, KvCacheKind::F32));
        eng.admit(
            Request {
                id: 0,
                prompt: vec![1, 2],
                max_new_tokens: 4,
                deadline: Some(Instant::now()),
                ..Request::default()
            },
            Instant::now(),
        );
        let done = eng.take_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].status, Status::DeadlineMiss);
        assert!(done[0].tokens.is_empty());
        assert_eq!(eng.free_slots(), 2, "no slot spent on dead-on-arrival work");
        assert_eq!(eng.arena().resident_pages(), 0, "no pages touched");
    }

    /// The merged telemetry histograms must tell the same story as the
    /// sorted-response percentiles: both use the same rank formula, so
    /// the histogram's TTFT quantile (a bucket upper bound) lands in
    /// the same log2 bucket as the sorted sample — the acceptance bar
    /// is agreement within one bucket.
    #[test]
    fn telemetry_histograms_agree_with_sorted_percentiles() {
        use crate::coordinator::telemetry::LatHist;
        let m = model();
        let q = ServeQueue::new();
        for id in 0..16u64 {
            let off = id as usize;
            q.submit(Request {
                id,
                prompt: (0..1 + (off % 7)).map(|i| ((i * 5 + off) % 32) as u16).collect(),
                max_new_tokens: 2 + (off % 9),
                ..Request::default()
            })
            .unwrap();
        }
        q.close();
        let t0 = Instant::now();
        let engines = serve_config(&m, &q, 2, ServeConfig::new(3, KvCacheKind::F32));
        let responses = q.drain();
        let mut stats = ServeStats::from_responses(&responses, t0.elapsed().as_secs_f64());
        stats.fill_telemetry(&engines);
        let t = stats.telemetry.expect("telemetry is on by default");
        assert!(t.steps > 0);
        assert_eq!(t.records_dropped, 0, "default ring holds a full quick run");
        assert_eq!(t.ttft_ns.count(), 16, "one TTFT observation per generating request");
        assert!(t.tpot_ns.count() > 0);
        assert_eq!(t.step_ns.count(), t.steps);
        assert_eq!(t.occupancy.count(), t.steps);
        // decode rows = total tokens − one per request (the first token
        // is sampled from prefill logits, the last needs no decode row)
        assert_eq!(t.tpot_ns.count(), (stats.total_tokens - stats.requests) as u64);
        // no overload events in this run — the v2 counters stay zero
        assert_eq!((t.shed, t.deadline_miss, t.cancelled), (0, 0, 0));
        for (q_, sorted_s) in [(0.50, stats.p50_ttft_s), (0.99, stats.p99_ttft_s)] {
            let hist_bucket = LatHist::bucket_of(t.ttft_ns.quantile(q_));
            let sorted_bucket = LatHist::bucket_of((sorted_s * 1e9) as u64);
            assert!(
                (hist_bucket as i64 - sorted_bucket as i64).abs() <= 1,
                "ttft q{q_}: histogram bucket {hist_bucket} vs sorted bucket {sorted_bucket}"
            );
        }
        // and telemetry can be switched off entirely
        let q2 = ServeQueue::new();
        q2.submit(Request { id: 0, prompt: vec![1, 2], max_new_tokens: 3, ..Request::default() })
            .unwrap();
        q2.close();
        let engines =
            serve_config(&m, &q2, 1, ServeConfig::new(1, KvCacheKind::F32).with_telemetry(false));
        q2.drain();
        assert!(engines[0].telemetry.is_none());
    }

    #[test]
    fn stats_percentiles() {
        let resp: Vec<Response> = (0..100)
            .map(|i| Response {
                id: i,
                tokens: vec![0; 2],
                queued_s: 0.0,
                gen_s: (i + 1) as f64 / 100.0,
                ttft_s: (i + 1) as f64 / 200.0,
                overflow_events: i % 5,
                // first half shared (and faster), second half cold
                prefill_tokens_skipped: if i < 50 { 8 } else { 0 },
                status: Status::Ok,
            })
            .collect();
        let s = ServeStats::from_responses(&resp, 1.0);
        assert!((s.p50_latency_s - 0.5).abs() < 0.02);
        assert!((s.p99_latency_s - 0.99).abs() < 0.02);
        assert!((s.p50_ttft_s - 0.25).abs() < 0.02);
        assert!((s.p99_ttft_s - 0.495).abs() < 0.02);
        assert_eq!(s.total_tokens, 200);
        assert_eq!(s.completed, 100);
        assert!(s.conserved(100));
        assert!(!s.conserved(101), "a lost submission must break conservation");
        // per-request counts are disjoint, so the total is their sum
        assert_eq!(s.overflow_events, (0..100u64).map(|i| i % 5).sum::<u64>());
        assert_eq!(s.arena_bytes, 0, "arena bytes are caller-filled");
        assert_eq!(s.prefix_hits, 50);
        assert!((s.prefix_hit_rate - 0.5).abs() < 1e-12);
        assert_eq!(s.prefill_tokens_skipped, 400);
        // shared admissions are ids 0..50 → ttfts 1/200 ..= 50/200
        assert!((s.p50_ttft_shared_s - 0.125).abs() < 0.01);
        assert!((s.p50_ttft_cold_s - 0.375).abs() < 0.01);
        assert_eq!(s.pages_shared, 0, "pages shared are caller-filled");

        // non-Ok responses: excluded from latency percentiles, counted
        // in the status partition
        let mut with_shed = resp;
        with_shed.push(Response {
            id: 100,
            tokens: Vec::new(),
            queued_s: 9.0,
            gen_s: 0.0,
            ttft_s: 9.0,
            overflow_events: 0,
            prefill_tokens_skipped: 0,
            status: Status::Shed,
        });
        let s2 = ServeStats::from_responses(&with_shed, 1.0);
        assert_eq!(s2.shed, 1);
        assert_eq!(s2.completed, 100);
        assert!(s2.conserved(101));
        assert!((s2.p99_latency_s - 0.99).abs() < 0.02, "shed wait must not poison latency");
    }

    /// THE speculative exactness property at the engine level: with a
    /// narrowed draft proposing k tokens per sequence and a full-width
    /// verify step accepting the longest matching prefix, every
    /// request's token stream AND per-request overflow attribution are
    /// bit-identical to the non-speculative engine — across draft
    /// depths, both KV backends (overflow live on the quant one),
    /// chunked admission, window slides and clipped prompts — while
    /// the run actually accepts draft tokens.
    #[test]
    fn speculative_serving_is_bit_exact_and_accepts() {
        use crate::model::KvQuantSpec;
        let m = model();
        // mixed lengths: clipped prompts (> max_seq 16), window-sliding
        // generations (30 > 16), and short stragglers that retire early
        let reqs: Vec<Request> = (0..6u64)
            .map(|id| {
                let off = id as usize;
                let plen = 1 + ((off * 7) % 20);
                Request {
                    id,
                    prompt: (0..plen).map(|i| ((i * 5 + off) % 32) as u16).collect(),
                    max_new_tokens: 2 + ((off * 13) % 29),
                    ..Request::default()
                }
            })
            .collect();
        for kind in [
            KvCacheKind::F32,
            KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6))), // overflow live
        ] {
            for k in [2usize, 4, 8] {
                let mut runs: Vec<(Vec<Response>, MetricsSummary)> = Vec::new();
                for spec_on in [true, false] {
                    let q = ServeQueue::new();
                    for r in &reqs {
                        q.submit(r.clone()).unwrap();
                    }
                    q.close();
                    let cfg = ServeConfig::new(3, kind).with_prefill_chunk(4).with_speculate(
                        if spec_on { k } else { 1 },
                        Some(4), // narrowed draft: wrong proposals allowed, never wrong output
                    );
                    let engines = serve_config(&m, &q, 1, cfg);
                    let mut done = q.drain();
                    done.sort_by_key(|r| r.id);
                    runs.push((done, engines[0].telemetry.expect("telemetry on")));
                }
                let ((spec, st), (plain, pt)) = (&runs[0], &runs[1]);
                for ((a, b), req) in spec.iter().zip(plain.iter()).zip(reqs.iter()) {
                    assert_eq!(
                        a.tokens, b.tokens,
                        "kind={kind:?} k={k} request {}: speculative tokens diverge",
                        req.id
                    );
                    assert_eq!(
                        a.overflow_events, b.overflow_events,
                        "kind={kind:?} k={k} request {}: overflow attribution diverges",
                        req.id
                    );
                    let clipped = m.clip_to_window(&req.prompt);
                    let want = m.generate_greedy_with(&clipped, req.max_new_tokens, kind);
                    assert_eq!(a.tokens, want[clipped.len()..], "kind={kind:?} k={k}");
                }
                // the speculation must be real: proposals made, never
                // more accepted than proposed, one narrow draft row per
                // proposal. On this float-weight model with f32 KV the
                // narrow knob has nothing to bite (no integer register
                // anywhere), so the draft is bit-identical to the
                // verify pass and EVERY proposal must be accepted — the
                // structural ceiling of self-speculation. The quant-KV
                // backend narrows the attention accumulators, so there
                // acceptance may genuinely drop below 100%.
                assert!(st.spec_proposed > 0, "kind={kind:?} k={k}: no draft tokens proposed");
                assert!(st.spec_accepted <= st.spec_proposed, "kind={kind:?} k={k}");
                assert_eq!(
                    st.draft_rows, st.spec_proposed,
                    "kind={kind:?} k={k}: one draft row per proposal"
                );
                if matches!(kind, KvCacheKind::F32) {
                    assert_eq!(
                        st.spec_accepted, st.spec_proposed,
                        "kind={kind:?} k={k}: an exact draft must be fully accepted"
                    );
                }
                assert_eq!(pt.spec_proposed, 0, "k=1 must not speculate");
                assert_eq!((pt.spec_accepted, pt.draft_rows, pt.overflow_draft), (0, 0, 0));
                // verify rows inflate decode_rows (work-done), but the
                // emitted token count matches the plain run exactly
                let spec_tokens: usize = spec.iter().map(|r| r.tokens.len()).sum();
                let plain_tokens: usize = plain.iter().map(|r| r.tokens.len()).sum();
                assert_eq!(spec_tokens, plain_tokens);
                assert!(
                    st.tokens >= pt.tokens,
                    "verify rows are counted work: {} < {}",
                    st.tokens,
                    pt.tokens
                );
            }
        }
    }

    /// Speculation's acceptance rule is the argmax — constructing an
    /// engine that speculates under a sampling spec must fail loudly
    /// instead of silently emitting non-reproducible streams.
    #[test]
    #[should_panic(expected = "greedy")]
    fn speculative_requires_greedy_sampling() {
        let m = model();
        let cfg = ServeConfig::new(2, KvCacheKind::F32)
            .with_speculate(4, None)
            .with_sampling(SampleSpec::temperature(0.9, 7));
        let _ = StepEngine::new(&m, cfg);
    }

    /// Sampled serving parity: with a seeded SampleSpec, the batched
    /// engine reproduces sequential sampled decode token for token —
    /// the draw is keyed per (request, position), so batch composition,
    /// chunked admission and mid-flight joins cannot perturb it.
    #[test]
    fn sampled_serving_matches_sequential_sampled() {
        let m = model();
        let spec = SampleSpec::temperature(0.8, 1234).with_top_k(12).with_top_p(0.95);
        let reqs: Vec<Request> = (0..6u64)
            .map(|id| {
                let off = id as usize;
                let plen = 1 + ((off * 5) % 14);
                Request {
                    id,
                    prompt: (0..plen).map(|i| ((i * 7 + off) % 32) as u16).collect(),
                    max_new_tokens: 3 + ((off * 11) % 20),
                    ..Request::default()
                }
            })
            .collect();
        for chunk in [2usize, usize::MAX] {
            let q = ServeQueue::new();
            for r in &reqs {
                q.submit(r.clone()).unwrap();
            }
            q.close();
            serve_config(
                &m,
                &q,
                1,
                ServeConfig::new(3, KvCacheKind::F32)
                    .with_prefill_chunk(chunk)
                    .with_sampling(spec),
            );
            let responses = q.drain();
            assert_eq!(responses.len(), reqs.len());
            for (resp, req) in responses.iter().zip(reqs.iter()) {
                let clipped = m.clip_to_window(&req.prompt);
                let want = m.generate_sampled_with(
                    &clipped,
                    req.max_new_tokens,
                    KvCacheKind::F32,
                    &spec,
                    req.id,
                );
                assert_eq!(
                    resp.tokens,
                    want[clipped.len()..],
                    "request {} diverged from sequential sampled decode at chunk {chunk}",
                    req.id
                );
            }
        }
    }
}
