//! Per-layer and per-run reporting structures (JSON-serializable via
//! `util::json`), plus the serve report's telemetry block.

use crate::coordinator::telemetry::MetricsSummary;
use crate::util::json::Json;

/// Outcome of quantizing one layer.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub k: usize,
    pub c: usize,
    /// Fraction of zero codes.
    pub sparsity: f64,
    /// Worst-case accumulator utilization from the audit (≤ 1.0 means
    /// guaranteed safe).
    pub worst_utilization: f64,
    /// Audit violations (must be 0 for constrained methods).
    pub audit_violations: usize,
    /// Wall-clock seconds spent quantizing this layer.
    pub seconds: f64,
}

impl LayerReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str().into())
            .set("k", self.k.into())
            .set("c", self.c.into())
            .set("sparsity", self.sparsity.into())
            .set("worst_utilization", self.worst_utilization.into())
            .set("audit_violations", self.audit_violations.into())
            .set("seconds", self.seconds.into());
        j
    }
}

/// Render the serve report's telemetry block from the cross-engine
/// merged summary: step-latency / TTFT / TPOT percentiles out of the
/// log2 histograms (each quantile is the bucket upper bound, exact to
/// within one bucket of the sorted-sample answer), occupancy, and the
/// per-step overflow split. Percentile lines print p50/p90/p99/max.
pub fn render_telemetry_report(t: &MetricsSummary) -> String {
    let ms = |ns: u64| ns as f64 / 1e6;
    let lat = |h: &crate::coordinator::telemetry::LatHist| {
        format!(
            "p50 {:.2} / p90 {:.2} / p99 {:.2} / max {:.2} ms",
            ms(h.quantile(0.50)),
            ms(h.quantile(0.90)),
            ms(h.quantile(0.99)),
            ms(h.max_value())
        )
    };
    let mut out = String::new();
    out.push_str(&format!(
        "telemetry     : {} steps recorded, {} dropped from the ring ({} rows executed)\n",
        t.steps, t.records_dropped, t.tokens
    ));
    out.push_str(&format!("  step latency: {}\n", lat(&t.step_ns)));
    out.push_str(&format!(
        "  ttft        : {} ({} requests)\n",
        lat(&t.ttft_ns),
        t.ttft_ns.count()
    ));
    out.push_str(&format!(
        "  tpot        : {} ({} decode rows)\n",
        lat(&t.tpot_ns),
        t.tpot_ns.count()
    ));
    out.push_str(&format!(
        "  occupancy   : p50 {} / p99 {} / max {} rows per step\n",
        t.occupancy.quantile(0.50),
        t.occupancy.quantile(0.99),
        t.occupancy.max_value()
    ));
    out.push_str(&format!(
        "  overflow    : {} linear + {} attention events ({:.4} per row)\n",
        t.overflow_linear,
        t.overflow_attn,
        (t.overflow_linear + t.overflow_attn) as f64 / t.tokens.max(1) as f64
    ));
    out.push_str(&format!(
        "  admission   : {} shed / {} deadline-missed / {} cancelled (queue hwm {})",
        t.shed, t.deadline_miss, t.cancelled, t.queue_hwm
    ));
    // only speculative runs propose draft tokens; the line is noise
    // otherwise
    if t.spec_proposed > 0 {
        out.push_str(&format!(
            "\n  speculative : {} / {} draft tokens accepted ({:.0}% accept rate, \
             {} draft rows, {} draft overflow events)",
            t.spec_accepted,
            t.spec_proposed,
            100.0 * t.spec_accepted as f64 / t.spec_proposed as f64,
            t.draft_rows,
            t.overflow_draft
        ));
    }
    out
}

/// Aggregate sparsity across layers (weighted by element count).
pub fn total_sparsity(layers: &[LayerReport]) -> f64 {
    let mut zeros = 0.0;
    let mut total = 0.0;
    for l in layers {
        let n = (l.k * l.c) as f64;
        zeros += l.sparsity * n;
        total += n;
    }
    if total > 0.0 {
        zeros / total
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let l = LayerReport {
            name: "b0.wq".into(),
            k: 64,
            c: 64,
            sparsity: 0.25,
            worst_utilization: 0.9,
            audit_violations: 0,
            seconds: 0.1,
        };
        let j = l.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("b0.wq"));
        assert_eq!(j.get("k").unwrap().as_usize(), Some(64));
    }

    #[test]
    fn telemetry_report_renders() {
        use crate::coordinator::telemetry::{StepMetrics, StepRecord};
        let mut m = StepMetrics::new(8);
        for i in 0..5u64 {
            m.record(StepRecord {
                step: i,
                wall_ns: 1_000_000,
                decode_rows: 3,
                prefill_rows: 1,
                prefill_chunks: 1,
                tokens: 4,
                overflow_linear: 2,
                shed: if i == 0 { 2 } else { 0 },
                deadline_miss: 1,
                queue_hwm: 7,
                ..StepRecord::default()
            });
            m.record_ttft(2_000_000);
        }
        let s = render_telemetry_report(&m.summary());
        assert!(s.contains("5 steps recorded"), "{s}");
        assert!(s.contains("step latency"), "{s}");
        assert!(s.contains("occupancy   : p50 4 / p99 4 / max 4 rows"), "{s}");
        assert!(s.contains("10 linear + 0 attention"), "{s}");
        assert!(s.contains("admission   : 2 shed / 5 deadline-missed / 0 cancelled"), "{s}");
        assert!(s.contains("queue hwm 7"), "{s}");
        // no speculative line unless the run proposed draft tokens
        assert!(!s.contains("speculative"), "{s}");
        m.record(StepRecord {
            step: 5,
            decode_rows: 3,
            tokens: 3,
            spec_proposed: 3,
            spec_accepted: 2,
            draft_rows: 3,
            overflow_draft: 4,
            ..StepRecord::default()
        });
        let s = render_telemetry_report(&m.summary());
        assert!(
            s.contains("speculative : 2 / 3 draft tokens accepted (67% accept rate"),
            "{s}"
        );
        assert!(s.contains("3 draft rows, 4 draft overflow events"), "{s}");
    }

    #[test]
    fn weighted_sparsity() {
        let mk = |n: usize, s: f64| LayerReport {
            name: "x".into(),
            k: n,
            c: 1,
            sparsity: s,
            worst_utilization: 0.0,
            audit_violations: 0,
            seconds: 0.0,
        };
        let layers = vec![mk(100, 0.0), mk(300, 1.0)];
        assert!((total_sparsity(&layers) - 0.75).abs() < 1e-12);
        assert_eq!(total_sparsity(&[]), 0.0);
    }
}
