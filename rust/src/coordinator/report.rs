//! Per-layer and per-run reporting structures (JSON-serializable via
//! `util::json`).

use crate::util::json::Json;

/// Outcome of quantizing one layer.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub k: usize,
    pub c: usize,
    /// Fraction of zero codes.
    pub sparsity: f64,
    /// Worst-case accumulator utilization from the audit (≤ 1.0 means
    /// guaranteed safe).
    pub worst_utilization: f64,
    /// Audit violations (must be 0 for constrained methods).
    pub audit_violations: usize,
    /// Wall-clock seconds spent quantizing this layer.
    pub seconds: f64,
}

impl LayerReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str().into())
            .set("k", self.k.into())
            .set("c", self.c.into())
            .set("sparsity", self.sparsity.into())
            .set("worst_utilization", self.worst_utilization.into())
            .set("audit_violations", self.audit_violations.into())
            .set("seconds", self.seconds.into());
        j
    }
}

/// Aggregate sparsity across layers (weighted by element count).
pub fn total_sparsity(layers: &[LayerReport]) -> f64 {
    let mut zeros = 0.0;
    let mut total = 0.0;
    for l in layers {
        let n = (l.k * l.c) as f64;
        zeros += l.sparsity * n;
        total += n;
    }
    if total > 0.0 {
        zeros / total
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let l = LayerReport {
            name: "b0.wq".into(),
            k: 64,
            c: 64,
            sparsity: 0.25,
            worst_utilization: 0.9,
            audit_violations: 0,
            seconds: 0.1,
        };
        let j = l.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("b0.wq"));
        assert_eq!(j.get("k").unwrap().as_usize(), Some(64));
    }

    #[test]
    fn weighted_sparsity() {
        let mk = |n: usize, s: f64| LayerReport {
            name: "x".into(),
            k: n,
            c: 1,
            sparsity: s,
            worst_utilization: 0.0,
            audit_violations: 0,
            seconds: 0.0,
        };
        let layers = vec![mk(100, 0.0), mk(300, 1.0)];
        assert!((total_sparsity(&layers) - 0.75).abs() < 1e-12);
        assert_eq!(total_sparsity(&[]), 0.0);
    }
}
