//! Experiment harness: the parameter sweeps that regenerate the paper's
//! tables and figures, shared by the CLI, the benches and the examples.

use super::pipeline::{quantize_mlp, quantize_transformer, PipelineConfig};
use crate::eval::{perplexity, top1_accuracy, GlyphSet};
use crate::model::{Mlp, Transformer};
use crate::quant::{Algorithm, Method};
use crate::util::Table;
use anyhow::Result;

/// One point of a Pareto sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub p_bits: u32,
    pub m_bits: u32,
    pub n_bits: u32,
    /// Perplexity (LM) or top-1 accuracy (image).
    pub metric: f64,
    pub sparsity: f64,
    pub safe: bool,
    pub seconds: f64,
}

/// For LM metrics lower is better; for accuracy higher is better.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Perplexity,
    Accuracy,
}

impl MetricKind {
    pub fn better(&self, a: f64, b: f64) -> bool {
        match self {
            MetricKind::Perplexity => a < b,
            MetricKind::Accuracy => a > b,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MetricKind::Perplexity => "PPL",
            MetricKind::Accuracy => "Top-1",
        }
    }
}

/// Quantize a fresh copy of the LM and evaluate perplexity.
pub fn run_lm_config(
    base: &Transformer,
    calib: &[&[u16]],
    eval_tokens: &[u16],
    seq: usize,
    eval_seqs: usize,
    cfg: &PipelineConfig,
) -> Result<SweepPoint> {
    let mut model = base.clone();
    let report = quantize_transformer(&mut model, calib, cfg)?;
    let ppl = perplexity(&model, eval_tokens, seq, eval_seqs);
    Ok(SweepPoint {
        p_bits: effective_p(cfg, base),
        m_bits: cfg.weight_bits,
        n_bits: cfg.act_bits,
        metric: ppl.ppl,
        sparsity: report.sparsity(),
        safe: report.guaranteed_safe(),
        seconds: report.total_seconds,
    })
}

/// Quantize a fresh copy of the classifier and evaluate accuracy.
pub fn run_img_config(
    base: &Mlp,
    calib: &[&[f32]],
    test: &GlyphSet,
    cfg: &PipelineConfig,
) -> Result<SweepPoint> {
    let mut model = base.clone();
    let report = quantize_mlp(&mut model, calib, cfg)?;
    let acc = top1_accuracy(&model, test);
    Ok(SweepPoint {
        p_bits: effective_p_mlp(cfg, base),
        m_bits: cfg.weight_bits,
        n_bits: cfg.act_bits,
        metric: acc,
        sparsity: report.sparsity(),
        safe: report.guaranteed_safe(),
        seconds: report.total_seconds,
    })
}

/// The deployment accumulator width for reporting: the constrained
/// target, or max-over-layers Eq. 3 for the naive baseline.
fn effective_p(cfg: &PipelineConfig, model: &Transformer) -> u32 {
    let k_max = model
        .linear_names()
        .iter()
        .filter_map(|n| model.get_linear(n))
        .map(|l| l.in_dim())
        .max()
        .unwrap_or(1);
    target_p(cfg, k_max)
}

fn effective_p_mlp(cfg: &PipelineConfig, model: &Mlp) -> u32 {
    let k_max = model.layers.iter().map(|l| l.in_dim()).max().unwrap_or(1);
    target_p(cfg, k_max)
}

fn target_p(cfg: &PipelineConfig, k_max: usize) -> u32 {
    use crate::quant::AccumTarget;
    match cfg.effective_target(k_max) {
        AccumTarget::Monolithic { p_bits } => p_bits,
        AccumTarget::MultiStage { p_inner, .. } => p_inner,
        AccumTarget::None => 32,
    }
}

/// The (M, N) design space of the paper's §4: 3..8 bits with N ≥ M.
pub fn design_space(min_bits: u32, max_bits: u32) -> Vec<(u32, u32)> {
    let mut v = Vec::new();
    for m in min_bits..=max_bits {
        for n in m..=max_bits {
            v.push((m, n));
        }
    }
    v
}

/// Pareto frontier: best metric observed per accumulator width P (with
/// cumulative dominance so the frontier is monotone in P).
pub fn pareto_frontier(points: &[SweepPoint], kind: MetricKind) -> Vec<SweepPoint> {
    use std::collections::BTreeMap;
    let mut best_at: BTreeMap<u32, SweepPoint> = BTreeMap::new();
    for p in points {
        if !p.safe {
            continue;
        }
        match best_at.get(&p.p_bits) {
            Some(cur) if !kind.better(p.metric, cur.metric) => {}
            _ => {
                best_at.insert(p.p_bits, p.clone());
            }
        }
    }
    // enforce monotonicity: a larger P can always adopt a smaller P's model
    let mut out: Vec<SweepPoint> = Vec::new();
    let mut best: Option<SweepPoint> = None;
    for (_, p) in best_at {
        let adopt = match &best {
            None => true,
            Some(b) => kind.better(p.metric, b.metric),
        };
        if adopt {
            best = Some(p.clone());
        }
        let mut row = best.clone().unwrap();
        row.p_bits = p.p_bits;
        out.push(row);
    }
    out
}

/// Render sweep points as a paper-style table.
pub fn render_frontier(title: &str, kind: MetricKind, frontier: &[SweepPoint]) -> String {
    let mut t = Table::new(&["P", kind.name(), "(M,N)", "Sparsity%"]);
    for p in frontier {
        t.row(&[
            format!("{}", p.p_bits),
            format!("{:.1}", p.metric),
            format!("({},{})", p.m_bits, p.n_bits),
            format!("{:.1}", p.sparsity * 100.0),
        ]);
    }
    format!("## {title}\n{}", t.render())
}

/// Standard method triplet used by the Pareto experiments.
pub fn methods() -> [(Method, &'static str); 3] {
    [(Method::Naive, "naive"), (Method::EpInit, "EP-init"), (Method::Axe, "AXE")]
}

/// Standard algorithm pair.
pub fn algorithms() -> [Algorithm; 2] {
    [Algorithm::Gpfq, Algorithm::Optq]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(p: u32, metric: f64, safe: bool) -> SweepPoint {
        SweepPoint { p_bits: p, m_bits: 4, n_bits: 8, metric, sparsity: 0.1, safe, seconds: 0.0 }
    }

    #[test]
    fn design_space_respects_n_ge_m() {
        let ds = design_space(3, 8);
        assert_eq!(ds.len(), 21);
        assert!(ds.iter().all(|&(m, n)| n >= m));
        assert!(ds.contains(&(3, 8)));
        assert!(!ds.contains(&(8, 3)));
    }

    #[test]
    fn frontier_takes_best_per_p_and_is_monotone() {
        let points = vec![
            pt(16, 100.0, true),
            pt(16, 80.0, true),
            pt(18, 90.0, true), // worse than the P=16 model → adopts it
            pt(20, 40.0, true),
            pt(14, 500.0, false), // unsafe: excluded
        ];
        let f = pareto_frontier(&points, MetricKind::Perplexity);
        assert_eq!(f.len(), 3);
        assert_eq!(f[0].p_bits, 16);
        assert!((f[0].metric - 80.0).abs() < 1e-9);
        assert!((f[1].metric - 80.0).abs() < 1e-9, "P=18 adopts P=16 model");
        assert!((f[2].metric - 40.0).abs() < 1e-9);
    }

    #[test]
    fn frontier_accuracy_direction() {
        let points = vec![pt(16, 50.0, true), pt(18, 70.0, true), pt(20, 60.0, true)];
        let f = pareto_frontier(&points, MetricKind::Accuracy);
        assert!((f[2].metric - 70.0).abs() < 1e-9, "P=20 adopts the P=18 model");
    }

    #[test]
    fn render_contains_rows() {
        let f = vec![pt(16, 42.0, true)];
        let s = render_frontier("test", MetricKind::Perplexity, &f);
        assert!(s.contains("42.0"));
        assert!(s.contains("(4,8)"));
    }
}
