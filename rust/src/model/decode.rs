//! Incremental decoding over a multi-sequence KV arena.
//!
//! `forward()` recomputes the whole prefix per step — fine for PPL
//! evaluation, quadratic-per-token for serving. The KV structures here
//! store each block's projected keys/values so one decode step costs
//! O(seq · d) attention instead of O(seq² · d) recompute.
//!
//! The serving engine decodes **many sequences per kernel call**:
//! [`KvArena`] holds a fixed number of slots (one in-flight sequence
//! each, with independent lengths), and
//! [`Transformer::decode_step_batch`] stacks the current token of every
//! scheduled slot into one [`super::Linear::forward_rows`] call per
//! linear — quantized layers amortize the fused qgemm kernel across the
//! whole in-flight batch. Attention stays ragged: each slot attends
//! over its own cached positions only.
//!
//! The single-sequence [`KvCache`] is a thin 1-slot arena view, and
//! `decode_step`/`prefill` delegate to the batched path, so sequential
//! decode (`generate_greedy`) and continuous-batched serving run the
//! **same arithmetic per row** — batched decode is token-exact versus
//! sequential decode (tested here and in `coordinator::serve`). This
//! relies on every row of a batched kernel being computed independently
//! of its batchmates (true of `linalg::qgemm` and `linalg::Mat`'s
//! banded GEMM).

use super::layers::attend_one_query;
use super::transformer::Transformer;

/// Multi-sequence key/value arena: `slots` independent sequences, each
/// owning a fixed `[max_seq × d]` region per layer. Slots are
/// allocated at admission, reused after retirement, and slide their
/// window independently (via [`KvArena::reset_slot`] + re-prefill, the
/// absolute-position re-encode the single-sequence path uses).
#[derive(Clone, Debug)]
pub struct KvArena {
    /// [layer][slot * max_seq * d + pos * d ..] cached keys.
    k: Vec<Vec<f32>>,
    /// [layer][slot * max_seq * d + pos * d ..] cached values.
    v: Vec<Vec<f32>>,
    d: usize,
    max_seq: usize,
    slots: usize,
    /// Per-slot cached length.
    lens: Vec<usize>,
    /// Per-slot liveness (allocated to a sequence).
    live: Vec<bool>,
    /// LIFO free list of slot ids.
    free: Vec<usize>,
}

impl KvArena {
    /// Arena with `slots` sequence slots, all free.
    pub fn new(model: &Transformer, slots: usize) -> KvArena {
        assert!(slots >= 1, "arena needs at least one slot");
        let d = model.cfg.d_model;
        let max_seq = model.cfg.max_seq;
        KvArena {
            k: vec![vec![0.0; slots * max_seq * d]; model.cfg.n_layers],
            v: vec![vec![0.0; slots * max_seq * d]; model.cfg.n_layers],
            d,
            max_seq,
            slots,
            lens: vec![0; slots],
            live: vec![false; slots],
            free: (0..slots).rev().collect(),
        }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Claim a free slot (length 0), or `None` when all are in flight.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.lens[slot] = 0;
        self.live[slot] = true;
        Some(slot)
    }

    /// Retire a sequence: its slot becomes reusable immediately.
    pub fn release(&mut self, slot: usize) {
        assert!(self.live[slot], "releasing a free slot");
        self.live[slot] = false;
        self.lens[slot] = 0;
        self.free.push(slot);
    }

    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.lens[slot] == 0
    }

    pub fn is_full(&self, slot: usize) -> bool {
        self.lens[slot] >= self.max_seq
    }

    /// Drop a slot's cached positions (window-slide: clear, then
    /// re-prefill the kept tail so absolute positions are re-encoded).
    pub fn reset_slot(&mut self, slot: usize) {
        assert!(self.live[slot], "resetting a free slot");
        self.lens[slot] = 0;
    }

    /// Drop the oldest `n` positions of one slot (sliding-window
    /// generation without re-encoding).
    /// NOTE: positional embeddings are absolute, so after sliding the
    /// model sees shifted positions; for the pico models with short
    /// windows this matches the serve example's windowed re-encode.
    pub fn truncate_front(&mut self, slot: usize, n: usize) {
        let n = n.min(self.lens[slot]);
        if n == 0 {
            return;
        }
        let d = self.d;
        let base = slot * self.max_seq * d;
        for slab in self.k.iter_mut().chain(self.v.iter_mut()) {
            slab.copy_within(base + n * d..base + self.lens[slot] * d, base);
        }
        self.lens[slot] -= n;
    }

    /// Append one position's K/V rows to a slot at `layer` (position =
    /// current length; the length advance happens once per step via
    /// [`KvArena::advance`]).
    #[inline]
    fn append_kv(&mut self, layer: usize, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(self.lens[slot] < self.max_seq);
        let at = slot * self.max_seq * self.d + self.lens[slot] * self.d;
        self.k[layer][at..at + self.d].copy_from_slice(k_row);
        self.v[layer][at..at + self.d].copy_from_slice(v_row);
    }

    #[inline]
    fn advance(&mut self, slot: usize, n: usize) {
        self.lens[slot] += n;
        debug_assert!(self.lens[slot] <= self.max_seq);
    }
}

/// Per-layer key/value cache for one sequence — a 1-slot [`KvArena`]
/// view, kept so single-sequence callers (eval, examples,
/// `generate_greedy`) read naturally.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub(crate) arena: KvArena,
}

impl KvCache {
    pub fn new(model: &Transformer) -> KvCache {
        let mut arena = KvArena::new(model, 1);
        arena.alloc().expect("fresh 1-slot arena");
        KvCache { arena }
    }

    pub fn len(&self) -> usize {
        self.arena.len(0)
    }

    pub fn is_empty(&self) -> bool {
        self.arena.is_empty(0)
    }

    pub fn is_full(&self) -> bool {
        self.arena.is_full(0)
    }

    pub fn clear(&mut self) {
        self.arena.reset_slot(0);
    }

    /// Drop the oldest `n` positions (sliding-window generation).
    pub fn truncate_front(&mut self, n: usize) {
        self.arena.truncate_front(0, n);
    }
}

impl Transformer {
    /// Decode one token given the cached prefix; returns the logits for
    /// this position and appends this position's K/V to the cache.
    ///
    /// Thin delegate to [`Transformer::decode_step_batch`] over the
    /// cache's single slot, so sequential and batched decode share one
    /// datapath.
    pub fn decode_step(&self, token: u16, cache: &mut KvCache) -> Vec<f32> {
        self.decode_step_batch(&[token], &[0], &mut cache.arena)
    }

    /// Decode one token for **each** scheduled sequence in one batched
    /// pass: `tokens[b]` is appended to arena slot `slots[b]`. Returns
    /// row-major `tokens.len() × vocab` logits.
    ///
    /// Every linear runs one [`super::Linear::forward_rows`] call over
    /// the whole batch (the fused qgemm kernel for quantized layers);
    /// attention is ragged — slot `b` attends over its own
    /// `len(slots[b]) + 1` cached positions at its own absolute
    /// position. Each output row is bit-identical to decoding that
    /// sequence alone.
    pub fn decode_step_batch(
        &self,
        tokens: &[u16],
        slots: &[usize],
        arena: &mut KvArena,
    ) -> Vec<f32> {
        assert_eq!(tokens.len(), slots.len(), "one slot per token");
        assert!(!tokens.is_empty(), "empty decode batch");
        assert_eq!(arena.d, self.cfg.d_model);
        let b = tokens.len();
        let d = self.cfg.d_model;
        for (i, &s) in slots.iter().enumerate() {
            assert!(arena.live[s], "slot {s} not allocated");
            assert!(!arena.is_full(s), "KV slot {s} full (max_seq {})", arena.max_seq);
            // hard assert: a doubled slot would append_kv twice at one
            // position and advance the length by 2, silently corrupting
            // the sequence (batch widths are small, the scan is cheap)
            assert!(!slots[..i].contains(&s), "slot {s} scheduled twice in one step");
        }

        // token + absolute positional embedding per row
        let mut h = vec![0.0f32; b * d];
        for (r, (&tok, &slot)) in tokens.iter().zip(slots.iter()).enumerate() {
            let e = &self.embed[(tok as usize) * d..(tok as usize + 1) * d];
            let pos = arena.len(slot);
            let p = &self.pos[pos * d..(pos + 1) * d];
            for i in 0..d {
                h[r * d + i] = e[i] + p[i];
            }
        }

        let mut ln_out = vec![0.0f32; b * d];
        let mut q = vec![0.0f32; b * d];
        let mut k_new = vec![0.0f32; b * d];
        let mut v_new = vec![0.0f32; b * d];
        let mut mix = vec![0.0f32; b * d];
        let mut attn_out = vec![0.0f32; b * d];
        let mut ff = vec![0.0f32; b * self.cfg.d_ff];
        let mut ff_out = vec![0.0f32; b * d];

        for (bi, blk) in self.blocks.iter().enumerate() {
            for r in 0..b {
                blk.ln1.forward_row(&h[r * d..(r + 1) * d], &mut ln_out[r * d..(r + 1) * d]);
            }
            blk.wq.forward_rows(&ln_out, b, &mut q);
            blk.wk.forward_rows(&ln_out, b, &mut k_new);
            blk.wv.forward_rows(&ln_out, b, &mut v_new);
            for (r, &slot) in slots.iter().enumerate() {
                arena.append_kv(bi, slot, &k_new[r * d..(r + 1) * d], &v_new[r * d..(r + 1) * d]);
            }
            // ragged single-query attention: each row over its own slot
            for (r, &slot) in slots.iter().enumerate() {
                let t_len = arena.len(slot) + 1;
                let base = slot * arena.max_seq * d;
                let kc = &arena.k[bi][base..base + t_len * d];
                let vc = &arena.v[bi][base..base + t_len * d];
                attend_one_query(
                    &q[r * d..(r + 1) * d],
                    kc,
                    vc,
                    t_len,
                    d,
                    self.cfg.n_heads,
                    &mut mix[r * d..(r + 1) * d],
                );
            }
            blk.wo.forward_rows(&mix, b, &mut attn_out);
            if !self.cfg.parallel_residual {
                for i in 0..b * d {
                    h[i] += attn_out[i];
                }
            }
            for r in 0..b {
                blk.ln2.forward_row(&h[r * d..(r + 1) * d], &mut ln_out[r * d..(r + 1) * d]);
            }
            blk.fc1.forward_rows(&ln_out, b, &mut ff);
            self.cfg.act.apply_vec(&mut ff);
            blk.fc2.forward_rows(&ff, b, &mut ff_out);
            if self.cfg.parallel_residual {
                for i in 0..b * d {
                    h[i] += attn_out[i] + ff_out[i];
                }
            } else {
                for i in 0..b * d {
                    h[i] += ff_out[i];
                }
            }
        }
        for &slot in slots {
            arena.advance(slot, 1);
        }
        let vocab = self.cfg.vocab;
        let mut logits = vec![0.0f32; b * vocab];
        for r in 0..b {
            self.ln_f.forward_row(&h[r * d..(r + 1) * d], &mut ln_out[r * d..(r + 1) * d]);
        }
        self.head.forward_rows(&ln_out[..b * d], b, &mut logits);
        logits
    }

    /// Prefill: push a whole prompt through one cache slot, returning
    /// the logits of the final position.
    ///
    /// On an empty slot this runs **batched**: every linear processes
    /// the whole prompt in one [`super::Linear::forward_rows`] call (the
    /// fused qgemm kernel for quantized layers) and the causal attention
    /// helper mixes all positions at once — the serving prefill fast
    /// path. On a non-empty slot it falls back to token-by-token
    /// decoding over the existing prefix.
    pub fn prefill_slot(&self, tokens: &[u16], slot: usize, arena: &mut KvArena) -> Vec<f32> {
        assert!(!tokens.is_empty());
        assert!(arena.live[slot], "slot {slot} not allocated");
        if !arena.is_empty(slot) {
            let mut last = Vec::new();
            for &t in tokens {
                last = self.decode_step_batch(&[t], &[slot], arena);
            }
            return last;
        }
        assert_eq!(arena.d, self.cfg.d_model);
        let d = self.cfg.d_model;
        let seq = tokens.len();
        assert!(seq <= arena.max_seq, "prompt longer than the context window");

        let mut h = vec![0.0f32; seq * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let e = &self.embed[(tok as usize) * d..(tok as usize + 1) * d];
            let p = &self.pos[t * d..(t + 1) * d];
            for i in 0..d {
                h[t * d + i] = e[i] + p[i];
            }
        }
        let mut ln_out = vec![0.0f32; seq * d];
        let mut q = vec![0.0f32; seq * d];
        let mut k_new = vec![0.0f32; seq * d];
        let mut v_new = vec![0.0f32; seq * d];
        let mut mix = vec![0.0f32; seq * d];
        let mut attn_out = vec![0.0f32; seq * d];
        let mut ff = vec![0.0f32; seq * self.cfg.d_ff];
        let mut ff_out = vec![0.0f32; seq * d];

        for (bi, blk) in self.blocks.iter().enumerate() {
            for t in 0..seq {
                blk.ln1.forward_row(&h[t * d..(t + 1) * d], &mut ln_out[t * d..(t + 1) * d]);
            }
            blk.wq.forward_rows(&ln_out, seq, &mut q);
            blk.wk.forward_rows(&ln_out, seq, &mut k_new);
            blk.wv.forward_rows(&ln_out, seq, &mut v_new);
            {
                let base = slot * arena.max_seq * d;
                arena.k[bi][base..base + seq * d].copy_from_slice(&k_new);
                arena.v[bi][base..base + seq * d].copy_from_slice(&v_new);
            }
            super::layers::attention(&q, &k_new, &v_new, seq, d, self.cfg.n_heads, true, &mut mix);
            blk.wo.forward_rows(&mix, seq, &mut attn_out);
            if !self.cfg.parallel_residual {
                for i in 0..seq * d {
                    h[i] += attn_out[i];
                }
            }
            for t in 0..seq {
                blk.ln2.forward_row(&h[t * d..(t + 1) * d], &mut ln_out[t * d..(t + 1) * d]);
            }
            blk.fc1.forward_rows(&ln_out, seq, &mut ff);
            self.cfg.act.apply_vec(&mut ff);
            blk.fc2.forward_rows(&ff, seq, &mut ff_out);
            if self.cfg.parallel_residual {
                for i in 0..seq * d {
                    h[i] += attn_out[i] + ff_out[i];
                }
            } else {
                for i in 0..seq * d {
                    h[i] += ff_out[i];
                }
            }
        }
        arena.advance(slot, seq);
        // logits for the final position only
        let mut ln_last = vec![0.0f32; d];
        self.ln_f.forward_row(&h[(seq - 1) * d..], &mut ln_last);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        self.head.forward_rows(&ln_last, 1, &mut logits);
        logits
    }

    /// Prefill a whole prompt through a single-sequence cache.
    pub fn prefill(&self, tokens: &[u16], cache: &mut KvCache) -> Vec<f32> {
        self.prefill_slot(tokens, 0, &mut cache.arena)
    }

    /// Longest servable prompt suffix: the last `max_seq - 1` tokens,
    /// so prefill plus one decode step always fit the window. Shared by
    /// every serving path so clipping stays in lockstep with
    /// [`Transformer::generate_greedy`].
    pub fn clip_to_window(&self, prompt: &[u16]) -> Vec<u16> {
        let max_seq = self.cfg.max_seq;
        if prompt.len() >= max_seq {
            prompt[prompt.len() - (max_seq - 1)..].to_vec()
        } else {
            prompt.to_vec()
        }
    }

    /// Context tokens re-encoded when a full sequence slides its
    /// window — the single source of truth for the slide, which every
    /// decode path must share for token-exact parity.
    pub fn slide_keep(&self) -> usize {
        self.cfg.max_seq / 2
    }

    /// Greedy generation: prompt → `n` new tokens.
    pub fn generate_greedy(&self, prompt: &[u16], n: usize) -> Vec<u16> {
        let mut cache = KvCache::new(self);
        let mut out = prompt.to_vec();
        let mut logits = self.prefill(prompt, &mut cache);
        for _ in 0..n {
            if cache.is_full() {
                // slide the window by re-encoding the tail
                let keep = self.slide_keep();
                let tail = out[out.len() - keep..].to_vec();
                cache.clear();
                logits = self.prefill(&tail, &mut cache);
            }
            let next = argmax(&logits) as u16;
            out.push(next);
            logits = self.decode_step(next, &mut cache);
        }
        out
    }
}

/// Index of the first maximum — the tie-break every greedy path in this
/// crate must share for token-exact parity across batch shapes.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{random_transformer, Activation, TransformerConfig};

    fn model(parallel: bool) -> Transformer {
        random_transformer(
            TransformerConfig {
                name: "d".into(),
                vocab: 48,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_seq: 16,
                act: Activation::Gelu,
                parallel_residual: parallel,
            },
            77,
        )
    }

    #[test]
    fn decode_matches_forward() {
        for parallel in [false, true] {
            let m = model(parallel);
            let toks: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6];
            let full = m.forward(&toks, None);
            let vocab = m.cfg.vocab;
            let mut cache = KvCache::new(&m);
            for (t, &tok) in toks.iter().enumerate() {
                let step_logits = m.decode_step(tok, &mut cache);
                let full_row = &full[t * vocab..(t + 1) * vocab];
                for (a, b) in step_logits.iter().zip(full_row.iter()) {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "parallel={parallel} pos={t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn prefill_equals_last_forward_row() {
        let m = model(true);
        let toks: Vec<u16> = vec![1, 2, 3, 4, 5];
        let mut cache = KvCache::new(&m);
        let last = m.prefill(&toks, &mut cache);
        let full = m.forward(&toks, None);
        let vocab = m.cfg.vocab;
        for (a, b) in last.iter().zip(&full[4 * vocab..5 * vocab]) {
            assert!((a - b).abs() < 1e-4);
        }
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn generate_deterministic_and_bounded() {
        let m = model(false);
        let out1 = m.generate_greedy(&[1, 2, 3], 20);
        let out2 = m.generate_greedy(&[1, 2, 3], 20);
        assert_eq!(out1, out2);
        assert_eq!(out1.len(), 23);
        assert!(out1.iter().all(|&t| (t as usize) < 48));
    }

    #[test]
    fn cache_overflow_guard() {
        let m = model(false);
        let mut cache = KvCache::new(&m);
        for t in 0..16 {
            m.decode_step(t as u16 % 48, &mut cache);
        }
        assert!(cache.is_full());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.decode_step(0, &mut cache);
        }));
        assert!(r.is_err(), "decoding past max_seq must panic");
    }

    #[test]
    fn truncate_front_keeps_suffix() {
        let m = model(true);
        let mut cache = KvCache::new(&m);
        for t in 0..8 {
            m.decode_step(t, &mut cache);
        }
        cache.truncate_front(3);
        assert_eq!(cache.len(), 5);
        cache.clear();
        assert!(cache.is_empty());
    }

    /// THE batched-decode parity property: stacking several sequences
    /// into one `decode_step_batch` call must produce, for every
    /// sequence, logits **bit-identical** to decoding it alone through a
    /// single-slot cache.
    #[test]
    fn batched_decode_is_bit_exact_vs_single() {
        for parallel in [false, true] {
            let m = model(parallel);
            let vocab = m.cfg.vocab;
            let seqs: Vec<Vec<u16>> = vec![
                vec![3, 1, 4, 1, 5],
                vec![9, 2, 6, 5, 3],
                vec![8, 9, 7, 9, 3],
            ];
            // reference: each sequence decoded alone
            let mut want: Vec<Vec<f32>> = Vec::new();
            for s in &seqs {
                let mut cache = KvCache::new(&m);
                let mut last = Vec::new();
                for &t in s {
                    last = m.decode_step(t, &mut cache);
                }
                want.push(last);
            }
            // batched: all three in one arena, one step per position
            let mut arena = KvArena::new(&m, 3);
            let slots: Vec<usize> = (0..3).map(|_| arena.alloc().unwrap()).collect();
            let mut got = Vec::new();
            for pos in 0..seqs[0].len() {
                let toks: Vec<u16> = seqs.iter().map(|s| s[pos]).collect();
                got = m.decode_step_batch(&toks, &slots, &mut arena);
            }
            for (b, w) in want.iter().enumerate() {
                assert_eq!(
                    &got[b * vocab..(b + 1) * vocab],
                    &w[..],
                    "parallel={parallel} seq {b} diverged under batching"
                );
            }
        }
    }

    /// Ragged batches: sequences of different lengths share steps, and a
    /// late joiner admitted mid-flight stays bit-exact.
    #[test]
    fn ragged_batch_with_late_join_is_exact() {
        let m = model(false);
        let vocab = m.cfg.vocab;
        let a: Vec<u16> = vec![1, 2, 3, 4, 5, 6, 7];
        let b: Vec<u16> = vec![11, 12, 13];
        // reference
        let seq_logits = |s: &[u16]| {
            let mut cache = KvCache::new(&m);
            let mut last = Vec::new();
            for &t in s {
                last = m.decode_step(t, &mut cache);
            }
            last
        };
        let want_a = seq_logits(&a);
        let want_b = seq_logits(&b);
        // batched: a decodes alone for 4 steps, then b joins (prefill
        // would be the serving path; token steps exercise raggedness)
        let mut arena = KvArena::new(&m, 2);
        let sa = arena.alloc().unwrap();
        let mut got_a = Vec::new();
        for &t in &a[..4] {
            got_a = m.decode_step_batch(&[t], &[sa], &mut arena);
        }
        let sb = arena.alloc().unwrap();
        for i in 0..3 {
            let logits = m.decode_step_batch(&[a[4 + i], b[i]], &[sa, sb], &mut arena);
            got_a = logits[..vocab].to_vec();
            if i == 2 {
                assert_eq!(&logits[vocab..], &want_b[..], "late joiner diverged");
            }
        }
        assert_eq!(got_a, want_a, "long-running sequence diverged");
    }

    #[test]
    fn arena_slot_reuse_after_release() {
        let m = model(true);
        let mut arena = KvArena::new(&m, 2);
        let s0 = arena.alloc().unwrap();
        let s1 = arena.alloc().unwrap();
        assert!(arena.alloc().is_none(), "over-allocation must fail");
        m.decode_step_batch(&[5, 6], &[s0, s1], &mut arena);
        m.decode_step_batch(&[7], &[s0], &mut arena);
        assert_eq!(arena.len(s0), 2);
        assert_eq!(arena.len(s1), 1);
        // retire s0; the slot comes back empty and decodes a fresh
        // sequence bit-exactly
        arena.release(s0);
        assert_eq!(arena.free_slots(), 1);
        let s2 = arena.alloc().unwrap();
        assert_eq!(s2, s0, "LIFO free list must reuse the retired slot");
        assert_eq!(arena.len(s2), 0);
        let got = m.decode_step_batch(&[9], &[s2], &mut arena);
        let mut cache = KvCache::new(&m);
        let want = m.decode_step(9, &mut cache);
        assert_eq!(got, want, "reused slot must behave like a fresh cache");
        // the surviving slot was untouched by the reuse
        assert_eq!(arena.len(s1), 1);
    }

    #[test]
    fn arena_guards() {
        let m = model(false);
        let mut arena = KvArena::new(&m, 2);
        let s = arena.alloc().unwrap();
        // scheduling a free slot panics
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a2 = arena.clone();
            m.decode_step_batch(&[1], &[s + 1], &mut a2);
        }));
        assert!(r.is_err(), "free slot must be rejected");
        // mismatched tokens/slots panics
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a2 = arena.clone();
            m.decode_step_batch(&[1, 2], &[s], &mut a2);
        }));
        assert!(r.is_err(), "token/slot length mismatch must be rejected");
    }
}
