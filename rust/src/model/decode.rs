//! Incremental decoding with a KV cache.
//!
//! `forward()` recomputes the whole prefix per step — fine for PPL
//! evaluation, quadratic-per-token for serving. The KV cache stores each
//! block's projected keys/values so one decode step costs O(seq · d)
//! attention instead of O(seq² · d) recompute. Bit-compatible with
//! `forward()` (tested): the quantized linears run the same integer
//! datapath in both paths.

use super::layers::{attention, softmax};
use super::transformer::Transformer;

/// Per-layer key/value cache for one sequence.
#[derive(Clone, Debug)]
pub struct KvCache {
    /// [layer][pos * d ..] cached keys.
    k: Vec<Vec<f32>>,
    /// [layer][pos * d ..] cached values.
    v: Vec<Vec<f32>>,
    d: usize,
    max_seq: usize,
    len: usize,
}

impl KvCache {
    pub fn new(model: &Transformer) -> KvCache {
        let d = model.cfg.d_model;
        let max_seq = model.cfg.max_seq;
        KvCache {
            k: vec![Vec::with_capacity(max_seq * d); model.cfg.n_layers],
            v: vec![Vec::with_capacity(max_seq * d); model.cfg.n_layers],
            d,
            max_seq,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.max_seq
    }

    pub fn clear(&mut self) {
        for layer in self.k.iter_mut().chain(self.v.iter_mut()) {
            layer.clear();
        }
        self.len = 0;
    }

    /// Drop the oldest `n` positions (sliding-window generation).
    /// NOTE: positional embeddings are absolute, so after sliding the
    /// model sees shifted positions; for the pico models with short
    /// windows this matches the serve example's windowed re-encode.
    pub fn truncate_front(&mut self, n: usize) {
        let n = n.min(self.len);
        for layer in self.k.iter_mut().chain(self.v.iter_mut()) {
            layer.drain(..n * self.d);
        }
        self.len -= n;
    }
}

impl Transformer {
    /// Decode one token given the cached prefix; returns the logits for
    /// this position and appends this position's K/V to the cache.
    pub fn decode_step(&self, token: u16, cache: &mut KvCache) -> Vec<f32> {
        assert!(!cache.is_full(), "KV cache full (max_seq {})", cache.max_seq);
        assert_eq!(cache.d, self.cfg.d_model);
        let d = self.cfg.d_model;
        let pos = cache.len;
        let mut h = vec![0.0f32; d];
        let e = &self.embed[(token as usize) * d..(token as usize + 1) * d];
        let p = &self.pos[pos * d..(pos + 1) * d];
        for i in 0..d {
            h[i] = e[i] + p[i];
        }
        let mut scratch: Vec<i64> = Vec::new();
        let mut ln_out = vec![0.0f32; d];
        let mut q = vec![0.0f32; d];
        let mut k_new = vec![0.0f32; d];
        let mut v_new = vec![0.0f32; d];
        let mut mix = vec![0.0f32; d];
        let mut attn_out = vec![0.0f32; d];
        let mut ff = vec![0.0f32; self.cfg.d_ff];
        let mut ff_out = vec![0.0f32; d];

        for (bi, blk) in self.blocks.iter().enumerate() {
            blk.ln1.forward_row(&h, &mut ln_out);
            blk.wq.forward_row(&ln_out, &mut q, &mut scratch);
            blk.wk.forward_row(&ln_out, &mut k_new, &mut scratch);
            blk.wv.forward_row(&ln_out, &mut v_new, &mut scratch);
            cache.k[bi].extend_from_slice(&k_new);
            cache.v[bi].extend_from_slice(&v_new);

            // single-query causal attention over the cache
            let n_heads = self.cfg.n_heads;
            let hd = d / n_heads;
            let scale = 1.0 / (hd as f32).sqrt();
            let kc = &cache.k[bi];
            let vc = &cache.v[bi];
            let t_len = pos + 1;
            let mut scores = vec![0.0f32; t_len];
            for hh in 0..n_heads {
                let off = hh * hd;
                for (s, score) in scores.iter_mut().enumerate() {
                    let krow = &kc[s * d + off..s * d + off + hd];
                    let mut dot = 0.0f32;
                    for i in 0..hd {
                        dot += q[off + i] * krow[i];
                    }
                    *score = dot * scale;
                }
                softmax(&mut scores);
                let orow = &mut mix[off..off + hd];
                orow.iter_mut().for_each(|o| *o = 0.0);
                for (s, &w) in scores.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    let vrow = &vc[s * d + off..s * d + off + hd];
                    for i in 0..hd {
                        orow[i] += w * vrow[i];
                    }
                }
            }
            blk.wo.forward_row(&mix, &mut attn_out, &mut scratch);

            if !self.cfg.parallel_residual {
                for i in 0..d {
                    h[i] += attn_out[i];
                }
            }
            blk.ln2.forward_row(&h, &mut ln_out);
            blk.fc1.forward_row(&ln_out, &mut ff, &mut scratch);
            self.cfg.act.apply_vec(&mut ff);
            blk.fc2.forward_row(&ff, &mut ff_out, &mut scratch);
            if self.cfg.parallel_residual {
                for i in 0..d {
                    h[i] += attn_out[i] + ff_out[i];
                }
            } else {
                for i in 0..d {
                    h[i] += ff_out[i];
                }
            }
        }
        cache.len += 1;
        let vocab = self.cfg.vocab;
        let mut logits = vec![0.0f32; vocab];
        self.ln_f.forward_row(&h, &mut ln_out);
        self.head.forward_row(&ln_out, &mut logits);
        logits
    }

    /// Prefill: push a whole prompt through the cache, returning the
    /// logits of the final position.
    ///
    /// On an empty cache this runs **batched**: every linear processes
    /// the whole prompt in one [`super::Linear::forward_rows`] call (the
    /// fused qgemm kernel for quantized layers) and the causal attention
    /// helper mixes all positions at once — the serving prefill fast
    /// path. On a non-empty cache it falls back to token-by-token
    /// decoding over the existing prefix.
    pub fn prefill(&self, tokens: &[u16], cache: &mut KvCache) -> Vec<f32> {
        assert!(!tokens.is_empty());
        if !cache.is_empty() {
            let mut last = Vec::new();
            for &t in tokens {
                last = self.decode_step(t, cache);
            }
            return last;
        }
        assert_eq!(cache.d, self.cfg.d_model);
        let d = self.cfg.d_model;
        let seq = tokens.len();
        assert!(seq <= cache.max_seq, "prompt longer than the context window");

        let mut h = vec![0.0f32; seq * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let e = &self.embed[(tok as usize) * d..(tok as usize + 1) * d];
            let p = &self.pos[t * d..(t + 1) * d];
            for i in 0..d {
                h[t * d + i] = e[i] + p[i];
            }
        }
        let mut ln_out = vec![0.0f32; seq * d];
        let mut q = vec![0.0f32; seq * d];
        let mut k_new = vec![0.0f32; seq * d];
        let mut v_new = vec![0.0f32; seq * d];
        let mut mix = vec![0.0f32; seq * d];
        let mut attn_out = vec![0.0f32; seq * d];
        let mut ff = vec![0.0f32; seq * self.cfg.d_ff];
        let mut ff_out = vec![0.0f32; seq * d];

        for (bi, blk) in self.blocks.iter().enumerate() {
            for t in 0..seq {
                blk.ln1.forward_row(&h[t * d..(t + 1) * d], &mut ln_out[t * d..(t + 1) * d]);
            }
            blk.wq.forward_rows(&ln_out, seq, &mut q);
            blk.wk.forward_rows(&ln_out, seq, &mut k_new);
            blk.wv.forward_rows(&ln_out, seq, &mut v_new);
            cache.k[bi].extend_from_slice(&k_new);
            cache.v[bi].extend_from_slice(&v_new);
            attention(&q, &k_new, &v_new, seq, d, self.cfg.n_heads, true, &mut mix);
            blk.wo.forward_rows(&mix, seq, &mut attn_out);
            if !self.cfg.parallel_residual {
                for i in 0..seq * d {
                    h[i] += attn_out[i];
                }
            }
            for t in 0..seq {
                blk.ln2.forward_row(&h[t * d..(t + 1) * d], &mut ln_out[t * d..(t + 1) * d]);
            }
            blk.fc1.forward_rows(&ln_out, seq, &mut ff);
            self.cfg.act.apply_vec(&mut ff);
            blk.fc2.forward_rows(&ff, seq, &mut ff_out);
            if self.cfg.parallel_residual {
                for i in 0..seq * d {
                    h[i] += attn_out[i] + ff_out[i];
                }
            } else {
                for i in 0..seq * d {
                    h[i] += ff_out[i];
                }
            }
        }
        cache.len += seq;
        // logits for the final position only
        let mut ln_last = vec![0.0f32; d];
        self.ln_f.forward_row(&h[(seq - 1) * d..], &mut ln_last);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        self.head.forward_row(&ln_last, &mut logits);
        logits
    }

    /// Greedy generation: prompt → `n` new tokens.
    pub fn generate_greedy(&self, prompt: &[u16], n: usize) -> Vec<u16> {
        let mut cache = KvCache::new(self);
        let mut out = prompt.to_vec();
        let mut logits = self.prefill(prompt, &mut cache);
        for _ in 0..n {
            if cache.is_full() {
                // slide the window by re-encoding the tail
                let keep = self.cfg.max_seq / 2;
                let tail = out[out.len() - keep..].to_vec();
                cache.clear();
                logits = self.prefill(&tail, &mut cache);
            }
            let next = argmax(&logits) as u16;
            out.push(next);
            logits = self.decode_step(next, &mut cache);
        }
        out
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{random_transformer, Activation, TransformerConfig};

    fn model(parallel: bool) -> Transformer {
        random_transformer(
            TransformerConfig {
                name: "d".into(),
                vocab: 48,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_seq: 16,
                act: Activation::Gelu,
                parallel_residual: parallel,
            },
            77,
        )
    }

    #[test]
    fn decode_matches_forward() {
        for parallel in [false, true] {
            let m = model(parallel);
            let toks: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6];
            let full = m.forward(&toks, None);
            let vocab = m.cfg.vocab;
            let mut cache = KvCache::new(&m);
            for (t, &tok) in toks.iter().enumerate() {
                let step_logits = m.decode_step(tok, &mut cache);
                let full_row = &full[t * vocab..(t + 1) * vocab];
                for (a, b) in step_logits.iter().zip(full_row.iter()) {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "parallel={parallel} pos={t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn prefill_equals_last_forward_row() {
        let m = model(true);
        let toks: Vec<u16> = vec![1, 2, 3, 4, 5];
        let mut cache = KvCache::new(&m);
        let last = m.prefill(&toks, &mut cache);
        let full = m.forward(&toks, None);
        let vocab = m.cfg.vocab;
        for (a, b) in last.iter().zip(&full[4 * vocab..5 * vocab]) {
            assert!((a - b).abs() < 1e-4);
        }
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn generate_deterministic_and_bounded() {
        let m = model(false);
        let out1 = m.generate_greedy(&[1, 2, 3], 20);
        let out2 = m.generate_greedy(&[1, 2, 3], 20);
        assert_eq!(out1, out2);
        assert_eq!(out1.len(), 23);
        assert!(out1.iter().all(|&t| (t as usize) < 48));
    }

    #[test]
    fn cache_overflow_guard() {
        let m = model(false);
        let mut cache = KvCache::new(&m);
        for t in 0..16 {
            m.decode_step(t as u16 % 48, &mut cache);
        }
        assert!(cache.is_full());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.decode_step(0, &mut cache);
        }));
        assert!(r.is_err(), "decoding past max_seq must panic");
    }

    #[test]
    fn truncate_front_keeps_suffix() {
        let m = model(true);
        let mut cache = KvCache::new(&m);
        for t in 0..8 {
            m.decode_step(t, &mut cache);
        }
        cache.truncate_front(3);
        assert_eq!(cache.len(), 5);
        cache.clear();
        assert!(cache.is_empty());
    }
}
