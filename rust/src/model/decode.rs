//! Incremental decoding over a multi-sequence KV arena.
//!
//! `forward()` recomputes the whole prefix per step — fine for PPL
//! evaluation, quadratic-per-token for serving. The KV structures here
//! store each block's projected keys/values so one decode step costs
//! O(seq · d) attention instead of O(seq² · d) recompute.
//!
//! The serving engine decodes **many sequences per kernel call**:
//! [`KvArena`] holds a fixed number of slots (one in-flight sequence
//! each, with independent lengths), and
//! [`Transformer::decode_step_ragged_scratch`] stacks a [`RowGroup`]
//! per scheduled slot — a 1-row decode step or a multi-row **prefill
//! chunk**, mixed freely in one call — into one batched linear call
//! per layer, so quantized layers amortize the fused qgemm kernel
//! across decode rows *and* admission prefill chunks at once.
//! Attention stays ragged: each group attends over its own slot's
//! cached positions (plus its own just-appended chunk rows, causally)
//! only. [`Transformer::decode_step_batch_scratch`] is the
//! all-1-row-groups wrapper; [`Transformer::prefill_slot_scratch`] the
//! single-group one.
//!
//! The `_scratch` entry points are the hot path: every operand buffer
//! (activations, quantized codes, attention panels, overflow counters,
//! logits) lives in a caller-owned [`super::DecodeScratch`] workspace,
//! so a steady-state decode step performs **zero heap allocations**
//! (`tests/zero_alloc_decode.rs` asserts this with a counting global
//! allocator; the guarantee covers kernel calls below the
//! band-threading work threshold — a batched call large enough to fan
//! out to scoped threads allocates for the spawns, by design). The serving engine owns one workspace per engine thread
//! and reuses it across admissions, steps and slides; the non-scratch
//! wrappers (`decode_step_batch`, `prefill_slot`, …) build a transient
//! workspace and exist for tests and one-shot callers.
//!
//! The arena runs on one of two **backends** ([`KvCacheKind`]): plain
//! f32 keys/values with float attention, or the accumulator-aware
//! quantized store ([`super::kvquant`]) — narrow integer codes with
//! per-(slot, position, head) bf16 scales, quantized once at append
//! time, with both attention matmuls executed on the multi-stage
//! integer datapath ([`super::layers::attend_one_query_quant`], fed by
//! the slab-resolved bulk gathers). Every decode entry point dispatches
//! internally, so callers pick a backend at arena construction and
//! nothing else changes.
//!
//! The single-sequence [`KvCache`] is a thin 1-slot arena view, and
//! `decode_step`/`prefill` delegate to the batched path, so sequential
//! decode (`generate_greedy`) and continuous-batched serving run the
//! **same arithmetic per row** — batched decode is token-exact versus
//! sequential decode on either backend (tested here and in
//! `coordinator::serve`). This relies on every row of a batched kernel
//! being computed independently of its batchmates (true of
//! `linalg::qgemm`, the banded f64 GEMM, and the per-slot quantized
//! attention).
//!
//! Overflow accounting is **unified**: the `_counted`/`_scratch`
//! variants attribute integer-datapath overflow events (linear layers
//! and quantized attention) to the row / request that produced them —
//! the serving engine's exact per-request accounting — and attention
//! events additionally land on the model-wide
//! [`Transformer::overflow_events`] counter alongside the quantized-
//! linear events, so eval and serve report one number (previously
//! attention events lived on a separate arena-side counter).

use super::kvquant::{KvCacheKind, QuantKv};
use super::layers::{attend_chunk, attend_chunk_quant};
use super::scratch::DecodeScratch;
use super::transformer::{Transformer, TransformerConfig};

/// One **row group** of a ragged decode step: `len` consecutive rows of
/// the step's flat token slice (starting at `start`), appended to
/// `slot` at consecutive positions beginning at the slot's current
/// length. A decode row is a 1-row group; a prefill chunk is a
/// multi-row group. Groups tile the token slice in order and name
/// pairwise-distinct slots.
#[derive(Clone, Copy, Debug)]
pub struct RowGroup {
    /// Arena slot the group's rows are appended to.
    pub slot: usize,
    /// First row of the group in the step's flat token slice.
    pub start: usize,
    /// Number of consecutive rows (≥ 1).
    pub len: usize,
}

/// Multi-sequence key/value arena: `slots` independent sequences, each
/// owning a fixed `[max_seq × d]` region per layer. Slots are
/// allocated at admission, reused after retirement, and slide their
/// window independently (via [`KvArena::reset_slot`] + re-prefill, the
/// absolute-position re-encode the single-sequence path uses).
#[derive(Clone, Debug)]
pub struct KvArena {
    store: KvStore,
    d: usize,
    max_seq: usize,
    slots: usize,
    /// Per-slot cached length.
    lens: Vec<usize>,
    /// Per-slot liveness (allocated to a sequence).
    live: Vec<bool>,
    /// LIFO free list of slot ids.
    free: Vec<usize>,
}

/// Backend storage of the arena (see [`KvCacheKind`]).
#[derive(Clone, Debug)]
enum KvStore {
    F32 {
        /// [layer][slot * max_seq * d + pos * d ..] cached keys.
        k: Vec<Vec<f32>>,
        /// [layer][slot * max_seq * d + pos * d ..] cached values.
        v: Vec<Vec<f32>>,
    },
    Quant(QuantKv),
}

impl KvArena {
    /// Arena with `slots` sequence slots, all free, on the f32 backend.
    pub fn new(model: &Transformer, slots: usize) -> KvArena {
        KvArena::with_kind(model, slots, KvCacheKind::F32)
    }

    /// Arena with `slots` sequence slots on the chosen backend.
    pub fn with_kind(model: &Transformer, slots: usize, kind: KvCacheKind) -> KvArena {
        assert!(slots >= 1, "arena needs at least one slot");
        let d = model.cfg.d_model;
        let max_seq = model.cfg.max_seq;
        let n_layers = model.cfg.n_layers;
        let store = match kind {
            KvCacheKind::F32 => KvStore::F32 {
                k: vec![vec![0.0; slots * max_seq * d]; n_layers],
                v: vec![vec![0.0; slots * max_seq * d]; n_layers],
            },
            KvCacheKind::Quant(spec) => {
                KvStore::Quant(QuantKv::new(spec, n_layers, slots, max_seq, d, model.cfg.n_heads))
            }
        };
        KvArena {
            store,
            d,
            max_seq,
            slots,
            lens: vec![0; slots],
            live: vec![false; slots],
            free: (0..slots).rev().collect(),
        }
    }

    /// Which backend this arena runs on.
    pub fn kind(&self) -> KvCacheKind {
        match &self.store {
            KvStore::F32 { .. } => KvCacheKind::F32,
            KvStore::Quant(q) => KvCacheKind::Quant(q.spec),
        }
    }

    /// KV storage footprint in bytes (the serving-memory figure the
    /// quantized backend exists to shrink).
    pub fn bytes(&self) -> usize {
        match &self.store {
            KvStore::F32 { k, v } => {
                let mut elems = 0usize;
                for slab in k.iter().chain(v.iter()) {
                    elems += slab.len();
                }
                elems * std::mem::size_of::<f32>()
            }
            KvStore::Quant(q) => q.bytes(),
        }
    }

    /// Storage footprint of an arena with `slots` slots for this model
    /// config on the given backend, without building it — lets reports
    /// compare f32 vs quantized footprints cheaply. Quantized scales
    /// are bf16-packed: 2 bytes per (slot, position, head) per tensor.
    pub fn footprint(cfg: &TransformerConfig, slots: usize, kind: KvCacheKind) -> usize {
        let positions = slots * cfg.max_seq;
        match kind {
            KvCacheKind::F32 => 2 * cfg.n_layers * positions * cfg.d_model * 4,
            KvCacheKind::Quant(spec) => {
                let code_bytes = if spec.kv_bits <= 8 { 1 } else { 2 };
                2 * cfg.n_layers * positions * (cfg.d_model * code_bytes + cfg.n_heads * 2)
            }
        }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Claim a free slot (length 0), or `None` when all are in flight.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.lens[slot] = 0;
        self.live[slot] = true;
        Some(slot)
    }

    /// Retire a sequence: its slot becomes reusable immediately.
    pub fn release(&mut self, slot: usize) {
        assert!(self.live[slot], "releasing a free slot");
        self.live[slot] = false;
        self.lens[slot] = 0;
        self.free.push(slot);
    }

    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.lens[slot] == 0
    }

    pub fn is_full(&self, slot: usize) -> bool {
        self.lens[slot] >= self.max_seq
    }

    /// Drop a slot's cached positions (window-slide: clear, then
    /// re-prefill the kept tail so absolute positions are re-encoded).
    pub fn reset_slot(&mut self, slot: usize) {
        assert!(self.live[slot], "resetting a free slot");
        self.lens[slot] = 0;
    }

    /// Drop the oldest `n` positions of one slot (sliding-window
    /// generation without re-encoding). On the quantized backend the
    /// codes **and** their scales slide together verbatim — a window
    /// slide never requantizes anything, so repeated slides cannot
    /// accumulate drift.
    /// NOTE: positional embeddings are absolute, so after sliding the
    /// model sees shifted positions; for the pico models with short
    /// windows this matches the serve example's windowed re-encode.
    pub fn truncate_front(&mut self, slot: usize, n: usize) {
        let n = n.min(self.lens[slot]);
        if n == 0 {
            return;
        }
        let (d, max_seq, len) = (self.d, self.max_seq, self.lens[slot]);
        match &mut self.store {
            KvStore::F32 { k, v } => {
                let base = slot * max_seq * d;
                for slab in k.iter_mut().chain(v.iter_mut()) {
                    slab.copy_within(base + n * d..base + len * d, base);
                }
            }
            KvStore::Quant(q) => q.truncate_front(slot, n, len),
        }
        self.lens[slot] -= n;
    }

    /// Cached K/V rows of one position, dequantized on the quantized
    /// backend — the backend-independent inspection hook slide/parity
    /// tests rely on.
    pub fn kv_row(&self, layer: usize, slot: usize, pos: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(pos < self.lens[slot], "position {pos} not cached");
        match &self.store {
            KvStore::F32 { k, v } => {
                let at = (slot * self.max_seq + pos) * self.d;
                (k[layer][at..at + self.d].to_vec(), v[layer][at..at + self.d].to_vec())
            }
            KvStore::Quant(q) => {
                let view = q.slot_view(layer, slot);
                (view.dequant_k_row(pos), view.dequant_v_row(pos))
            }
        }
    }

    /// Write a chunk of `n` consecutive positions' K/V rows into a slot
    /// starting at `pos` — one bulk copy on the f32 backend,
    /// quantize-at-append per position on the quantized backend
    /// ([`QuantKv::append_rows`]). `n == 1` is the decode-row case.
    #[inline]
    fn append_kv_rows_at(
        &mut self,
        layer: usize,
        slot: usize,
        pos: usize,
        n: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) {
        debug_assert!(pos + n <= self.max_seq);
        let (d, max_seq) = (self.d, self.max_seq);
        debug_assert_eq!(k_rows.len(), n * d);
        debug_assert_eq!(v_rows.len(), n * d);
        match &mut self.store {
            KvStore::F32 { k, v } => {
                let at = (slot * max_seq + pos) * d;
                k[layer][at..at + n * d].copy_from_slice(k_rows);
                v[layer][at..at + n * d].copy_from_slice(v_rows);
            }
            KvStore::Quant(q) => q.append_rows(layer, slot, pos, n, k_rows, v_rows),
        }
    }

    #[inline]
    fn advance(&mut self, slot: usize, n: usize) {
        self.lens[slot] += n;
        debug_assert!(self.lens[slot] <= self.max_seq);
    }
}

/// Per-layer key/value cache for one sequence — a 1-slot [`KvArena`]
/// view, kept so single-sequence callers (eval, examples,
/// `generate_greedy`) read naturally.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub(crate) arena: KvArena,
}

impl KvCache {
    pub fn new(model: &Transformer) -> KvCache {
        KvCache::with_kind(model, KvCacheKind::F32)
    }

    /// Single-sequence cache on the chosen backend.
    pub fn with_kind(model: &Transformer, kind: KvCacheKind) -> KvCache {
        let mut arena = KvArena::with_kind(model, 1, kind);
        arena.alloc().expect("fresh 1-slot arena");
        KvCache { arena }
    }

    pub fn len(&self) -> usize {
        self.arena.len(0)
    }

    pub fn is_empty(&self) -> bool {
        self.arena.is_empty(0)
    }

    pub fn is_full(&self) -> bool {
        self.arena.is_full(0)
    }

    pub fn bytes(&self) -> usize {
        self.arena.bytes()
    }

    pub fn clear(&mut self) {
        self.arena.reset_slot(0);
    }

    /// Drop the oldest `n` positions (sliding-window generation).
    pub fn truncate_front(&mut self, n: usize) {
        self.arena.truncate_front(0, n);
    }
}

impl Transformer {
    /// Decode one token given the cached prefix; returns the logits for
    /// this position and appends this position's K/V to the cache.
    ///
    /// Thin delegate to [`Transformer::decode_step_batch`] over the
    /// cache's single slot, so sequential and batched decode share one
    /// datapath.
    pub fn decode_step(&self, token: u16, cache: &mut KvCache) -> Vec<f32> {
        self.decode_step_batch(&[token], &[0], &mut cache.arena)
    }

    /// Decode one token for **each** scheduled sequence in one batched
    /// pass: `tokens[b]` is appended to arena slot `slots[b]`. Returns
    /// row-major `tokens.len() × vocab` logits.
    ///
    /// Transient-workspace wrapper around
    /// [`Transformer::decode_step_batch_scratch`] (tests and one-shot
    /// callers; the serving engine holds its own workspace).
    pub fn decode_step_batch(
        &self,
        tokens: &[u16],
        slots: &[usize],
        arena: &mut KvArena,
    ) -> Vec<f32> {
        let mut row_ovf = vec![0u64; tokens.len()];
        self.decode_step_batch_counted(tokens, slots, arena, &mut row_ovf)
    }

    /// [`Transformer::decode_step_batch`] with **exact per-row overflow
    /// attribution**: `row_ovf[b]` is incremented by every integer-
    /// datapath overflow event row `b` triggered this step — its rows of
    /// each quantized linear plus (on the quantized-KV backend) its own
    /// attention matmuls.
    pub fn decode_step_batch_counted(
        &self,
        tokens: &[u16],
        slots: &[usize],
        arena: &mut KvArena,
        row_ovf: &mut [u64],
    ) -> Vec<f32> {
        let mut scratch = DecodeScratch::new();
        self.decode_step_batch_scratch(tokens, slots, arena, row_ovf, &mut scratch);
        scratch.step.logits[..tokens.len() * self.cfg.vocab].to_vec()
    }

    /// The batched decode step over a caller-owned workspace — one
    /// 1-row [`RowGroup`] per scheduled sequence through
    /// [`Transformer::decode_step_ragged_scratch`]. Each output row is
    /// bit-identical to decoding that sequence alone, and `row_ovf[b]`
    /// is incremented by exactly the overflow events row `b` triggered
    /// (the serving engine threads per-request counters through here).
    ///
    /// The step's logits land in `scratch.step.logits[..b * vocab]`
    /// (row-major, one row per scheduled sequence) — read them from the
    /// workspace; nothing is allocated or returned. With a warm
    /// workspace the whole step performs zero heap allocations (the
    /// group list lives in a reused workspace buffer).
    pub fn decode_step_batch_scratch(
        &self,
        tokens: &[u16],
        slots: &[usize],
        arena: &mut KvArena,
        row_ovf: &mut [u64],
        scratch: &mut DecodeScratch,
    ) {
        assert_eq!(tokens.len(), slots.len(), "one slot per token");
        let mut groups = std::mem::take(&mut scratch.groups_buf);
        groups.clear();
        groups.extend(
            slots.iter().enumerate().map(|(i, &slot)| RowGroup { slot, start: i, len: 1 }),
        );
        self.decode_step_ragged_scratch(tokens, &groups, arena, row_ovf, scratch);
        scratch.groups_buf = groups;
    }

    /// The **ragged** decode step — the serving hot path since chunked
    /// prefill: every scheduled row group (a 1-row decode step or a
    /// multi-row prefill chunk, mixed freely in one call) rides the
    /// same batched kernel dispatches. Every linear runs one
    /// [`super::Linear::forward_rows_scratch`] call over **all** rows
    /// of the step (the fused qgemm kernel for quantized layers), so
    /// prefill chunks amortize the kernel across the in-flight decode
    /// batch instead of blocking it. Attention stays ragged per group:
    /// chunk row `i` attends causally over its slot's cached prefix
    /// plus chunk rows `0..=i` ([`attend_chunk`] /
    /// [`attend_chunk_quant`]), on the arena's backend.
    ///
    /// **Token-exactness:** every row's arithmetic (embedding at its
    /// absolute position, row-independent linears, attention over its
    /// own slot only) is identical no matter how rows are grouped into
    /// chunks or batched with other sequences — so any chunked schedule
    /// reproduces sequential decode bit for bit (tested in
    /// `tests/chunked_prefill.rs`).
    ///
    /// **Attribution:** `group_ovf[g]` is incremented by exactly the
    /// integer-datapath overflow events group `g`'s rows triggered
    /// (linear rows + its own attention matmuls) — disjoint across
    /// groups and invariant to step composition.
    ///
    /// One logits row per **group** (its last row — the only one a
    /// scheduler can ever sample from) lands in
    /// `scratch.step.logits[..groups.len() * vocab]`.
    pub fn decode_step_ragged_scratch(
        &self,
        tokens: &[u16],
        groups: &[RowGroup],
        arena: &mut KvArena,
        group_ovf: &mut [u64],
        scratch: &mut DecodeScratch,
    ) {
        assert!(!groups.is_empty(), "empty ragged step");
        assert_eq!(group_ovf.len(), groups.len(), "one counter per group");
        assert_eq!(arena.d, self.cfg.d_model);
        let n = tokens.len();
        let g_n = groups.len();
        let d = self.cfg.d_model;
        let d_ff = self.cfg.d_ff;
        let vocab = self.cfg.vocab;
        let mut cursor = 0usize;
        for (gi, g) in groups.iter().enumerate() {
            assert!(g.len >= 1, "group {gi} is empty");
            assert_eq!(g.start, cursor, "groups must tile the token slice in order");
            cursor += g.len;
            assert!(arena.live[g.slot], "slot {} not allocated", g.slot);
            assert!(
                arena.len(g.slot) + g.len <= arena.max_seq,
                "group {gi} overflows KV slot {} ({} + {} > max_seq {})",
                g.slot,
                arena.len(g.slot),
                g.len,
                arena.max_seq
            );
            // hard assert: a doubled slot would append twice at one
            // position and advance the length twice, silently corrupting
            // the sequence (step widths are small, the scan is cheap)
            assert!(
                !groups[..gi].iter().any(|p| p.slot == g.slot),
                "slot {} scheduled twice in one step",
                g.slot
            );
        }
        assert_eq!(cursor, n, "tokens beyond the last group");

        let DecodeScratch { lin, attn, step, .. } = scratch;
        step.ensure(n, g_n, d, d_ff, vocab);
        // Live-size views over the grow-only step buffers; everything
        // below operates on exactly n rows (g_n logit rows).
        let h = &mut step.h[..n * d];
        let ln_out = &mut step.ln_out[..n * d];
        let q = &mut step.q[..n * d];
        let k_new = &mut step.k_new[..n * d];
        let v_new = &mut step.v_new[..n * d];
        let mix = &mut step.mix[..n * d];
        let attn_out = &mut step.attn_out[..n * d];
        let ff = &mut step.ff[..n * d_ff];
        let ff_out = &mut step.ff_out[..n * d];
        let row_ovf = &mut step.row_ovf[..n];
        row_ovf.fill(0);

        // token + absolute positional embedding: chunk row i of a group
        // sits at its slot's position len(slot) + i
        for g in groups {
            let pos0 = arena.len(g.slot);
            for i in 0..g.len {
                let r = g.start + i;
                let tok = tokens[r] as usize;
                let e = &self.embed[tok * d..(tok + 1) * d];
                let p = &self.pos[(pos0 + i) * d..(pos0 + i + 1) * d];
                for j in 0..d {
                    h[r * d + j] = e[j] + p[j];
                }
            }
        }

        let mut attn_total = 0u64;
        for (bi, blk) in self.blocks.iter().enumerate() {
            for r in 0..n {
                blk.ln1.forward_row(&h[r * d..(r + 1) * d], &mut ln_out[r * d..(r + 1) * d]);
            }
            blk.wq.forward_rows_scratch(ln_out, n, q, row_ovf, lin);
            blk.wk.forward_rows_scratch(ln_out, n, k_new, row_ovf, lin);
            blk.wv.forward_rows_scratch(ln_out, n, v_new, row_ovf, lin);
            for g in groups {
                let pos0 = arena.len(g.slot);
                arena.append_kv_rows_at(
                    bi,
                    g.slot,
                    pos0,
                    g.len,
                    &k_new[g.start * d..(g.start + g.len) * d],
                    &v_new[g.start * d..(g.start + g.len) * d],
                );
            }
            // ragged causal attention: each group over its own slot
            // only (prefix + its just-appended chunk rows), on the
            // arena's backend, all through one reused workspace
            for g in groups {
                let t0 = arena.len(g.slot);
                let qrows = &q[g.start * d..(g.start + g.len) * d];
                let orows = &mut mix[g.start * d..(g.start + g.len) * d];
                match &arena.store {
                    KvStore::F32 { k, v } => {
                        let base = g.slot * arena.max_seq * d;
                        let kc = &k[bi][base..base + (t0 + g.len) * d];
                        let vc = &v[bi][base..base + (t0 + g.len) * d];
                        attend_chunk(qrows, kc, vc, t0, g.len, d, self.cfg.n_heads, attn, orows);
                    }
                    KvStore::Quant(qkv) => {
                        let spec = qkv.spec;
                        let ovf = attend_chunk_quant(
                            qrows,
                            &qkv.slot_view(bi, g.slot),
                            t0,
                            g.len,
                            d,
                            self.cfg.n_heads,
                            &spec,
                            attn,
                            orows,
                        );
                        if ovf > 0 {
                            // a chunk belongs entirely to one request;
                            // the group fold below picks this up
                            row_ovf[g.start] += ovf;
                            attn_total += ovf;
                        }
                    }
                }
            }
            blk.wo.forward_rows_scratch(mix, n, attn_out, row_ovf, lin);
            if !self.cfg.parallel_residual {
                for i in 0..n * d {
                    h[i] += attn_out[i];
                }
            }
            for r in 0..n {
                blk.ln2.forward_row(&h[r * d..(r + 1) * d], &mut ln_out[r * d..(r + 1) * d]);
            }
            blk.fc1.forward_rows_scratch(ln_out, n, ff, row_ovf, lin);
            self.cfg.act.apply_vec(ff);
            blk.fc2.forward_rows_scratch(ff, n, ff_out, row_ovf, lin);
            if self.cfg.parallel_residual {
                for i in 0..n * d {
                    h[i] += attn_out[i] + ff_out[i];
                }
            } else {
                for i in 0..n * d {
                    h[i] += ff_out[i];
                }
            }
        }
        if attn_total > 0 {
            // unified accounting: attention events join the model-wide
            // overflow counter next to the quantized-linear events
            self.add_attention_overflows(attn_total);
        }
        for g in groups {
            arena.advance(g.slot, g.len);
        }
        // per-group attribution: fold the kernel's per-row counts
        for (gi, g) in groups.iter().enumerate() {
            group_ovf[gi] += row_ovf[g.start..g.start + g.len].iter().sum::<u64>();
        }
        // one logits row per group, from its last row: gather the
        // final-norm rows contiguously, one head GEMM over all groups
        for (gi, g) in groups.iter().enumerate() {
            let r = g.start + g.len - 1;
            self.ln_f.forward_row(&h[r * d..(r + 1) * d], &mut ln_out[gi * d..(gi + 1) * d]);
        }
        self.head.forward_rows_scratch(
            &ln_out[..g_n * d],
            g_n,
            &mut step.logits[..g_n * vocab],
            lin,
        );
    }

    /// Prefill: push a whole prompt through one cache slot, returning
    /// the logits of the final position.
    ///
    /// Transient-workspace wrapper around
    /// [`Transformer::prefill_slot_scratch`].
    pub fn prefill_slot(&self, tokens: &[u16], slot: usize, arena: &mut KvArena) -> Vec<f32> {
        let mut ovf = 0u64;
        self.prefill_slot_counted(tokens, slot, arena, &mut ovf)
    }

    /// [`Transformer::prefill_slot`] accumulating the prompt's integer-
    /// datapath overflow events into `ovf` — a prefill belongs entirely
    /// to one request, so a scalar counter suffices for exact
    /// per-request attribution.
    pub fn prefill_slot_counted(
        &self,
        tokens: &[u16],
        slot: usize,
        arena: &mut KvArena,
        ovf: &mut u64,
    ) -> Vec<f32> {
        let mut scratch = DecodeScratch::new();
        self.prefill_slot_scratch(tokens, slot, arena, ovf, &mut scratch);
        scratch.step.logits[..self.cfg.vocab].to_vec()
    }

    /// Prefill over a caller-owned workspace — the **1-group special
    /// case** of [`Transformer::decode_step_ragged_scratch`]: the whole
    /// prompt rides one multi-row [`RowGroup`], so every linear
    /// processes it in one [`super::Linear::forward_rows_scratch`] call
    /// (the fused qgemm kernel for quantized layers) and causal
    /// attention runs position by position over the just-appended
    /// K/V — exactly the arithmetic decode uses, so prefill-then-decode
    /// equals pure decode bit for bit, on an empty **or** partially
    /// filled slot.
    ///
    /// The final position's logits land in
    /// `scratch.step.logits[..vocab]`; overflow events are accumulated
    /// into `ovf`.
    pub fn prefill_slot_scratch(
        &self,
        tokens: &[u16],
        slot: usize,
        arena: &mut KvArena,
        ovf: &mut u64,
        scratch: &mut DecodeScratch,
    ) {
        assert!(!tokens.is_empty());
        assert!(
            arena.len(slot) + tokens.len() <= arena.max_seq,
            "prompt longer than the context window"
        );
        let group = [RowGroup { slot, start: 0, len: tokens.len() }];
        let mut g_ovf = [0u64; 1];
        self.decode_step_ragged_scratch(tokens, &group, arena, &mut g_ovf, scratch);
        *ovf += g_ovf[0];
    }

    /// Prefill a whole prompt through a single-sequence cache.
    pub fn prefill(&self, tokens: &[u16], cache: &mut KvCache) -> Vec<f32> {
        self.prefill_slot(tokens, 0, &mut cache.arena)
    }

    /// Longest servable prompt suffix: the last `max_seq - 1` tokens,
    /// so prefill plus one decode step always fit the window. Shared by
    /// every serving path so clipping stays in lockstep with
    /// [`Transformer::generate_greedy`].
    pub fn clip_to_window(&self, prompt: &[u16]) -> Vec<u16> {
        let max_seq = self.cfg.max_seq;
        if prompt.len() >= max_seq {
            prompt[prompt.len() - (max_seq - 1)..].to_vec()
        } else {
            prompt.to_vec()
        }
    }

    /// Context tokens re-encoded when a full sequence slides its
    /// window — the single source of truth for the slide, which every
    /// decode path must share for token-exact parity.
    pub fn slide_keep(&self) -> usize {
        self.cfg.max_seq / 2
    }

    /// Greedy generation: prompt → `n` new tokens (f32 KV cache).
    pub fn generate_greedy(&self, prompt: &[u16], n: usize) -> Vec<u16> {
        self.generate_greedy_with(prompt, n, KvCacheKind::F32)
    }

    /// Greedy generation on the chosen KV backend — the sequential
    /// reference continuous-batched serving must reproduce token for
    /// token on that same backend. Runs on the scratch hot path (one
    /// workspace for the whole generation), so the sequential baseline
    /// benches the same kernels the engine serves with.
    pub fn generate_greedy_with(&self, prompt: &[u16], n: usize, kind: KvCacheKind) -> Vec<u16> {
        let mut cache = KvCache::with_kind(self, kind);
        let mut scratch = DecodeScratch::new();
        let vocab = self.cfg.vocab;
        let mut out = prompt.to_vec();
        let mut ovf = 0u64;
        self.prefill_slot_scratch(prompt, 0, &mut cache.arena, &mut ovf, &mut scratch);
        let mut row = [0u64; 1];
        for _ in 0..n {
            if cache.is_full() {
                // slide the window by re-encoding the tail
                let keep = self.slide_keep();
                let tail = out[out.len() - keep..].to_vec();
                cache.clear();
                self.prefill_slot_scratch(&tail, 0, &mut cache.arena, &mut ovf, &mut scratch);
            }
            let next = argmax(&scratch.step.logits[..vocab]) as u16;
            out.push(next);
            row[0] = 0;
            self.decode_step_batch_scratch(&[next], &[0], &mut cache.arena, &mut row, &mut scratch);
        }
        out
    }
}

/// Index of the first maximum — the tie-break every greedy path in this
/// crate must share for token-exact parity across batch shapes.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kvquant::KvQuantSpec;
    use crate::model::{random_transformer, Activation, TransformerConfig};

    fn model(parallel: bool) -> Transformer {
        random_transformer(
            TransformerConfig {
                name: "d".into(),
                vocab: 48,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_seq: 16,
                act: Activation::Gelu,
                parallel_residual: parallel,
            },
            77,
        )
    }

    #[test]
    fn decode_matches_forward() {
        for parallel in [false, true] {
            let m = model(parallel);
            let toks: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6];
            let full = m.forward(&toks, None);
            let vocab = m.cfg.vocab;
            let mut cache = KvCache::new(&m);
            for (t, &tok) in toks.iter().enumerate() {
                let step_logits = m.decode_step(tok, &mut cache);
                let full_row = &full[t * vocab..(t + 1) * vocab];
                for (a, b) in step_logits.iter().zip(full_row.iter()) {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "parallel={parallel} pos={t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn prefill_equals_last_forward_row() {
        let m = model(true);
        let toks: Vec<u16> = vec![1, 2, 3, 4, 5];
        let mut cache = KvCache::new(&m);
        let last = m.prefill(&toks, &mut cache);
        let full = m.forward(&toks, None);
        let vocab = m.cfg.vocab;
        for (a, b) in last.iter().zip(&full[4 * vocab..5 * vocab]) {
            assert!((a - b).abs() < 1e-4);
        }
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn generate_deterministic_and_bounded() {
        let m = model(false);
        let out1 = m.generate_greedy(&[1, 2, 3], 20);
        let out2 = m.generate_greedy(&[1, 2, 3], 20);
        assert_eq!(out1, out2);
        assert_eq!(out1.len(), 23);
        assert!(out1.iter().all(|&t| (t as usize) < 48));
    }

    #[test]
    fn cache_overflow_guard() {
        let m = model(false);
        let mut cache = KvCache::new(&m);
        for t in 0..16 {
            m.decode_step(t as u16 % 48, &mut cache);
        }
        assert!(cache.is_full());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.decode_step(0, &mut cache);
        }));
        assert!(r.is_err(), "decoding past max_seq must panic");
    }

    #[test]
    fn truncate_front_keeps_suffix() {
        let m = model(true);
        let mut cache = KvCache::new(&m);
        for t in 0..8 {
            m.decode_step(t, &mut cache);
        }
        cache.truncate_front(3);
        assert_eq!(cache.len(), 5);
        cache.clear();
        assert!(cache.is_empty());
    }

    /// THE batched-decode parity property: stacking several sequences
    /// into one `decode_step_batch` call must produce, for every
    /// sequence, logits **bit-identical** to decoding it alone through a
    /// single-slot cache — on both KV backends.
    #[test]
    fn batched_decode_is_bit_exact_vs_single() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            for parallel in [false, true] {
                let m = model(parallel);
                let vocab = m.cfg.vocab;
                let seqs: Vec<Vec<u16>> = vec![
                    vec![3, 1, 4, 1, 5],
                    vec![9, 2, 6, 5, 3],
                    vec![8, 9, 7, 9, 3],
                ];
                // reference: each sequence decoded alone
                let mut want: Vec<Vec<f32>> = Vec::new();
                for s in &seqs {
                    let mut cache = KvCache::with_kind(&m, kind);
                    let mut last = Vec::new();
                    for &t in s {
                        last = m.decode_step(t, &mut cache);
                    }
                    want.push(last);
                }
                // batched: all three in one arena, one step per position,
                // one shared scratch workspace across every step
                let mut arena = KvArena::with_kind(&m, 3, kind);
                let slots: Vec<usize> = (0..3).map(|_| arena.alloc().unwrap()).collect();
                let mut scratch = DecodeScratch::new();
                let mut row_ovf = vec![0u64; 3];
                for pos in 0..seqs[0].len() {
                    let toks: Vec<u16> = seqs.iter().map(|s| s[pos]).collect();
                    row_ovf.iter_mut().for_each(|v| *v = 0);
                    m.decode_step_batch_scratch(
                        &toks,
                        &slots,
                        &mut arena,
                        &mut row_ovf,
                        &mut scratch,
                    );
                }
                let got = &scratch.step.logits[..3 * vocab];
                for (b, w) in want.iter().enumerate() {
                    assert_eq!(
                        &got[b * vocab..(b + 1) * vocab],
                        &w[..],
                        "kind={kind:?} parallel={parallel} seq {b} diverged under batching"
                    );
                }
            }
        }
    }

    /// Ragged batches: sequences of different lengths share steps, and a
    /// late joiner admitted mid-flight stays bit-exact.
    #[test]
    fn ragged_batch_with_late_join_is_exact() {
        let m = model(false);
        let vocab = m.cfg.vocab;
        let a: Vec<u16> = vec![1, 2, 3, 4, 5, 6, 7];
        let b: Vec<u16> = vec![11, 12, 13];
        // reference
        let seq_logits = |s: &[u16]| {
            let mut cache = KvCache::new(&m);
            let mut last = Vec::new();
            for &t in s {
                last = m.decode_step(t, &mut cache);
            }
            last
        };
        let want_a = seq_logits(&a);
        let want_b = seq_logits(&b);
        // batched: a decodes alone for 4 steps, then b joins (prefill
        // would be the serving path; token steps exercise raggedness)
        let mut arena = KvArena::new(&m, 2);
        let sa = arena.alloc().unwrap();
        let mut got_a = Vec::new();
        for &t in &a[..4] {
            got_a = m.decode_step_batch(&[t], &[sa], &mut arena);
        }
        let sb = arena.alloc().unwrap();
        for i in 0..3 {
            let logits = m.decode_step_batch(&[a[4 + i], b[i]], &[sa, sb], &mut arena);
            got_a = logits[..vocab].to_vec();
            if i == 2 {
                assert_eq!(&logits[vocab..], &want_b[..], "late joiner diverged");
            }
        }
        assert_eq!(got_a, want_a, "long-running sequence diverged");
    }

    #[test]
    fn arena_slot_reuse_after_release() {
        let m = model(true);
        let mut arena = KvArena::new(&m, 2);
        let s0 = arena.alloc().unwrap();
        let s1 = arena.alloc().unwrap();
        assert!(arena.alloc().is_none(), "over-allocation must fail");
        m.decode_step_batch(&[5, 6], &[s0, s1], &mut arena);
        m.decode_step_batch(&[7], &[s0], &mut arena);
        assert_eq!(arena.len(s0), 2);
        assert_eq!(arena.len(s1), 1);
        // retire s0; the slot comes back empty and decodes a fresh
        // sequence bit-exactly
        arena.release(s0);
        assert_eq!(arena.free_slots(), 1);
        let s2 = arena.alloc().unwrap();
        assert_eq!(s2, s0, "LIFO free list must reuse the retired slot");
        assert_eq!(arena.len(s2), 0);
        let got = m.decode_step_batch(&[9], &[s2], &mut arena);
        let mut cache = KvCache::new(&m);
        let want = m.decode_step(9, &mut cache);
        assert_eq!(got, want, "reused slot must behave like a fresh cache");
        // the surviving slot was untouched by the reuse
        assert_eq!(arena.len(s1), 1);
    }

    #[test]
    fn arena_guards() {
        let m = model(false);
        let mut arena = KvArena::new(&m, 2);
        let s = arena.alloc().unwrap();
        // scheduling a free slot panics
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a2 = arena.clone();
            m.decode_step_batch(&[1], &[s + 1], &mut a2);
        }));
        assert!(r.is_err(), "free slot must be rejected");
        // mismatched tokens/slots panics
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a2 = arena.clone();
            m.decode_step_batch(&[1, 2], &[s], &mut a2);
        }));
        assert!(r.is_err(), "token/slot length mismatch must be rejected");
    }

    #[test]
    fn arena_bytes_and_footprint_agree() {
        let m = model(false);
        for kind in [
            KvCacheKind::F32,
            KvCacheKind::Quant(KvQuantSpec::int8()),
            KvCacheKind::Quant(KvQuantSpec::int16()),
        ] {
            let arena = KvArena::with_kind(&m, 3, kind);
            assert_eq!(
                arena.bytes(),
                KvArena::footprint(&m.cfg, 3, kind),
                "{kind:?} footprint formula disagrees with the arena"
            );
        }
        // i8 codes shrink the arena; the exact ≤30% bar (wide heads) is
        // asserted in tests/kvquant_decode.rs
        let f = KvArena::footprint(&m.cfg, 4, KvCacheKind::F32);
        let q = KvArena::footprint(&m.cfg, 4, KvCacheKind::Quant(KvQuantSpec::int8()));
        assert!(q < f / 2, "quantized arena must at least halve f32 ({q} vs {f})");
    }

    #[test]
    fn quant_prefill_matches_quant_decode() {
        // On the quantized backend, batched prefill must be bit-exact
        // with token-by-token decode — both attend over the same codes.
        let m = model(true);
        let kind = KvCacheKind::Quant(KvQuantSpec::int8());
        let toks: Vec<u16> = vec![4, 7, 1, 9, 2, 8];
        let mut c1 = KvCache::with_kind(&m, kind);
        let batched = m.prefill(&toks, &mut c1);
        let mut c2 = KvCache::with_kind(&m, kind);
        let mut step = Vec::new();
        for &t in &toks {
            step = m.decode_step(t, &mut c2);
        }
        assert_eq!(batched, step, "quant prefill diverged from quant decode");
        assert_eq!(c1.len(), toks.len());
        // cached rows identical too (codes + scales, via dequant view)
        for layer in 0..m.cfg.n_layers {
            for pos in 0..toks.len() {
                assert_eq!(
                    c1.arena.kv_row(layer, 0, pos),
                    c2.arena.kv_row(layer, 0, pos),
                    "layer {layer} pos {pos}"
                );
            }
        }
    }

    /// THE chunked-prefill kernel property: splitting a prompt into
    /// arbitrary chunks across successive ragged steps must produce the
    /// same cached K/V rows and the same final logits as one-shot
    /// prefill — bit for bit, on both backends.
    #[test]
    fn chunked_prefill_matches_whole_prefill() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            for parallel in [false, true] {
                let m = model(parallel);
                let vocab = m.cfg.vocab;
                let prompt: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5];
                // reference: whole-prompt prefill
                let mut arena_w = KvArena::with_kind(&m, 1, kind);
                let sw = arena_w.alloc().unwrap();
                let mut ovf_w = 0u64;
                let want = m.prefill_slot_counted(&prompt, sw, &mut arena_w, &mut ovf_w);
                for chunks in [&[1usize, 7, 3][..], &[4, 4, 3], &[11], &[1; 11]] {
                    let mut arena = KvArena::with_kind(&m, 1, kind);
                    let slot = arena.alloc().unwrap();
                    let mut scratch = DecodeScratch::new();
                    let mut ovf = 0u64;
                    let mut at = 0usize;
                    for &c in chunks {
                        let group = [RowGroup { slot, start: 0, len: c }];
                        let mut g_ovf = [0u64; 1];
                        m.decode_step_ragged_scratch(
                            &prompt[at..at + c],
                            &group,
                            &mut arena,
                            &mut g_ovf,
                            &mut scratch,
                        );
                        ovf += g_ovf[0];
                        at += c;
                    }
                    assert_eq!(
                        &scratch.step.logits[..vocab],
                        &want[..],
                        "kind={kind:?} parallel={parallel} chunks={chunks:?}: logits diverge"
                    );
                    assert_eq!(ovf, ovf_w, "chunked overflow attribution diverges");
                    for layer in 0..m.cfg.n_layers {
                        for pos in 0..prompt.len() {
                            assert_eq!(
                                arena.kv_row(layer, slot, pos),
                                arena_w.kv_row(layer, sw, pos),
                                "layer {layer} pos {pos} cached rows diverge"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Mixing a prefill chunk with decode rows in ONE ragged step must
    /// leave every sequence bit-identical to running it alone — the
    /// interleaved-admission invariant the chunked serving engine
    /// rests on.
    #[test]
    fn mixed_chunk_and_decode_step_is_exact() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            let m = model(false);
            let vocab = m.cfg.vocab;
            let decode_seq: Vec<u16> = vec![1, 2, 3, 4, 5];
            let chunk_prompt: Vec<u16> = vec![11, 12, 13, 14];
            // references: each sequence alone
            let mut solo = KvCache::with_kind(&m, kind);
            let mut want_dec = Vec::new();
            for &t in &decode_seq {
                want_dec = m.decode_step(t, &mut solo);
            }
            let mut arena_p = KvArena::with_kind(&m, 1, kind);
            let sp = arena_p.alloc().unwrap();
            let want_chunk = m.prefill_slot(&chunk_prompt, sp, &mut arena_p);
            // mixed: sequence A decodes 4 tokens, then its 5th decode row
            // shares a ragged step with B's whole prompt as one chunk
            let mut arena = KvArena::with_kind(&m, 2, kind);
            let sa = arena.alloc().unwrap();
            let sb = arena.alloc().unwrap();
            let mut scratch = DecodeScratch::new();
            let mut row = [0u64; 1];
            for &t in &decode_seq[..4] {
                row[0] = 0;
                m.decode_step_batch_scratch(&[t], &[sa], &mut arena, &mut row, &mut scratch);
            }
            let mut tokens = vec![decode_seq[4]];
            tokens.extend_from_slice(&chunk_prompt);
            let groups = [
                RowGroup { slot: sa, start: 0, len: 1 },
                RowGroup { slot: sb, start: 1, len: chunk_prompt.len() },
            ];
            let mut g_ovf = [0u64; 2];
            m.decode_step_ragged_scratch(&tokens, &groups, &mut arena, &mut g_ovf, &mut scratch);
            assert_eq!(
                &scratch.step.logits[..vocab],
                &want_dec[..],
                "kind={kind:?}: decode row diverged when sharing a step with a chunk"
            );
            assert_eq!(
                &scratch.step.logits[vocab..2 * vocab],
                &want_chunk[..],
                "kind={kind:?}: chunk logits diverged when sharing a step with decode rows"
            );
            assert_eq!(arena.len(sa), 5);
            assert_eq!(arena.len(sb), chunk_prompt.len());
            for layer in 0..m.cfg.n_layers {
                for pos in 0..chunk_prompt.len() {
                    assert_eq!(
                        arena.kv_row(layer, sb, pos),
                        arena_p.kv_row(layer, sp, pos),
                        "kind={kind:?} layer {layer} pos {pos}"
                    );
                }
            }
        }
    }

    /// Ragged-step guards: malformed group lists must be rejected.
    #[test]
    fn ragged_step_guards() {
        let m = model(false);
        let arena = KvArena::new(&m, 2);
        // groups must tile the token slice
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a = arena.clone();
            let s = a.alloc().unwrap();
            let groups = [RowGroup { slot: s, start: 1, len: 1 }];
            let mut scratch = DecodeScratch::new();
            m.decode_step_ragged_scratch(&[1, 2], &groups, &mut a, &mut [0], &mut scratch);
        }));
        assert!(r.is_err(), "a gap before the first group must be rejected");
        // a chunk past the window must be rejected
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a = arena.clone();
            let s = a.alloc().unwrap();
            let toks: Vec<u16> = (0..17).map(|i| i as u16).collect();
            let groups = [RowGroup { slot: s, start: 0, len: 17 }];
            let mut scratch = DecodeScratch::new();
            m.decode_step_ragged_scratch(&toks, &groups, &mut a, &mut [0], &mut scratch);
        }));
        assert!(r.is_err(), "a chunk past the window must be rejected");
        // one slot in two groups must be rejected
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a = arena.clone();
            let s = a.alloc().unwrap();
            let groups = [
                RowGroup { slot: s, start: 0, len: 1 },
                RowGroup { slot: s, start: 1, len: 1 },
            ];
            let mut scratch = DecodeScratch::new();
            m.decode_step_ragged_scratch(&[1, 2], &groups, &mut a, &mut [0, 0], &mut scratch);
        }));
        assert!(r.is_err(), "one slot in two groups must be rejected");
    }

    /// Unified accounting: attention overflow events on the quantized
    /// backend land on the model-wide `Transformer::overflow_events`
    /// counter (next to quantized-linear events) AND in the per-row
    /// attribution — one number for eval and serve.
    #[test]
    fn attention_overflows_join_the_model_counter() {
        let m = model(false); // float linears: only attention can overflow
        let kind = KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6))); // hopeless width
        let mut arena = KvArena::with_kind(&m, 1, kind);
        let slot = arena.alloc().unwrap();
        let before = m.overflow_events();
        assert_eq!(m.attention_overflow_events(), 0);
        let mut attributed = 0u64;
        let mut row = vec![0u64; 1];
        for t in 0..6u16 {
            row[0] = 0;
            m.decode_step_batch_counted(&[t % 48], &[slot], &mut arena, &mut row);
            attributed += row[0];
        }
        assert!(attributed > 0, "the narrow attention register must overflow");
        assert_eq!(
            m.overflow_events() - before,
            attributed,
            "model-wide counter must equal the attributed attention events"
        );
        assert_eq!(m.attention_overflow_events(), attributed);
    }
}
