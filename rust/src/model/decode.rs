//! Incremental decoding over a multi-sequence **paged** KV arena.
//!
//! `forward()` recomputes the whole prefix per step — fine for PPL
//! evaluation, quadratic-per-token for serving. The KV structures here
//! store each block's projected keys/values so one decode step costs
//! O(seq · d) attention instead of O(seq² · d) recompute.
//!
//! The serving engine decodes **many sequences per kernel call**:
//! [`KvArena`] holds a fixed number of slots (one in-flight sequence
//! each, with independent lengths), and
//! [`Transformer::decode_step_ragged_scratch`] stacks a [`RowGroup`]
//! per scheduled slot — a 1-row decode step or a multi-row **prefill
//! chunk**, mixed freely in one call — into one batched linear call
//! per layer, so quantized layers amortize the fused qgemm kernel
//! across decode rows *and* admission prefill chunks at once.
//! Attention stays ragged: each group attends over its own slot's
//! cached positions (plus its own just-appended chunk rows, causally)
//! only. [`Transformer::decode_step_batch_scratch`] is the
//! all-1-row-groups wrapper; [`Transformer::prefill_slot_scratch`] the
//! single-group one.
//!
//! **Paged storage.** A slot no longer owns a contiguous
//! `[max_seq × d]` region: K/V live in fixed-size pages
//! ([`super::paging`]) drawn from one [`PagePool`], and each slot holds
//! a page *table* (plus an in-page head offset after window slides).
//! Appends write into the slot's open tail page at its high-water
//! position, so a **full** page is immutable from the moment its last
//! row lands — on the quantized backend the codes and bf16 scales are
//! written exactly once (quantize-at-append), which makes a full page
//! bit-identical for every reader. That immutability is what the
//! shared-prefix machinery rests on: [`KvArena::register_prefix`] files
//! a slot's full position-0-aligned pages in a content-addressed
//! [`PrefixCache`], and [`KvArena::adopt_prefix`] maps already-encoded
//! pages read-only into a fresh slot's table (a refcount bump — the
//! "copy" in copy-on-write never happens because the open tail page is
//! always private), so admission prefill skips straight to the unshared
//! tail. `truncate_front` window slides become head-page drops
//! (refcount decrements) instead of `copy_within` memmoves. All
//! position → (page, offset) resolution happens at the attention-gather
//! / append boundary through a borrowed [`PageMap`]; per-page inner
//! loops stay contiguous, so the zero-allocation and safe-tile fast
//! paths survive the indirection (page allocation itself is a free-list
//! pop).
//!
//! The `_scratch` entry points are the hot path: every operand buffer
//! (activations, quantized codes, attention panels, overflow counters,
//! logits) lives in a caller-owned [`super::DecodeScratch`] workspace,
//! so a steady-state decode step performs **zero heap allocations**
//! (`tests/zero_alloc_decode.rs` asserts this with a counting global
//! allocator; the guarantee covers kernel calls below the
//! band-threading work threshold — a batched call large enough to fan
//! out to scoped threads allocates for the spawns, by design). The
//! serving engine owns one workspace per engine thread and reuses it
//! across admissions, steps and slides; the non-scratch wrappers
//! (`decode_step_batch`, `prefill_slot`, …) build a transient
//! workspace and exist for tests and one-shot callers.
//!
//! The arena runs on one of two **backends** ([`KvCacheKind`]): plain
//! f32 keys/values with float attention, or the accumulator-aware
//! quantized store ([`super::kvquant`]) — narrow integer codes with
//! per-(page, offset, head) bf16 scales, quantized once at append
//! time, with both attention matmuls executed on the multi-stage
//! integer datapath ([`super::layers::attend_one_query_quant`], fed by
//! the slab-resolved bulk gathers). Every decode entry point dispatches
//! internally, so callers pick a backend at arena construction and
//! nothing else changes.
//!
//! The single-sequence [`KvCache`] is a thin 1-slot arena view, and
//! `decode_step`/`prefill` delegate to the batched path, so sequential
//! decode (`generate_greedy`) and continuous-batched serving run the
//! **same arithmetic per row** — batched decode is token-exact versus
//! sequential decode on either backend (tested here and in
//! `coordinator::serve`). This relies on every row of a batched kernel
//! being computed independently of its batchmates (true of
//! `linalg::qgemm`, the banded f64 GEMM, and the per-slot quantized
//! attention).
//!
//! Overflow accounting is **unified and page-aware**: the
//! `_counted`/`_scratch` variants attribute integer-datapath overflow
//! events (linear layers and quantized attention) to the row / request
//! that produced them, attention events additionally land on the
//! model-wide [`Transformer::overflow_events`] counter, and each row's
//! fill-time events are *also* recorded on the page holding that row
//! ([`PagePool::record_ovf`]). A sequence adopting a shared page
//! credits the page's stored events instead of re-incurring them —
//! that, plus determinism and the chunking invariance of per-row
//! events, is exactly what keeps per-request overflow counts
//! bit-identical with prefix sharing on vs off. (The LM head is a
//! float linear and contributes no events, so per-row body events are
//! the complete record.)

use super::kvquant::{KvCacheKind, QuantKv};
use super::layers::{attend_chunk_quant, attend_chunk_rows, KvRows};
use super::paging::{PageMap, PagePool, PrefixCache, DEFAULT_KV_PAGE, NO_PREFIX};
use super::scratch::{AttnScratch, DecodeScratch};
use super::transformer::{Transformer, TransformerConfig};

/// One **row group** of a ragged decode step: `len` consecutive rows of
/// the step's flat token slice (starting at `start`), appended to
/// `slot` at consecutive positions beginning at the slot's current
/// length. A decode row is a 1-row group; a prefill chunk is a
/// multi-row group. Groups tile the token slice in order and name
/// pairwise-distinct slots.
#[derive(Clone, Copy, Debug)]
pub struct RowGroup {
    /// Arena slot the group's rows are appended to.
    pub slot: usize,
    /// First row of the group in the step's flat token slice.
    pub start: usize,
    /// Number of consecutive rows (≥ 1).
    pub len: usize,
}

/// Which rows of a ragged step produce logits (see
/// [`Transformer::decode_step_ragged_opts`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LogitRows {
    /// One logits row per **group**, from its last row — the only row a
    /// non-speculative scheduler can ever sample from. The default, and
    /// the shape every pre-speculative caller sees.
    #[default]
    GroupLast,
    /// One logits row per **step row** — the speculative verify shape:
    /// acceptance needs the full-width distribution at every chunk
    /// position, not just the last. Logits land row-major in
    /// `scratch.step.logits[..n * vocab]`, `n` the step's row count.
    All,
}

/// Knobs of the ragged step that the speculative engine varies per
/// call. [`RaggedOpts::standard`] reproduces
/// [`Transformer::decode_step_ragged_scratch`] exactly — same logits
/// layout, full-width registers, fill-time page attribution on.
#[derive(Clone, Copy, Debug)]
pub struct RaggedOpts {
    /// Logit-row layout.
    pub logits: LogitRows,
    /// Narrow every integer datapath (quantized linears and
    /// quantized-KV attention) to at most this many inner-register
    /// bits — the self-speculative **draft** configuration: same
    /// weights, same codes, narrower accumulators. `None` runs the
    /// layers' own widths.
    pub draft_bits: Option<u32>,
    /// Record fill-time overflow events onto the pages holding the
    /// appended rows (the ledger prefix adoption credits from). Draft
    /// steps pass `false`: their K/V rows are rolled back before the
    /// verify re-encodes those positions full-width, and they must
    /// leave no trace in any page ledger.
    pub record_fill: bool,
}

impl Default for RaggedOpts {
    fn default() -> Self {
        RaggedOpts::standard()
    }
}

impl RaggedOpts {
    /// The non-speculative shape: group-last logits, stored register
    /// widths, fill attribution on.
    pub fn standard() -> RaggedOpts {
        RaggedOpts { logits: LogitRows::GroupLast, draft_bits: None, record_fill: true }
    }

    /// The speculative draft shape: group-last logits on registers
    /// narrowed to at most `bits` (`None` = stored widths — a
    /// same-width "draft" that the verify accepts in full), with page
    /// ledgers untouched because every draft append is rolled back.
    pub fn draft(bits: Option<u32>) -> RaggedOpts {
        RaggedOpts { logits: LogitRows::GroupLast, draft_bits: bits, record_fill: false }
    }

    /// The speculative verify shape: full-width registers, one logits
    /// row per step row so acceptance can compare every chunk position.
    pub fn verify() -> RaggedOpts {
        RaggedOpts { logits: LogitRows::All, draft_bits: None, record_fill: true }
    }
}

/// Multi-sequence key/value arena over a fixed [`PagePool`]: `slots`
/// independent sequences, each holding a table of refcounted fixed-size
/// pages. Slots are allocated at admission, reused after retirement,
/// and slide their window independently (via [`KvArena::reset_slot`] +
/// re-prefill, the absolute-position re-encode the single-sequence path
/// uses — which keeps slid tails position-0-aligned and therefore
/// shareable). Full prefix pages can be shared across slots through the
/// built-in [`PrefixCache`] ([`KvArena::register_prefix`] /
/// [`KvArena::adopt_prefix`]).
#[derive(Clone, Debug)]
pub struct KvArena {
    store: KvStore,
    d: usize,
    max_seq: usize,
    slots: usize,
    /// Positions per page (clamped to `1..=max_seq` at construction).
    page_size: usize,
    /// Refcounts + free list + per-page overflow attribution.
    pool: PagePool,
    /// Per-slot page table (physical page ids), pre-reserved to the
    /// per-slot maximum so table growth never touches the heap.
    tables: Vec<Vec<u32>>,
    /// Per-slot in-page offset of logical position 0 (nonzero only
    /// after a `truncate_front` that lands mid-page).
    heads: Vec<usize>,
    /// Per-slot cached length.
    lens: Vec<usize>,
    /// Per-slot liveness (allocated to a sequence).
    live: Vec<bool>,
    /// LIFO free list of slot ids.
    free: Vec<usize>,
    /// Whether the slot's pages encode a position-0-aligned prefix
    /// (false after `truncate_front`, which shifts absolute positions).
    shareable: Vec<bool>,
    /// How many of the slot's leading pages are already in the cache.
    registered: Vec<usize>,
    /// Prefix-chain anchor: cache entry id of the slot's last
    /// registered/adopted page ([`NO_PREFIX`] at the chain root).
    chain: Vec<u32>,
    /// Content-addressed index of shareable full pages.
    cache: PrefixCache,
    /// High-water mark of resident pages (capacity-planning signal).
    peak_pages: usize,
    /// Full pages mapped read-only via [`KvArena::adopt_prefix`].
    pages_adopted: u64,
    /// Times allocation pressure flushed the prefix cache.
    cache_flushes: u64,
    /// Private pages remapped onto an already-cached twin at
    /// registration (late dedup of concurrent same-prefix admissions).
    pages_deduped: u64,
    /// Unreferenced cache entries evicted individually under allocation
    /// pressure (oldest-first; see [`KvArena::ensure_capacity`]).
    cache_evictions: u64,
}

/// Backend storage of the arena (see [`KvCacheKind`]). Payload is
/// indexed by **physical page id**; which pages form a sequence is the
/// arena's page tables' business.
#[derive(Clone, Debug)]
enum KvStore {
    F32 {
        /// [layer][(page * page_size + off) * d ..] cached keys.
        k: Vec<Vec<f32>>,
        /// [layer][(page * page_size + off) * d ..] cached values.
        v: Vec<Vec<f32>>,
    },
    Quant(QuantKv),
}

/// Paged f32 K/V rows of one slot at one layer — the float backend's
/// single position → (page, offset) resolution point, fed to the
/// row-resolved float attention ([`attend_chunk_rows`]).
struct PagedKvRows<'a> {
    k: &'a [f32],
    v: &'a [f32],
    map: PageMap<'a>,
    d: usize,
}

impl KvRows for PagedKvRows<'_> {
    #[inline]
    fn k_row(&self, pos: usize) -> &[f32] {
        let (pg, off) = self.map.locate(pos);
        let at = (pg * self.map.page_size() + off) * self.d;
        &self.k[at..at + self.d]
    }

    #[inline]
    fn v_row(&self, pos: usize) -> &[f32] {
        let (pg, off) = self.map.locate(pos);
        let at = (pg * self.map.page_size() + off) * self.d;
        &self.v[at..at + self.d]
    }
}

impl KvArena {
    /// Arena with `slots` sequence slots, all free, on the f32 backend.
    pub fn new(model: &Transformer, slots: usize) -> KvArena {
        KvArena::with_kind(model, slots, KvCacheKind::F32)
    }

    /// Arena with `slots` sequence slots on the chosen backend, at the
    /// default page size ([`DEFAULT_KV_PAGE`]).
    pub fn with_kind(model: &Transformer, slots: usize, kind: KvCacheKind) -> KvArena {
        KvArena::with_kind_paged(model, slots, kind, DEFAULT_KV_PAGE)
    }

    /// Arena with an explicit page size (`--kv-page`; clamped to
    /// `1..=max_seq`). The pool holds `slots × pages_per_slot` pages —
    /// enough for every slot to be simultaneously full even with a
    /// mid-page head offset — so sequences can always make progress
    /// with sharing off, and sharing only ever *frees* headroom.
    pub fn with_kind_paged(
        model: &Transformer,
        slots: usize,
        kind: KvCacheKind,
        page_size: usize,
    ) -> KvArena {
        assert!(slots >= 1, "arena needs at least one slot");
        let d = model.cfg.d_model;
        let max_seq = model.cfg.max_seq;
        let n_layers = model.cfg.n_layers;
        let page_size = page_size.clamp(1, max_seq);
        let pps = KvArena::pages_per_slot(max_seq, page_size);
        let n_pages = slots * pps;
        let store = match kind {
            KvCacheKind::F32 => KvStore::F32 {
                k: vec![vec![0.0; n_pages * page_size * d]; n_layers],
                v: vec![vec![0.0; n_pages * page_size * d]; n_layers],
            },
            KvCacheKind::Quant(spec) => KvStore::Quant(QuantKv::new(
                spec,
                n_layers,
                n_pages,
                page_size,
                d,
                model.cfg.n_heads,
            )),
        };
        KvArena {
            store,
            d,
            max_seq,
            slots,
            page_size,
            pool: PagePool::new(page_size, n_pages),
            tables: (0..slots).map(|_| Vec::with_capacity(pps)).collect(),
            heads: vec![0; slots],
            lens: vec![0; slots],
            live: vec![false; slots],
            free: (0..slots).rev().collect(),
            shareable: vec![true; slots],
            registered: vec![0; slots],
            chain: vec![NO_PREFIX; slots],
            cache: PrefixCache::new(),
            peak_pages: 0,
            pages_adopted: 0,
            cache_flushes: 0,
            pages_deduped: 0,
            cache_evictions: 0,
        }
    }

    /// Pages one slot may need at worst: a slid slot carries a head
    /// offset `< page_size`, so its table can span one page more than
    /// `ceil(max_seq / page_size)`.
    fn pages_per_slot(max_seq: usize, page_size: usize) -> usize {
        (max_seq + page_size - 1) / page_size + 1
    }

    /// Which backend this arena runs on.
    pub fn kind(&self) -> KvCacheKind {
        match &self.store {
            KvStore::F32 { .. } => KvCacheKind::F32,
            KvStore::Quant(q) => KvCacheKind::Quant(q.spec),
        }
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Payload bytes of one page (K + V, codes/rows + scales, all
    /// layers) — the unit of resident accounting.
    fn page_payload_bytes(&self) -> usize {
        match &self.store {
            KvStore::F32 { k, .. } => 2 * k.len() * self.page_size * self.d * 4,
            KvStore::Quant(q) => q.page_bytes(),
        }
    }

    /// Bookkeeping bytes resident regardless of occupancy: pool
    /// refcounts/free-list/attribution plus each slot's reserved page
    /// table and head/len words.
    fn meta_bytes(&self) -> usize {
        self.pool.meta_bytes()
            + self.slots * (KvArena::pages_per_slot(self.max_seq, self.page_size) * 4 + 2 * 8)
    }

    /// **Resident** KV bytes: live pages counted once each — pages
    /// shared across slots are deduplicated by construction — plus page
    /// tables, pool bookkeeping, and prefix-cache metadata. This is the
    /// serving-memory figure the quantized backend and prefix sharing
    /// exist to shrink.
    pub fn bytes(&self) -> usize {
        self.pool.allocated() * self.page_payload_bytes()
            + self.meta_bytes()
            + self.cache.meta_bytes()
    }

    /// Bytes the arena reserves up front (every page backed, tables at
    /// capacity) — equals [`KvArena::footprint_paged`] for this
    /// geometry.
    pub fn capacity_bytes(&self) -> usize {
        self.pool.n_pages() * self.page_payload_bytes() + self.meta_bytes()
    }

    /// High-water resident bytes since construction.
    pub fn peak_bytes(&self) -> usize {
        self.peak_pages * self.page_payload_bytes() + self.meta_bytes()
    }

    /// Pages currently resident (refcounted by a table or the cache).
    pub fn resident_pages(&self) -> usize {
        self.pool.allocated()
    }

    /// Pages on the pool's free list — the complement of
    /// [`KvArena::resident_pages`] (release/cancellation accounting).
    pub fn free_pages(&self) -> usize {
        self.pool.free_pages()
    }

    /// Full pages mapped read-only into slots via prefix adoption.
    pub fn pages_shared(&self) -> u64 {
        self.pages_adopted
    }

    /// Entries (full pages) currently in the prefix cache.
    pub fn prefix_cache_pages(&self) -> usize {
        self.cache.len()
    }

    /// Times allocation pressure flushed the prefix cache.
    pub fn cache_flushes(&self) -> u64 {
        self.cache_flushes
    }

    /// Private pages remapped onto an already-cached twin at
    /// registration — each one deduplicated a concurrent same-prefix
    /// admission after the fact.
    pub fn pages_deduped(&self) -> u64 {
        self.pages_deduped
    }

    /// Unreferenced prefix-cache entries evicted under allocation
    /// pressure (oldest-first), keeping still-referenced entries — hot
    /// system prompts — resident.
    pub fn cache_evictions(&self) -> u64 {
        self.cache_evictions
    }

    /// Reserved storage of an arena with `slots` slots for this model
    /// config on the given backend at the default page size, without
    /// building it — lets reports compare f32 vs quantized footprints
    /// cheaply. Includes page-table/refcount metadata (satellite of the
    /// paged refactor: the comparison stays honest under sharing).
    pub fn footprint(cfg: &TransformerConfig, slots: usize, kind: KvCacheKind) -> usize {
        KvArena::footprint_paged(cfg, slots, kind, DEFAULT_KV_PAGE)
    }

    /// [`KvArena::footprint`] at an explicit page size. Quantized scales
    /// are bf16-packed: 2 bytes per (position, head) per tensor.
    pub fn footprint_paged(
        cfg: &TransformerConfig,
        slots: usize,
        kind: KvCacheKind,
        page_size: usize,
    ) -> usize {
        let ps = page_size.clamp(1, cfg.max_seq);
        let pps = KvArena::pages_per_slot(cfg.max_seq, ps);
        let n_pages = slots * pps;
        let per_page = match kind {
            KvCacheKind::F32 => 2 * cfg.n_layers * ps * cfg.d_model * 4,
            KvCacheKind::Quant(spec) => {
                2 * cfg.n_layers * ps * (cfg.d_model * spec.code_bytes() + cfg.n_heads * 2)
            }
        };
        n_pages * per_page + n_pages * (4 + 4 + 8) + slots * (pps * 4 + 2 * 8)
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Claim a free slot (length 0, empty table), or `None` when all
    /// are in flight.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(self.tables[slot].is_empty() && self.heads[slot] == 0);
        self.lens[slot] = 0;
        self.live[slot] = true;
        Some(slot)
    }

    /// Retire a sequence: every page reference is dropped (shared pages
    /// survive under their other holders) and the slot becomes reusable
    /// immediately.
    pub fn release(&mut self, slot: usize) {
        assert!(self.live[slot], "releasing a free slot");
        self.drop_pages(slot);
        self.live[slot] = false;
        self.lens[slot] = 0;
        self.free.push(slot);
    }

    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.lens[slot] == 0
    }

    pub fn is_full(&self, slot: usize) -> bool {
        self.lens[slot] >= self.max_seq
    }

    /// Drop every page reference a slot holds and reset its sharing
    /// state to the fresh-sequence shape. Pages return through the
    /// pool's free list within its original capacity — no heap traffic.
    fn drop_pages(&mut self, slot: usize) {
        let KvArena { tables, pool, heads, shareable, registered, chain, .. } = self;
        for &p in tables[slot].iter() {
            pool.unref(p);
        }
        tables[slot].clear();
        heads[slot] = 0;
        shareable[slot] = true;
        registered[slot] = 0;
        chain[slot] = NO_PREFIX;
    }

    /// Drop a slot's cached positions (window-slide: clear, then
    /// re-prefill the kept tail so absolute positions are re-encoded —
    /// which keeps the slid tail position-0-aligned and therefore
    /// eligible for prefix sharing).
    pub fn reset_slot(&mut self, slot: usize) {
        assert!(self.live[slot], "resetting a free slot");
        self.drop_pages(slot);
        self.lens[slot] = 0;
    }

    /// Drop the oldest `n` positions of one slot (sliding-window
    /// generation without re-encoding) — now a page-table operation:
    /// whole head pages are unreferenced (a refcount decrement, no
    /// memmove; data never moves, so repeated slides cannot accumulate
    /// drift) and a sub-page remainder becomes the slot's head offset.
    /// NOTE: positional embeddings are absolute, so after sliding the
    /// model sees shifted positions; the slot therefore drops out of
    /// prefix registration until it is reset (its pages no longer
    /// encode a position-0-aligned prefix).
    pub fn truncate_front(&mut self, slot: usize, n: usize) {
        let n = n.min(self.lens[slot]);
        if n == 0 {
            return;
        }
        self.heads[slot] += n;
        self.lens[slot] -= n;
        let drop = self.heads[slot] / self.page_size;
        for _ in 0..drop {
            let page = self.tables[slot].remove(0);
            self.pool.unref(page);
        }
        self.heads[slot] -= drop * self.page_size;
        self.shareable[slot] = false;
        self.registered[slot] = 0;
        self.chain[slot] = NO_PREFIX;
    }

    /// Roll back the **newest** `n` cached positions of one slot — the
    /// speculative-decode rollback path (draft rows before the verify
    /// re-encodes their positions full-width, rejected verify rows
    /// after acceptance). Strictly the inverse of the appends that grew
    /// the tail: the length shrinks, and pages no longer covered by the
    /// new length pop off the table back to the pool (refcount
    /// decrements — a tail page freshly opened by the rolled-back rows
    /// is freed the moment the rollback crosses its boundary). Nothing
    /// else moves: head offset, sharing state and the surviving pages'
    /// bytes and overflow ledgers are untouched, so a rollback of rows
    /// appended with fill attribution off restores the arena
    /// byte-identically (asserted in `super::paging` tests).
    ///
    /// Registered (prefix-cached) pages can never be cut into: drafts
    /// only ever extend past the verified high-water mark, and the
    /// assert below keeps that invariant load-bearing.
    pub fn truncate_tail(&mut self, slot: usize, n: usize) {
        assert!(self.live[slot], "truncating a free slot");
        let n = n.min(self.lens[slot]);
        if n == 0 {
            return;
        }
        self.lens[slot] -= n;
        assert!(
            self.lens[slot] >= self.registered[slot] * self.page_size,
            "tail rollback cut into prefix-registered pages of slot {slot}"
        );
        let keep = (self.heads[slot] + self.lens[slot] + self.page_size - 1) / self.page_size;
        while self.tables[slot].len() > keep {
            let page = self.tables[slot].pop().expect("table covered the pre-rollback length");
            self.pool.unref(page);
        }
    }

    /// Borrowed position → (page, offset) resolver for one slot.
    fn page_map(&self, slot: usize) -> PageMap<'_> {
        PageMap::new(&self.tables[slot], self.heads[slot], self.page_size)
    }

    /// Cached K/V rows of one position, dequantized on the quantized
    /// backend — the backend-independent inspection hook slide/parity
    /// tests rely on.
    pub fn kv_row(&self, layer: usize, slot: usize, pos: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(pos < self.lens[slot], "position {pos} not cached");
        let map = self.page_map(slot);
        match &self.store {
            KvStore::F32 { k, v } => {
                let (pg, off) = map.locate(pos);
                let at = (pg * self.page_size + off) * self.d;
                (k[layer][at..at + self.d].to_vec(), v[layer][at..at + self.d].to_vec())
            }
            KvStore::Quant(q) => {
                let view = q.slot_view(layer, map);
                (view.dequant_k_row(pos), view.dequant_v_row(pos))
            }
        }
    }

    /// Grow a slot's page table until it covers `new_len` cached
    /// positions. Allocation is a free-list pop; on exhaustion,
    /// **unreferenced** prefix-cache entries (held by the cache alone —
    /// no live table maps them) are evicted oldest-first until a page
    /// frees, so entries still adopted by in-flight sequences — hot
    /// system prompts — stay resident under churn. The pool is sized so
    /// that live slots alone can never exhaust it, so an evictable
    /// entry always exists under pressure.
    fn ensure_capacity(&mut self, slot: usize, new_len: usize) {
        let needed = (self.heads[slot] + new_len + self.page_size - 1) / self.page_size;
        while self.tables[slot].len() < needed {
            let page = match self.pool.alloc() {
                Some(p) => p,
                None => loop {
                    assert!(
                        self.cache.evict_oldest_unreferenced(&mut self.pool),
                        "page pool exhausted with no evictable prefix-cache entry"
                    );
                    self.cache_evictions += 1;
                    if let Some(p) = self.pool.alloc() {
                        break p;
                    }
                },
            };
            self.tables[slot].push(page);
        }
        self.peak_pages = self.peak_pages.max(self.pool.allocated());
    }

    /// Drop every prefix-cache entry at once (the blunt instrument —
    /// allocation pressure evicts entry-by-entry instead, see
    /// [`KvArena::ensure_capacity`]; this stays the explicit
    /// full-invalidation API). Pages mapped into live slots survive
    /// under their table refcounts; only future admissions miss. Every
    /// slot's registration chain is restarted — entry ids are dangling
    /// after a flush, and re-inserting a slot's full pages later is
    /// cheap and idempotent.
    pub fn flush_prefix_cache(&mut self) {
        let KvArena { cache, pool, registered, chain, .. } = self;
        cache.flush(|p| pool.unref(p));
        for r in registered.iter_mut() {
            *r = 0;
        }
        for c in chain.iter_mut() {
            *c = NO_PREFIX;
        }
        self.cache_flushes += 1;
    }

    /// Map already-encoded full prefix pages of `tokens` read-only into
    /// a fresh slot's table (refcount bumps — no data is copied or
    /// recomputed). Walks the cache's hash chain page by page as far as
    /// it matches, but always leaves at least one token un-adopted so
    /// the admission still runs a real prefill producing final logits.
    /// Returns `(positions mapped, fill-time overflow events credited)`
    /// — the credited events are exactly what prefilling those
    /// positions would have cost, which keeps per-request overflow
    /// attribution bit-identical with sharing on vs off.
    pub fn adopt_prefix(&mut self, slot: usize, tokens: &[u16]) -> (usize, u64) {
        assert!(
            self.live[slot] && self.lens[slot] == 0 && self.tables[slot].is_empty(),
            "prefix adoption needs a fresh slot"
        );
        let ps = self.page_size;
        let mut mapped = 0usize;
        let mut ovf = 0u64;
        let mut parent = NO_PREFIX;
        for chunk in tokens.chunks_exact(ps) {
            if mapped + ps >= tokens.len() {
                break;
            }
            let Some((entry, page)) = self.cache.lookup(parent, chunk) else { break };
            self.pool.retain(page);
            self.tables[slot].push(page);
            ovf += self.pool.ovf(page);
            parent = entry;
            mapped += ps;
        }
        if mapped > 0 {
            self.lens[slot] = mapped;
            self.chain[slot] = parent;
            self.registered[slot] = mapped / ps;
            self.pages_adopted += (mapped / ps) as u64;
        }
        (mapped, ovf)
    }

    /// File this slot's full, position-0-aligned pages covering
    /// `prefix` (the tokens encoded so far) in the prefix cache, so
    /// later admissions sharing the prefix can adopt them. Idempotent
    /// per page; the cache takes its own refcount on each page it
    /// indexes. No-op for slots that slid via `truncate_front` (their
    /// pages are position-shifted) — serve-path slides reset and
    /// re-encode, so they stay eligible.
    pub fn register_prefix(&mut self, slot: usize, prefix: &[u16]) {
        if !self.shareable[slot] || self.heads[slot] != 0 {
            return;
        }
        let ps = self.page_size;
        let full = prefix.len().min(self.lens[slot]) / ps;
        while self.registered[slot] < full {
            let k = self.registered[slot];
            let chunk = &prefix[k * ps..(k + 1) * ps];
            let page = self.tables[slot][k];
            let parent = self.chain[slot];
            let entry = match self.cache.lookup(parent, chunk) {
                // already cached (another admission prefilled the same
                // prefix concurrently and registered first): remap this
                // slot's table onto the cached twin and drop the
                // private copy. Full pages are bit-identical for equal
                // (parent chain, tokens) by determinism — including
                // their fill-time overflow ledgers — so the swap is
                // invisible to reads and to adoption credits, and it
                // frees the duplicate page immediately.
                Some((e, cached)) => {
                    if cached != page {
                        self.pool.retain(cached);
                        self.tables[slot][k] = cached;
                        self.pool.unref(page);
                        self.pages_deduped += 1;
                    }
                    e
                }
                None => {
                    self.pool.retain(page);
                    self.cache.insert(parent, chunk, page)
                }
            };
            self.chain[slot] = entry;
            self.registered[slot] += 1;
        }
    }

    /// Record fill-time overflow events of the row at logical `pos`
    /// onto the page holding it (see module docs: adopters credit these
    /// instead of re-incurring them). Appends are monotone at the
    /// slot's high-water position, so the target page is always private
    /// here — shared pages are full and never receive new events.
    fn record_fill_ovf(&mut self, slot: usize, pos: usize, events: u64) {
        let idx = self.heads[slot] + pos;
        let page = self.tables[slot][idx / self.page_size];
        self.pool.record_ovf(page, events);
    }

    /// Write a chunk of `n` consecutive positions' K/V rows into a slot
    /// starting at `pos` — page-run-wise copies on the f32 backend,
    /// quantize-at-append per position on the quantized backend
    /// ([`QuantKv::append_rows`]). `n == 1` is the decode-row case. The
    /// caller (the ragged step) has already ensured table capacity.
    #[inline]
    fn append_kv_rows_at(
        &mut self,
        layer: usize,
        slot: usize,
        pos: usize,
        n: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) {
        debug_assert!(pos + n <= self.max_seq);
        debug_assert_eq!(k_rows.len(), n * self.d);
        debug_assert_eq!(v_rows.len(), n * self.d);
        let KvArena { store, tables, heads, page_size, d, .. } = self;
        let (ps, d) = (*page_size, *d);
        let map = PageMap::new(&tables[slot], heads[slot], ps);
        match store {
            KvStore::F32 { k, v } => {
                let mut i = 0usize;
                while i < n {
                    let run = map.run(pos + i, n - i);
                    let (pg, off) = map.locate(pos + i);
                    let at = (pg * ps + off) * d;
                    k[layer][at..at + run * d].copy_from_slice(&k_rows[i * d..(i + run) * d]);
                    v[layer][at..at + run * d].copy_from_slice(&v_rows[i * d..(i + run) * d]);
                    i += run;
                }
            }
            KvStore::Quant(q) => q.append_rows(layer, &map, pos, n, k_rows, v_rows),
        }
    }

    #[inline]
    fn advance(&mut self, slot: usize, n: usize) {
        self.lens[slot] += n;
        debug_assert!(self.lens[slot] <= self.max_seq);
        debug_assert!(
            self.heads[slot] + self.lens[slot] <= self.tables[slot].len() * self.page_size,
            "advance past the slot's page table"
        );
    }
}

/// Per-layer key/value cache for one sequence — a 1-slot [`KvArena`]
/// view, kept so single-sequence callers (eval, examples,
/// `generate_greedy`) read naturally.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub(crate) arena: KvArena,
}

impl KvCache {
    pub fn new(model: &Transformer) -> KvCache {
        KvCache::with_kind(model, KvCacheKind::F32)
    }

    /// Single-sequence cache on the chosen backend.
    pub fn with_kind(model: &Transformer, kind: KvCacheKind) -> KvCache {
        let mut arena = KvArena::with_kind(model, 1, kind);
        arena.alloc().expect("fresh 1-slot arena");
        KvCache { arena }
    }

    pub fn len(&self) -> usize {
        self.arena.len(0)
    }

    pub fn is_empty(&self) -> bool {
        self.arena.is_empty(0)
    }

    pub fn is_full(&self) -> bool {
        self.arena.is_full(0)
    }

    pub fn bytes(&self) -> usize {
        self.arena.bytes()
    }

    pub fn clear(&mut self) {
        self.arena.reset_slot(0);
    }

    /// Drop the oldest `n` positions (sliding-window generation).
    pub fn truncate_front(&mut self, n: usize) {
        self.arena.truncate_front(0, n);
    }
}

impl Transformer {
    /// Decode one token given the cached prefix; returns the logits for
    /// this position and appends this position's K/V to the cache.
    ///
    /// Thin delegate to [`Transformer::decode_step_batch`] over the
    /// cache's single slot, so sequential and batched decode share one
    /// datapath.
    pub fn decode_step(&self, token: u16, cache: &mut KvCache) -> Vec<f32> {
        self.decode_step_batch(&[token], &[0], &mut cache.arena)
    }

    /// Decode one token for **each** scheduled sequence in one batched
    /// pass: `tokens[b]` is appended to arena slot `slots[b]`. Returns
    /// row-major `tokens.len() × vocab` logits.
    ///
    /// Transient-workspace wrapper around
    /// [`Transformer::decode_step_batch_scratch`] (tests and one-shot
    /// callers; the serving engine holds its own workspace).
    pub fn decode_step_batch(
        &self,
        tokens: &[u16],
        slots: &[usize],
        arena: &mut KvArena,
    ) -> Vec<f32> {
        let mut row_ovf = vec![0u64; tokens.len()];
        self.decode_step_batch_counted(tokens, slots, arena, &mut row_ovf)
    }

    /// [`Transformer::decode_step_batch`] with **exact per-row overflow
    /// attribution**: `row_ovf[b]` is incremented by every integer-
    /// datapath overflow event row `b` triggered this step — its rows of
    /// each quantized linear plus (on the quantized-KV backend) its own
    /// attention matmuls.
    pub fn decode_step_batch_counted(
        &self,
        tokens: &[u16],
        slots: &[usize],
        arena: &mut KvArena,
        row_ovf: &mut [u64],
    ) -> Vec<f32> {
        let mut scratch = DecodeScratch::new();
        self.decode_step_batch_scratch(tokens, slots, arena, row_ovf, &mut scratch);
        scratch.step.logits[..tokens.len() * self.cfg.vocab].to_vec()
    }

    /// The batched decode step over a caller-owned workspace — one
    /// 1-row [`RowGroup`] per scheduled sequence through
    /// [`Transformer::decode_step_ragged_scratch`]. Each output row is
    /// bit-identical to decoding that sequence alone, and `row_ovf[b]`
    /// is incremented by exactly the overflow events row `b` triggered
    /// (the serving engine threads per-request counters through here).
    ///
    /// The step's logits land in `scratch.step.logits[..b * vocab]`
    /// (row-major, one row per scheduled sequence) — read them from the
    /// workspace; nothing is allocated or returned. With a warm
    /// workspace the whole step performs zero heap allocations (the
    /// group list lives in a reused workspace buffer).
    pub fn decode_step_batch_scratch(
        &self,
        tokens: &[u16],
        slots: &[usize],
        arena: &mut KvArena,
        row_ovf: &mut [u64],
        scratch: &mut DecodeScratch,
    ) {
        assert_eq!(tokens.len(), slots.len(), "one slot per token");
        let mut groups = std::mem::take(&mut scratch.groups_buf);
        groups.clear();
        groups.extend(
            slots.iter().enumerate().map(|(i, &slot)| RowGroup { slot, start: i, len: 1 }),
        );
        self.decode_step_ragged_scratch(tokens, &groups, arena, row_ovf, scratch);
        scratch.groups_buf = groups;
    }

    /// The **ragged** decode step — the serving hot path since chunked
    /// prefill: every scheduled row group (a 1-row decode step or a
    /// multi-row prefill chunk, mixed freely in one call) rides the
    /// same batched kernel dispatches. Every linear runs one
    /// [`super::Linear::forward_rows_scratch`] call over **all** rows
    /// of the step (the fused qgemm kernel for quantized layers), so
    /// prefill chunks amortize the kernel across the in-flight decode
    /// batch instead of blocking it. Attention stays ragged per group:
    /// chunk row `i` attends causally over its slot's cached prefix
    /// plus chunk rows `0..=i` ([`attend_chunk_rows`] /
    /// [`attend_chunk_quant`]), resolving positions through the slot's
    /// page table. When the workspace is configured with
    /// [`DecodeScratch::set_attn_threads`] and the step's estimated
    /// attention MACs clear the threshold, groups fan out across
    /// contiguous work-balanced **bands** of scoped threads (the qgemm
    /// band idiom); the serial sweep is the `threads = 1` oracle and
    /// results are bit-identical at every thread count.
    ///
    /// **Token-exactness:** every row's arithmetic (embedding at its
    /// absolute position, row-independent linears, attention over its
    /// own slot only) is identical no matter how rows are grouped into
    /// chunks or batched with other sequences — and independent of the
    /// physical pages behind the slot (the page map only changes
    /// *where* a row is stored, never its value) — so any chunked
    /// schedule reproduces sequential decode bit for bit, with or
    /// without shared prefix pages (tested in
    /// `tests/chunked_prefill.rs`).
    ///
    /// **Attribution:** `group_ovf[g]` is incremented by exactly the
    /// integer-datapath overflow events group `g`'s rows triggered
    /// (linear rows + its own attention matmuls) — disjoint across
    /// groups and invariant to step composition. Per-row fill events
    /// are also recorded onto the pages holding the appended rows, the
    /// record prefix adoption credits from.
    ///
    /// One logits row per **group** (its last row — the only one a
    /// scheduler can ever sample from) lands in
    /// `scratch.step.logits[..groups.len() * vocab]`.
    pub fn decode_step_ragged_scratch(
        &self,
        tokens: &[u16],
        groups: &[RowGroup],
        arena: &mut KvArena,
        group_ovf: &mut [u64],
        scratch: &mut DecodeScratch,
    ) {
        let opts = RaggedOpts::standard();
        self.decode_step_ragged_opts(tokens, groups, arena, group_ovf, scratch, opts);
    }

    /// [`Transformer::decode_step_ragged_scratch`] with explicit
    /// [`RaggedOpts`] — the speculative entry point. With
    /// [`RaggedOpts::standard`] it is that function, bit for bit. A
    /// [`RaggedOpts::draft`] call narrows every integer register (same
    /// weights, codes and scales) and leaves page overflow ledgers
    /// untouched; a [`RaggedOpts::verify`] call produces one logits row
    /// per step row so a k-row chunk-causal group scores a whole draft
    /// chunk in one full-width pass. Per-group and per-row overflow
    /// attribution semantics are unchanged in every mode (per-row
    /// counts stay readable in `scratch.step.row_ovf[..n]` after the
    /// call — the accepted-rows-only attribution the speculative
    /// engine needs).
    pub fn decode_step_ragged_opts(
        &self,
        tokens: &[u16],
        groups: &[RowGroup],
        arena: &mut KvArena,
        group_ovf: &mut [u64],
        scratch: &mut DecodeScratch,
        opts: RaggedOpts,
    ) {
        assert!(!groups.is_empty(), "empty ragged step");
        assert_eq!(group_ovf.len(), groups.len(), "one counter per group");
        assert_eq!(arena.d, self.cfg.d_model);
        let n = tokens.len();
        let g_n = groups.len();
        let d = self.cfg.d_model;
        let d_ff = self.cfg.d_ff;
        let vocab = self.cfg.vocab;
        let mut cursor = 0usize;
        for (gi, g) in groups.iter().enumerate() {
            assert!(g.len >= 1, "group {gi} is empty");
            assert_eq!(g.start, cursor, "groups must tile the token slice in order");
            cursor += g.len;
            assert!(arena.live[g.slot], "slot {} not allocated", g.slot);
            assert!(
                arena.len(g.slot) + g.len <= arena.max_seq,
                "group {gi} overflows KV slot {} ({} + {} > max_seq {})",
                g.slot,
                arena.len(g.slot),
                g.len,
                arena.max_seq
            );
            // hard assert: a doubled slot would append twice at one
            // position and advance the length twice, silently corrupting
            // the sequence (step widths are small, the scan is cheap)
            assert!(
                !groups[..gi].iter().any(|p| p.slot == g.slot),
                "slot {} scheduled twice in one step",
                g.slot
            );
        }
        assert_eq!(cursor, n, "tokens beyond the last group");
        // page tables grown up front (free-list pops, no heap traffic),
        // so the append/attention loops below never see a missing page
        for g in groups {
            let target = arena.len(g.slot) + g.len;
            arena.ensure_capacity(g.slot, target);
        }

        let DecodeScratch { lin, attn, step, attn_pool, attn_threads, attn_par_min, .. } = scratch;
        let (attn_threads, attn_par_min) = (*attn_threads, *attn_par_min);
        let logit_rows = match opts.logits {
            LogitRows::GroupLast => g_n,
            LogitRows::All => n,
        };
        step.ensure(n, logit_rows, d, d_ff, vocab);
        // Live-size views over the grow-only step buffers; everything
        // below operates on exactly n rows (g_n logit rows).
        let h = &mut step.h[..n * d];
        let ln_out = &mut step.ln_out[..n * d];
        let q = &mut step.q[..n * d];
        let k_new = &mut step.k_new[..n * d];
        let v_new = &mut step.v_new[..n * d];
        let mix = &mut step.mix[..n * d];
        let attn_out = &mut step.attn_out[..n * d];
        let ff = &mut step.ff[..n * d_ff];
        let ff_out = &mut step.ff_out[..n * d];
        let row_ovf = &mut step.row_ovf[..n];
        row_ovf.fill(0);

        // token + absolute positional embedding: chunk row i of a group
        // sits at its slot's position len(slot) + i
        for g in groups {
            let pos0 = arena.len(g.slot);
            for i in 0..g.len {
                let r = g.start + i;
                let tok = tokens[r] as usize;
                let e = &self.embed[tok * d..(tok + 1) * d];
                let p = &self.pos[(pos0 + i) * d..(pos0 + i + 1) * d];
                for j in 0..d {
                    h[r * d + j] = e[j] + p[j];
                }
            }
        }

        // Band plan for the attention sweep, computed once per step:
        // slot lengths advance only after the layer loop, so every
        // group's MAC estimate (score + value matmuls over its slot's
        // prefix plus its own chunk rows) is constant across layers and
        // one contiguous, work-balanced partition serves all of them.
        // Groups are the parallel unit — they name pairwise-distinct
        // slots (asserted above) and write disjoint `mix`/`row_ovf`
        // ranges. Below the work threshold the step stays serial (and
        // allocation-free); `bounds` is only built when it fans out.
        let n_heads = self.cfg.n_heads;
        let mut bands = attn_threads.min(g_n).max(1);
        if bands > 1 {
            let est: usize = groups
                .iter()
                .map(|g| 2 * g.len * (arena.len(g.slot) + g.len) * d)
                .sum();
            if est < attn_par_min {
                bands = 1;
            }
        }
        let bounds: Vec<usize> = if bands > 1 {
            band_bounds(groups.iter().map(|g| g.len * (arena.len(g.slot) + g.len)), bands)
        } else {
            Vec::new()
        };

        let mut attn_total = 0u64;
        for (bi, blk) in self.blocks.iter().enumerate() {
            for r in 0..n {
                blk.ln1.forward_row(&h[r * d..(r + 1) * d], &mut ln_out[r * d..(r + 1) * d]);
            }
            blk.wq.forward_rows_scratch_narrowed(ln_out, n, q, row_ovf, lin, opts.draft_bits);
            blk.wk.forward_rows_scratch_narrowed(ln_out, n, k_new, row_ovf, lin, opts.draft_bits);
            blk.wv.forward_rows_scratch_narrowed(ln_out, n, v_new, row_ovf, lin, opts.draft_bits);
            for g in groups {
                let pos0 = arena.len(g.slot);
                arena.append_kv_rows_at(
                    bi,
                    g.slot,
                    pos0,
                    g.len,
                    &k_new[g.start * d..(g.start + g.len) * d],
                    &v_new[g.start * d..(g.start + g.len) * d],
                );
            }
            // ragged causal attention: each group over its own slot
            // only (prefix + its just-appended chunk rows), positions
            // resolved through the slot's page map. The appends above
            // are complete, so the arena is read-only for the whole
            // sweep; one band covering every group runs serially on
            // the caller thread (the threads=1 oracle), a fanned-out
            // step sweeps its bands under `std::thread::scope` — band
            // 0 on the caller thread with the step's own attention
            // workspace, bands 1.. on the engine-owned per-thread pool
            // — and folds per-band overflow totals in band order, so
            // tokens AND per-request overflow attribution are
            // bit-identical at every thread count.
            let mix_base = mix.as_mut_ptr() as usize;
            let ovf_base = row_ovf.as_mut_ptr() as usize;
            if bands <= 1 {
                attn_total += attend_groups_band(
                    n_heads, arena, groups, 0, g_n, bi, q, d, mix_base, ovf_base,
                    opts.draft_bits, attn,
                );
            } else {
                let arena_ro: &KvArena = arena;
                let q_ro: &[f32] = q;
                let narrow = opts.draft_bits;
                std::thread::scope(|s| {
                    let mut handles = Vec::with_capacity(bands - 1);
                    let mut pool = attn_pool.iter_mut();
                    for b in 1..bands {
                        let (lo, hi) = (bounds[b], bounds[b + 1]);
                        let a = pool.next().expect("attn pool presized to attn_threads - 1");
                        if lo >= hi {
                            continue;
                        }
                        handles.push(s.spawn(move || {
                            attend_groups_band(
                                n_heads, arena_ro, groups, lo, hi, bi, q_ro, d, mix_base,
                                ovf_base, narrow, a,
                            )
                        }));
                    }
                    attn_total += attend_groups_band(
                        n_heads, arena_ro, groups, bounds[0], bounds[1], bi, q_ro, d, mix_base,
                        ovf_base, narrow, attn,
                    );
                    for h in handles {
                        attn_total += h.join().expect("attention band panicked");
                    }
                });
            }
            blk.wo.forward_rows_scratch_narrowed(mix, n, attn_out, row_ovf, lin, opts.draft_bits);
            if !self.cfg.parallel_residual {
                for i in 0..n * d {
                    h[i] += attn_out[i];
                }
            }
            for r in 0..n {
                blk.ln2.forward_row(&h[r * d..(r + 1) * d], &mut ln_out[r * d..(r + 1) * d]);
            }
            blk.fc1.forward_rows_scratch_narrowed(ln_out, n, ff, row_ovf, lin, opts.draft_bits);
            self.cfg.act.apply_vec(ff);
            blk.fc2.forward_rows_scratch_narrowed(ff, n, ff_out, row_ovf, lin, opts.draft_bits);
            if self.cfg.parallel_residual {
                for i in 0..n * d {
                    h[i] += attn_out[i] + ff_out[i];
                }
            } else {
                for i in 0..n * d {
                    h[i] += ff_out[i];
                }
            }
        }
        // leave the step's attention overflow share and band fan-out
        // where the engine can read them cheaply (telemetry records)
        step.last_attn_ovf = attn_total;
        step.last_attn_bands = bands;
        if attn_total > 0 {
            // unified accounting: attention events join the model-wide
            // overflow counter next to the quantized-linear events
            self.add_attention_overflows(attn_total);
        }
        // fill-time page attribution: each appended row's complete event
        // count (all its linear rows + its own attention; the float LM
        // head below contributes none) lands on the page holding it, so
        // a later adopter of that page credits exactly these events.
        // Draft steps skip this (their rows are rolled back and must
        // leave the ledgers byte-identical).
        if opts.record_fill {
            for g in groups {
                let pos0 = arena.len(g.slot);
                for i in 0..g.len {
                    let events = row_ovf[g.start + i];
                    if events > 0 {
                        arena.record_fill_ovf(g.slot, pos0 + i, events);
                    }
                }
            }
        }
        for g in groups {
            arena.advance(g.slot, g.len);
        }
        // per-group attribution: fold the kernel's per-row counts
        for (gi, g) in groups.iter().enumerate() {
            group_ovf[gi] += row_ovf[g.start..g.start + g.len].iter().sum::<u64>();
        }
        match opts.logits {
            // one logits row per group, from its last row: gather the
            // final-norm rows contiguously, one head GEMM over all groups
            LogitRows::GroupLast => {
                for (gi, g) in groups.iter().enumerate() {
                    let r = g.start + g.len - 1;
                    self.ln_f
                        .forward_row(&h[r * d..(r + 1) * d], &mut ln_out[gi * d..(gi + 1) * d]);
                }
                self.head.forward_rows_scratch(
                    &ln_out[..g_n * d],
                    g_n,
                    &mut step.logits[..g_n * vocab],
                    lin,
                );
            }
            // verify shape: one logits row per step row, in place — the
            // head GEMM covers every chunk position so acceptance can
            // compare all of them against the drafts
            LogitRows::All => {
                for r in 0..n {
                    self.ln_f
                        .forward_row(&h[r * d..(r + 1) * d], &mut ln_out[r * d..(r + 1) * d]);
                }
                self.head.forward_rows_scratch(
                    &ln_out[..n * d],
                    n,
                    &mut step.logits[..n * vocab],
                    lin,
                );
            }
        }
    }

    /// Prefill: push a whole prompt through one cache slot, returning
    /// the logits of the final position.
    ///
    /// Transient-workspace wrapper around
    /// [`Transformer::prefill_slot_scratch`].
    pub fn prefill_slot(&self, tokens: &[u16], slot: usize, arena: &mut KvArena) -> Vec<f32> {
        let mut ovf = 0u64;
        self.prefill_slot_counted(tokens, slot, arena, &mut ovf)
    }

    /// [`Transformer::prefill_slot`] accumulating the prompt's integer-
    /// datapath overflow events into `ovf` — a prefill belongs entirely
    /// to one request, so a scalar counter suffices for exact
    /// per-request attribution.
    pub fn prefill_slot_counted(
        &self,
        tokens: &[u16],
        slot: usize,
        arena: &mut KvArena,
        ovf: &mut u64,
    ) -> Vec<f32> {
        let mut scratch = DecodeScratch::new();
        self.prefill_slot_scratch(tokens, slot, arena, ovf, &mut scratch);
        scratch.step.logits[..self.cfg.vocab].to_vec()
    }

    /// Prefill over a caller-owned workspace — the **1-group special
    /// case** of [`Transformer::decode_step_ragged_scratch`]: the whole
    /// prompt rides one multi-row [`RowGroup`], so every linear
    /// processes it in one [`super::Linear::forward_rows_scratch`] call
    /// (the fused qgemm kernel for quantized layers) and causal
    /// attention runs position by position over the just-appended
    /// K/V — exactly the arithmetic decode uses, so prefill-then-decode
    /// equals pure decode bit for bit, on an empty **or** partially
    /// filled slot (including a slot holding adopted prefix pages:
    /// prefill then starts at the first unshared position).
    ///
    /// The final position's logits land in
    /// `scratch.step.logits[..vocab]`; overflow events are accumulated
    /// into `ovf`.
    pub fn prefill_slot_scratch(
        &self,
        tokens: &[u16],
        slot: usize,
        arena: &mut KvArena,
        ovf: &mut u64,
        scratch: &mut DecodeScratch,
    ) {
        assert!(!tokens.is_empty());
        assert!(
            arena.len(slot) + tokens.len() <= arena.max_seq,
            "prompt longer than the context window"
        );
        let group = [RowGroup { slot, start: 0, len: tokens.len() }];
        let mut g_ovf = [0u64; 1];
        self.decode_step_ragged_scratch(tokens, &group, arena, &mut g_ovf, scratch);
        *ovf += g_ovf[0];
    }

    /// Prefill a whole prompt through a single-sequence cache.
    pub fn prefill(&self, tokens: &[u16], cache: &mut KvCache) -> Vec<f32> {
        self.prefill_slot(tokens, 0, &mut cache.arena)
    }

    /// Longest servable prompt suffix: the last `max_seq - 1` tokens,
    /// so prefill plus one decode step always fit the window. Shared by
    /// every serving path so clipping stays in lockstep with
    /// [`Transformer::generate_greedy`].
    pub fn clip_to_window(&self, prompt: &[u16]) -> Vec<u16> {
        let max_seq = self.cfg.max_seq;
        if prompt.len() >= max_seq {
            prompt[prompt.len() - (max_seq - 1)..].to_vec()
        } else {
            prompt.to_vec()
        }
    }

    /// Context tokens re-encoded when a full sequence slides its
    /// window — the single source of truth for the slide, which every
    /// decode path must share for token-exact parity.
    pub fn slide_keep(&self) -> usize {
        self.cfg.max_seq / 2
    }

    /// Greedy generation: prompt → `n` new tokens (f32 KV cache).
    pub fn generate_greedy(&self, prompt: &[u16], n: usize) -> Vec<u16> {
        self.generate_greedy_with(prompt, n, KvCacheKind::F32)
    }

    /// Greedy generation on the chosen KV backend — the sequential
    /// reference continuous-batched serving must reproduce token for
    /// token on that same backend. Runs on the scratch hot path (one
    /// workspace for the whole generation), so the sequential baseline
    /// benches the same kernels the engine serves with.
    pub fn generate_greedy_with(&self, prompt: &[u16], n: usize, kind: KvCacheKind) -> Vec<u16> {
        let mut cache = KvCache::with_kind(self, kind);
        let mut scratch = DecodeScratch::new();
        let vocab = self.cfg.vocab;
        let mut out = prompt.to_vec();
        let mut ovf = 0u64;
        self.prefill_slot_scratch(prompt, 0, &mut cache.arena, &mut ovf, &mut scratch);
        let mut row = [0u64; 1];
        for _ in 0..n {
            if cache.is_full() {
                // slide the window by re-encoding the tail
                let keep = self.slide_keep();
                let tail = out[out.len() - keep..].to_vec();
                cache.clear();
                self.prefill_slot_scratch(&tail, 0, &mut cache.arena, &mut ovf, &mut scratch);
            }
            let next = argmax(&scratch.step.logits[..vocab]) as u16;
            out.push(next);
            row[0] = 0;
            self.decode_step_batch_scratch(&[next], &[0], &mut cache.arena, &mut row, &mut scratch);
        }
        out
    }

    /// Seeded sampled generation on the chosen KV backend — the
    /// sequential reference batched **sampled** serving must reproduce
    /// token for token. `stream` keys this sequence's RNG stream (the
    /// engine uses the request id), and position `i` of the generation
    /// draws from `spec` at `(stream, i)` — a pure function of the
    /// logits and those three keys, independent of batch composition.
    /// With a greedy `spec` this is [`Transformer::generate_greedy_with`]
    /// exactly.
    pub fn generate_sampled_with(
        &self,
        prompt: &[u16],
        n: usize,
        kind: KvCacheKind,
        spec: &super::sample::SampleSpec,
        stream: u64,
    ) -> Vec<u16> {
        let mut cache = KvCache::with_kind(self, kind);
        let mut scratch = DecodeScratch::new();
        let mut buf = Vec::new();
        let vocab = self.cfg.vocab;
        let mut out = prompt.to_vec();
        let mut ovf = 0u64;
        self.prefill_slot_scratch(prompt, 0, &mut cache.arena, &mut ovf, &mut scratch);
        let mut row = [0u64; 1];
        for i in 0..n {
            if cache.is_full() {
                let keep = self.slide_keep();
                let tail = out[out.len() - keep..].to_vec();
                cache.clear();
                self.prefill_slot_scratch(&tail, 0, &mut cache.arena, &mut ovf, &mut scratch);
            }
            let next =
                spec.sample_with(&scratch.step.logits[..vocab], stream, i as u64, &mut buf) as u16;
            out.push(next);
            row[0] = 0;
            self.decode_step_batch_scratch(&[next], &[0], &mut cache.arena, &mut row, &mut scratch);
        }
        out
    }
}

/// Split `count` work items into `bands` contiguous, work-balanced
/// runs: `bounds[b]..bounds[b + 1]` is band `b`'s item range (runs may
/// be empty). Item `i` lands in band `⌊(cum_i + w_i / 2) · bands /
/// total⌋` — its work midpoint scaled into band space — which is
/// monotone in `i`, so runs are contiguous and every item lands in
/// exactly one band. Pure function of the work profile: the same
/// schedule always yields the same partition, at every thread count.
fn band_bounds(work: impl Iterator<Item = usize>, bands: usize) -> Vec<usize> {
    debug_assert!(bands >= 1);
    let work: Vec<usize> = work.collect();
    let total = work.iter().sum::<usize>().max(1);
    let mut bounds = vec![0usize; bands + 1];
    let mut cum = 0usize;
    for (i, &w) in work.iter().enumerate() {
        let mid = cum + w / 2;
        let b = (((mid as u128) * (bands as u128)) / (total as u128)) as usize;
        bounds[b.min(bands - 1) + 1] = i + 1;
        cum += w;
    }
    for b in 1..=bands {
        bounds[b] = bounds[b].max(bounds[b - 1]);
    }
    bounds
}

/// One band of the ragged attention sweep: attend `groups[lo..hi]` at
/// layer `layer`, writing each group's mixed output rows and (on the
/// quantized backend) per-row overflow counts through raw base
/// pointers into the step's `mix` / `row_ovf` buffers. Returns the
/// band's attention-overflow total.
///
/// Shared by the serial sweep (one band covering every group) and the
/// scoped-thread sweep, so the thread count can never change the
/// per-group arithmetic — only who executes it.
///
/// SAFETY contract (upheld by `decode_step_ragged_scratch`): groups
/// tile the token slice and name pairwise-distinct slots, so distinct
/// groups — hence distinct bands — write pairwise-disjoint `mix` and
/// `row_ovf` ranges; both buffers outlive the sweep, and no `&mut`
/// reference to either is live while the raw base pointers are in use.
#[allow(clippy::too_many_arguments)]
fn attend_groups_band(
    n_heads: usize,
    arena: &KvArena,
    groups: &[RowGroup],
    lo: usize,
    hi: usize,
    layer: usize,
    q: &[f32],
    d: usize,
    mix_base: usize,
    ovf_base: usize,
    narrow: Option<u32>,
    attn: &mut AttnScratch,
) -> u64 {
    let mut total = 0u64;
    for g in &groups[lo..hi] {
        let t0 = arena.len(g.slot);
        let qrows = &q[g.start * d..(g.start + g.len) * d];
        // SAFETY: disjoint range per group (see contract above)
        let orows = unsafe {
            std::slice::from_raw_parts_mut((mix_base as *mut f32).add(g.start * d), g.len * d)
        };
        let map = PageMap::new(&arena.tables[g.slot], arena.heads[g.slot], arena.page_size);
        match &arena.store {
            KvStore::F32 { k, v } => {
                let view = PagedKvRows { k: &k[layer], v: &v[layer], map, d };
                attend_chunk_rows(qrows, &view, t0, g.len, d, n_heads, attn, orows);
            }
            KvStore::Quant(qkv) => {
                let spec = match narrow {
                    Some(bits) => qkv.spec.narrowed(bits),
                    None => qkv.spec,
                };
                // SAFETY: disjoint range per group (see contract above)
                let rovf = unsafe {
                    std::slice::from_raw_parts_mut((ovf_base as *mut u64).add(g.start), g.len)
                };
                total += attend_chunk_quant(
                    qrows,
                    &qkv.slot_view(layer, map),
                    t0,
                    g.len,
                    d,
                    n_heads,
                    &spec,
                    attn,
                    orows,
                    rovf,
                );
            }
        }
    }
    total
}

/// Index of the first maximum — the tie-break every greedy path in this
/// crate must share for token-exact parity across batch shapes.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kvquant::KvQuantSpec;
    use crate::model::{random_transformer, Activation, TransformerConfig};

    fn model(parallel: bool) -> Transformer {
        random_transformer(
            TransformerConfig {
                name: "d".into(),
                vocab: 48,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_seq: 16,
                act: Activation::Gelu,
                parallel_residual: parallel,
            },
            77,
        )
    }

    #[test]
    fn decode_matches_forward() {
        for parallel in [false, true] {
            let m = model(parallel);
            let toks: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6];
            let full = m.forward(&toks, None);
            let vocab = m.cfg.vocab;
            let mut cache = KvCache::new(&m);
            for (t, &tok) in toks.iter().enumerate() {
                let step_logits = m.decode_step(tok, &mut cache);
                let full_row = &full[t * vocab..(t + 1) * vocab];
                for (a, b) in step_logits.iter().zip(full_row.iter()) {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "parallel={parallel} pos={t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn prefill_equals_last_forward_row() {
        let m = model(true);
        let toks: Vec<u16> = vec![1, 2, 3, 4, 5];
        let mut cache = KvCache::new(&m);
        let last = m.prefill(&toks, &mut cache);
        let full = m.forward(&toks, None);
        let vocab = m.cfg.vocab;
        for (a, b) in last.iter().zip(&full[4 * vocab..5 * vocab]) {
            assert!((a - b).abs() < 1e-4);
        }
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn generate_deterministic_and_bounded() {
        let m = model(false);
        let out1 = m.generate_greedy(&[1, 2, 3], 20);
        let out2 = m.generate_greedy(&[1, 2, 3], 20);
        assert_eq!(out1, out2);
        assert_eq!(out1.len(), 23);
        assert!(out1.iter().all(|&t| (t as usize) < 48));
    }

    #[test]
    fn cache_overflow_guard() {
        let m = model(false);
        let mut cache = KvCache::new(&m);
        for t in 0..16 {
            m.decode_step(t as u16 % 48, &mut cache);
        }
        assert!(cache.is_full());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.decode_step(0, &mut cache);
        }));
        assert!(r.is_err(), "decoding past max_seq must panic");
    }

    #[test]
    fn truncate_front_keeps_suffix() {
        let m = model(true);
        let mut cache = KvCache::new(&m);
        for t in 0..8 {
            m.decode_step(t, &mut cache);
        }
        cache.truncate_front(3);
        assert_eq!(cache.len(), 5);
        cache.clear();
        assert!(cache.is_empty());
    }

    /// A slide is a page-table operation now: dropping whole head pages
    /// and carrying a mid-page head offset must expose exactly the
    /// surviving rows, bit-identical, and return the dropped pages to
    /// the pool — on both backends.
    #[test]
    fn truncate_front_drops_head_pages_and_preserves_rows() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            let m = model(false);
            let mut arena = KvArena::with_kind_paged(&m, 1, kind, 4);
            assert_eq!(arena.page_size(), 4);
            let slot = arena.alloc().unwrap();
            for t in 0..10u16 {
                m.decode_step_batch(&[t], &[slot], &mut arena);
            }
            assert_eq!(arena.resident_pages(), 3, "10 rows over 4-sized pages");
            let snapshot: Vec<_> =
                (5..10).map(|p| arena.kv_row(1, slot, p)).collect();
            // drop 5: one whole page (4 rows) + head offset 1
            arena.truncate_front(slot, 5);
            assert_eq!(arena.len(slot), 5);
            assert_eq!(arena.resident_pages(), 2, "head page went back to the pool");
            for (i, want) in snapshot.iter().enumerate() {
                assert_eq!(
                    &arena.kv_row(1, slot, i),
                    want,
                    "kind={kind:?} surviving row {i} drifted across the slide"
                );
            }
            // the slot keeps decoding correctly from its slid state
            m.decode_step_batch(&[7], &[slot], &mut arena);
            assert_eq!(arena.len(slot), 6);
        }
    }

    /// THE batched-decode parity property: stacking several sequences
    /// into one `decode_step_batch` call must produce, for every
    /// sequence, logits **bit-identical** to decoding it alone through a
    /// single-slot cache — on both KV backends.
    #[test]
    fn batched_decode_is_bit_exact_vs_single() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            for parallel in [false, true] {
                let m = model(parallel);
                let vocab = m.cfg.vocab;
                let seqs: Vec<Vec<u16>> = vec![
                    vec![3, 1, 4, 1, 5],
                    vec![9, 2, 6, 5, 3],
                    vec![8, 9, 7, 9, 3],
                ];
                // reference: each sequence decoded alone
                let mut want: Vec<Vec<f32>> = Vec::new();
                for s in &seqs {
                    let mut cache = KvCache::with_kind(&m, kind);
                    let mut last = Vec::new();
                    for &t in s {
                        last = m.decode_step(t, &mut cache);
                    }
                    want.push(last);
                }
                // batched: all three in one arena, one step per position,
                // one shared scratch workspace across every step
                let mut arena = KvArena::with_kind(&m, 3, kind);
                let slots: Vec<usize> = (0..3).map(|_| arena.alloc().unwrap()).collect();
                let mut scratch = DecodeScratch::new();
                let mut row_ovf = vec![0u64; 3];
                for pos in 0..seqs[0].len() {
                    let toks: Vec<u16> = seqs.iter().map(|s| s[pos]).collect();
                    row_ovf.iter_mut().for_each(|v| *v = 0);
                    m.decode_step_batch_scratch(
                        &toks,
                        &slots,
                        &mut arena,
                        &mut row_ovf,
                        &mut scratch,
                    );
                }
                let got = &scratch.step.logits[..3 * vocab];
                for (b, w) in want.iter().enumerate() {
                    assert_eq!(
                        &got[b * vocab..(b + 1) * vocab],
                        &w[..],
                        "kind={kind:?} parallel={parallel} seq {b} diverged under batching"
                    );
                }
            }
        }
    }

    /// Ragged batches: sequences of different lengths share steps, and a
    /// late joiner admitted mid-flight stays bit-exact.
    #[test]
    fn ragged_batch_with_late_join_is_exact() {
        let m = model(false);
        let vocab = m.cfg.vocab;
        let a: Vec<u16> = vec![1, 2, 3, 4, 5, 6, 7];
        let b: Vec<u16> = vec![11, 12, 13];
        // reference
        let seq_logits = |s: &[u16]| {
            let mut cache = KvCache::new(&m);
            let mut last = Vec::new();
            for &t in s {
                last = m.decode_step(t, &mut cache);
            }
            last
        };
        let want_a = seq_logits(&a);
        let want_b = seq_logits(&b);
        // batched: a decodes alone for 4 steps, then b joins (prefill
        // would be the serving path; token steps exercise raggedness)
        let mut arena = KvArena::new(&m, 2);
        let sa = arena.alloc().unwrap();
        let mut got_a = Vec::new();
        for &t in &a[..4] {
            got_a = m.decode_step_batch(&[t], &[sa], &mut arena);
        }
        let sb = arena.alloc().unwrap();
        for i in 0..3 {
            let logits = m.decode_step_batch(&[a[4 + i], b[i]], &[sa, sb], &mut arena);
            got_a = logits[..vocab].to_vec();
            if i == 2 {
                assert_eq!(&logits[vocab..], &want_b[..], "late joiner diverged");
            }
        }
        assert_eq!(got_a, want_a, "long-running sequence diverged");
    }

    #[test]
    fn arena_slot_reuse_after_release() {
        let m = model(true);
        let mut arena = KvArena::new(&m, 2);
        let s0 = arena.alloc().unwrap();
        let s1 = arena.alloc().unwrap();
        assert!(arena.alloc().is_none(), "over-allocation must fail");
        m.decode_step_batch(&[5, 6], &[s0, s1], &mut arena);
        m.decode_step_batch(&[7], &[s0], &mut arena);
        assert_eq!(arena.len(s0), 2);
        assert_eq!(arena.len(s1), 1);
        // retire s0; the slot comes back empty and decodes a fresh
        // sequence bit-exactly, and its pages went back to the pool
        let resident_before = arena.resident_pages();
        arena.release(s0);
        assert!(arena.resident_pages() < resident_before, "released pages must free");
        assert_eq!(arena.free_slots(), 1);
        let s2 = arena.alloc().unwrap();
        assert_eq!(s2, s0, "LIFO free list must reuse the retired slot");
        assert_eq!(arena.len(s2), 0);
        let got = m.decode_step_batch(&[9], &[s2], &mut arena);
        let mut cache = KvCache::new(&m);
        let want = m.decode_step(9, &mut cache);
        assert_eq!(got, want, "reused slot must behave like a fresh cache");
        // the surviving slot was untouched by the reuse
        assert_eq!(arena.len(s1), 1);
    }

    #[test]
    fn arena_guards() {
        let m = model(false);
        let mut arena = KvArena::new(&m, 2);
        let s = arena.alloc().unwrap();
        // scheduling a free slot panics
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a2 = arena.clone();
            m.decode_step_batch(&[1], &[s + 1], &mut a2);
        }));
        assert!(r.is_err(), "free slot must be rejected");
        // mismatched tokens/slots panics
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a2 = arena.clone();
            m.decode_step_batch(&[1, 2], &[s], &mut a2);
        }));
        assert!(r.is_err(), "token/slot length mismatch must be rejected");
    }

    #[test]
    fn arena_capacity_and_footprint_agree() {
        let m = model(false);
        for kind in [
            KvCacheKind::F32,
            KvCacheKind::Quant(KvQuantSpec::int8()),
            KvCacheKind::Quant(KvQuantSpec::int16()),
        ] {
            for ps in [4usize, 8, 16, 64] {
                let arena = KvArena::with_kind_paged(&m, 3, kind, ps);
                assert_eq!(
                    arena.capacity_bytes(),
                    KvArena::footprint_paged(&m.cfg, 3, kind, ps),
                    "{kind:?} ps={ps} footprint formula disagrees with the arena"
                );
            }
            let arena = KvArena::with_kind(&m, 3, kind);
            assert_eq!(arena.capacity_bytes(), KvArena::footprint(&m.cfg, 3, kind));
            // a fresh arena holds no pages: resident = metadata only
            assert_eq!(
                arena.bytes(),
                arena.capacity_bytes() - arena.pool.n_pages() * arena.page_payload_bytes(),
                "fresh arena must be metadata-only resident"
            );
        }
        // i8 codes shrink the arena; the exact ≤30% bar (wide heads) is
        // asserted in tests/kvquant_decode.rs
        let f = KvArena::footprint(&m.cfg, 4, KvCacheKind::F32);
        let q = KvArena::footprint(&m.cfg, 4, KvCacheKind::Quant(KvQuantSpec::int8()));
        assert!(q < f / 2, "quantized arena must at least halve f32 ({q} vs {f})");
    }

    /// Prefix sharing end to end at arena level: register a prefilled
    /// slot's full pages, adopt them into a fresh slot, prefill only the
    /// tail — logits, cached rows, overflow attribution, and resident
    /// pages must all be exactly right, on both backends.
    #[test]
    fn shared_prefix_adoption_is_bit_exact_and_deduplicated() {
        // narrow attention register so overflow credit is live on quant
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)))] {
            let m = model(false);
            let ps = 4usize;
            let prompt: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5];
            // solo reference: a private arena, no sharing anywhere
            let mut solo = KvArena::with_kind_paged(&m, 1, kind, ps);
            let s = solo.alloc().unwrap();
            let mut ovf_solo = 0u64;
            let want = m.prefill_slot_counted(&prompt, s, &mut solo, &mut ovf_solo);
            // shared arena: A prefills + registers, B adopts + prefills
            // only the unshared tail
            let mut arena = KvArena::with_kind_paged(&m, 2, kind, ps);
            let a = arena.alloc().unwrap();
            let mut ovf_a = 0u64;
            let got_a = m.prefill_slot_counted(&prompt, a, &mut arena, &mut ovf_a);
            assert_eq!(got_a, want, "kind={kind:?}: slot A diverged from solo");
            assert_eq!(ovf_a, ovf_solo);
            arena.register_prefix(a, &prompt);
            assert_eq!(arena.prefix_cache_pages(), 2, "9 tokens / ps=4 → 2 full pages");
            let pages_a = arena.resident_pages();
            let b = arena.alloc().unwrap();
            let (mapped, ovf_adopt) = arena.adopt_prefix(b, &prompt);
            assert_eq!(mapped, 8, "two full pages adopted");
            assert_eq!(arena.len(b), 8);
            assert_eq!(
                arena.resident_pages(),
                pages_a,
                "adoption maps existing pages — nothing new resident"
            );
            assert_eq!(arena.pages_shared(), 2);
            let mut ovf_tail = 0u64;
            let got_b = m.prefill_slot_counted(&prompt[mapped..], b, &mut arena, &mut ovf_tail);
            assert_eq!(got_b, want, "kind={kind:?}: adopted prefill diverged");
            assert_eq!(
                ovf_adopt + ovf_tail,
                ovf_solo,
                "kind={kind:?}: credited + tail events must equal the solo count"
            );
            for layer in 0..m.cfg.n_layers {
                for pos in 0..prompt.len() {
                    assert_eq!(
                        arena.kv_row(layer, b, pos),
                        solo.kv_row(layer, s, pos),
                        "kind={kind:?} layer {layer} pos {pos}"
                    );
                }
            }
            // B's tail page is private: releasing B keeps A intact
            arena.release(b);
            assert_eq!(arena.kv_row(0, a, 0), solo.kv_row(0, s, 0));
        }
    }

    /// Adoption never swallows a whole prompt (the admission must still
    /// prefill ≥ 1 token for final logits), and a truncated slot drops
    /// out of registration.
    #[test]
    fn adoption_and_registration_guards() {
        let m = model(false);
        let ps = 4usize;
        let mut arena = KvArena::with_kind_paged(&m, 2, KvCacheKind::F32, ps);
        let prompt: Vec<u16> = (0..8).map(|i| i as u16).collect(); // exactly 2 pages
        let a = arena.alloc().unwrap();
        m.prefill_slot(&prompt, a, &mut arena);
        arena.register_prefix(a, &prompt);
        let b = arena.alloc().unwrap();
        let (mapped, _) = arena.adopt_prefix(b, &prompt);
        assert_eq!(mapped, 4, "only one page: the second would leave nothing to prefill");
        arena.release(b);
        // a slot that slid via truncate_front is position-shifted and
        // must refuse to register
        arena.truncate_front(a, 2);
        let before = arena.prefix_cache_pages();
        arena.register_prefix(a, &prompt[2..]);
        assert_eq!(arena.prefix_cache_pages(), before, "slid slot must not register");
        // flushing invalidates entries and restarts chains safely
        arena.flush_prefix_cache();
        assert_eq!(arena.prefix_cache_pages(), 0);
        assert_eq!(arena.cache_flushes(), 1);
        let c = arena.alloc().unwrap();
        let (mapped, _) = arena.adopt_prefix(c, &prompt);
        assert_eq!(mapped, 0, "flushed cache has nothing to adopt");
    }

    #[test]
    fn quant_prefill_matches_quant_decode() {
        // On the quantized backend, batched prefill must be bit-exact
        // with token-by-token decode — both attend over the same codes.
        let m = model(true);
        let kind = KvCacheKind::Quant(KvQuantSpec::int8());
        let toks: Vec<u16> = vec![4, 7, 1, 9, 2, 8];
        let mut c1 = KvCache::with_kind(&m, kind);
        let batched = m.prefill(&toks, &mut c1);
        let mut c2 = KvCache::with_kind(&m, kind);
        let mut step = Vec::new();
        for &t in &toks {
            step = m.decode_step(t, &mut c2);
        }
        assert_eq!(batched, step, "quant prefill diverged from quant decode");
        assert_eq!(c1.len(), toks.len());
        // cached rows identical too (codes + scales, via dequant view)
        for layer in 0..m.cfg.n_layers {
            for pos in 0..toks.len() {
                assert_eq!(
                    c1.arena.kv_row(layer, 0, pos),
                    c2.arena.kv_row(layer, 0, pos),
                    "layer {layer} pos {pos}"
                );
            }
        }
    }

    /// THE chunked-prefill kernel property: splitting a prompt into
    /// arbitrary chunks across successive ragged steps must produce the
    /// same cached K/V rows and the same final logits as one-shot
    /// prefill — bit for bit, on both backends, and regardless of the
    /// page size the rows land in.
    #[test]
    fn chunked_prefill_matches_whole_prefill() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            for parallel in [false, true] {
                let m = model(parallel);
                let vocab = m.cfg.vocab;
                let prompt: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5];
                // reference: whole-prompt prefill at the default page size
                let mut arena_w = KvArena::with_kind(&m, 1, kind);
                let sw = arena_w.alloc().unwrap();
                let mut ovf_w = 0u64;
                let want = m.prefill_slot_counted(&prompt, sw, &mut arena_w, &mut ovf_w);
                for ps in [3usize, 16] {
                    for chunks in [&[1usize, 7, 3][..], &[4, 4, 3], &[11], &[1; 11]] {
                        let mut arena = KvArena::with_kind_paged(&m, 1, kind, ps);
                        let slot = arena.alloc().unwrap();
                        let mut scratch = DecodeScratch::new();
                        let mut ovf = 0u64;
                        let mut at = 0usize;
                        for &c in chunks {
                            let group = [RowGroup { slot, start: 0, len: c }];
                            let mut g_ovf = [0u64; 1];
                            m.decode_step_ragged_scratch(
                                &prompt[at..at + c],
                                &group,
                                &mut arena,
                                &mut g_ovf,
                                &mut scratch,
                            );
                            ovf += g_ovf[0];
                            at += c;
                        }
                        assert_eq!(
                            &scratch.step.logits[..vocab],
                            &want[..],
                            "kind={kind:?} parallel={parallel} ps={ps} \
                             chunks={chunks:?}: logits diverge"
                        );
                        assert_eq!(ovf, ovf_w, "chunked overflow attribution diverges");
                        for layer in 0..m.cfg.n_layers {
                            for pos in 0..prompt.len() {
                                assert_eq!(
                                    arena.kv_row(layer, slot, pos),
                                    arena_w.kv_row(layer, sw, pos),
                                    "layer {layer} pos {pos} cached rows diverge"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    /// Mixing a prefill chunk with decode rows in ONE ragged step must
    /// leave every sequence bit-identical to running it alone — the
    /// interleaved-admission invariant the chunked serving engine
    /// rests on.
    #[test]
    fn mixed_chunk_and_decode_step_is_exact() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            let m = model(false);
            let vocab = m.cfg.vocab;
            let decode_seq: Vec<u16> = vec![1, 2, 3, 4, 5];
            let chunk_prompt: Vec<u16> = vec![11, 12, 13, 14];
            // references: each sequence alone
            let mut solo = KvCache::with_kind(&m, kind);
            let mut want_dec = Vec::new();
            for &t in &decode_seq {
                want_dec = m.decode_step(t, &mut solo);
            }
            let mut arena_p = KvArena::with_kind(&m, 1, kind);
            let sp = arena_p.alloc().unwrap();
            let want_chunk = m.prefill_slot(&chunk_prompt, sp, &mut arena_p);
            // mixed: sequence A decodes 4 tokens, then its 5th decode row
            // shares a ragged step with B's whole prompt as one chunk
            let mut arena = KvArena::with_kind(&m, 2, kind);
            let sa = arena.alloc().unwrap();
            let sb = arena.alloc().unwrap();
            let mut scratch = DecodeScratch::new();
            let mut row = [0u64; 1];
            for &t in &decode_seq[..4] {
                row[0] = 0;
                m.decode_step_batch_scratch(&[t], &[sa], &mut arena, &mut row, &mut scratch);
            }
            let mut tokens = vec![decode_seq[4]];
            tokens.extend_from_slice(&chunk_prompt);
            let groups = [
                RowGroup { slot: sa, start: 0, len: 1 },
                RowGroup { slot: sb, start: 1, len: chunk_prompt.len() },
            ];
            let mut g_ovf = [0u64; 2];
            m.decode_step_ragged_scratch(&tokens, &groups, &mut arena, &mut g_ovf, &mut scratch);
            assert_eq!(
                &scratch.step.logits[..vocab],
                &want_dec[..],
                "kind={kind:?}: decode row diverged when sharing a step with a chunk"
            );
            assert_eq!(
                &scratch.step.logits[vocab..2 * vocab],
                &want_chunk[..],
                "kind={kind:?}: chunk logits diverged when sharing a step with decode rows"
            );
            assert_eq!(arena.len(sa), 5);
            assert_eq!(arena.len(sb), chunk_prompt.len());
            for layer in 0..m.cfg.n_layers {
                for pos in 0..chunk_prompt.len() {
                    assert_eq!(
                        arena.kv_row(layer, sb, pos),
                        arena_p.kv_row(layer, sp, pos),
                        "kind={kind:?} layer {layer} pos {pos}"
                    );
                }
            }
        }
    }

    /// Ragged-step guards: malformed group lists must be rejected.
    #[test]
    fn ragged_step_guards() {
        let m = model(false);
        let arena = KvArena::new(&m, 2);
        // groups must tile the token slice
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a = arena.clone();
            let s = a.alloc().unwrap();
            let groups = [RowGroup { slot: s, start: 1, len: 1 }];
            let mut scratch = DecodeScratch::new();
            m.decode_step_ragged_scratch(&[1, 2], &groups, &mut a, &mut [0], &mut scratch);
        }));
        assert!(r.is_err(), "a gap before the first group must be rejected");
        // a chunk past the window must be rejected
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a = arena.clone();
            let s = a.alloc().unwrap();
            let toks: Vec<u16> = (0..17).map(|i| i as u16).collect();
            let groups = [RowGroup { slot: s, start: 0, len: 17 }];
            let mut scratch = DecodeScratch::new();
            m.decode_step_ragged_scratch(&toks, &groups, &mut a, &mut [0], &mut scratch);
        }));
        assert!(r.is_err(), "a chunk past the window must be rejected");
        // one slot in two groups must be rejected
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a = arena.clone();
            let s = a.alloc().unwrap();
            let groups = [
                RowGroup { slot: s, start: 0, len: 1 },
                RowGroup { slot: s, start: 1, len: 1 },
            ];
            let mut scratch = DecodeScratch::new();
            m.decode_step_ragged_scratch(&[1, 2], &groups, &mut a, &mut [0, 0], &mut scratch);
        }));
        assert!(r.is_err(), "one slot in two groups must be rejected");
    }

    /// Unified accounting: attention overflow events on the quantized
    /// backend land on the model-wide `Transformer::overflow_events`
    /// counter (next to quantized-linear events) AND in the per-row
    /// attribution — one number for eval and serve.
    #[test]
    fn attention_overflows_join_the_model_counter() {
        let m = model(false); // float linears: only attention can overflow
        let kind = KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6))); // hopeless width
        let mut arena = KvArena::with_kind(&m, 1, kind);
        let slot = arena.alloc().unwrap();
        let before = m.overflow_events();
        assert_eq!(m.attention_overflow_events(), 0);
        let mut attributed = 0u64;
        let mut row = vec![0u64; 1];
        for t in 0..6u16 {
            row[0] = 0;
            m.decode_step_batch_counted(&[t % 48], &[slot], &mut arena, &mut row);
            attributed += row[0];
        }
        assert!(attributed > 0, "the narrow attention register must overflow");
        assert_eq!(
            m.overflow_events() - before,
            attributed,
            "model-wide counter must equal the attributed attention events"
        );
        assert_eq!(m.attention_overflow_events(), attributed);
    }

    /// The band partition covers every group exactly once in order
    /// (monotone bounds), at every band count, and isolates dominant
    /// work items.
    #[test]
    fn band_bounds_is_contiguous_exhaustive_and_balanced() {
        let profiles: [&[usize]; 5] =
            [&[1, 1, 1, 1], &[100, 1, 1, 1], &[1, 1, 1, 100], &[0, 0, 5, 0], &[3]];
        for w in profiles {
            for bands in 1..=6usize {
                let b = band_bounds(w.iter().copied(), bands);
                assert_eq!(b.len(), bands + 1, "{w:?} bands={bands}");
                assert_eq!(b[0], 0);
                assert_eq!(b[bands], w.len(), "{w:?} bands={bands}: items dropped");
                for i in 1..=bands {
                    assert!(b[i - 1] <= b[i], "{w:?} bands={bands}: non-monotone {b:?}");
                }
            }
        }
        // uniform work splits in half; a dominant item gets its own band
        assert_eq!(band_bounds([1usize, 1, 1, 1].into_iter(), 2), vec![0, 2, 4]);
        assert_eq!(band_bounds([100usize, 1, 1, 1].into_iter(), 2), vec![0, 1, 4]);
    }

    /// Tentpole parity: the banded attention sweep is bit-identical to
    /// the serial oracle — logits, per-group overflow attribution, and
    /// cached rows — at every thread count, on both backends (the
    /// narrow quant spec keeps attention overflow events live).
    #[test]
    fn parallel_attention_bands_match_serial_oracle() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)))] {
            let m = model(false);
            let vocab = m.cfg.vocab;
            // one ragged step mixing a warm decode row with two fresh
            // prefill chunks — three groups with skewed work
            let build = |threads: usize| {
                let mut arena = KvArena::with_kind(&m, 3, kind);
                let sa = arena.alloc().unwrap();
                let sb = arena.alloc().unwrap();
                let sc = arena.alloc().unwrap();
                let mut scratch = DecodeScratch::new();
                if threads > 1 {
                    scratch.set_attn_threads(&m.cfg, threads);
                    scratch.set_attn_par_min_work(0);
                }
                let mut row = [0u64; 1];
                for &t in &[1u16, 2, 3, 4] {
                    row[0] = 0;
                    m.decode_step_batch_scratch(&[t], &[sa], &mut arena, &mut row, &mut scratch);
                }
                let tokens: Vec<u16> = vec![5, 11, 12, 13, 14, 15, 21, 22, 23];
                let groups = [
                    RowGroup { slot: sa, start: 0, len: 1 },
                    RowGroup { slot: sb, start: 1, len: 5 },
                    RowGroup { slot: sc, start: 6, len: 3 },
                ];
                let mut g_ovf = [0u64; 3];
                m.decode_step_ragged_scratch(&tokens, &groups, &mut arena, &mut g_ovf, &mut scratch);
                (scratch.step.logits[..3 * vocab].to_vec(), g_ovf, arena)
            };
            let (want_logits, want_ovf, want_arena) = build(1);
            for threads in [2usize, 8] {
                let (logits, ovf, arena) = build(threads);
                assert_eq!(
                    logits, want_logits,
                    "kind={kind:?} threads={threads}: logits diverged"
                );
                assert_eq!(
                    ovf, want_ovf,
                    "kind={kind:?} threads={threads}: overflow attribution diverged"
                );
                for layer in 0..m.cfg.n_layers {
                    for slot in 0..3 {
                        for pos in 0..arena.len(slot) {
                            assert_eq!(
                                arena.kv_row(layer, slot, pos),
                                want_arena.kv_row(layer, slot, pos),
                                "kind={kind:?} threads={threads} layer {layer} \
                                 slot {slot} pos {pos}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Satellite: concurrent same-prefix admissions prefill privately
    /// before either registers; the second registration must remap its
    /// table onto the cached twin pages and free the duplicates.
    #[test]
    fn registration_dedup_remaps_onto_cached_twin() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            let m = model(false);
            let ps = 4usize;
            let prompt: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5]; // 2 full pages + tail
            let mut arena = KvArena::with_kind_paged(&m, 2, kind, ps);
            let a = arena.alloc().unwrap();
            let b = arena.alloc().unwrap();
            // both prefill privately (nothing cached yet, so no adoption)
            m.prefill_slot(&prompt, a, &mut arena);
            m.prefill_slot(&prompt, b, &mut arena);
            let resident_dup = arena.resident_pages();
            arena.register_prefix(a, &prompt);
            assert_eq!(arena.pages_deduped(), 0, "first registration only caches");
            let snapshot: Vec<_> = (0..prompt.len()).map(|p| arena.kv_row(0, b, p)).collect();
            arena.register_prefix(b, &prompt);
            assert_eq!(arena.pages_deduped(), 2, "kind={kind:?}: both full pages remap");
            assert_eq!(
                arena.resident_pages(),
                resident_dup - 2,
                "kind={kind:?}: duplicate pages must free immediately"
            );
            // B reads identically through the remapped table…
            for (p, want) in snapshot.iter().enumerate() {
                assert_eq!(&arena.kv_row(0, b, p), want, "kind={kind:?} pos {p}");
            }
            // …and keeps decoding exactly (tail page stays private)
            let mut solo = KvArena::with_kind_paged(&m, 1, kind, ps);
            let s = solo.alloc().unwrap();
            m.prefill_slot(&prompt, s, &mut solo);
            let want = m.decode_step_batch(&[7], &[s], &mut solo);
            let got = m.decode_step_batch(&[7], &[b], &mut arena);
            assert_eq!(got, want, "kind={kind:?}: remapped slot diverged");
            // releasing A keeps B alive on the now-shared pages
            arena.release(a);
            assert_eq!(&arena.kv_row(0, b, 0), &snapshot[0]);
        }
    }

    /// Satellite: allocation pressure evicts unreferenced cache entries
    /// oldest-first — a hot prefix still mapped into a live slot stays
    /// resident and adoptable through arbitrary churn.
    #[test]
    fn pressure_evicts_unreferenced_cache_entries_oldest_first() {
        let m = model(false);
        let ps = 4usize;
        // pool: 2 slots × (16/4 + 1) = 10 pages
        let mut arena = KvArena::with_kind_paged(&m, 2, KvCacheKind::F32, ps);
        let hot: Vec<u16> = (30..39).collect(); // 2 full pages + tail
        let h = arena.alloc().unwrap();
        m.prefill_slot(&hot, h, &mut arena);
        arena.register_prefix(h, &hot); // entries 0,1 — referenced by h
        // churn: distinct prompts fill the cache until the pool runs dry
        for r in 0..4u16 {
            let p: Vec<u16> = (0..9).map(|i| (r * 9 + i) % 48).collect();
            let t = arena.alloc().unwrap();
            m.prefill_slot(&p, t, &mut arena);
            arena.register_prefix(t, &p);
            arena.release(t);
        }
        assert_eq!(arena.cache_evictions(), 2, "round 3 must evict two cold entries");
        assert_eq!(arena.cache_flushes(), 0, "pressure must not flush anymore");
        // the hot prefix survived the churn: still adoptable in full
        let f = arena.alloc().unwrap();
        let (mapped, _) = arena.adopt_prefix(f, &hot);
        assert_eq!(mapped, 8, "hot entries must survive eviction under pressure");
    }

    /// The verify logits shape: a [`LogitRows::All`] step over one
    /// multi-row group yields, at every row, logits bit-identical to
    /// sequential decode at that position — the property greedy
    /// acceptance rests on.
    #[test]
    fn all_logit_rows_match_sequential_decode() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            let m = model(false);
            let vocab = m.cfg.vocab;
            let toks: Vec<u16> = vec![3, 1, 4, 1, 5, 9];
            let mut cache = KvCache::with_kind(&m, kind);
            let want: Vec<Vec<f32>> = toks.iter().map(|&t| m.decode_step(t, &mut cache)).collect();
            let mut arena = KvArena::with_kind(&m, 1, kind);
            let slot = arena.alloc().unwrap();
            let mut scratch = DecodeScratch::new();
            let groups = [RowGroup { slot, start: 0, len: toks.len() }];
            let mut g_ovf = [0u64; 1];
            m.decode_step_ragged_opts(
                &toks,
                &groups,
                &mut arena,
                &mut g_ovf,
                &mut scratch,
                RaggedOpts::verify(),
            );
            for (i, w) in want.iter().enumerate() {
                assert_eq!(
                    &scratch.step.logits[i * vocab..(i + 1) * vocab],
                    &w[..],
                    "kind={kind:?}: verify logits row {i} diverged from sequential decode"
                );
            }
        }
    }

    /// The tentpole oracle at model level: a full self-speculative
    /// loop — narrow-register draft rounds, tail rollback, one
    /// full-width k-row verify, longest-matching-prefix acceptance —
    /// reproduces non-speculative greedy generation bit for bit,
    /// including every cached K/V row, on both backends.
    #[test]
    fn draft_verify_composition_reproduces_plain_decode() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            let m = model(false);
            let vocab = m.cfg.vocab;
            let prompt: Vec<u16> = vec![3, 1, 4];
            let n = 8usize;
            let k = 3usize; // chunk depth: 1 sampled + up to 2 drafts
            let want = m.generate_greedy_with(&prompt, n, kind);
            // non-speculative arena for the final cached-row comparison
            let mut plain = KvArena::with_kind(&m, 1, kind);
            let ps = plain.alloc().unwrap();
            m.prefill_slot(&prompt, ps, &mut plain);
            for &t in &want[prompt.len()..] {
                m.decode_step_batch(&[t], &[ps], &mut plain);
            }
            let mut arena = KvArena::with_kind(&m, 1, kind);
            let slot = arena.alloc().unwrap();
            let mut scratch = DecodeScratch::new();
            let mut draft = DecodeScratch::new();
            let mut ovf = 0u64;
            m.prefill_slot_scratch(&prompt, slot, &mut arena, &mut ovf, &mut scratch);
            let mut out = prompt.to_vec();
            let mut accepted_drafts = 0usize;
            while out.len() < prompt.len() + n {
                // c1 is sampled from committed full-width logits; the
                // drafts extend it on 4-bit inner registers
                let c1 = argmax(&scratch.step.logits[..vocab]) as u16;
                let remaining = prompt.len() + n - out.len();
                let space = m.cfg.max_seq - arena.len(slot);
                let l = k.min(remaining).min(space);
                let mut chunk = vec![c1];
                for _ in 1..l {
                    let groups = [RowGroup { slot, start: 0, len: 1 }];
                    let mut g = [0u64; 1];
                    m.decode_step_ragged_opts(
                        &[*chunk.last().unwrap()],
                        &groups,
                        &mut arena,
                        &mut g,
                        &mut draft,
                        RaggedOpts::draft(Some(4)),
                    );
                    chunk.push(argmax(&draft.step.logits[..vocab]) as u16);
                }
                // roll the draft appends back, then re-encode the whole
                // chunk full-width in one k-row verify group
                arena.truncate_tail(slot, chunk.len() - 1);
                let groups = [RowGroup { slot, start: 0, len: chunk.len() }];
                let mut g = [0u64; 1];
                m.decode_step_ragged_opts(
                    &chunk,
                    &groups,
                    &mut arena,
                    &mut g,
                    &mut scratch,
                    RaggedOpts::verify(),
                );
                // longest matching prefix: draft i stands iff the
                // full-width argmax after chunk[..i] agrees with it
                out.push(c1);
                let mut acc = 1usize;
                while acc < chunk.len() {
                    let t = argmax(&scratch.step.logits[(acc - 1) * vocab..acc * vocab]) as u16;
                    if t != chunk[acc] {
                        break;
                    }
                    out.push(t);
                    accepted_drafts += 1;
                    acc += 1;
                }
                arena.truncate_tail(slot, chunk.len() - acc);
                // the row after the last accepted token seeds the next
                // chunk (exactly the logits plain decode would hold)
                scratch.step.logits.copy_within((acc - 1) * vocab..acc * vocab, 0);
            }
            assert_eq!(out, want, "kind={kind:?}: speculative stream diverged");
            assert_eq!(arena.len(slot), plain.len(ps), "kind={kind:?}: lengths diverged");
            for layer in 0..m.cfg.n_layers {
                for pos in 0..arena.len(slot) {
                    assert_eq!(
                        arena.kv_row(layer, slot, pos),
                        plain.kv_row(layer, ps, pos),
                        "kind={kind:?} layer {layer} pos {pos}: cached rows diverged"
                    );
                }
            }
            // the harness is only meaningful if drafting actually ran
            assert!(accepted_drafts > 0 || k == 1, "kind={kind:?}: no draft ever accepted");
        }
    }
}
