//! MLP image classifiers (the "glyph" family — this repo's stand-in for
//! ResNet18 / MobileNetV2 / ViT-B-32, see DESIGN.md §2):
//!
//! - `glyph-res`        — deep residual MLP (ResNet analog)
//! - `glyph-bottleneck` — narrow inverted-bottleneck MLP (MobileNet analog)
//! - `glyph-mlp`        — plain wide MLP (dense baseline)

use super::layers::Activation;
use super::linear::{FloatLinear, Linear};
use super::transformer::Capture;

/// MLP architecture.
#[derive(Clone, Debug)]
pub struct MlpConfig {
    pub name: String,
    pub input_dim: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
    pub act: Activation,
    /// Add identity skip connections between equal-width layers.
    pub residual: bool,
}

impl MlpConfig {
    pub fn param_count(&self) -> usize {
        let mut n = 0;
        let mut prev = self.input_dim;
        for &h in &self.hidden {
            n += prev * h + h;
            prev = h;
        }
        n + prev * self.classes + self.classes
    }
}

/// Feed-forward classifier.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub cfg: MlpConfig,
    pub layers: Vec<Linear>,
    /// Final classification head (kept 8-bit/float per paper App. C.1).
    pub head: FloatLinear,
}

impl Mlp {
    pub fn linear_names(&self) -> Vec<String> {
        (0..self.layers.len()).map(|i| format!("l{i}")).collect()
    }

    /// Each hidden layer is its own "block" for prefix refresh purposes.
    pub fn block_groups(&self) -> Vec<Vec<String>> {
        self.linear_names().into_iter().map(|n| vec![n]).collect()
    }

    pub fn get_linear(&self, name: &str) -> Option<&Linear> {
        let i: usize = name.strip_prefix('l')?.parse().ok()?;
        self.layers.get(i)
    }

    pub fn get_linear_mut(&mut self, name: &str) -> Option<&mut Linear> {
        let i: usize = name.strip_prefix('l')?.parse().ok()?;
        self.layers.get_mut(i)
    }

    /// Forward one input row to class logits.
    pub fn forward(&self, x: &[f32], mut capture: Option<&mut Capture>) -> Vec<f32> {
        assert_eq!(x.len(), self.cfg.input_dim);
        let mut cur = x.to_vec();
        let mut scratch: Vec<i64> = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            if let Some(c) = capture.as_deref_mut() {
                c.record(&format!("l{i}"), &cur);
            }
            let mut out = vec![0.0f32; layer.out_dim()];
            layer.forward_row(&cur, &mut out, &mut scratch);
            self.cfg.act.apply_vec(&mut out);
            if self.cfg.residual && out.len() == cur.len() {
                for (o, c) in out.iter_mut().zip(cur.iter()) {
                    *o += c;
                }
            }
            cur = out;
        }
        let mut logits = vec![0.0f32; self.cfg.classes];
        self.head.forward_row(&cur, &mut logits);
        logits
    }

    pub fn overflow_events(&self) -> u64 {
        self.layers
            .iter()
            .filter_map(|l| l.as_quant())
            .map(|q| q.overflow_count())
            .sum()
    }
}

/// Randomly-initialized MLP for tests.
pub fn random_mlp(cfg: MlpConfig, seed: u64) -> Mlp {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut prev = cfg.input_dim;
    for &h in &cfg.hidden {
        let std = (2.0 / prev as f64).sqrt();
        let w: Vec<f32> = (0..prev * h).map(|_| (rng.normal() * std) as f32).collect();
        layers.push(Linear::Float(FloatLinear::new(prev, h, w, vec![0.0; h])));
        prev = h;
    }
    let w: Vec<f32> =
        (0..prev * cfg.classes).map(|_| (rng.normal() * 0.05) as f32).collect();
    let head = FloatLinear::new(prev, cfg.classes, w, vec![0.0; cfg.classes]);
    Mlp { cfg, layers, head }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(residual: bool) -> MlpConfig {
        MlpConfig {
            name: "t".into(),
            input_dim: 16,
            hidden: vec![24, 24, 24],
            classes: 5,
            act: Activation::Relu,
            residual,
        }
    }

    #[test]
    fn forward_shapes() {
        let m = random_mlp(cfg(false), 1);
        let x = vec![0.5f32; 16];
        let y = m.forward(&x, None);
        assert_eq!(y.len(), 5);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn residual_changes_output() {
        let m1 = random_mlp(cfg(false), 2);
        let mut m2 = m1.clone();
        m2.cfg.residual = true;
        let x = vec![0.3f32; 16];
        let y1 = m1.forward(&x, None);
        let y2 = m2.forward(&x, None);
        assert!(y1.iter().zip(&y2).any(|(a, b)| (a - b).abs() > 1e-6));
    }

    #[test]
    fn capture_per_layer() {
        let m = random_mlp(cfg(false), 3);
        let mut cap = Capture::for_layers(&m.linear_names());
        m.forward(&[0.1; 16], Some(&mut cap));
        m.forward(&[0.2; 16], Some(&mut cap));
        let x0 = cap.matrix_kd("l0").unwrap();
        assert_eq!(x0.rows(), 16);
        assert_eq!(x0.cols(), 2);
        let x1 = cap.matrix_kd("l1").unwrap();
        assert_eq!(x1.rows(), 24);
    }

    #[test]
    fn accessors() {
        let mut m = random_mlp(cfg(false), 4);
        assert!(m.get_linear("l0").is_some());
        assert!(m.get_linear("l3").is_none());
        assert!(m.get_linear_mut("l2").is_some());
        assert_eq!(m.linear_names(), vec!["l0", "l1", "l2"]);
    }

    #[test]
    fn param_count() {
        let c = cfg(false);
        assert_eq!(c.param_count(), 16 * 24 + 24 + 24 * 24 + 24 + 24 * 24 + 24 + 24 * 5 + 5);
    }
}
