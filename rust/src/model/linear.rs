//! Linear layers: float reference and the integer-datapath quantized
//! version.
//!
//! Quantized layers execute on the fused tiled integer GEMM kernel
//! ([`crate::linalg::qgemm`]), which is bit-for-bit equal to the scalar
//! per-MAC accumulator simulator (the audit oracle in [`crate::accum`])
//! while running at plain-matmul speed whenever the overflow-avoidance
//! guarantee holds.
//!
//! Batched forwards come in two flavours: the `_scratch` entry points
//! stream their operand buffers (quantized codes, raw accumulators,
//! per-row overflow counters; f64 staging for the float path) through a
//! caller-owned [`LinearScratch`] and perform **zero heap allocations**
//! in steady state — the decode hot path — while the plain
//! `forward_rows` / `forward_rows_counted` wrappers build a transient
//! workspace per call (evaluation and calibration, where a per-call
//! allocation is irrelevant). Both produce bit-identical results.

use super::scratch::LinearScratch;
use crate::accum::simulator::{AccumSpec, OverflowMode};
use crate::linalg::qgemm;
use crate::quant::{ActQuantizer, QuantResult};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Lazily built f64 copy of a [`FloatLinear`]'s weights, valid while
/// its recorded version matches the layer's mutation counter.
#[derive(Clone, Debug, Default)]
struct WidenedW {
    /// Layer version this copy was widened from (0 = never built;
    /// layer versions start at 1).
    version: u64,
    /// [out, in] row-major weights widened to f64.
    fw: Vec<f64>,
}

/// Plain f32 linear layer, weights stored [out, in] row-major.
///
/// The batched forward runs a banded f64 GEMM over an f64 copy of the
/// weights. That copy is **cached behind a mutation-bumped version**:
/// the weight buffer is private and every in-place rescale goes through
/// [`FloatLinear::w_mut`], which bumps `version` and thereby
/// invalidates the cache — calibration (SmoothQuant / equalization)
/// can still rewrite weights freely, while serving re-widens only when
/// something actually changed instead of once per decode step.
#[derive(Debug)]
pub struct FloatLinear {
    pub in_dim: usize,
    pub out_dim: usize,
    /// [out, in] row-major — private so every mutation goes through
    /// [`FloatLinear::w_mut`] and the widened cache can never go stale.
    w: Vec<f32>,
    pub b: Vec<f32>,
    /// Bumped by every [`FloatLinear::w_mut`] borrow.
    version: u64,
    cache: RwLock<WidenedW>,
}

impl Clone for FloatLinear {
    fn clone(&self) -> FloatLinear {
        FloatLinear {
            in_dim: self.in_dim,
            out_dim: self.out_dim,
            w: self.w.clone(),
            b: self.b.clone(),
            version: self.version,
            // a warm cache stays warm across clones
            cache: RwLock::new(self.cache.read().unwrap().clone()),
        }
    }
}

impl FloatLinear {
    pub fn new(in_dim: usize, out_dim: usize, w: Vec<f32>, b: Vec<f32>) -> FloatLinear {
        assert_eq!(w.len(), in_dim * out_dim);
        assert_eq!(b.len(), out_dim);
        FloatLinear { in_dim, out_dim, w, b, version: 1, cache: RwLock::new(WidenedW::default()) }
    }

    pub fn zeros(in_dim: usize, out_dim: usize) -> FloatLinear {
        FloatLinear::new(in_dim, out_dim, vec![0.0; in_dim * out_dim], vec![0.0; out_dim])
    }

    /// The weights, [out, in] row-major.
    pub fn w(&self) -> &[f32] {
        &self.w
    }

    /// Mutable weights — the only mutation path. Bumps the version so
    /// the next batched forward re-widens instead of serving a stale
    /// f64 copy (tested below).
    pub fn w_mut(&mut self) -> &mut [f32] {
        self.version = self.version.wrapping_add(1);
        &mut self.w
    }

    /// Read guard over the up-to-date widened weights, rebuilding them
    /// under the write lock when the version moved. Steady-state
    /// serving takes the read path only: no allocation, no copy.
    fn widened(&self) -> std::sync::RwLockReadGuard<'_, WidenedW> {
        {
            let r = self.cache.read().unwrap();
            if r.version == self.version {
                return r;
            }
        }
        {
            let mut c = self.cache.write().unwrap();
            if c.version != self.version {
                c.fw.clear();
                c.fw.extend(self.w.iter().map(|&x| x as f64));
                c.version = self.version;
            }
        }
        self.cache.read().unwrap()
    }

    /// y = W x + b for one input row.
    pub fn forward_row(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(y.len(), self.out_dim);
        for (o, yo) in y.iter_mut().enumerate() {
            let row = &self.w[o * self.in_dim..(o + 1) * self.in_dim];
            let mut s = 0.0f32;
            for (wi, xi) in row.iter().zip(x.iter()) {
                s += wi * xi;
            }
            *yo = s + self.b[o];
        }
    }

    /// Batched y = W x + b over `rows` stacked input rows, routed
    /// through the banded multi-threaded f64 GEMM
    /// ([`crate::linalg::gemm_bt_into`]) — the float-path analogue of
    /// the fused qgemm dispatch, so float baselines and mixed models
    /// batch the same way quantized ones do. Allocates a transient
    /// workspace; the decode hot path uses
    /// [`FloatLinear::forward_rows_scratch`] instead.
    ///
    /// Every output row is computed independently of its batchmates
    /// (the GEMM parallelizes over row bands and accumulates each
    /// element sequentially in f64), so per-row results are
    /// **batch-size invariant** — the property batched decode's
    /// token-exactness rests on.
    pub fn forward_rows(&self, xs: &[f32], rows: usize, ys: &mut [f32]) {
        self.forward_rows_scratch(xs, rows, ys, &mut LinearScratch::new());
    }

    /// [`FloatLinear::forward_rows`] over a caller-owned workspace:
    /// activations are widened into the scratch f64 buffer and the GEMM
    /// lands in a scratch accumulator, so a warm workspace makes the
    /// whole forward allocation-free.
    ///
    /// The weight operand comes from the layer's **widened cache**:
    /// widening f32→f64 is exact, so the cached copy is bit-identical
    /// to an in-call widening, and the mutation-bumped version
    /// guarantees a calibration-time rescale (via
    /// [`FloatLinear::w_mut`]) rebuilds it before the next forward —
    /// serving drops the former once-per-step O(out·in) widening pass
    /// without any staleness risk. A cheaper rows==1 special case
    /// remains ruled out: every row must be computed identically at
    /// every batch size.
    pub fn forward_rows_scratch(
        &self,
        xs: &[f32],
        rows: usize,
        ys: &mut [f32],
        scratch: &mut LinearScratch,
    ) {
        debug_assert_eq!(xs.len(), rows * self.in_dim);
        debug_assert_eq!(ys.len(), rows * self.out_dim);
        let (k, c) = (self.in_dim, self.out_dim);
        scratch.ensure_float(rows, k, c);
        let fa = &mut scratch.fa[..rows * k];
        for (dst, &src) in fa.iter_mut().zip(xs.iter()) {
            *dst = src as f64;
        }
        let fy = &mut scratch.fy[..rows * c];
        let cache = self.widened();
        crate::linalg::gemm_bt_into(fa, &cache.fw[..c * k], rows, k, c, fy);
        drop(cache);
        for r in 0..rows {
            let yrow = &mut ys[r * c..(r + 1) * c];
            let arow = &fy[r * c..(r + 1) * c];
            for (o, (yo, &acc)) in yrow.iter_mut().zip(arow.iter()).enumerate() {
                *yo = acc as f32 + self.b[o];
            }
        }
    }

    /// Weight matrix as K×C f64 (input-major) for the PTQ algorithms.
    pub fn weights_kc(&self) -> crate::linalg::Mat {
        crate::linalg::Mat::from_fn(self.in_dim, self.out_dim, |k, c| {
            self.w[c * self.in_dim + k] as f64
        })
    }
}

/// How the integer dot products are executed.
#[derive(Clone, Copy, Debug)]
pub enum Datapath {
    /// Exact i64 accumulation — valid stand-in when overflow is
    /// guaranteed absent; the fast evaluation path.
    Exact,
    /// Faithful simulation: tiles of `tile` accumulate in `inner`-bit
    /// registers, partial sums in `outer`-bit registers, with the given
    /// overflow behaviour. `tile >= in_dim` models a monolithic
    /// accumulator.
    Simulated { tile: usize, inner_bits: u32, outer_bits: u32, mode: OverflowMode },
}

impl Datapath {
    /// Copy of this datapath with the inner registers narrowed to at
    /// most `bits` (clamped to the 2-bit floor; never widens). `Exact`
    /// stays exact — there is no register to narrow. The
    /// self-speculative draft pass runs every quantized linear through
    /// this: same stored codes and scales, narrower accumulators, so a
    /// draft model costs zero extra weight memory.
    pub fn narrowed(&self, bits: u32) -> Datapath {
        match *self {
            Datapath::Exact => Datapath::Exact,
            Datapath::Simulated { tile, inner_bits, outer_bits, mode } => Datapath::Simulated {
                tile,
                inner_bits: inner_bits.min(bits.max(2)),
                outer_bits,
                mode,
            },
        }
    }
}

/// Quantized linear layer executing on the integer datapath.
///
/// Weights are integer codes with per-channel scales; input activations
/// are quantized to unsigned `act.bits`-bit codes on entry. The
/// zero-point correction term z·Σq is applied after accumulation, as
/// real kernels do.
#[derive(Debug)]
pub struct QuantLinear {
    pub in_dim: usize,
    pub out_dim: usize,
    /// [out, in] row-major codes.
    pub codes: Vec<i32>,
    /// Per-output-channel weight scale.
    pub scales: Vec<f32>,
    /// Per-output-channel Σ_k q (zero-point correction).
    pub code_sums: Vec<i64>,
    pub bias: Vec<f32>,
    pub act: ActQuantizer,
    pub datapath: Datapath,
    /// Optional QuaRot-style input rotation (paper §5 future work);
    /// applied to the activation row before quantization. The weights
    /// were rotated correspondingly at quantization time.
    pub rotation: Option<crate::quant::rotation::Rotation>,
    /// Overflow events observed during forward passes (Simulated only).
    pub overflow_events: AtomicU64,
    /// MAC count processed (for overflow-rate reporting).
    pub macs: AtomicU64,
}

impl Clone for QuantLinear {
    fn clone(&self) -> Self {
        QuantLinear {
            in_dim: self.in_dim,
            out_dim: self.out_dim,
            codes: self.codes.clone(),
            scales: self.scales.clone(),
            code_sums: self.code_sums.clone(),
            bias: self.bias.clone(),
            act: self.act,
            datapath: self.datapath,
            rotation: self.rotation.clone(),
            overflow_events: AtomicU64::new(self.overflow_events.load(Ordering::Relaxed)),
            macs: AtomicU64::new(self.macs.load(Ordering::Relaxed)),
        }
    }
}

impl QuantLinear {
    /// Assemble from a PTQ result (K×C codes) plus the layer's bias and
    /// input activation quantizer.
    pub fn from_result(
        result: &QuantResult,
        bias: Vec<f32>,
        act: ActQuantizer,
        datapath: Datapath,
    ) -> QuantLinear {
        let (k, c) = (result.k, result.c);
        assert_eq!(bias.len(), c);
        // transpose K×C -> [out, in]
        let mut codes = vec![0i32; k * c];
        for i in 0..k {
            for ch in 0..c {
                codes[ch * k + i] = result.code(i, ch) as i32;
            }
        }
        let code_sums = result.channel_sums();
        QuantLinear {
            in_dim: k,
            out_dim: c,
            codes,
            scales: result.scales.iter().map(|&s| s as f32).collect(),
            code_sums,
            bias,
            act,
            datapath,
            rotation: None,
            overflow_events: AtomicU64::new(0),
            macs: AtomicU64::new(0),
        }
    }

    /// Quantize an input row into integer codes.
    pub fn quantize_input(&self, x: &[f32], codes: &mut [i64]) {
        debug_assert_eq!(x.len(), self.in_dim);
        for (c, &v) in codes.iter_mut().zip(x.iter()) {
            *c = self.act.to_code(v as f64);
        }
    }

    /// Run the integer datapath kernel over `rows` quantized input rows,
    /// writing raw accumulator outputs and per-row overflow-event
    /// counts into `row_ovf` (overwrite semantics; all zeros on the
    /// Exact datapath, which cannot overflow by construction). The
    /// datapath is a parameter so the speculative draft pass can run
    /// the same layer through [`Datapath::narrowed`] registers without
    /// touching the stored configuration.
    fn run_kernel(
        &self,
        dp: Datapath,
        x_codes: &[i64],
        rows: usize,
        acc: &mut [i64],
        row_ovf: &mut [u64],
    ) {
        match dp {
            Datapath::Exact => {
                qgemm::qgemm_exact(x_codes, rows, &self.codes, self.out_dim, self.in_dim, acc);
                row_ovf.fill(0);
            }
            Datapath::Simulated { tile, inner_bits, outer_bits, mode } => qgemm::qgemm_multistage(
                x_codes,
                rows,
                &self.codes,
                self.out_dim,
                self.in_dim,
                tile,
                AccumSpec::new(inner_bits, mode),
                AccumSpec::new(outer_bits, mode),
                acc,
                row_ovf,
            ),
        }
    }

    /// Dequantize raw accumulator outputs: zero-point correction, weight
    /// and activation scales, bias.
    fn dequant_rows(&self, acc: &[i64], rows: usize, ys: &mut [f32]) {
        let sx = self.act.scale as f32;
        let zp = self.act.zero_point;
        for r in 0..rows {
            let arow = &acc[r * self.out_dim..(r + 1) * self.out_dim];
            let yrow = &mut ys[r * self.out_dim..(r + 1) * self.out_dim];
            for o in 0..self.out_dim {
                let corrected = arow[o] - zp * self.code_sums[o];
                yrow[o] = self.scales[o] * sx * corrected as f32 + self.bias[o];
            }
        }
    }

    /// y = dequant(∫ integer-datapath(W_q, x_q)) + b for one input row.
    /// `x_codes` is scratch of length in_dim.
    pub fn forward_row(&self, x: &[f32], y: &mut [f32], x_codes: &mut [i64]) {
        debug_assert_eq!(y.len(), self.out_dim);
        if let Some(rot) = &self.rotation {
            // online rotation: x' = Rᵀx (O(K log b) FWHT), then quantize
            let mut xr = x.to_vec();
            rot.apply_row(&mut xr);
            self.quantize_input(&xr, x_codes);
        } else {
            self.quantize_input(x, x_codes);
        }
        let mut acc = vec![0i64; self.out_dim];
        let mut row1 = [0u64; 1];
        self.run_kernel(self.datapath, &x_codes[..self.in_dim], 1, &mut acc, &mut row1);
        self.dequant_rows(&acc, 1, y);
        if row1[0] > 0 {
            self.overflow_events.fetch_add(row1[0], Ordering::Relaxed);
        }
        self.macs.fetch_add((self.in_dim * self.out_dim) as u64, Ordering::Relaxed);
    }

    /// Batched forward over `rows` stacked input rows — the prefill /
    /// calibration fast path. One fused kernel call covers every row and
    /// output channel, so the thread-parallel channel bands amortize
    /// across the whole batch.
    pub fn forward_rows(&self, xs: &[f32], rows: usize, ys: &mut [f32]) {
        self.forward_rows_counted(xs, rows, ys, &mut []);
    }

    /// [`QuantLinear::forward_rows`] that additionally **attributes**
    /// overflow events to the rows that produced them: `row_ovf[r]` is
    /// incremented by the events row `r` triggered (pass `&mut []` to
    /// skip attribution). The serving engine threads per-request
    /// counters through here so each [`crate::coordinator::serve::Response`]
    /// carries an exact overflow count rather than a batch-window bound.
    pub fn forward_rows_counted(
        &self,
        xs: &[f32],
        rows: usize,
        ys: &mut [f32],
        row_ovf: &mut [u64],
    ) {
        self.forward_rows_scratch(xs, rows, ys, row_ovf, &mut LinearScratch::new());
    }

    /// [`QuantLinear::forward_rows_counted`] over a caller-owned
    /// workspace — the decode hot path. Activation codes, raw
    /// accumulators and the kernel's fresh per-row overflow counts all
    /// live in `scratch`; a warm workspace makes the whole forward
    /// allocation-free.
    pub fn forward_rows_scratch(
        &self,
        xs: &[f32],
        rows: usize,
        ys: &mut [f32],
        row_ovf: &mut [u64],
        scratch: &mut LinearScratch,
    ) {
        self.forward_rows_scratch_dp(xs, rows, ys, row_ovf, scratch, self.datapath);
    }

    /// [`QuantLinear::forward_rows_scratch`] on an explicit datapath —
    /// the speculative draft entry point. `dp` is normally
    /// `self.datapath` or [`Datapath::narrowed`] of it; codes, scales
    /// and the activation quantizer are the stored ones either way, so
    /// a widened-register verify over the same inputs reproduces the
    /// non-speculative forward bit for bit.
    pub fn forward_rows_scratch_dp(
        &self,
        xs: &[f32],
        rows: usize,
        ys: &mut [f32],
        row_ovf: &mut [u64],
        scratch: &mut LinearScratch,
        dp: Datapath,
    ) {
        debug_assert_eq!(xs.len(), rows * self.in_dim);
        debug_assert_eq!(ys.len(), rows * self.out_dim);
        debug_assert!(row_ovf.is_empty() || row_ovf.len() == rows);
        scratch.ensure_quant(rows, self.in_dim, self.out_dim);
        let codes = &mut scratch.codes[..rows * self.in_dim];
        match &self.rotation {
            Some(rot) => {
                let xr = &mut scratch.xr[..self.in_dim];
                for r in 0..rows {
                    xr.copy_from_slice(&xs[r * self.in_dim..(r + 1) * self.in_dim]);
                    rot.apply_row(xr);
                    self.quantize_input(xr, &mut codes[r * self.in_dim..(r + 1) * self.in_dim]);
                }
            }
            None => {
                for r in 0..rows {
                    self.quantize_input(
                        &xs[r * self.in_dim..(r + 1) * self.in_dim],
                        &mut codes[r * self.in_dim..(r + 1) * self.in_dim],
                    );
                }
            }
        }
        let acc = &mut scratch.acc[..rows * self.out_dim];
        let kernel_ovf = &mut scratch.row_ovf[..rows];
        self.run_kernel(dp, codes, rows, acc, kernel_ovf);
        self.dequant_rows(acc, rows, ys);
        let overflow_total: u64 = kernel_ovf.iter().sum();
        if overflow_total > 0 {
            self.overflow_events.fetch_add(overflow_total, Ordering::Relaxed);
            if !row_ovf.is_empty() {
                for (dst, src) in row_ovf.iter_mut().zip(kernel_ovf.iter()) {
                    *dst += src;
                }
            }
        }
        self.macs.fetch_add((rows * self.in_dim * self.out_dim) as u64, Ordering::Relaxed);
    }

    /// Dequantized weights as an [out, in] f32 matrix (diagnostics).
    pub fn dequant_weights(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.codes.len()];
        for o in 0..self.out_dim {
            let s = self.scales[o];
            for i in 0..self.in_dim {
                w[o * self.in_dim + i] = self.codes[o * self.in_dim + i] as f32 * s;
            }
        }
        w
    }

    pub fn overflow_count(&self) -> u64 {
        self.overflow_events.load(Ordering::Relaxed)
    }
}

/// A layer that is either float or quantized — the unit the coordinator
/// swaps during the pipeline.
#[derive(Clone, Debug)]
pub enum Linear {
    Float(FloatLinear),
    Quant(QuantLinear),
}

impl Linear {
    pub fn in_dim(&self) -> usize {
        match self {
            Linear::Float(l) => l.in_dim,
            Linear::Quant(l) => l.in_dim,
        }
    }

    pub fn out_dim(&self) -> usize {
        match self {
            Linear::Float(l) => l.out_dim,
            Linear::Quant(l) => l.out_dim,
        }
    }

    pub fn forward_row(&self, x: &[f32], y: &mut [f32], scratch: &mut Vec<i64>) {
        match self {
            Linear::Float(l) => l.forward_row(x, y),
            Linear::Quant(l) => {
                scratch.resize(l.in_dim, 0);
                l.forward_row(x, y, scratch);
            }
        }
    }

    /// Batched y = W x + b over `rows` stacked input rows. Quantized
    /// layers run one fused qgemm call across every row and channel;
    /// float layers run one banded f64 GEMM ([`FloatLinear::forward_rows`]).
    pub fn forward_rows(&self, xs: &[f32], rows: usize, ys: &mut [f32]) {
        match self {
            Linear::Float(l) => l.forward_rows(xs, rows, ys),
            Linear::Quant(l) => l.forward_rows(xs, rows, ys),
        }
    }

    /// [`Linear::forward_rows`] with per-row overflow attribution:
    /// quantized layers add each row's overflow events into
    /// `row_ovf[r]`; float layers never overflow and leave it untouched.
    pub fn forward_rows_counted(
        &self,
        xs: &[f32],
        rows: usize,
        ys: &mut [f32],
        row_ovf: &mut [u64],
    ) {
        match self {
            Linear::Float(l) => l.forward_rows(xs, rows, ys),
            Linear::Quant(l) => l.forward_rows_counted(xs, rows, ys, row_ovf),
        }
    }

    /// [`Linear::forward_rows_counted`] over a caller-owned workspace —
    /// the allocation-free decode dispatch. Bit-identical to the
    /// transient-workspace wrappers on both datapaths.
    pub fn forward_rows_scratch(
        &self,
        xs: &[f32],
        rows: usize,
        ys: &mut [f32],
        row_ovf: &mut [u64],
        scratch: &mut LinearScratch,
    ) {
        match self {
            Linear::Float(l) => l.forward_rows_scratch(xs, rows, ys, scratch),
            Linear::Quant(l) => l.forward_rows_scratch(xs, rows, ys, row_ovf, scratch),
        }
    }

    /// [`Linear::forward_rows_scratch`] with the integer registers
    /// optionally narrowed to at most `narrow` inner bits — the
    /// self-speculative draft dispatch. `None` (and any float layer)
    /// is bit-identical to [`Linear::forward_rows_scratch`].
    pub fn forward_rows_scratch_narrowed(
        &self,
        xs: &[f32],
        rows: usize,
        ys: &mut [f32],
        row_ovf: &mut [u64],
        scratch: &mut LinearScratch,
        narrow: Option<u32>,
    ) {
        match self {
            Linear::Float(l) => l.forward_rows_scratch(xs, rows, ys, scratch),
            Linear::Quant(l) => {
                let dp = match narrow {
                    Some(bits) => l.datapath.narrowed(bits),
                    None => l.datapath,
                };
                l.forward_rows_scratch_dp(xs, rows, ys, row_ovf, scratch, dp)
            }
        }
    }

    pub fn bias(&self) -> &[f32] {
        match self {
            Linear::Float(l) => &l.b,
            Linear::Quant(l) => &l.bias,
        }
    }

    pub fn bias_mut(&mut self) -> &mut Vec<f32> {
        match self {
            Linear::Float(l) => &mut l.b,
            Linear::Quant(l) => &mut l.bias,
        }
    }

    pub fn as_float(&self) -> Option<&FloatLinear> {
        match self {
            Linear::Float(l) => Some(l),
            _ => None,
        }
    }

    pub fn as_quant(&self) -> Option<&QuantLinear> {
        match self {
            Linear::Quant(l) => Some(l),
            _ => None,
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, Linear::Quant(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{gpfq_quantize, GpfqParams};
    use crate::util::rng::Rng;

    fn random_float_linear(k: usize, c: usize, seed: u64) -> FloatLinear {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..k * c).map(|_| (rng.normal() * 0.3) as f32).collect();
        let b: Vec<f32> = (0..c).map(|_| (rng.normal() * 0.1) as f32).collect();
        FloatLinear::new(k, c, w, b)
    }

    fn quantize_layer(fl: &FloatLinear, bits: u32, seed: u64) -> QuantLinear {
        let mut rng = Rng::new(seed);
        let w_kc = fl.weights_kc();
        let x = crate::linalg::Mat::random_normal(fl.in_dim, 64, &mut rng, 1.0);
        let r = gpfq_quantize(&w_kc, &x, &x, &GpfqParams::base(bits, 8));
        let samples: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let act = ActQuantizer::calibrate(&samples, 8, 0.999);
        QuantLinear::from_result(&r, fl.b.clone(), act, Datapath::Exact)
    }

    #[test]
    fn float_forward_known_values() {
        let l = FloatLinear::new(2, 2, vec![1.0, 2.0, 3.0, 4.0], vec![0.5, -0.5]);
        let mut y = vec![0.0; 2];
        l.forward_row(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.5, 6.5]);
    }

    #[test]
    fn quantized_approximates_float_at_8_bits() {
        let fl = random_float_linear(32, 16, 90);
        let ql = quantize_layer(&fl, 8, 91);
        let mut rng = Rng::new(92);
        let x: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
        let mut y_f = vec![0.0; 16];
        let mut y_q = vec![0.0; 16];
        let mut scratch = vec![0i64; 32];
        fl.forward_row(&x, &mut y_f);
        ql.forward_row(&x, &mut y_q, &mut scratch);
        for (f, q) in y_f.iter().zip(y_q.iter()) {
            assert!((f - q).abs() < 0.15, "f={f} q={q}");
        }
    }

    #[test]
    fn exact_and_wide_simulated_agree() {
        let fl = random_float_linear(48, 8, 93);
        let mut ql = quantize_layer(&fl, 4, 94);
        let mut rng = Rng::new(95);
        let x: Vec<f32> = (0..48).map(|_| rng.normal() as f32).collect();
        let mut y1 = vec![0.0; 8];
        let mut y2 = vec![0.0; 8];
        let mut scratch = vec![0i64; 48];
        ql.forward_row(&x, &mut y1, &mut scratch);
        ql.datapath = Datapath::Simulated {
            tile: 48,
            inner_bits: 32,
            outer_bits: 32,
            mode: OverflowMode::Wraparound,
        };
        ql.forward_row(&x, &mut y2, &mut scratch);
        assert_eq!(y1, y2);
        assert_eq!(ql.overflow_count(), 0);
    }

    #[test]
    fn narrow_simulated_corrupts() {
        let fl = random_float_linear(128, 4, 96);
        let mut ql = quantize_layer(&fl, 8, 97);
        // 10-bit accumulator is hopeless for 8-bit codes at K=128
        ql.datapath = Datapath::Simulated {
            tile: 128,
            inner_bits: 10,
            outer_bits: 10,
            mode: OverflowMode::Wraparound,
        };
        let mut rng = Rng::new(98);
        let x: Vec<f32> = (0..128).map(|_| rng.normal() as f32 + 1.0).collect();
        let mut y = vec![0.0; 4];
        let mut scratch = vec![0i64; 128];
        ql.forward_row(&x, &mut y, &mut scratch);
        assert!(ql.overflow_count() > 0, "narrow accumulator must overflow");
    }

    #[test]
    fn per_row_overflow_attribution_matches_solo_rows() {
        // forward_rows_counted must attribute to each batched row
        // exactly the events that row triggers when run alone — the
        // invariant per-request serving attribution rests on.
        let fl = random_float_linear(96, 6, 110);
        let mut ql = quantize_layer(&fl, 8, 111);
        ql.datapath = Datapath::Simulated {
            tile: 96,
            inner_bits: 11,
            outer_bits: 11,
            mode: OverflowMode::Wraparound,
        };
        let mut rng = Rng::new(112);
        let rows = 4;
        let xs: Vec<f32> = (0..rows * 96).map(|_| rng.normal() as f32 + 0.8).collect();
        let mut ys = vec![0.0f32; rows * 6];
        let mut row_ovf = vec![0u64; rows];
        let before = ql.overflow_count();
        ql.forward_rows_counted(&xs, rows, &mut ys, &mut row_ovf);
        let total: u64 = row_ovf.iter().sum();
        assert_eq!(ql.overflow_count() - before, total, "layer counter must match row sum");
        assert!(total > 0, "the narrow register must overflow in this fixture");
        for r in 0..rows {
            let mut y1 = vec![0.0f32; 6];
            let mut solo = vec![0u64; 1];
            ql.forward_rows_counted(&xs[r * 96..(r + 1) * 96], 1, &mut y1, &mut solo);
            assert_eq!(solo[0], row_ovf[r], "row {r} attribution depends on batchmates");
            assert_eq!(&ys[r * 6..(r + 1) * 6], &y1[..], "row {r} values diverge");
        }
    }

    #[test]
    fn scratch_forward_matches_transient_forward_bit_for_bit() {
        // The reused-workspace entry point must equal the transient
        // wrapper exactly — values, attribution and layer counters —
        // including when the workspace is warm from a *larger* problem
        // (stale-buffer shape), on both datapaths and the float path.
        let fl = random_float_linear(64, 12, 130);
        let mut ql = quantize_layer(&fl, 6, 131);
        ql.datapath = Datapath::Simulated {
            tile: 16,
            inner_bits: 12,
            outer_bits: 15,
            mode: OverflowMode::Wraparound,
        };
        let mut rng = Rng::new(132);
        let mut shared = LinearScratch::new();
        // warm the workspace on a larger batch first
        let warm: Vec<f32> = (0..7 * 64).map(|_| rng.normal() as f32).collect();
        let mut sink = vec![0.0f32; 7 * 12];
        ql.forward_rows_scratch(&warm, 7, &mut sink, &mut [], &mut shared);
        fl.forward_rows_scratch(&warm, 7, &mut sink, &mut shared);
        for rows in [1usize, 3, 5] {
            let xs: Vec<f32> = (0..rows * 64).map(|_| rng.normal() as f32 + 0.4).collect();
            let mut y_scratch = vec![0.0f32; rows * 12];
            let mut y_plain = vec![0.0f32; rows * 12];
            let mut ovf_scratch = vec![0u64; rows];
            let mut ovf_plain = vec![0u64; rows];
            ql.forward_rows_scratch(&xs, rows, &mut y_scratch, &mut ovf_scratch, &mut shared);
            ql.forward_rows_counted(&xs, rows, &mut y_plain, &mut ovf_plain);
            assert_eq!(y_scratch, y_plain, "rows={rows}: quant values diverge");
            assert_eq!(ovf_scratch, ovf_plain, "rows={rows}: attribution diverges");
            // float path too
            let mut f_scratch = vec![0.0f32; rows * 12];
            let mut f_plain = vec![0.0f32; rows * 12];
            fl.forward_rows_scratch(&xs, rows, &mut f_scratch, &mut shared);
            fl.forward_rows(&xs, rows, &mut f_plain);
            assert_eq!(f_scratch, f_plain, "rows={rows}: float values diverge");
        }
    }

    #[test]
    fn zero_point_correction_is_exact() {
        // With act zero-point z, the corrected integer result must equal
        // the dot of dequantized values / (s_w s_x).
        let fl = random_float_linear(16, 3, 99);
        let ql = quantize_layer(&fl, 6, 100);
        let mut rng = Rng::new(101);
        let x: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        let mut codes = vec![0i64; 16];
        ql.quantize_input(&x, &mut codes);
        for o in 0..3 {
            let row = &ql.codes[o * 16..(o + 1) * 16];
            let w_row: Vec<i64> = row.iter().map(|&q| q as i64).collect();
            let acc = crate::accum::simulator::dot_exact(&codes, &w_row);
            let corrected = acc - ql.act.zero_point * ql.code_sums[o];
            // reference: Σ q_k (code_k − z)
            let mut reference = 0i64;
            for (q, c) in w_row.iter().zip(codes.iter()) {
                reference += q * (c - ql.act.zero_point);
            }
            assert_eq!(corrected, reference);
        }
    }

    #[test]
    fn forward_rows_matches_row_by_row() {
        // Batched kernel dispatch must be value-identical to per-row
        // dispatch, on both datapaths.
        let fl = random_float_linear(64, 12, 102);
        let mut ql = quantize_layer(&fl, 4, 103);
        let mut rng = Rng::new(104);
        let rows = 5;
        let xs: Vec<f32> = (0..rows * 64).map(|_| rng.normal() as f32).collect();
        for datapath in [
            Datapath::Exact,
            Datapath::Simulated {
                tile: 16,
                inner_bits: 14,
                outer_bits: 17,
                mode: OverflowMode::Wraparound,
            },
        ] {
            ql.datapath = datapath;
            let mut batched = vec![0.0f32; rows * 12];
            ql.forward_rows(&xs, rows, &mut batched);
            let mut scratch = vec![0i64; 64];
            for r in 0..rows {
                let mut y = vec![0.0f32; 12];
                ql.forward_row(&xs[r * 64..(r + 1) * 64], &mut y, &mut scratch);
                assert_eq!(&batched[r * 12..(r + 1) * 12], &y[..], "row {r}");
            }
        }
    }

    #[test]
    fn float_forward_rows_batches_and_stays_row_invariant() {
        let fl = random_float_linear(48, 10, 105);
        let mut rng = Rng::new(106);
        let rows = 7;
        let xs: Vec<f32> = (0..rows * 48).map(|_| rng.normal() as f32).collect();
        let mut batched = vec![0.0f32; rows * 10];
        fl.forward_rows(&xs, rows, &mut batched);
        for r in 0..rows {
            // approximates the f32 per-row loop (f64 accumulation)…
            let mut y = vec![0.0f32; 10];
            fl.forward_row(&xs[r * 48..(r + 1) * 48], &mut y);
            for (a, b) in batched[r * 10..(r + 1) * 10].iter().zip(&y) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
            // …and is bit-identical regardless of batch composition,
            // the invariant batched decode parity rests on.
            let mut alone = vec![0.0f32; 10];
            fl.forward_rows(&xs[r * 48..(r + 1) * 48], 1, &mut alone);
            assert_eq!(&batched[r * 10..(r + 1) * 10], &alone[..], "row {r}");
        }
    }

    /// The widened-weight cache must be invisible (bit-identical to
    /// per-call widening) AND must be invalidated by calibration-time
    /// in-place mutation through `w_mut` — the dirty-flag contract.
    #[test]
    fn widened_weight_cache_invalidates_on_mutation() {
        let fl = random_float_linear(24, 6, 140);
        let mut rng = Rng::new(141);
        let rows = 3;
        let xs: Vec<f32> = (0..rows * 24).map(|_| rng.normal() as f32).collect();
        let mut scratch = LinearScratch::new();
        // warm the cache
        let mut y_cold = vec![0.0f32; rows * 6];
        fl.forward_rows_scratch(&xs, rows, &mut y_cold, &mut scratch);
        let mut y_warm = vec![0.0f32; rows * 6];
        fl.forward_rows_scratch(&xs, rows, &mut y_warm, &mut scratch);
        assert_eq!(y_cold, y_warm, "warm cache must be bit-identical to the cold pass");
        // mutate in place the way SmoothQuant/equalization do
        let mut fl = fl;
        for w in fl.w_mut() {
            *w *= 2.0;
        }
        let mut y_mut = vec![0.0f32; rows * 6];
        fl.forward_rows_scratch(&xs, rows, &mut y_mut, &mut scratch);
        // reference: a fresh layer built from the mutated weights (no
        // cache history at all)
        let fresh = FloatLinear::new(24, 6, fl.w().to_vec(), fl.b.clone());
        let mut y_fresh = vec![0.0f32; rows * 6];
        fresh.forward_rows_scratch(&xs, rows, &mut y_fresh, &mut LinearScratch::new());
        assert_eq!(
            y_mut, y_fresh,
            "mutation through w_mut must invalidate the widened cache"
        );
        assert_ne!(y_mut, y_warm, "doubled weights must change the output");
        // a clone carries the (valid) cache and stays correct
        let cloned = fl.clone();
        let mut y_clone = vec![0.0f32; rows * 6];
        cloned.forward_rows_scratch(&xs, rows, &mut y_clone, &mut scratch);
        assert_eq!(y_clone, y_fresh);
    }

    #[test]
    fn from_result_transposes_correctly() {
        let mut r = QuantResult::new(2, 3, 4, vec![1.0, 1.0, 1.0]);
        r.set_code(0, 1, 5);
        r.set_code(1, 2, -3);
        let ql = QuantLinear::from_result(
            &r,
            vec![0.0; 3],
            ActQuantizer::unit(8),
            Datapath::Exact,
        );
        // codes[out=1][in=0] == 5
        assert_eq!(ql.codes[1 * 2 + 0], 5);
        assert_eq!(ql.codes[2 * 2 + 1], -3);
        assert_eq!(ql.code_sums, vec![0, 5, -3]);
    }
}
