//! Reusable workspaces for the decode hot path.
//!
//! PR 3's quantized decode loop gave a large constant factor back to
//! per-call heap allocation: every `qgemm_multistage` call built a
//! `Vec<AtomicU64>` and a result `Vec`, every `attend_one_query_quant`
//! call allocated seven operand buffers per query, and every
//! `forward_rows` call allocated its code/accumulator buffers. This
//! module centralizes all of that state into one [`DecodeScratch`]
//! workspace that the serving engine owns per engine thread and reuses
//! across admissions, decode steps and window slides — after warmup, a
//! steady-state decode step performs **zero heap allocations**
//! (asserted by `tests/zero_alloc_decode.rs` with a counting global
//! allocator). The guarantee is scoped to kernel calls below the
//! band-threading work threshold: a batched call big enough to fan out
//! across scoped threads pays thread-spawn allocations by design.
//!
//! Buffers are **grow-only**: `ensure_*` resizes upward and never
//! shrinks, so a workspace reaches its high-water shape after the first
//! step at each batch size and stays allocation-free from then on.
//! Because buffers are reused across calls with *different* live sizes,
//! every consumer slices explicitly to the current problem size (e.g.
//! `&scores[..t_len]`) — stale state beyond the slice can never leak
//! into a matmul.
//!
//! The workspace is split into three independently-borrowable parts so
//! the batched decode step can hold activation buffers (`step`) while
//! handing the linear-layer (`lin`) and attention (`attn`) workspaces
//! to inner calls:
//!
//! - [`LinearScratch`] — quantized-linear operand codes, raw
//!   accumulators and per-row overflow counters, plus the f64 buffers
//!   the float-linear banded GEMM streams through.
//! - [`AttnScratch`] — per-head attention operands: online-quantized
//!   query/probability codes, gathered K/V head panels, score/value
//!   accumulators and the single-row overflow counter.
//! - [`StepScratch`] — per-step activation tensors (`h`, layer-norm
//!   output, q/k/v projections, attention mix, FFN buffers) and the
//!   step's logits, which callers read back from the workspace instead
//!   of receiving a freshly allocated `Vec`.

use super::transformer::TransformerConfig;

/// Grow `v` to at least `n` elements (never shrinks — see module docs).
#[inline]
fn grow<T: Default + Clone>(v: &mut Vec<T>, n: usize) {
    if v.len() < n {
        v.resize(n, T::default());
    }
}

/// Operand workspace for [`super::linear::QuantLinear`] /
/// [`super::linear::FloatLinear`] batched forwards.
#[derive(Debug, Default)]
pub struct LinearScratch {
    /// `rows * in_dim` quantized activation codes.
    pub codes: Vec<i64>,
    /// `rows * out_dim` raw integer accumulators.
    pub acc: Vec<i64>,
    /// `rows` fresh kernel overflow counts (before attribution).
    pub row_ovf: Vec<u64>,
    /// `in_dim` rotated-activation staging row (QuaRot layers only).
    pub xr: Vec<f32>,
    /// Float path: `rows * in_dim` activations widened to f64.
    pub fa: Vec<f64>,
    /// Float path: `rows * out_dim` f64 accumulators. (The widened
    /// weights live on the layer itself — see
    /// [`super::linear::FloatLinear`]'s mutation-versioned cache — so
    /// serving never re-widens an unchanged weight matrix.)
    pub fy: Vec<f64>,
}

impl LinearScratch {
    pub fn new() -> LinearScratch {
        LinearScratch::default()
    }

    /// Size the integer-datapath buffers for a `rows`-row forward.
    pub fn ensure_quant(&mut self, rows: usize, in_dim: usize, out_dim: usize) {
        grow(&mut self.codes, rows * in_dim);
        grow(&mut self.acc, rows * out_dim);
        grow(&mut self.row_ovf, rows);
        grow(&mut self.xr, in_dim);
    }

    /// Size the float-datapath buffers for a `rows`-row forward.
    pub fn ensure_float(&mut self, rows: usize, in_dim: usize, out_dim: usize) {
        grow(&mut self.fa, rows * in_dim);
        grow(&mut self.fy, rows * out_dim);
    }
}

/// Per-head operand workspace for single-query attention
/// ([`super::layers::attend_one_query`] and
/// [`super::layers::attend_one_query_quant`]).
#[derive(Debug, Default)]
pub struct AttnScratch {
    /// `hd` online-quantized signed query codes.
    pub q_codes: Vec<i64>,
    /// `t_len * hd` gathered key codes for the current head, row-major.
    pub k_head: Vec<i32>,
    /// `t_len` raw score accumulators.
    pub score_acc: Vec<i64>,
    /// `t_len` dequantized scores / softmax probabilities.
    pub scores: Vec<f32>,
    /// `t_len` online-quantized unsigned probability codes.
    pub p_codes: Vec<i64>,
    /// `hd * t_len` gathered value codes, transposed, row-major.
    pub v_head_t: Vec<i32>,
    /// `hd` raw value accumulators.
    pub val_acc: Vec<i64>,
    /// Single-row overflow counter for the rows==1 kernel calls.
    pub row1: [u64; 1],
}

impl AttnScratch {
    pub fn new() -> AttnScratch {
        AttnScratch::default()
    }

    /// Size for head dimension `hd` attending over `t_len` positions
    /// on the integer datapath (all buffers).
    pub fn ensure(&mut self, hd: usize, t_len: usize) {
        grow(&mut self.q_codes, hd);
        grow(&mut self.k_head, t_len * hd);
        grow(&mut self.score_acc, t_len);
        grow(&mut self.scores, t_len);
        grow(&mut self.p_codes, t_len);
        grow(&mut self.v_head_t, hd * t_len);
        grow(&mut self.val_acc, hd);
    }

    /// Size for the float attention path, which only needs the
    /// probability row — the integer-only panels stay untouched, so an
    /// f32-backend engine never materializes dead code buffers.
    pub fn ensure_scores(&mut self, t_len: usize) {
        grow(&mut self.scores, t_len);
    }
}

/// Per-step activation workspace for
/// [`super::transformer::Transformer::decode_step_batch_scratch`] and
/// [`super::transformer::Transformer::prefill_slot_scratch`].
#[derive(Debug, Default)]
pub struct StepScratch {
    /// `rows * d` residual stream.
    pub h: Vec<f32>,
    /// `rows * d` layer-norm output.
    pub ln_out: Vec<f32>,
    /// `rows * d` query projection.
    pub q: Vec<f32>,
    /// `rows * d` key projection.
    pub k_new: Vec<f32>,
    /// `rows * d` value projection.
    pub v_new: Vec<f32>,
    /// `rows * d` attention value mix (pre-projection).
    pub mix: Vec<f32>,
    /// `rows * d` attention output projection.
    pub attn_out: Vec<f32>,
    /// `rows * d_ff` FFN hidden activations.
    pub ff: Vec<f32>,
    /// `rows * d` FFN output.
    pub ff_out: Vec<f32>,
    /// `logit_rows * vocab` logits — the step's result lives here;
    /// callers read `&logits[..rows * vocab]` instead of receiving a
    /// fresh `Vec`.
    pub logits: Vec<f32>,
    /// `rows` per-row overflow counters (prefill-internal attribution).
    pub row_ovf: Vec<u64>,
    /// Attention-share overflow events of the most recent ragged step
    /// (the linear share is `Σ row_ovf − this`) — left behind where
    /// the serving engine's telemetry can read it without re-deriving.
    pub last_attn_ovf: u64,
    /// Bands the most recent ragged step's attention sweep actually
    /// fanned out across (1 = serial).
    pub last_attn_bands: usize,
}

impl StepScratch {
    pub fn new() -> StepScratch {
        StepScratch::default()
    }

    /// Size for `rows` activation rows and `logit_rows` logit rows
    /// (batched decode emits one logit row per sequence; prefill only
    /// the final position's).
    pub fn ensure(
        &mut self,
        rows: usize,
        logit_rows: usize,
        d: usize,
        d_ff: usize,
        vocab: usize,
    ) {
        grow(&mut self.h, rows * d);
        grow(&mut self.ln_out, rows * d);
        grow(&mut self.q, rows * d);
        grow(&mut self.k_new, rows * d);
        grow(&mut self.v_new, rows * d);
        grow(&mut self.mix, rows * d);
        grow(&mut self.attn_out, rows * d);
        grow(&mut self.ff, rows * d_ff);
        grow(&mut self.ff_out, rows * d);
        grow(&mut self.logits, logit_rows * vocab);
        grow(&mut self.row_ovf, rows);
    }
}

/// Default MAC-count threshold below which a ragged step's attention
/// sweep stays serial even when a thread pool is configured — matches
/// the band-threading threshold in `linalg::qgemm`, so tiny steps keep
/// the zero-allocation guarantee and big steps pay spawns only when
/// the arithmetic dwarfs them.
pub const PAR_ATTN_MIN_WORK: usize = 64 * 64 * 64;

/// One engine thread's complete decode workspace, reused across
/// admissions, batched decode steps and window slides.
#[derive(Debug)]
pub struct DecodeScratch {
    pub lin: LinearScratch,
    pub attn: AttnScratch,
    pub step: StepScratch,
    /// Reused group list for the all-1-row-groups wrapper
    /// (`decode_step_batch_scratch`), taken out for the duration of the
    /// ragged call so the wrapper stays allocation-free in steady state.
    pub(crate) groups_buf: Vec<super::decode::RowGroup>,
    /// Extra per-thread attention workspaces for the band-parallel
    /// ragged sweep: band 0 runs on `attn`, bands 1.. each take one
    /// pool entry. Owned by the engine (grow-only, presized by
    /// [`DecodeScratch::set_attn_threads`]), never by the step.
    pub(crate) attn_pool: Vec<AttnScratch>,
    /// Attention sweep thread count (≥ 1; 1 = the serial oracle path).
    pub(crate) attn_threads: usize,
    /// Minimum estimated step MACs before the sweep fans out.
    pub(crate) attn_par_min: usize,
}

impl Default for DecodeScratch {
    fn default() -> DecodeScratch {
        DecodeScratch {
            lin: LinearScratch::default(),
            attn: AttnScratch::default(),
            step: StepScratch::default(),
            groups_buf: Vec::new(),
            attn_pool: Vec::new(),
            attn_threads: 1,
            attn_par_min: PAR_ATTN_MIN_WORK,
        }
    }
}

impl DecodeScratch {
    /// Empty workspace; buffers grow to their high-water shape on first
    /// use and are reused from then on.
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }

    /// Configure the ragged attention sweep to use up to `threads`
    /// scoped threads (clamped to ≥ 1), presizing one pool workspace
    /// per extra thread so the parallel path never grows a buffer
    /// mid-step. Serial callers (`threads == 1`) keep the exact PR 5
    /// code path.
    pub fn set_attn_threads(&mut self, cfg: &TransformerConfig, threads: usize) {
        self.attn_threads = threads.max(1);
        let hd = cfg.d_model / cfg.n_heads.max(1);
        while self.attn_pool.len() + 1 < self.attn_threads {
            self.attn_pool.push(AttnScratch::new());
        }
        for a in &mut self.attn_pool {
            a.ensure(hd, cfg.max_seq);
        }
    }

    /// Configured attention sweep thread count.
    pub fn attn_threads(&self) -> usize {
        self.attn_threads
    }

    /// Attention-share overflow events of the most recent ragged step
    /// run through this workspace (telemetry).
    pub fn last_attn_overflows(&self) -> u64 {
        self.step.last_attn_ovf
    }

    /// Attention bands the most recent ragged step fanned out across
    /// (telemetry; 1 = the serial sweep).
    pub fn last_attn_bands(&self) -> usize {
        self.step.last_attn_bands
    }

    /// Override the work threshold gating the parallel attention sweep
    /// (tests and benches set 0 to force banding on tiny fixtures).
    pub fn set_attn_par_min_work(&mut self, macs: usize) {
        self.attn_par_min = macs;
    }

    /// Workspace pre-sized for a model config and at most `max_rows`
    /// stacked step rows, so even the first step allocates nothing.
    /// Whole-prompt prefill runs up to `max_seq` rows, so the
    /// activation buffers are sized for the larger of the two. Linear
    /// buffers are sized to the model's **actual** layer shapes —
    /// block linears are d↔d_ff and the only vocab-wide layer is the
    /// d→vocab float head — not to the max-in × max-out cross product,
    /// which no layer has.
    pub fn for_model(cfg: &TransformerConfig, max_rows: usize) -> DecodeScratch {
        let mut s = DecodeScratch::new();
        let rows = max_rows.max(cfg.max_seq).max(1);
        let dmax = cfg.d_model.max(cfg.d_ff);
        s.lin.ensure_quant(rows, dmax, dmax);
        s.lin.ensure_float(rows, cfg.d_model, cfg.d_ff); // fc1-shaped float blocks
        s.lin.ensure_float(rows, cfg.d_ff, cfg.d_model); // fc2-shaped float blocks
        s.lin.ensure_float(rows, cfg.d_model, cfg.vocab); // the head
        s.attn.ensure(cfg.d_model / cfg.n_heads.max(1), cfg.max_seq);
        s.step.ensure(rows, max_rows.max(1), cfg.d_model, cfg.d_ff, cfg.vocab);
        s.groups_buf.reserve(rows);
        s
    }

    /// Workspace pre-sized for the chunked-prefill serving engine: a
    /// ragged step stacks at most `max_batch` decode rows plus the
    /// per-step prefill budget of `prefill_chunk` chunk rows (clamped
    /// here to the window length), which covers every step for chunk
    /// settings up to `max_seq`. Larger/unchunked settings can stack
    /// several whole prompts into one step and grow past this presize
    /// once — buffers are grow-only, so the steady-state step loop is
    /// allocation-free as soon as the true high-water step has run.
    pub fn for_serve(
        cfg: &TransformerConfig,
        max_batch: usize,
        prefill_chunk: usize,
    ) -> DecodeScratch {
        let budget = prefill_chunk.clamp(1, cfg.max_seq);
        DecodeScratch::for_model(cfg, max_batch.max(1) + budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Activation;

    #[test]
    fn buffers_grow_and_never_shrink() {
        let mut a = AttnScratch::new();
        a.ensure(8, 32);
        assert_eq!(a.k_head.len(), 256);
        assert_eq!(a.scores.len(), 32);
        let cap = a.k_head.capacity();
        a.ensure(8, 8); // smaller problem: no shrink, no realloc
        assert_eq!(a.k_head.len(), 256);
        assert_eq!(a.k_head.capacity(), cap);
        a.ensure(8, 64);
        assert_eq!(a.k_head.len(), 512);
    }

    #[test]
    fn for_model_presizes_everything() {
        let cfg = TransformerConfig {
            name: "s".into(),
            vocab: 48,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 24,
            act: Activation::Gelu,
            parallel_residual: false,
        };
        let s = DecodeScratch::for_model(&cfg, 4);
        // prefill dominates the row count (max_seq 24 > batch 4)
        assert_eq!(s.step.h.len(), 24 * 16);
        assert_eq!(s.step.ff.len(), 24 * 32);
        // decode dominates the logit rows (4 * vocab)
        assert_eq!(s.step.logits.len(), 4 * 48);
        assert_eq!(s.attn.k_head.len(), 24 * 8);
        assert_eq!(s.lin.codes.len(), 24 * 32);
        // float staging covers the widest real operand shapes: fc2-wide
        // inputs (d_ff) and head-wide outputs (vocab)
        assert_eq!(s.lin.fa.len(), 24 * 32);
        assert_eq!(s.lin.fy.len(), 24 * 48);
    }

    #[test]
    fn attn_thread_pool_is_presized_and_grow_only() {
        let cfg = TransformerConfig {
            name: "s".into(),
            vocab: 48,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 24,
            act: Activation::Gelu,
            parallel_residual: false,
        };
        let mut s = DecodeScratch::for_model(&cfg, 4);
        assert_eq!(s.attn_threads(), 1);
        assert!(s.attn_pool.is_empty());
        s.set_attn_threads(&cfg, 4);
        assert_eq!(s.attn_threads(), 4);
        assert_eq!(s.attn_pool.len(), 3);
        for a in &s.attn_pool {
            // presized like the main workspace: head dim 8 over max_seq
            assert_eq!(a.k_head.len(), 24 * 8);
        }
        // shrinking the thread count keeps the pool (grow-only)
        s.set_attn_threads(&cfg, 2);
        assert_eq!(s.attn_threads(), 2);
        assert_eq!(s.attn_pool.len(), 3);
        s.set_attn_threads(&cfg, 0); // clamped to serial
        assert_eq!(s.attn_threads(), 1);
    }

    #[test]
    fn for_serve_covers_the_ragged_high_water() {
        let cfg = TransformerConfig {
            name: "s".into(),
            vocab: 48,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 24,
            act: Activation::Gelu,
            parallel_residual: false,
        };
        // 4 decode rows + an 8-token prefill budget per step
        let s = DecodeScratch::for_serve(&cfg, 4, 8);
        assert!(s.step.h.len() >= (4 + 8) * 16);
        // a huge chunk setting clamps at the window (a single chunk can
        // never exceed the longest servable prompt)
        let s = DecodeScratch::for_serve(&cfg, 4, usize::MAX);
        assert_eq!(s.step.h.len(), (4 + 24) * 16);
    }
}
