//! Fixed-size refcounted KV pages and the shared-prefix page cache.
//!
//! A *page* covers `page_size` consecutive sequence positions — for all
//! layers and both K and V at once — so page identity coincides with
//! token-prefix identity, which is what makes pages the natural unit of
//! prefix sharing. The arena keeps the payload (codes/scales or f32
//! rows) in per-layer slabs indexed by physical page id; this module
//! owns only the bookkeeping:
//!
//! * [`PagePool`] — refcounts, the free list, and per-page overflow
//!   attribution. Allocation is a free-list pop and never touches the
//!   heap after construction, so the zero-allocation decode guarantee
//!   survives page turnover.
//! * [`PageMap`] — a borrowed per-slot page table resolving a logical
//!   position to `(physical page, in-page offset)`. This is the single
//!   indirection point the attention gathers go through; inner loops
//!   stay contiguous within a page run.
//! * [`PrefixCache`] — content-addressed full pages, keyed by a chained
//!   hash of the admitted token prefix at page granularity. Lookups
//!   verify the parent entry *and* the chunk tokens, so a hash
//!   collision can never map a wrong page (bit-exactness is the bar,
//!   not probabilistic correctness).
//!
//! Immutability is by construction: appends only ever touch the open
//! tail page at the slot's high-water position, so a *full* page is
//! frozen the moment its last row is quantized. Quantize-at-append
//! (codes + bf16 scale written once, never re-derived) means a shared
//! page is bit-identical for every reader — the copy in copy-on-write
//! never actually happens; the open tail page is simply always private.

use std::collections::HashMap;

/// Default positions per KV page (`--kv-page`).
pub const DEFAULT_KV_PAGE: usize = 16;

/// Sentinel "no parent" / "no entry" id for [`PrefixCache`] chains.
pub const NO_PREFIX: u32 = u32::MAX;

// ---------------------------------------------------------------------------
// PagePool

/// Refcounts, free list, and per-page overflow attribution for a fixed
/// population of physical pages. Payload lives elsewhere (the arena's
/// per-layer slabs); the pool only says which pages are live and who
/// still needs them.
#[derive(Clone, Debug)]
pub struct PagePool {
    page_size: usize,
    n_pages: usize,
    refcounts: Vec<u32>,
    /// Free physical pages; construction pushes ids in reverse so pops
    /// hand out page 0 first (deterministic layouts in tests).
    free: Vec<u32>,
    /// Overflow events recorded while each page's rows were *filled*
    /// (quantize-at-append time). A sequence that adopts a shared page
    /// credits these events instead of re-incurring them, which is what
    /// keeps per-request overflow attribution bit-identical with
    /// sharing on vs off.
    page_ovf: Vec<u64>,
}

impl PagePool {
    pub fn new(page_size: usize, n_pages: usize) -> Self {
        assert!(page_size > 0, "page size must be positive");
        let mut free: Vec<u32> = Vec::with_capacity(n_pages);
        for p in (0..n_pages as u32).rev() {
            free.push(p);
        }
        PagePool {
            page_size,
            n_pages,
            refcounts: vec![0; n_pages],
            free,
            page_ovf: vec![0; n_pages],
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn n_pages(&self) -> usize {
        self.n_pages
    }

    /// Pages currently referenced by at least one holder.
    pub fn allocated(&self) -> usize {
        self.n_pages - self.free.len()
    }

    /// Pages on the free list right now — the complement of
    /// [`PagePool::allocated`]. Cancellation tests assert a reaped
    /// sequence's pages come back here.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pop a free page (refcount 1, overflow attribution reset). `None`
    /// when the pool is exhausted — the arena reacts by flushing the
    /// prefix cache and retrying.
    pub fn alloc(&mut self) -> Option<u32> {
        let p = self.free.pop()?;
        self.refcounts[p as usize] = 1;
        self.page_ovf[p as usize] = 0;
        Some(p)
    }

    /// Add a reference (adoption into another page table, or the prefix
    /// cache taking its own hold).
    pub fn retain(&mut self, page: u32) {
        debug_assert!(self.refcounts[page as usize] > 0, "retain of a free page");
        self.refcounts[page as usize] += 1;
    }

    /// Drop a reference; the page returns to the free list when the
    /// last holder lets go. The push stays within the free list's
    /// original capacity, so recycling never allocates.
    pub fn unref(&mut self, page: u32) {
        let rc = &mut self.refcounts[page as usize];
        assert!(*rc > 0, "unref of a free page");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
        }
    }

    pub fn refcount(&self, page: u32) -> u32 {
        self.refcounts[page as usize]
    }

    /// Record overflow events incurred while filling rows of `page`.
    pub fn record_ovf(&mut self, page: u32, events: u64) {
        self.page_ovf[page as usize] += events;
    }

    /// Fill-time overflow events stored on `page`.
    pub fn ovf(&self, page: u32) -> u64 {
        self.page_ovf[page as usize]
    }

    /// Bookkeeping bytes this pool holds resident regardless of how
    /// many pages are live: refcount + free-list slot + overflow
    /// counter per page.
    pub fn meta_bytes(&self) -> usize {
        self.n_pages * (4 + 4 + 8)
    }
}

// ---------------------------------------------------------------------------
// PageMap

/// Borrowed view of one slot's page table: logical position →
/// `(physical page, in-page offset)`. `head` is the in-page offset of
/// logical position 0 (nonzero only after `truncate_front` slides that
/// drop whole head pages but land mid-page).
#[derive(Clone, Copy, Debug)]
pub struct PageMap<'a> {
    table: &'a [u32],
    head: usize,
    page_size: usize,
}

impl<'a> PageMap<'a> {
    pub fn new(table: &'a [u32], head: usize, page_size: usize) -> Self {
        debug_assert!(head < page_size.max(1));
        PageMap { table, head, page_size }
    }

    #[inline]
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Resolve a logical position to `(physical page, in-page offset)`.
    #[inline]
    pub fn locate(&self, pos: usize) -> (usize, usize) {
        let idx = self.head + pos;
        (self.table[idx / self.page_size] as usize, idx % self.page_size)
    }

    /// Length of the contiguous run starting at logical `pos`, capped
    /// at `limit`: gathers walk the sequence run by run, staying
    /// contiguous within each page.
    #[inline]
    pub fn run(&self, pos: usize, limit: usize) -> usize {
        let off = (self.head + pos) % self.page_size;
        (self.page_size - off).min(limit)
    }
}

// ---------------------------------------------------------------------------
// PrefixCache

#[derive(Clone, Debug)]
struct Entry {
    /// Parent entry id ([`NO_PREFIX`] for a first-page entry).
    parent: u32,
    /// Chain hash over (parent hash, this page's tokens).
    hash: u64,
    /// Physical page holding the encoded rows. The cache owns one
    /// refcount on it for as long as the entry lives.
    page: u32,
    /// The page's tokens, kept to verify lookups exactly.
    tokens: Vec<u16>,
    /// Evicted under allocation pressure. Entry ids are stable
    /// addresses — descendant entries and slot registration chains
    /// hold them by index — so eviction tombstones instead of
    /// compacting: the husk keeps its chain hash readable for
    /// descendants while its tokens are freed and lookups skip it.
    dead: bool,
}

/// Content-addressed index of full, immutable, position-0-aligned KV
/// pages. An entry chain mirrors a token prefix one page at a time;
/// admission walks the chain as far as it matches and maps those pages
/// read-only into the new sequence's table.
#[derive(Clone, Debug, Default)]
pub struct PrefixCache {
    entries: Vec<Entry>,
    index: HashMap<u64, Vec<u32>>,
    /// Non-tombstoned entries (what [`PrefixCache::len`] reports).
    live: usize,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn chain_hash(parent: u64, chunk: &[u16]) -> u64 {
    let mut h = FNV_OFFSET;
    for b in parent.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for &t in chunk {
        for b in t.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

impl PrefixCache {
    pub fn new() -> Self {
        PrefixCache::default()
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    fn parent_hash(&self, parent: u32) -> u64 {
        if parent == NO_PREFIX {
            FNV_OFFSET
        } else {
            self.entries[parent as usize].hash
        }
    }

    /// Find the entry extending `parent` with exactly `chunk`. The hash
    /// narrows candidates; parent id and stored tokens are compared
    /// outright, so a collision yields a miss, never a wrong page.
    pub fn lookup(&self, parent: u32, chunk: &[u16]) -> Option<(u32, u32)> {
        let h = chain_hash(self.parent_hash(parent), chunk);
        for &e in self.index.get(&h)? {
            let ent = &self.entries[e as usize];
            if !ent.dead && ent.parent == parent && ent.tokens == chunk {
                return Some((e, ent.page));
            }
        }
        None
    }

    /// Register `page` as the encoding of `chunk` under `parent`. The
    /// caller must already have bumped the page's refcount for the
    /// cache's hold. Returns the new entry id.
    pub fn insert(&mut self, parent: u32, chunk: &[u16], page: u32) -> u32 {
        let h = chain_hash(self.parent_hash(parent), chunk);
        let id = self.entries.len() as u32;
        self.entries.push(Entry { parent, hash: h, page, tokens: chunk.to_vec(), dead: false });
        self.index.entry(h).or_default().push(id);
        self.live += 1;
        id
    }

    /// Evict the **oldest** entry whose page no slot table references
    /// (the cache holds its only refcount), handing the page back to
    /// the pool. Entry ids grow monotonically with insertion, so the
    /// index-order scan is oldest-first by construction. Returns
    /// `false` when every live entry is still referenced — nothing is
    /// evictable without stealing a page out from under a sequence.
    ///
    /// The entry is tombstoned, not removed (see [`Entry::dead`]). A
    /// descendant of an evicted entry becomes unreachable for adoption
    /// walks (they start at the chain root) and therefore drifts to
    /// unreferenced as its adopters retire — later evictions collect
    /// it in turn.
    pub fn evict_oldest_unreferenced(&mut self, pool: &mut PagePool) -> bool {
        for id in 0..self.entries.len() {
            let e = &self.entries[id];
            if e.dead || pool.refcount(e.page) != 1 {
                continue;
            }
            let (hash, page) = (e.hash, e.page);
            if let Some(bucket) = self.index.get_mut(&hash) {
                bucket.retain(|&x| x != id as u32);
                if bucket.is_empty() {
                    self.index.remove(&hash);
                }
            }
            let e = &mut self.entries[id];
            e.dead = true;
            e.tokens = Vec::new();
            self.live -= 1;
            pool.unref(page);
            return true;
        }
        false
    }

    /// Drop every entry at once, handing each held page to `unref`
    /// (the arena decrements the pool). Live mappings in slot tables
    /// are unaffected — only future lookups miss. Allocation pressure
    /// uses [`PrefixCache::evict_oldest_unreferenced`] instead; this is
    /// the explicit full-invalidation API, and the one point where
    /// tombstone husks are actually reclaimed.
    pub fn flush(&mut self, mut unref: impl FnMut(u32)) {
        for e in &self.entries {
            if !e.dead {
                unref(e.page);
            }
        }
        self.entries.clear();
        self.index.clear();
        self.live = 0;
    }

    /// Logical bytes of cache bookkeeping: per live entry the fixed
    /// fields, the stored tokens, and the index slot that points at it;
    /// per tombstone just the husk.
    pub fn meta_bytes(&self) -> usize {
        self.entries
            .iter()
            .map(|e| {
                let husk = 4 + 8 + 4 + 1;
                if e.dead { husk } else { husk + 2 * e.tokens.len() + (8 + 4) }
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_pages_through_the_free_list() {
        let mut pool = PagePool::new(8, 3);
        assert_eq!(pool.allocated(), 0);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert_eq!((a, b, c), (0, 1, 2), "deterministic first-fit order");
        assert!(pool.alloc().is_none(), "pool of 3 is exhausted");
        pool.unref(b);
        assert_eq!(pool.allocated(), 2);
        assert_eq!(pool.alloc(), Some(b), "freed page comes back");
    }

    #[test]
    fn refcounts_keep_shared_pages_alive() {
        let mut pool = PagePool::new(8, 2);
        let p = pool.alloc().unwrap();
        pool.retain(p); // second holder
        pool.unref(p);
        assert_eq!(pool.refcount(p), 1, "one holder left");
        assert_eq!(pool.allocated(), 1, "still resident");
        pool.unref(p);
        assert_eq!(pool.allocated(), 0, "last unref frees");
    }

    #[test]
    fn alloc_resets_overflow_attribution() {
        let mut pool = PagePool::new(4, 1);
        let p = pool.alloc().unwrap();
        pool.record_ovf(p, 7);
        assert_eq!(pool.ovf(p), 7);
        pool.unref(p);
        let q = pool.alloc().unwrap();
        assert_eq!(q, p, "same physical page recycled");
        assert_eq!(pool.ovf(q), 0, "stale attribution cleared");
    }

    #[test]
    fn page_map_resolves_runs_and_offsets() {
        let table = [5u32, 2, 9];
        let map = PageMap::new(&table, 3, 4); // head offset 3 in page 5
        assert_eq!(map.locate(0), (5, 3));
        assert_eq!(map.locate(1), (2, 0));
        assert_eq!(map.locate(5), (9, 0));
        assert_eq!(map.run(0, 100), 1, "one row left in the head page");
        assert_eq!(map.run(1, 100), 4, "full page run");
        assert_eq!(map.run(1, 2), 2, "capped by limit");
    }

    #[test]
    fn prefix_cache_chains_verify_tokens_not_just_hashes() {
        let mut cache = PrefixCache::new();
        let a = cache.insert(NO_PREFIX, &[1, 2, 3, 4], 10);
        let b = cache.insert(a, &[5, 6, 7, 8], 11);
        assert_eq!(cache.lookup(NO_PREFIX, &[1, 2, 3, 4]), Some((a, 10)));
        assert_eq!(cache.lookup(a, &[5, 6, 7, 8]), Some((b, 11)));
        // same tokens under the wrong parent: miss
        assert_eq!(cache.lookup(NO_PREFIX, &[5, 6, 7, 8]), None);
        // different tokens under the right parent: miss
        assert_eq!(cache.lookup(a, &[5, 6, 7, 9]), None);
    }

    #[test]
    fn eviction_is_oldest_first_and_skips_referenced_entries() {
        let mut pool = PagePool::new(4, 4);
        let pa = pool.alloc().unwrap();
        let pb = pool.alloc().unwrap();
        let pc = pool.alloc().unwrap();
        let mut cache = PrefixCache::new();
        // the cache takes its own hold on each page (the arena's retain)
        pool.retain(pa);
        pool.retain(pb);
        pool.retain(pc);
        let a = cache.insert(NO_PREFIX, &[1, 2, 3, 4], pa);
        let b = cache.insert(a, &[5, 6, 7, 8], pb);
        cache.insert(b, &[9, 9, 9, 9], pc);
        // drop the slot references of b and c: they become cache-only
        pool.unref(pb);
        pool.unref(pc);
        assert_eq!(cache.len(), 3);
        // a is still mapped into a live table → skipped; b is the
        // oldest evictable entry
        assert!(cache.evict_oldest_unreferenced(&mut pool));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.lookup(a, &[5, 6, 7, 8]), None, "evicted entry must miss");
        assert_eq!(
            cache.lookup(NO_PREFIX, &[1, 2, 3, 4]),
            Some((a, pa)),
            "referenced entry survives"
        );
        assert_eq!(pool.refcount(pb), 0, "evicted page returned to the pool");
        assert!(cache.evict_oldest_unreferenced(&mut pool), "c is next-oldest");
        assert_eq!(cache.len(), 1);
        assert!(
            !cache.evict_oldest_unreferenced(&mut pool),
            "only a referenced entry remains — nothing evictable"
        );
        // flush releases exactly the surviving page (tombstones are not
        // double-unreffed)
        let mut released = Vec::new();
        cache.flush(|p| released.push(p));
        assert_eq!(released, vec![pa]);
        assert!(cache.is_empty());
    }

    #[test]
    fn tombstones_keep_descendant_chain_hashes_stable() {
        let mut pool = PagePool::new(4, 3);
        let pa = pool.alloc().unwrap();
        let pb = pool.alloc().unwrap();
        let mut cache = PrefixCache::new();
        pool.retain(pa);
        pool.retain(pb);
        let a = cache.insert(NO_PREFIX, &[1, 2], pa);
        let b = cache.insert(a, &[3, 4], pb);
        pool.unref(pa); // the parent becomes cache-only; the child stays mapped
        assert!(cache.evict_oldest_unreferenced(&mut pool), "parent evicts first");
        assert_eq!(cache.lookup(NO_PREFIX, &[1, 2]), None);
        // the child is still addressable by its parent id — slot
        // registration chains anchored at the tombstone keep working
        assert_eq!(cache.lookup(a, &[3, 4]), Some((b, pb)));
        // and can still grow: the tombstone's chain hash feeds the
        // grandchild's key exactly as before the eviction
        let pc = pool.alloc().unwrap();
        pool.retain(pc);
        let c = cache.insert(b, &[5, 6], pc);
        assert_eq!(cache.lookup(b, &[5, 6]), Some((c, pc)));
    }

    #[test]
    fn flush_releases_every_held_page() {
        let mut cache = PrefixCache::new();
        let a = cache.insert(NO_PREFIX, &[1, 2], 3);
        cache.insert(a, &[3, 4], 4);
        let mut released = Vec::new();
        cache.flush(|p| released.push(p));
        released.sort_unstable();
        assert_eq!(released, vec![3, 4]);
        assert!(cache.is_empty());
        assert_eq!(cache.lookup(NO_PREFIX, &[1, 2]), None);
    }

    // -----------------------------------------------------------------
    // Speculative tail rollback (satellite of the draft/verify PR):
    // `KvArena::truncate_tail` must be the exact inverse of draft
    // appends at the page/refcount/cache level. The latent bug class
    // here is off-by-one page accounting — freeing the open tail page
    // on a partial rollback, or leaking the page a rolled-back draft
    // freshly opened.

    use crate::model::decode::{KvArena, RaggedOpts, RowGroup};
    use crate::model::kvquant::{KvCacheKind, KvQuantSpec};
    use crate::model::scratch::DecodeScratch;
    use crate::model::transformer::Transformer;
    use crate::model::{random_transformer, Activation, TransformerConfig};

    fn spec_model() -> Transformer {
        random_transformer(
            TransformerConfig {
                name: "p".into(),
                vocab: 48,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_seq: 16,
                act: Activation::Gelu,
                parallel_residual: false,
            },
            31,
        )
    }

    /// Append `toks` to `slot` as one draft group (narrow registers,
    /// fill attribution off — exactly what the speculative engine
    /// rolls back afterwards).
    fn draft_append(m: &Transformer, arena: &mut KvArena, slot: usize, toks: &[u16]) {
        let groups = [RowGroup { slot, start: 0, len: toks.len() }];
        let mut g_ovf = [0u64; 1];
        let mut scratch = DecodeScratch::new();
        m.decode_step_ragged_opts(
            toks,
            &groups,
            arena,
            &mut g_ovf,
            &mut scratch,
            RaggedOpts::draft(Some(4)),
        );
    }

    /// Rolling back draft positions that stayed **within** the open
    /// tail page must restore page/refcount state identically: same
    /// resident and free page counts, the tail page still held, and
    /// every surviving row bit-identical — on both backends.
    #[test]
    fn tail_rollback_within_open_page_restores_state() {
        for kind in [KvCacheKind::F32, KvCacheKind::Quant(KvQuantSpec::int8())] {
            let m = spec_model();
            let mut arena = KvArena::with_kind_paged(&m, 1, kind, 4);
            let slot = arena.alloc().unwrap();
            m.prefill_slot(&[3, 1, 4, 1, 5, 9], slot, &mut arena); // 1 full page + 2 tail rows
            let resident = arena.resident_pages();
            let free = arena.free_pages();
            let rows: Vec<_> = (0..6).map(|p| arena.kv_row(1, slot, p)).collect();
            // two draft rows fill the open tail page exactly — no new page
            draft_append(&m, &mut arena, slot, &[7, 7]);
            assert_eq!(arena.len(slot), 8);
            assert_eq!(arena.resident_pages(), resident, "drafts stayed in the open page");
            arena.truncate_tail(slot, 2);
            assert_eq!(arena.len(slot), 6);
            assert_eq!(arena.resident_pages(), resident, "kind={kind:?}: page count changed");
            assert_eq!(arena.free_pages(), free, "kind={kind:?}: free list changed");
            for (p, want) in rows.iter().enumerate() {
                assert_eq!(
                    &arena.kv_row(1, slot, p),
                    want,
                    "kind={kind:?}: surviving row {p} drifted across the rollback"
                );
            }
            // a partial rollback must NOT free the open tail page: the
            // slot keeps decoding through it without re-allocating
            m.decode_step_batch(&[2], &[slot], &mut arena);
            assert_eq!(arena.len(slot), 7);
            assert_eq!(arena.resident_pages(), resident);
        }
    }

    /// A rollback crossing a page boundary must free the page the
    /// rolled-back rows freshly opened (refcount to zero, back on the
    /// free list), while a rollback stopping exactly at the boundary
    /// keeps the still-covered page resident.
    #[test]
    fn tail_rollback_across_boundary_frees_fresh_page() {
        let m = spec_model();
        let mut arena = KvArena::with_kind_paged(&m, 1, KvCacheKind::F32, 4);
        let slot = arena.alloc().unwrap();
        m.prefill_slot(&[3, 1, 4, 1], slot, &mut arena); // exactly one full page
        assert_eq!(arena.resident_pages(), 1);
        let free = arena.free_pages();
        // drafts open a second page…
        draft_append(&m, &mut arena, slot, &[9, 2]);
        assert_eq!(arena.resident_pages(), 2, "drafts opened the tail page");
        // …and rolling them back must hand it straight back
        arena.truncate_tail(slot, 2);
        assert_eq!(arena.len(slot), 4);
        assert_eq!(arena.resident_pages(), 1, "freshly-opened page must free");
        assert_eq!(arena.free_pages(), free, "page must return to the free list");
        // partial rollbacks stage by stage: 6 rows → drop 1 (page
        // still covered) → drop 1 more (crosses the boundary)
        draft_append(&m, &mut arena, slot, &[9, 2]);
        assert_eq!(arena.resident_pages(), 2);
        arena.truncate_tail(slot, 1);
        assert_eq!(arena.len(slot), 5);
        assert_eq!(arena.resident_pages(), 2, "page with a live row must survive");
        arena.truncate_tail(slot, 1);
        assert_eq!(arena.len(slot), 4);
        assert_eq!(arena.resident_pages(), 1, "boundary crossing frees the page");
    }

    /// Rollback arithmetic must count the slot's **head offset**: after
    /// a mid-page `truncate_front` slide, position → page mapping is
    /// shifted, and the keep-page computation has to shift with it.
    #[test]
    fn tail_rollback_respects_head_offset() {
        let m = spec_model();
        let mut arena = KvArena::with_kind_paged(&m, 1, KvCacheKind::F32, 4);
        let slot = arena.alloc().unwrap();
        m.prefill_slot(&[3, 1, 4, 1, 5, 9], slot, &mut arena);
        arena.truncate_front(slot, 5); // head offset 1, one page dropped
        assert_eq!(arena.len(slot), 1);
        let resident = arena.resident_pages();
        // head(1) + len(1) + 3 appends = 5 > ps: opens a second page
        draft_append(&m, &mut arena, slot, &[7, 7, 7]);
        assert_eq!(arena.resident_pages(), resident + 1);
        arena.truncate_tail(slot, 3);
        assert_eq!(arena.len(slot), 1);
        assert_eq!(
            arena.resident_pages(),
            resident,
            "head-offset slot must free exactly the page its drafts opened"
        );
    }

    /// Prefix-cache neutrality: drafts and their rollback must leave
    /// the cache, adoption credit, and per-page overflow ledgers
    /// byte-identical — a draft recorded onto a shared ledger would
    /// corrupt every later adopter's attribution.
    #[test]
    fn tail_rollback_leaves_cache_and_ovf_ledgers_untouched() {
        // narrow attention register so fill-time events are live
        let kind = KvCacheKind::Quant(KvQuantSpec::new(8, 8, Some(6)));
        let m = spec_model();
        let prompt: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5]; // 2 full pages + 1 tail row
        let mut arena = KvArena::with_kind_paged(&m, 3, kind, 4);
        let a = arena.alloc().unwrap();
        m.prefill_slot(&prompt, a, &mut arena);
        arena.register_prefix(a, &prompt);
        assert_eq!(arena.prefix_cache_pages(), 2);
        // baseline adoption credit before any speculation
        let b = arena.alloc().unwrap();
        let (mapped, credit) = arena.adopt_prefix(b, &prompt);
        assert_eq!(mapped, 8);
        arena.release(b);
        // draft rows on A's open tail page, then roll them back
        draft_append(&m, &mut arena, a, &[7, 7, 7]);
        arena.truncate_tail(a, 3);
        assert_eq!(arena.prefix_cache_pages(), 2, "rollback must not disturb the cache");
        let c = arena.alloc().unwrap();
        let (mapped2, credit2) = arena.adopt_prefix(c, &prompt);
        assert_eq!(mapped2, mapped);
        assert_eq!(
            credit2, credit,
            "draft + rollback changed a shared page's overflow ledger"
        );
    }

    /// The registered-prefix guard: a rollback can never cut into pages
    /// the cache indexes (drafts only extend past the verified
    /// high-water mark).
    #[test]
    fn tail_rollback_into_registered_pages_panics() {
        let m = spec_model();
        let prompt: Vec<u16> = vec![3, 1, 4, 1, 5]; // 1 full page + 1 tail row
        let mut arena = KvArena::with_kind_paged(&m, 1, KvCacheKind::F32, 4);
        let slot = arena.alloc().unwrap();
        m.prefill_slot(&prompt, slot, &mut arena);
        arena.register_prefix(slot, &prompt);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut a2 = arena.clone();
            a2.truncate_tail(slot, 2); // would cut into the registered page
        }));
        assert!(r.is_err(), "rollback into registered pages must panic");
        // rolling back only the unregistered tail row is fine
        arena.truncate_tail(slot, 1);
        assert_eq!(arena.len(slot), 4);
    }
}
