//! Inference substrate: float and integer-datapath model execution.
//!
//! The paper quantizes pre-trained ImageNet classifiers and HF language
//! models; this repo's zoo (trained by `python/compile/train.py`) is a
//! pico-LM transformer family plus glyph MLP classifiers — see DESIGN.md
//! §2 for the substitution rationale. Quantized linears execute on the
//! bit-accurate accumulator simulator from [`crate::accum`].

pub mod decode;
pub mod kvquant;
pub mod layers;
pub mod linear;
pub mod loader;
pub mod mlp;
pub mod paging;
pub mod sample;
pub mod scratch;
pub mod transformer;

pub use decode::{argmax, KvArena, KvCache, LogitRows, RaggedOpts, RowGroup};
pub use kvquant::{KvCacheKind, KvQuantSpec};
pub use layers::{
    attend_chunk, attend_chunk_quant, attend_chunk_rows, attend_one_query,
    attend_one_query_quant, attend_one_query_quant_ref, attend_one_query_rows, attention,
    softmax, Activation, ContigKv, KvRows, LayerNorm,
};
pub use linear::{Datapath, FloatLinear, Linear, QuantLinear};
pub use loader::{
    list_models, load_model, load_named, read_f32_bin, read_f32_bin_any, write_f32_bin, Model,
};
pub use mlp::{random_mlp, Mlp, MlpConfig};
pub use paging::{PageMap, PagePool, PrefixCache, DEFAULT_KV_PAGE, NO_PREFIX};
pub use sample::SampleSpec;
pub use scratch::{AttnScratch, DecodeScratch, LinearScratch, StepScratch, PAR_ATTN_MIN_WORK};
pub use transformer::{random_transformer, Block, Capture, Transformer, TransformerConfig};
