//! Accumulator-aware quantized KV cache — the storage half of the
//! integer attention datapath.
//!
//! The linear layers already carry the paper's overflow-avoidance
//! guarantee; the KV arena was the last float island: `f32` keys/values
//! dominate serving memory and the attention score (q·kᵀ) and value
//! (p·V) matmuls ran outside the accumulator machinery. This module
//! stores per-layer K/V as narrow integer codes with **per-(page,
//! offset, head) scales**, quantized once at append time (prefill and
//! decode) and never requantized afterwards.
//!
//! Storage is **paged** ([`super::paging`]): slabs are indexed by
//! physical page id, and every accessor resolves a logical position
//! through a borrowed [`PageMap`] — the single indirection point of the
//! paged arena. Quantize-at-append makes a *full* page bit-immutable,
//! which is what lets the arena share prefix pages across sequences by
//! refcount without weakening bit-exactness: every reader decodes the
//! same codes against the same scales.
//!
//! Scales are packed as **bf16-in-u16** (the top 16 bits of the f32,
//! rounded *up* so the decoded scale can never under-cover the head's
//! max element — codes are always computed against the decoded scale,
//! so quantize/dequantize stay exactly consistent and the ±½·scale
//! round-trip bound survives the packing). This halves the scale
//! overhead versus f32 storage: 1/(2·head_dim) instead of 1/head_dim.
//!
//! The matching compute half is
//! [`super::layers::attend_one_query_quant`], which runs both attention
//! matmuls through the same multi-stage integer datapath
//! ([`crate::linalg::qgemm`] tiles, [`crate::accum::simulator`]
//! semantics) the linear layers use. Because the cached codes carry no
//! AXE-trained ℓ1 guarantee, the default inner register width is the
//! data-type bound [`crate::quant::bounds::attention_inner_bits`]
//! (overflow provably impossible); narrower widths are accepted and
//! surface their overflow events through the serving accounting (and
//! the unified [`super::Transformer::overflow_events`] view).
//!
//! Reads happen through [`QuantKvSlot`]'s **bulk gather accessors**
//! ([`QuantKvSlot::gather_k_head`] / [`QuantKvSlot::gather_v_head_t`]):
//! the storage-width enum is matched **once per page run**, after which
//! the head's contiguous K segment per position is widened with a tight
//! slice-to-slice loop (and V with a blocked transposing copy). Inner
//! loops never cross a page boundary, so the memcpy-shaped fast paths
//! survive the paging indirection.

use crate::accum::simulator::OverflowMode;
use crate::model::paging::PageMap;
use crate::quant::bounds::attention_inner_bits;

/// Configuration of the quantized-KV attention datapath.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvQuantSpec {
    /// K/V code width (2..=16; 8 → i8 storage, >8 → i16 storage).
    pub kv_bits: u32,
    /// Width of the online-quantized operands: the query codes (signed
    /// symmetric) and the probability codes (unsigned).
    pub op_bits: u32,
    /// Multi-stage accumulation tile size (Eq. 22).
    pub tile: usize,
    /// Inner accumulator width P_I for both attention matmuls.
    pub inner_bits: u32,
    /// Overflow behaviour of the attention registers.
    pub mode: OverflowMode,
}

impl KvQuantSpec {
    /// Spec with `kv_bits` codes and `tile`-sized inner accumulation.
    /// `inner_bits: None` picks the data-type-safe width (Eq. 3 at the
    /// tile depth) — attention then provably never overflows; a
    /// narrower explicit width turns the overflow counters live.
    pub fn new(kv_bits: u32, tile: usize, inner_bits: Option<u32>) -> KvQuantSpec {
        assert!((2..=16).contains(&kv_bits), "kv codes must be 2..=16 bits");
        assert!(tile >= 1, "tile must be >= 1");
        let op_bits = 8;
        let inner = inner_bits.unwrap_or_else(|| attention_inner_bits(tile, op_bits, kv_bits));
        assert!((2..=64).contains(&inner), "inner register must be 2..=64 bits");
        KvQuantSpec { kv_bits, op_bits, tile, inner_bits: inner, mode: OverflowMode::Wraparound }
    }

    /// The deployment default: i8 codes, 64-wide tiles, safe inner width.
    pub fn int8() -> KvQuantSpec {
        KvQuantSpec::new(8, 64, None)
    }

    /// Higher-fidelity variant: i16 codes (half the f32 saving).
    pub fn int16() -> KvQuantSpec {
        KvQuantSpec::new(16, 64, None)
    }

    /// Copy of this spec with the inner accumulator narrowed to at most
    /// `bits` (clamped to the 2-bit floor; never widens). The draft
    /// pass of self-speculative decoding runs the attention matmuls
    /// through this — same codes, same scales, narrower registers —
    /// so narrowing costs zero extra storage.
    pub fn narrowed(&self, bits: u32) -> KvQuantSpec {
        KvQuantSpec { inner_bits: self.inner_bits.min(bits.max(2)), ..*self }
    }

    /// Largest representable K/V code magnitude.
    #[inline]
    pub fn code_max(&self) -> i32 {
        (1i32 << (self.kv_bits - 1)) - 1
    }

    /// Bytes one stored code occupies (i8 below 9 bits, i16 above).
    #[inline]
    pub fn code_bytes(&self) -> usize {
        if self.kv_bits <= 8 {
            1
        } else {
            2
        }
    }
}

/// Which backend a KV arena runs on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KvCacheKind {
    /// Full-precision f32 keys/values, float attention (the baseline).
    F32,
    /// Integer codes + per-(page, offset, head) scales, attention on
    /// the multi-stage integer datapath.
    Quant(KvQuantSpec),
}

/// Encode a positive finite scale as bf16 (top half of the f32),
/// rounding **up** (toward +∞): the decoded scale is always ≥ the exact
/// one, so `round(x / scale)` can never exceed `code_max` for the
/// segment's max element and the ±½·scale round-trip bound holds even
/// for 16-bit codes. Incrementing the truncated u16 is a correct
/// ceiling because positive IEEE floats order like their bit patterns
/// (a mantissa carry rolls into the exponent).
#[inline]
pub fn bf16_encode_ceil(x: f32) -> u16 {
    debug_assert!(x >= 0.0 && x.is_finite(), "scales are positive finite");
    let bits = x.to_bits();
    let hi = (bits >> 16) as u16;
    if bits & 0xFFFF != 0 {
        hi + 1
    } else {
        hi
    }
}

/// Decode a bf16-packed scale back to f32 (exact: bf16 ⊂ f32).
#[inline]
pub fn bf16_decode(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Storage-width-erased code slab: i8 for ≤8-bit codes, i16 above —
/// the whole point of the quantized arena is its byte footprint, so
/// 8-bit codes must really occupy one byte each.
#[derive(Clone, Debug)]
pub enum CodeSlab {
    I8(Vec<i8>),
    I16(Vec<i16>),
}

impl CodeSlab {
    pub fn new(bits: u32, len: usize) -> CodeSlab {
        if bits <= 8 {
            CodeSlab::I8(vec![0; len])
        } else {
            CodeSlab::I16(vec![0; len])
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> i32 {
        match self {
            CodeSlab::I8(v) => v[i] as i32,
            CodeSlab::I16(v) => v[i] as i32,
        }
    }

    /// Store a code; the caller guarantees it fits the storage width
    /// (quantization clamps to ±code_max, which always fits).
    #[inline]
    pub fn set(&mut self, i: usize, code: i32) {
        match self {
            CodeSlab::I8(v) => v[i] = code as i8,
            CodeSlab::I16(v) => v[i] = code as i16,
        }
    }

    /// Widen the contiguous segment `[base, base + out.len())` into
    /// `out` — the enum is matched once, then the copy is a single
    /// tight (auto-vectorizable) loop over a contiguous source slice.
    #[inline]
    pub fn head_segment(&self, base: usize, out: &mut [i32]) {
        match self {
            CodeSlab::I8(v) => widen(&v[base..base + out.len()], out),
            CodeSlab::I16(v) => widen(&v[base..base + out.len()], out),
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            CodeSlab::I8(v) => v.len(),
            CodeSlab::I16(v) => v.len() * std::mem::size_of::<i16>(),
        }
    }
}

/// Contiguous widening copy (the memcpy-shaped inner loop of the bulk
/// gathers).
#[inline]
fn widen<T: Copy + Into<i32>>(src: &[T], out: &mut [i32]) {
    debug_assert_eq!(src.len(), out.len());
    for (o, &s) in out.iter_mut().zip(src.iter()) {
        *o = s.into();
    }
}

/// Strided gather of one head across `t_len` positions into a
/// `(t_len, hd)` row-major panel: each position's head segment is
/// contiguous in the slab, so the inner copy is contiguous.
fn gather_rows<T: Copy + Into<i32>>(
    src: &[T],
    base: usize,
    stride: usize,
    t_len: usize,
    hd: usize,
    out: &mut [i32],
) {
    debug_assert!(out.len() >= t_len * hd);
    for s in 0..t_len {
        let row = &src[base + s * stride..base + s * stride + hd];
        widen(row, &mut out[s * hd..(s + 1) * hd]);
    }
}

/// Blocked transposing gather of `n_rows` positions of one head into
/// columns `s0..s0 + n_rows` of a `(hd, t_cols)` row-major panel
/// (`out[i * t_cols + s0 + s] = src[base + s*stride + i]`) — the
/// value-matmul operand layout, fillable one page run at a time. 32×32
/// blocks keep both streams cache-resident.
fn gather_rows_t<T: Copy + Into<i32>>(
    src: &[T],
    base: usize,
    stride: usize,
    n_rows: usize,
    hd: usize,
    s0: usize,
    t_cols: usize,
    out: &mut [i32],
) {
    debug_assert!(s0 + n_rows <= t_cols);
    debug_assert!(out.len() >= hd * t_cols);
    const TB: usize = 32;
    for sb in (0..n_rows).step_by(TB) {
        let se = (sb + TB).min(n_rows);
        for ib in (0..hd).step_by(TB) {
            let ie = (ib + TB).min(hd);
            for s in sb..se {
                let row = &src[base + s * stride + ib..base + s * stride + ie];
                for (i, &v) in row.iter().enumerate() {
                    out[(ib + i) * t_cols + s0 + s] = v.into();
                }
            }
        }
    }
}

/// Quantized K/V page storage: per layer, `n_pages × page_size`
/// positions of `d` codes plus `n_heads` bf16 scales per position per
/// tensor, indexed by **physical page id**. Which pages form a
/// sequence — and in what order — is the arena's business; every
/// accessor here takes a [`PageMap`].
#[derive(Clone, Debug)]
pub struct QuantKv {
    pub spec: KvQuantSpec,
    d: usize,
    page_size: usize,
    n_heads: usize,
    /// [layer] → n_pages·page_size·d codes.
    k_codes: Vec<CodeSlab>,
    v_codes: Vec<CodeSlab>,
    /// [layer] → n_pages·page_size·n_heads per-(page, offset, head)
    /// bf16-packed scales.
    k_scales: Vec<Vec<u16>>,
    v_scales: Vec<Vec<u16>>,
}

impl QuantKv {
    pub fn new(
        spec: KvQuantSpec,
        n_layers: usize,
        n_pages: usize,
        page_size: usize,
        d: usize,
        n_heads: usize,
    ) -> QuantKv {
        assert!(n_heads >= 1 && d % n_heads == 0, "d must divide n_heads");
        assert!(page_size >= 1, "pages hold at least one position");
        let codes = n_pages * page_size * d;
        let scales = n_pages * page_size * n_heads;
        QuantKv {
            spec,
            d,
            page_size,
            n_heads,
            k_codes: (0..n_layers).map(|_| CodeSlab::new(spec.kv_bits, codes)).collect(),
            v_codes: (0..n_layers).map(|_| CodeSlab::new(spec.kv_bits, codes)).collect(),
            k_scales: vec![vec![0; scales]; n_layers],
            v_scales: vec![vec![0; scales]; n_layers],
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    #[inline]
    fn code_base(&self, page: usize, off: usize) -> usize {
        (page * self.page_size + off) * self.d
    }

    #[inline]
    fn scale_base(&self, page: usize, off: usize) -> usize {
        (page * self.page_size + off) * self.n_heads
    }

    /// Quantize one position's K/V rows — per-head symmetric scales
    /// (bf16-packed), codes clamped to ±code_max. This is the only
    /// place K/V values are ever quantized; a page, once full, is never
    /// rewritten (sharing and slides move page *references*, not data).
    pub fn append_row(
        &mut self,
        layer: usize,
        map: &PageMap<'_>,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        debug_assert_eq!(map.page_size(), self.page_size);
        let (pg, off) = map.locate(pos);
        let hd = self.d / self.n_heads;
        let qmax = self.spec.code_max();
        let cb = self.code_base(pg, off);
        let sb = self.scale_base(pg, off);
        for h in 0..self.n_heads {
            let o = h * hd;
            self.k_scales[layer][sb + h] =
                quantize_head(&k_row[o..o + hd], qmax, &mut self.k_codes[layer], cb + o);
            self.v_scales[layer][sb + h] =
                quantize_head(&v_row[o..o + hd], qmax, &mut self.v_codes[layer], cb + o);
        }
    }

    /// Quantize a **chunk** of `n` consecutive positions — the
    /// ragged-step prefill append path. `k_rows`/`v_rows` are `(n, d)`
    /// row-major; position `pos + i` receives row `i`. Identical, row
    /// for row, to `n` calls of [`QuantKv::append_row`] (each
    /// position's scales depend only on its own row), so chunked and
    /// token-by-token appends fill the pages with the same bits.
    pub fn append_rows(
        &mut self,
        layer: usize,
        map: &PageMap<'_>,
        pos: usize,
        n: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) {
        debug_assert_eq!(k_rows.len(), n * self.d);
        debug_assert_eq!(v_rows.len(), n * self.d);
        let d = self.d;
        for i in 0..n {
            self.append_row(
                layer,
                map,
                pos + i,
                &k_rows[i * d..(i + 1) * d],
                &v_rows[i * d..(i + 1) * d],
            );
        }
    }

    /// Read-only view of one sequence at one layer (for the attention
    /// path): the layer's slabs plus the slot's page map.
    pub fn slot_view<'a>(&'a self, layer: usize, map: PageMap<'a>) -> QuantKvSlot<'a> {
        debug_assert_eq!(map.page_size(), self.page_size);
        QuantKvSlot {
            k_codes: &self.k_codes[layer],
            v_codes: &self.v_codes[layer],
            k_scales: &self.k_scales[layer],
            v_scales: &self.v_scales[layer],
            map,
            d: self.d,
            n_heads: self.n_heads,
        }
    }

    /// Full slab footprint in bytes (codes + bf16 scales, every page).
    pub fn bytes(&self) -> usize {
        let mut total = 0usize;
        for slab in self.k_codes.iter().chain(self.v_codes.iter()) {
            total += slab.bytes();
        }
        for scales in self.k_scales.iter().chain(self.v_scales.iter()) {
            total += scales.len() * std::mem::size_of::<u16>();
        }
        total
    }

    /// Payload bytes of a single page at this geometry (codes + scales,
    /// K and V, all layers) — the unit of resident accounting.
    pub fn page_bytes(&self) -> usize {
        let layers = self.k_codes.len();
        2 * layers * self.page_size * (self.d * self.spec.code_bytes() + self.n_heads * 2)
    }
}

/// Borrowed view of one sequence's codes and scales at one layer.
/// Positions are sequence-local (0 = oldest cached position); every
/// accessor resolves them through the slot's [`PageMap`].
pub struct QuantKvSlot<'a> {
    k_codes: &'a CodeSlab,
    v_codes: &'a CodeSlab,
    k_scales: &'a [u16],
    v_scales: &'a [u16],
    map: PageMap<'a>,
    d: usize,
    n_heads: usize,
}

impl QuantKvSlot<'_> {
    #[inline]
    fn code_base(&self, pos: usize) -> usize {
        let (pg, off) = self.map.locate(pos);
        (pg * self.map.page_size() + off) * self.d
    }

    #[inline]
    fn scale_base(&self, pos: usize) -> usize {
        let (pg, off) = self.map.locate(pos);
        (pg * self.map.page_size() + off) * self.n_heads
    }

    #[inline]
    pub fn k_code(&self, pos: usize, i: usize) -> i32 {
        self.k_codes.get(self.code_base(pos) + i)
    }

    #[inline]
    pub fn v_code(&self, pos: usize, i: usize) -> i32 {
        self.v_codes.get(self.code_base(pos) + i)
    }

    #[inline]
    pub fn k_scale(&self, pos: usize, head: usize) -> f32 {
        bf16_decode(self.k_scales[self.scale_base(pos) + head])
    }

    #[inline]
    pub fn v_scale(&self, pos: usize, head: usize) -> f32 {
        bf16_decode(self.v_scales[self.scale_base(pos) + head])
    }

    /// Bulk-gather head `head`'s key codes over positions `0..t_len`
    /// into a `(t_len, hd)` row-major panel — page run by page run, one
    /// enum match and then contiguous widening copies per run (the
    /// score-matmul operand).
    pub fn gather_k_head(&self, t_len: usize, head: usize, out: &mut [i32]) {
        let hd = self.d / self.n_heads;
        debug_assert!(out.len() >= t_len * hd);
        let mut s = 0usize;
        while s < t_len {
            let run = self.map.run(s, t_len - s);
            let base = self.code_base(s) + head * hd;
            let dst = &mut out[s * hd..(s + run) * hd];
            match self.k_codes {
                CodeSlab::I8(v) => gather_rows(v.as_slice(), base, self.d, run, hd, dst),
                CodeSlab::I16(v) => gather_rows(v.as_slice(), base, self.d, run, hd, dst),
            }
            s += run;
        }
    }

    /// Bulk-gather head `head`'s value codes over positions `0..t_len`
    /// into a `(hd, t_len)` row-major **transposed** panel via a
    /// blocked copy per page run (the value-matmul operand).
    pub fn gather_v_head_t(&self, t_len: usize, head: usize, out: &mut [i32]) {
        let hd = self.d / self.n_heads;
        debug_assert!(out.len() >= t_len * hd);
        let mut s = 0usize;
        while s < t_len {
            let run = self.map.run(s, t_len - s);
            let base = self.code_base(s) + head * hd;
            match self.v_codes {
                CodeSlab::I8(v) => {
                    gather_rows_t(v.as_slice(), base, self.d, run, hd, s, t_len, out)
                }
                CodeSlab::I16(v) => {
                    gather_rows_t(v.as_slice(), base, self.d, run, hd, s, t_len, out)
                }
            }
            s += run;
        }
    }

    /// Dequantized K row at `pos` (tests / diagnostics).
    pub fn dequant_k_row(&self, pos: usize) -> Vec<f32> {
        self.dequant_row(pos, true)
    }

    /// Dequantized V row at `pos` (tests / diagnostics).
    pub fn dequant_v_row(&self, pos: usize) -> Vec<f32> {
        self.dequant_row(pos, false)
    }

    fn dequant_row(&self, pos: usize, key: bool) -> Vec<f32> {
        let hd = self.d / self.n_heads;
        let mut out = vec![0.0f32; self.d];
        let mut seg = vec![0i32; hd];
        let base = self.code_base(pos);
        for h in 0..self.n_heads {
            let (slab, s) = if key {
                (self.k_codes, self.k_scale(pos, h))
            } else {
                (self.v_codes, self.v_scale(pos, h))
            };
            slab.head_segment(base + h * hd, &mut seg);
            for (o, &c) in out[h * hd..(h + 1) * hd].iter_mut().zip(seg.iter()) {
                *o = c as f32 * s;
            }
        }
        out
    }
}

/// Quantize one head segment symmetrically: scale = max|x| / qmax
/// rounded **up** to bf16, codes = round(x / scale) ∈ [−qmax, qmax],
/// computed against the *decoded* scale so storage and arithmetic agree
/// exactly. All-zero segments get a benign scale of 1.0 with all-zero
/// codes. Returns the bf16-packed scale.
fn quantize_head(xs: &[f32], qmax: i32, codes: &mut CodeSlab, base: usize) -> u16 {
    let mut maxabs = 0.0f32;
    for &v in xs {
        maxabs = maxabs.max(v.abs());
    }
    if maxabs <= 0.0 {
        for i in 0..xs.len() {
            codes.set(base + i, 0);
        }
        return bf16_encode_ceil(1.0);
    }
    let packed = bf16_encode_ceil(maxabs / qmax as f32);
    let scale = bf16_decode(packed);
    for (i, &v) in xs.iter().enumerate() {
        let c = (v / scale).round() as i32;
        codes.set(base + i, c.clamp(-qmax, qmax));
    }
    packed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layers::{attend_one_query, attend_one_query_quant};
    use crate::model::scratch::AttnScratch;
    use crate::util::rng::Rng;

    /// Build a 1-layer QuantKv of one `t_len`-sized page holding
    /// `t_len` random K/V rows; returns the float rows alongside for
    /// reference computations. View with `PageMap::new(&[0], 0, t_len)`.
    fn filled_kv(
        spec: KvQuantSpec,
        t_len: usize,
        d: usize,
        h: usize,
        seed: u64,
    ) -> (QuantKv, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut kv = QuantKv::new(spec, 1, 1, t_len, d, h);
        let mut k = vec![0.0f32; t_len * d];
        let mut v = vec![0.0f32; t_len * d];
        for x in k.iter_mut().chain(v.iter_mut()) {
            *x = rng.normal() as f32;
        }
        let table = [0u32];
        let map = PageMap::new(&table, 0, t_len);
        for pos in 0..t_len {
            kv.append_row(0, &map, pos, &k[pos * d..(pos + 1) * d], &v[pos * d..(pos + 1) * d]);
        }
        (kv, k, v)
    }

    #[test]
    fn spec_defaults_are_safe_widths() {
        let s = KvQuantSpec::int8();
        assert_eq!(s.kv_bits, 8);
        assert_eq!(s.tile, 64);
        assert_eq!(s.inner_bits, attention_inner_bits(64, 8, 8));
        assert_eq!(s.code_max(), 127);
        assert_eq!(s.code_bytes(), 1);
        let s16 = KvQuantSpec::int16();
        assert_eq!(s16.code_max(), 32767);
        assert_eq!(s16.code_bytes(), 2);
        // explicit narrow width is honoured (for overflow experiments)
        assert_eq!(KvQuantSpec::new(8, 32, Some(10)).inner_bits, 10);
    }

    #[test]
    fn code_slab_widths_and_bytes() {
        let mut s8 = CodeSlab::new(8, 4);
        let mut s16 = CodeSlab::new(12, 4);
        assert_eq!(s8.bytes(), 4);
        assert_eq!(s16.bytes(), 8);
        s8.set(1, -127);
        s16.set(1, 2047);
        assert_eq!(s8.get(1), -127);
        assert_eq!(s16.get(1), 2047);
        // head_segment widens a contiguous run in one call
        let mut seg = [0i32; 2];
        s8.head_segment(0, &mut seg);
        assert_eq!(seg, [0, -127]);
    }

    #[test]
    fn bf16_round_trip_is_upward_and_tight() {
        // exactly-representable values survive unchanged
        for &x in &[1.0f32, 0.5, 2.0, 0.0078125] {
            assert_eq!(bf16_decode(bf16_encode_ceil(x)), x);
        }
        // arbitrary positives round up by less than one bf16 ulp (2^-8 rel)
        let mut rng = Rng::new(77);
        for _ in 0..500 {
            let x = (rng.normal().abs() + 1e-6) as f32;
            let d = bf16_decode(bf16_encode_ceil(x));
            assert!(d >= x, "ceil must not under-cover: {d} < {x}");
            // bf16 has 7 explicit mantissa bits → one ulp is 2^-7 rel
            assert!(d <= x * (1.0 + 1.0 / 64.0), "ceil too loose: {d} vs {x}");
        }
    }

    #[test]
    fn append_roundtrip_error_is_bounded() {
        let mut rng = Rng::new(501);
        let (d, h) = (16usize, 4usize);
        let spec = KvQuantSpec::int8();
        let mut kv = QuantKv::new(spec, 1, 1, 8, d, h);
        let table = [0u32];
        let map = PageMap::new(&table, 0, 8);
        let k_row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let v_row: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 3.0).collect();
        kv.append_row(0, &map, 0, &k_row, &v_row);
        let view = kv.slot_view(0, map);
        let k_hat = view.dequant_k_row(0);
        let v_hat = view.dequant_v_row(0);
        for i in 0..d {
            let ks = view.k_scale(0, i / (d / h));
            let vs = view.v_scale(0, i / (d / h));
            assert!((k_row[i] - k_hat[i]).abs() <= 0.5 * ks + 1e-6, "k[{i}]");
            assert!((v_row[i] - v_hat[i]).abs() <= 0.5 * vs + 1e-6, "v[{i}]");
        }
    }

    #[test]
    fn roundtrip_bound_survives_bf16_even_at_16_bit_codes() {
        // The ceil-rounded scale is what makes this hold: a truncated
        // scale would under-cover max|x| and the clamp at ±code_max
        // could cost up to qmax·2^-8 · scale ≫ ½·scale for i16 codes.
        let mut rng = Rng::new(502);
        let (d, h) = (16usize, 2usize);
        let spec = KvQuantSpec::int16();
        let mut kv = QuantKv::new(spec, 1, 1, 4, d, h);
        let table = [0u32];
        let map = PageMap::new(&table, 0, 4);
        for trial in 0..50 {
            let row: Vec<f32> = (0..d).map(|_| (rng.normal() * 2.5) as f32).collect();
            kv.append_row(0, &map, 0, &row, &row);
            let view = kv.slot_view(0, map);
            let hat = view.dequant_k_row(0);
            for i in 0..d {
                let s = view.k_scale(0, i / (d / h));
                // 1e-6 slack covers f32 divide/multiply rounding noise
                assert!(
                    (row[i] - hat[i]).abs() <= 0.5 * s + 1e-6,
                    "trial {trial} dim {i}: {} vs {} (scale {s})",
                    row[i],
                    hat[i]
                );
            }
        }
    }

    #[test]
    fn append_rows_chunk_equals_row_by_row() {
        let mut rng = Rng::new(503);
        let (d, h, max) = (16usize, 2usize, 10usize);
        for spec in [KvQuantSpec::int8(), KvQuantSpec::int16()] {
            let mut chunked = QuantKv::new(spec, 2, 2, max, d, h);
            let mut single = QuantKv::new(spec, 2, 2, max, d, h);
            // both write page 1 (page 0 left alone as a canary)
            let table = [1u32];
            let map = PageMap::new(&table, 0, max);
            // 3 existing positions, then a 4-row chunk at pos 3
            let rows: Vec<f32> = (0..7 * d).map(|_| rng.normal() as f32).collect();
            let vals: Vec<f32> = (0..7 * d).map(|_| rng.normal() as f32 * 2.0).collect();
            for layer in 0..2 {
                for pos in 0..3 {
                    for kv in [&mut chunked, &mut single] {
                        kv.append_row(
                            layer,
                            &map,
                            pos,
                            &rows[pos * d..(pos + 1) * d],
                            &vals[pos * d..(pos + 1) * d],
                        );
                    }
                }
                chunked.append_rows(layer, &map, 3, 4, &rows[3 * d..], &vals[3 * d..]);
                for pos in 3..7 {
                    single.append_row(
                        layer,
                        &map,
                        pos,
                        &rows[pos * d..(pos + 1) * d],
                        &vals[pos * d..(pos + 1) * d],
                    );
                }
                for pos in 0..7 {
                    let (a, b) = (chunked.slot_view(layer, map), single.slot_view(layer, map));
                    assert_eq!(a.dequant_k_row(pos), b.dequant_k_row(pos), "k {spec:?} {pos}");
                    assert_eq!(a.dequant_v_row(pos), b.dequant_v_row(pos), "v {spec:?} {pos}");
                    for head in 0..h {
                        assert_eq!(a.k_scale(pos, head), b.k_scale(pos, head));
                        assert_eq!(a.v_scale(pos, head), b.v_scale(pos, head));
                    }
                }
            }
        }
    }

    #[test]
    fn zero_rows_quantize_benignly() {
        let spec = KvQuantSpec::int8();
        let mut kv = QuantKv::new(spec, 1, 1, 4, 8, 2);
        let table = [0u32];
        let map = PageMap::new(&table, 0, 4);
        kv.append_row(0, &map, 0, &[0.0; 8], &[0.0; 8]);
        let view = kv.slot_view(0, map);
        assert_eq!(view.k_scale(0, 0), 1.0);
        assert!(view.dequant_k_row(0).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn head_offset_map_reads_slid_rows_verbatim() {
        // A window slide is a *page-table* operation now: dropping the
        // head page and carrying an in-page head offset must expose
        // exactly the surviving rows, bit-identical — no data moves.
        let mut rng = Rng::new(502);
        let (d, h, ps) = (8usize, 2usize, 2usize);
        let mut kv = QuantKv::new(KvQuantSpec::int8(), 2, 4, ps, d, h);
        // sequence over pages [1, 2, 3]: 5 positions (page 0 = canary)
        let table = [1u32, 2, 3];
        let map = PageMap::new(&table, 0, ps);
        let canary_table = [0u32];
        let canary_map = PageMap::new(&canary_table, 0, ps);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for _ in 0..5 {
            rows.push((0..d).map(|_| rng.normal() as f32).collect());
        }
        for (pos, row) in rows.iter().enumerate() {
            for layer in 0..2 {
                kv.append_row(layer, &map, pos, row, row);
            }
        }
        kv.append_row(0, &canary_map, 0, &rows[0], &rows[0]);
        let mut before: Vec<Vec<f32>> = Vec::new();
        for p in 3..5 {
            before.push(kv.slot_view(1, map).dequant_k_row(p));
        }
        let canary = kv.slot_view(0, canary_map).dequant_k_row(0);
        // slide by 3: drop page 1 (one full page), head offset 1 in page 2
        let slid_table = [2u32, 3];
        let slid = PageMap::new(&slid_table, 1, ps);
        for (p, want) in before.iter().enumerate() {
            let got = kv.slot_view(1, slid).dequant_k_row(p);
            assert_eq!(&got, want, "position {p} drifted across the slide");
        }
        assert_eq!(kv.slot_view(0, canary_map).dequant_k_row(0), canary, "other page touched");
    }

    #[test]
    fn bulk_gathers_match_element_accessors() {
        // gather_k_head / gather_v_head_t must reproduce exactly what a
        // per-element k_code / v_code gather produces — for both slab
        // widths, every head, and short t_len prefixes (buffer-reuse
        // shape).
        for spec in [KvQuantSpec::int8(), KvQuantSpec::int16()] {
            let (d, h, max) = (24usize, 3usize, 9usize);
            let hd = d / h;
            let (kv, _, _) = filled_kv(spec, max, d, h, 540);
            let table = [0u32];
            let view = kv.slot_view(0, PageMap::new(&table, 0, max));
            let mut k_panel = vec![0i32; max * hd + 7]; // oversized on purpose
            let mut v_panel = vec![0i32; max * hd + 7];
            for t_len in [1usize, 5, max] {
                for head in 0..h {
                    k_panel.iter_mut().for_each(|v| *v = -9999);
                    v_panel.iter_mut().for_each(|v| *v = -9999);
                    view.gather_k_head(t_len, head, &mut k_panel);
                    view.gather_v_head_t(t_len, head, &mut v_panel);
                    for s in 0..t_len {
                        for i in 0..hd {
                            assert_eq!(
                                k_panel[s * hd + i],
                                view.k_code(s, head * hd + i),
                                "k {spec:?} t_len={t_len} head={head} [{s},{i}]"
                            );
                            assert_eq!(
                                v_panel[i * t_len + s],
                                view.v_code(s, head * hd + i),
                                "v {spec:?} t_len={t_len} head={head} [{s},{i}]"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn gathers_cross_page_boundaries_exactly() {
        // Same rows stored (a) in one big page and (b) scattered over
        // small pages in non-identity order with a head offset: every
        // accessor — element, bulk K, bulk transposed V — must agree
        // bit-for-bit between the two layouts.
        for spec in [KvQuantSpec::int8(), KvQuantSpec::int16()] {
            let (d, h, t_len, ps) = (12usize, 3usize, 10usize, 4usize);
            let hd = d / h;
            let (big, k, v) = filled_kv(spec, t_len, d, h, 541);
            let big_table = [0u32];
            let big_view = big.slot_view(0, PageMap::new(&big_table, 0, t_len));
            // paged copy: pages [3, 1, 4] with head offset 2 → needs
            // ceil((2 + 10) / 4) = 3 pages out of a 5-page pool
            let mut paged = QuantKv::new(spec, 1, 5, ps, d, h);
            let table = [3u32, 1, 4];
            let map = PageMap::new(&table, 2, ps);
            for pos in 0..t_len {
                let (ks, vs) = (&k[pos * d..(pos + 1) * d], &v[pos * d..(pos + 1) * d]);
                paged.append_row(0, &map, pos, ks, vs);
            }
            let view = paged.slot_view(0, map);
            let mut want = vec![0i32; t_len * hd];
            let mut got = vec![0i32; t_len * hd];
            for head in 0..h {
                big_view.gather_k_head(t_len, head, &mut want);
                view.gather_k_head(t_len, head, &mut got);
                assert_eq!(got, want, "k panel {spec:?} head {head}");
                big_view.gather_v_head_t(t_len, head, &mut want);
                view.gather_v_head_t(t_len, head, &mut got);
                assert_eq!(got, want, "v panel {spec:?} head {head}");
            }
            for pos in 0..t_len {
                assert_eq!(view.dequant_k_row(pos), big_view.dequant_k_row(pos), "row {pos}");
                for head in 0..h {
                    assert_eq!(view.k_scale(pos, head), big_view.k_scale(pos, head));
                    assert_eq!(view.v_scale(pos, head), big_view.v_scale(pos, head));
                }
            }
        }
    }

    #[test]
    fn quant_attention_tracks_float_attention() {
        // The integer attention path must approximate the float path to
        // within 8-bit quantization error on well-conditioned inputs.
        let (t_len, d, h) = (12usize, 16usize, 2usize);
        let spec = KvQuantSpec::int8();
        let (kv, k, v) = filled_kv(spec, t_len, d, h, 510);
        let table = [0u32];
        let map = PageMap::new(&table, 0, t_len);
        let mut rng = Rng::new(511);
        let mut scratch = AttnScratch::new();
        let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; d];
        attend_one_query(&q, &k, &v, t_len, d, h, &mut scratch, &mut want);
        let mut got = vec![0.0f32; d];
        let ovf = attend_one_query_quant(
            &q,
            &kv.slot_view(0, map),
            t_len,
            d,
            h,
            &spec,
            &mut scratch,
            &mut got,
        );
        assert_eq!(ovf, 0, "data-type-safe inner width must never overflow");
        for i in 0..d {
            assert!(
                (got[i] - want[i]).abs() < 0.2,
                "dim {i}: quant {} vs float {}",
                got[i],
                want[i]
            );
        }
        // the 16-bit variant stays within the same (tighter K/V
        // representation) envelope
        let spec16 = KvQuantSpec::int16();
        let (kv16, _, _) = filled_kv(spec16, t_len, d, h, 510);
        let mut got16 = vec![0.0f32; d];
        let ovf16 = attend_one_query_quant(
            &q,
            &kv16.slot_view(0, map),
            t_len,
            d,
            h,
            &spec16,
            &mut scratch,
            &mut got16,
        );
        assert_eq!(ovf16, 0);
        for i in 0..d {
            assert!((got16[i] - want[i]).abs() < 0.2, "kv16 dim {i}");
        }
    }

    #[test]
    fn uniform_attention_recovers_dequantized_value_row() {
        // Identical K rows → uniform probabilities; identical V rows →
        // the value reduction must reproduce the dequantized V row to
        // within float rounding, a closed-form check of the whole
        // integer chain (codes, folded scales, dequant).
        let (t_len, d, h) = (5usize, 8usize, 2usize);
        let spec = KvQuantSpec::int8();
        let mut kv = QuantKv::new(spec, 1, 1, t_len, d, h);
        let table = [0u32];
        let map = PageMap::new(&table, 0, t_len);
        let k_row: Vec<f32> = (0..d).map(|i| 0.3 + 0.01 * i as f32).collect();
        let v_row: Vec<f32> = (0..d).map(|i| (i as f32 - 3.0) * 0.2).collect();
        for pos in 0..t_len {
            kv.append_row(0, &map, pos, &k_row, &v_row);
        }
        let q = vec![0.5f32; d];
        let mut out = vec![0.0f32; d];
        let mut scratch = AttnScratch::new();
        let ovf = attend_one_query_quant(
            &q,
            &kv.slot_view(0, map),
            t_len,
            d,
            h,
            &spec,
            &mut scratch,
            &mut out,
        );
        assert_eq!(ovf, 0);
        let v_hat = kv.slot_view(0, map).dequant_v_row(0);
        for i in 0..d {
            assert!(
                (out[i] - v_hat[i]).abs() < 2e-3,
                "dim {i}: {} vs dequant {}",
                out[i],
                v_hat[i]
            );
        }
    }

    #[test]
    fn narrow_inner_register_overflows_and_is_deterministic() {
        let (t_len, d, h) = (16usize, 16usize, 2usize);
        // 6-bit inner register at tile 8 with 8-bit operands: hopeless.
        let spec = KvQuantSpec::new(8, 8, Some(6));
        let (kv, _, _) = filled_kv(spec, t_len, d, h, 520);
        let table = [0u32];
        let map = PageMap::new(&table, 0, t_len);
        let mut rng = Rng::new(521);
        let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32 + 0.5).collect();
        let mut out1 = vec![0.0f32; d];
        let mut out2 = vec![0.0f32; d];
        let mut scratch = AttnScratch::new();
        let ovf1 = attend_one_query_quant(
            &q,
            &kv.slot_view(0, map),
            t_len,
            d,
            h,
            &spec,
            &mut scratch,
            &mut out1,
        );
        let ovf2 = attend_one_query_quant(
            &q,
            &kv.slot_view(0, map),
            t_len,
            d,
            h,
            &spec,
            &mut scratch,
            &mut out2,
        );
        assert!(ovf1 > 0, "6-bit inner register must overflow");
        assert_eq!(ovf1, ovf2, "overflow counting must be deterministic");
        assert_eq!(out1, out2, "wrapped values must be deterministic");
    }

    #[test]
    fn safe_width_never_overflows_on_random_codes() {
        // The extended guarantee: at the data-type-bound inner width,
        // random (adversarial-scale) inputs can never overflow either
        // attention matmul — mirrors prop_safe_codes_never_overflow for
        // the linear datapath.
        let mut rng = Rng::new(530);
        let mut scratch = AttnScratch::new();
        for trial in 0..25usize {
            let h = 1 + (trial % 3);
            let hd = [4usize, 8, 16][trial % 3];
            let d = h * hd;
            let t_len = 1 + (trial * 7) % 24;
            let tile = [4usize, 16, 64][(trial / 3) % 3];
            let spec = KvQuantSpec::new(8, tile, None);
            let (kv, _, _) = filled_kv(spec, t_len, d, h, 531 + trial as u64);
            let table = [0u32];
            let map = PageMap::new(&table, 0, t_len);
            let q: Vec<f32> = (0..d).map(|_| (rng.normal() * 10.0) as f32).collect();
            let mut out = vec![0.0f32; d];
            let ovf = attend_one_query_quant(
                &q,
                &kv.slot_view(0, map),
                t_len,
                d,
                h,
                &spec,
                &mut scratch,
                &mut out,
            );
            assert_eq!(ovf, 0, "trial {trial}: safe width overflowed");
            assert!(out.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn bytes_quarter_f32_when_heads_are_wide() {
        // d=64, 2 heads (head dim 32): codes are 1/4 of f32 and the
        // bf16 per-(page, offset, head) scale overhead is 1/(2·hd) = 1.6%.
        let (layers, pages, ps, d, h) = (2usize, 3usize, 16usize, 64usize, 2usize);
        let kv = QuantKv::new(KvQuantSpec::int8(), layers, pages, ps, d, h);
        let f32_bytes = 2 * layers * pages * ps * d * 4;
        let want = 2 * layers * pages * ps * (d + h * 2);
        assert_eq!(kv.bytes(), want);
        assert_eq!(kv.page_bytes() * pages, want, "page_bytes is the per-page payload");
        assert!(
            (kv.bytes() as f64) <= 0.27 * f32_bytes as f64,
            "{} vs f32 {}",
            kv.bytes(),
            f32_bytes
        );
        // i16 codes cost exactly one extra byte per element
        let kv16 = QuantKv::new(KvQuantSpec::int16(), layers, pages, ps, d, h);
        assert_eq!(kv16.bytes(), want + 2 * layers * pages * ps * d);
    }

    #[test]
    fn bf16_scales_pull_narrow_heads_under_the_30_percent_bar() {
        // Head dim 16 (d=64, 4 heads): f32 scales put the i8 arena at
        // (64 + 4·4)/256 = 31.2% of f32 — over the bar. bf16 scales
        // land it at (64 + 4·2)/256 = 28.1%.
        let (layers, pages, ps, d, h) = (2usize, 2usize, 8usize, 64usize, 4usize);
        let kv = QuantKv::new(KvQuantSpec::int8(), layers, pages, ps, d, h);
        let f32_bytes = 2 * layers * pages * ps * d * 4;
        assert_eq!(kv.bytes(), 2 * layers * pages * ps * (d + h * 2));
        assert!(
            (kv.bytes() as f64) <= 0.30 * f32_bytes as f64,
            "head-dim-16 arena {} B exceeds 30% of f32 {} B",
            kv.bytes(),
            f32_bytes
        );
    }
}
