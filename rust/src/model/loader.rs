//! Weight-zoo loader: reads the manifests and raw-f32 tensor files that
//! `python/compile/train.py` exports into `artifacts/weights/<model>/`.
//!
//! Format: `manifest.json` carries the architecture and a tensor table
//! `{name: shape}`; each tensor lives in `<name>.bin` as little-endian
//! f32, row-major, shape `[out, in]` for weight matrices (matching the
//! rust `FloatLinear` layout directly).

use super::layers::{Activation, LayerNorm};
use super::linear::{FloatLinear, Linear};
use super::mlp::{Mlp, MlpConfig};
use super::transformer::{Block, Transformer, TransformerConfig};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// A loaded model of either family.
pub enum Model {
    Lm(Transformer),
    Img(Mlp),
}

impl Model {
    pub fn name(&self) -> &str {
        match self {
            Model::Lm(m) => &m.cfg.name,
            Model::Img(m) => &m.cfg.name,
        }
    }

    pub fn param_count(&self) -> usize {
        match self {
            Model::Lm(m) => m.cfg.param_count(),
            Model::Img(m) => m.cfg.param_count(),
        }
    }
}

/// Read a raw little-endian f32 tensor file.
pub fn read_f32_bin(path: &Path, expect_len: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expect_len * 4 {
        return Err(anyhow!(
            "{}: expected {} f32 ({} bytes), got {} bytes",
            path.display(),
            expect_len,
            expect_len * 4,
            bytes.len()
        ));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a raw little-endian f32 file of unknown length.
pub fn read_f32_bin_any(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{}: not a multiple of 4 bytes", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a raw little-endian f32 tensor file (used by tests and tools).
pub fn write_f32_bin(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

struct TensorTable {
    dir: PathBuf,
    shapes: std::collections::BTreeMap<String, Vec<usize>>,
}

impl TensorTable {
    fn from_manifest(dir: &Path, manifest: &Json) -> Result<TensorTable> {
        let tensors = manifest
            .get("tensors")
            .and_then(|t| t.as_obj())
            .ok_or_else(|| anyhow!("manifest missing 'tensors'"))?;
        let mut shapes = std::collections::BTreeMap::new();
        for (name, shape) in tensors {
            let dims: Vec<usize> = shape
                .as_arr()
                .ok_or_else(|| anyhow!("tensor {name}: shape must be array"))?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            shapes.insert(name.clone(), dims);
        }
        Ok(TensorTable { dir: dir.to_path_buf(), shapes })
    }

    fn load(&self, name: &str) -> Result<Vec<f32>> {
        let shape = self
            .shapes
            .get(name)
            .ok_or_else(|| anyhow!("tensor '{name}' not in manifest"))?;
        let len: usize = shape.iter().product();
        read_f32_bin(&self.dir.join(format!("{name}.bin")), len)
    }

    fn shape(&self, name: &str) -> Result<&[usize]> {
        self.shapes
            .get(name)
            .map(|s| s.as_slice())
            .ok_or_else(|| anyhow!("tensor '{name}' not in manifest"))
    }
}

/// Load a model directory produced by `train.py`.
pub fn load_model(dir: &Path) -> Result<Model> {
    let manifest_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let manifest = Json::parse(&text).map_err(|e| anyhow!("bad manifest: {e}"))?;
    let family = manifest.req_str("family")?;
    match family {
        "lm" => Ok(Model::Lm(load_transformer(dir, &manifest)?)),
        "img" => Ok(Model::Img(load_mlp(dir, &manifest)?)),
        other => Err(anyhow!("unknown model family '{other}'")),
    }
}

/// Load a model by name from `<artifacts>/weights/<name>/`.
pub fn load_named(name: &str) -> Result<Model> {
    let dir = crate::artifacts_dir().join("weights").join(name);
    load_model(&dir)
}

/// Names of all models present in the artifacts weight zoo.
pub fn list_models() -> Vec<String> {
    let dir = crate::artifacts_dir().join("weights");
    let mut names = Vec::new();
    if let Ok(rd) = std::fs::read_dir(&dir) {
        for e in rd.flatten() {
            if e.path().join("manifest.json").is_file() {
                if let Some(n) = e.file_name().to_str() {
                    names.push(n.to_string());
                }
            }
        }
    }
    names.sort();
    names
}

fn load_transformer(dir: &Path, manifest: &Json) -> Result<Transformer> {
    let arch = manifest.get("lm").ok_or_else(|| anyhow!("manifest missing 'lm'"))?;
    let cfg = TransformerConfig {
        name: manifest.req_str("name")?.to_string(),
        vocab: arch.req_usize("vocab")?,
        d_model: arch.req_usize("d_model")?,
        n_layers: arch.req_usize("n_layers")?,
        n_heads: arch.req_usize("n_heads")?,
        d_ff: arch.req_usize("d_ff")?,
        max_seq: arch.req_usize("max_seq")?,
        act: Activation::parse(arch.req_str("act")?)
            .ok_or_else(|| anyhow!("bad activation"))?,
        parallel_residual: arch
            .get("parallel_residual")
            .and_then(|v| v.as_bool())
            .unwrap_or(false),
    };
    let t = TensorTable::from_manifest(dir, manifest)?;
    let d = cfg.d_model;

    let load_linear = |name: &str, in_dim: usize, out_dim: usize| -> Result<Linear> {
        let w = t.load(&format!("{name}.w"))?;
        let shape = t.shape(&format!("{name}.w"))?;
        if shape != [out_dim, in_dim] {
            return Err(anyhow!("{name}.w: expected [{out_dim},{in_dim}], got {shape:?}"));
        }
        let b = t.load(&format!("{name}.b"))?;
        Ok(Linear::Float(FloatLinear::new(in_dim, out_dim, w, b)))
    };

    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for bi in 0..cfg.n_layers {
        let p = format!("b{bi}");
        blocks.push(Block {
            ln1: LayerNorm::new(t.load(&format!("{p}.ln1.g"))?, t.load(&format!("{p}.ln1.b"))?),
            ln2: LayerNorm::new(t.load(&format!("{p}.ln2.g"))?, t.load(&format!("{p}.ln2.b"))?),
            wq: load_linear(&format!("{p}.wq"), d, d)?,
            wk: load_linear(&format!("{p}.wk"), d, d)?,
            wv: load_linear(&format!("{p}.wv"), d, d)?,
            wo: load_linear(&format!("{p}.wo"), d, d)?,
            fc1: load_linear(&format!("{p}.fc1"), d, cfg.d_ff)?,
            fc2: load_linear(&format!("{p}.fc2"), cfg.d_ff, d)?,
        });
    }
    let embed = t.load("embed")?;
    let pos = t.load("pos")?;
    let ln_f = LayerNorm::new(t.load("ln_f.g")?, t.load("ln_f.b")?);
    let head_w = t.load("head.w")?;
    let head = FloatLinear::new(d, cfg.vocab, head_w, vec![0.0; cfg.vocab]);
    Ok(Transformer {
        cfg,
        embed,
        pos,
        blocks,
        ln_f,
        head,
        attn_overflows: std::sync::atomic::AtomicU64::new(0),
    })
}

fn load_mlp(dir: &Path, manifest: &Json) -> Result<Mlp> {
    let arch = manifest.get("img").ok_or_else(|| anyhow!("manifest missing 'img'"))?;
    let hidden: Vec<usize> = arch
        .req_arr("hidden")?
        .iter()
        .map(|v| v.as_usize().unwrap_or(0))
        .collect();
    let cfg = MlpConfig {
        name: manifest.req_str("name")?.to_string(),
        input_dim: arch.req_usize("input_dim")?,
        hidden,
        classes: arch.req_usize("classes")?,
        act: Activation::parse(arch.req_str("act")?)
            .ok_or_else(|| anyhow!("bad activation"))?,
        residual: arch.get("residual").and_then(|v| v.as_bool()).unwrap_or(false),
    };
    let t = TensorTable::from_manifest(dir, manifest)?;
    let mut layers = Vec::new();
    let mut prev = cfg.input_dim;
    for (i, &h) in cfg.hidden.iter().enumerate() {
        let w = t.load(&format!("l{i}.w"))?;
        let b = t.load(&format!("l{i}.b"))?;
        layers.push(Linear::Float(FloatLinear::new(prev, h, w, b)));
        prev = h;
    }
    let head = FloatLinear::new(prev, cfg.classes, t.load("head.w")?, t.load("head.b")?);
    Ok(Mlp { cfg, layers, head })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bin_roundtrip() {
        let dir = std::env::temp_dir().join(format!("axe_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let data = vec![1.5f32, -2.25, 0.0, 1e-9];
        write_f32_bin(&path, &data).unwrap();
        let back = read_f32_bin(&path, 4).unwrap();
        assert_eq!(back, data);
        assert!(read_f32_bin(&path, 5).is_err(), "length mismatch detected");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_roundtrip_mlp() {
        let dir = std::env::temp_dir().join(format!("axe_mlp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // build a tiny mlp manifest by hand
        let mut tensors = Json::obj();
        tensors.set("l0.w", vec![3usize, 4].into());
        tensors.set("l0.b", vec![3usize].into());
        tensors.set("head.w", vec![2usize, 3].into());
        tensors.set("head.b", vec![2usize].into());
        let mut arch = Json::obj();
        arch.set("input_dim", 4usize.into())
            .set("hidden", vec![3usize].into())
            .set("classes", 2usize.into())
            .set("act", "relu".into())
            .set("residual", false.into());
        let mut m = Json::obj();
        m.set("name", "tiny-img".into())
            .set("family", "img".into())
            .set("img", arch)
            .set("tensors", tensors);
        std::fs::write(dir.join("manifest.json"), m.to_pretty()).unwrap();
        write_f32_bin(&dir.join("l0.w.bin"), &[0.1; 12]).unwrap();
        write_f32_bin(&dir.join("l0.b.bin"), &[0.0; 3]).unwrap();
        write_f32_bin(&dir.join("head.w.bin"), &[0.2; 6]).unwrap();
        write_f32_bin(&dir.join("head.b.bin"), &[0.0; 2]).unwrap();
        let model = load_model(&dir).unwrap();
        match model {
            Model::Img(mlp) => {
                let y = mlp.forward(&[1.0, 1.0, 1.0, 1.0], None);
                assert_eq!(y.len(), 2);
                // l0: 0.1*4=0.4 relu -> head: 0.2*0.4*3=0.24
                assert!((y[0] - 0.24).abs() < 1e-6);
            }
            _ => panic!("wrong family"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_is_error() {
        let dir = std::env::temp_dir().join(format!("axe_miss_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut m = Json::obj();
        m.set("name", "x".into())
            .set("family", "img".into())
            .set("img", {
                let mut a = Json::obj();
                a.set("input_dim", 4usize.into())
                    .set("hidden", vec![3usize].into())
                    .set("classes", 2usize.into())
                    .set("act", "relu".into());
                a
            })
            .set("tensors", Json::obj());
        std::fs::write(dir.join("manifest.json"), m.to_pretty()).unwrap();
        assert!(load_model(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
