//! Seeded, **batch-invariant** token sampling.
//!
//! The serving engine's exactness story (sequential ≡ batched ≡ ragged,
//! bit for bit) only extends beyond greedy decoding if the sampled
//! token is a pure function of the logits and a key that does not
//! depend on batch composition. [`SampleSpec::sample`] is exactly
//! that: the RNG draw is keyed per `(seed, stream, position)` — the
//! engine uses the request id as the stream and the request's emitted
//! count as the position — so a sequence draws the same randomness
//! whether it decodes alone, inside any batch, or interleaved with
//! prefill chunks, and two runs with the same seed replay identically.
//!
//! **Greedy is the `temperature == 0` corner** and routes through
//! [`super::decode::argmax`] (first maximum), so every greedy path in
//! the crate keeps one tie-break. Candidate ordering is a total order
//! (logit descending, index ascending), which makes `top_k == 1`
//! coincide with greedy exactly, and `top_p == 1.0` skip the nucleus
//! cut entirely (bit-identical to temperature-only sampling). All
//! probability arithmetic is fixed-order scalar f64, so results are
//! identical at every thread count and SIMD setting.

use super::decode::argmax;
use crate::util::rng::Rng;

/// Sampling configuration of one serve run (`--temperature`,
/// `--top-k`, `--top-p`, `--seed`). The default is greedy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleSpec {
    /// Softmax temperature; `<= 0` means greedy argmax (the other
    /// fields are ignored then).
    pub temperature: f32,
    /// Keep only the `top_k` highest-logit candidates before
    /// renormalizing; `0` disables the cut.
    pub top_k: usize,
    /// Nucleus cut: keep the smallest candidate prefix whose
    /// probability mass reaches `top_p`; `1.0` disables the cut.
    pub top_p: f32,
    /// Root seed of the run; every `(stream, position)` derives its own
    /// independent generator from it.
    pub seed: u64,
}

impl Default for SampleSpec {
    fn default() -> Self {
        SampleSpec::greedy()
    }
}

impl SampleSpec {
    /// Deterministic argmax decoding — the spec every pre-sampling
    /// caller implicitly ran.
    pub fn greedy() -> SampleSpec {
        SampleSpec { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }

    /// Temperature sampling with no top-k/top-p cut.
    pub fn temperature(t: f32, seed: u64) -> SampleSpec {
        SampleSpec { temperature: t, seed, ..SampleSpec::greedy() }
    }

    /// This spec with a top-k cut.
    pub fn with_top_k(self, k: usize) -> SampleSpec {
        SampleSpec { top_k: k, ..self }
    }

    /// This spec with a nucleus (top-p) cut.
    pub fn with_top_p(self, p: f32) -> SampleSpec {
        assert!((0.0..=1.0).contains(&p), "top_p must be in [0, 1]");
        SampleSpec { top_p: p, ..self }
    }

    /// Whether this spec decodes greedily (no randomness drawn at all —
    /// the speculative scheduler requires this for its exactness
    /// oracle).
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    /// Sample one token id from `logits`. `stream` and `position` key
    /// the draw (see module docs); equal keys and logits always yield
    /// equal tokens. Allocates a transient candidate buffer — hot
    /// paths hold one and call [`SampleSpec::sample_with`].
    pub fn sample(&self, logits: &[f32], stream: u64, position: u64) -> usize {
        let mut buf = Vec::new();
        self.sample_with(logits, stream, position, &mut buf)
    }

    /// [`SampleSpec::sample`] over a caller-owned candidate buffer —
    /// allocation-free once `buf` has reached vocab capacity (the
    /// engine presizes it, keeping sampled decode on the zero-alloc
    /// steady state).
    pub fn sample_with(
        &self,
        logits: &[f32],
        stream: u64,
        position: u64,
        buf: &mut Vec<(f32, u32)>,
    ) -> usize {
        if self.is_greedy() {
            return argmax(logits);
        }
        // independent generator per (seed, stream, position): a pure
        // function of the three keys, so the draw is batch-invariant
        // and replayable by construction
        let mut rng = Rng::new(self.seed).fork(stream).fork(position);
        buf.clear();
        buf.extend(logits.iter().enumerate().map(|(i, &v)| (v, i as u32)));
        // total order (logit desc, index asc): the head of the sorted
        // list is argmax's first maximum, so top_k == 1 ≡ greedy
        buf.sort_unstable_by(|a, b| {
            b.0.partial_cmp(&a.0).expect("non-finite logit").then(a.1.cmp(&b.1))
        });
        if self.top_k > 0 {
            buf.truncate(self.top_k.max(1));
        }
        // softmax at temperature over the kept candidates, shifted by
        // the max logit for stability; probabilities replace the logit
        // component in place
        let t = self.temperature as f64;
        let m = buf[0].0 as f64;
        let mut total = 0f64;
        for c in buf.iter_mut() {
            let p = ((c.0 as f64 - m) / t).exp();
            c.0 = p as f32;
            total += p;
        }
        // nucleus cut: smallest prefix reaching top_p of the mass
        // (candidates are probability-sorted already). top_p == 1.0
        // never truncates — the full mass is reached only at the end,
        // so the branch is bit-identical to temperature-only sampling.
        if self.top_p < 1.0 {
            let target = self.top_p as f64 * total;
            let mut cum = 0f64;
            let mut keep = 0usize;
            for c in buf.iter() {
                keep += 1;
                cum += c.0 as f64;
                if cum >= target {
                    break;
                }
            }
            buf.truncate(keep.max(1));
            total = buf.iter().map(|c| c.0 as f64).sum();
        }
        // inverse-CDF draw in fixed candidate order
        let r = rng.f64() * total;
        let mut cum = 0f64;
        for c in buf.iter() {
            cum += c.0 as f64;
            if r < cum {
                return c.1 as usize;
            }
        }
        buf.last().expect("at least one candidate").1 as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.1, 2.5, -1.0, 2.5, 0.7, 1.9, -0.3, 0.0]
    }

    #[test]
    fn greedy_is_first_argmax() {
        let spec = SampleSpec::greedy();
        // index 1 and 3 tie at 2.5 — first maximum wins
        assert_eq!(spec.sample(&logits(), 0, 0), 1);
        assert!(spec.is_greedy());
        assert_eq!(SampleSpec::default(), SampleSpec::greedy());
    }

    #[test]
    fn top_k_one_equals_greedy() {
        let spec = SampleSpec::temperature(0.8, 42).with_top_k(1);
        for pos in 0..50u64 {
            assert_eq!(spec.sample(&logits(), 7, pos), 1, "top_k=1 must match greedy");
        }
    }

    #[test]
    fn top_p_one_is_temperature_only() {
        let base = SampleSpec::temperature(1.3, 99);
        let cut = base.with_top_p(1.0);
        for stream in 0..4u64 {
            for pos in 0..40u64 {
                assert_eq!(
                    base.sample(&logits(), stream, pos),
                    cut.sample(&logits(), stream, pos),
                    "top_p=1.0 must be bit-identical to no nucleus cut"
                );
            }
        }
    }

    #[test]
    fn draws_are_keyed_and_replayable() {
        let spec = SampleSpec::temperature(1.0, 1234).with_top_k(4).with_top_p(0.9);
        let a: Vec<usize> = (0..64).map(|p| spec.sample(&logits(), 3, p)).collect();
        let b: Vec<usize> = (0..64).map(|p| spec.sample(&logits(), 3, p)).collect();
        assert_eq!(a, b, "same keys must replay identically");
        let c: Vec<usize> = (0..64).map(|p| spec.sample(&logits(), 4, p)).collect();
        assert_ne!(a, c, "a different stream must draw differently somewhere");
        // the candidate-buffer path is the same function
        let mut buf = Vec::new();
        for p in 0..64 {
            assert_eq!(spec.sample_with(&logits(), 3, p, &mut buf), a[p as usize]);
        }
    }

    #[test]
    fn tight_nucleus_collapses_to_argmax() {
        // top_p → 0 keeps exactly one candidate: the first maximum
        let spec = SampleSpec::temperature(1.0, 5).with_top_p(0.0);
        for pos in 0..20u64 {
            assert_eq!(spec.sample(&logits(), 0, pos), 1);
        }
    }

    #[test]
    fn samples_respect_top_k_support() {
        let spec = SampleSpec::temperature(2.0, 7).with_top_k(3);
        // top-3 of the fixture: indices 1, 3 (2.5) and 5 (1.9)
        for pos in 0..200u64 {
            let t = spec.sample(&logits(), 11, pos);
            assert!([1usize, 3, 5].contains(&t), "token {t} outside the top-k support");
        }
    }
}
