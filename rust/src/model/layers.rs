//! Non-linear building blocks: layer norm, activations, softmax,
//! attention math helpers — including the integer attention datapath
//! over the quantized KV cache ([`attend_one_query_quant`]).

use super::kvquant::{KvQuantSpec, QuantKvSlot};
use crate::accum::simulator::AccumSpec;
use crate::linalg::qgemm_multistage;
use crate::quant::bounds::outer_bits;

/// Layer normalization with learned gain and bias.
#[derive(Clone, Debug)]
pub struct LayerNorm {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub eps: f32,
}

impl LayerNorm {
    pub fn new(gamma: Vec<f32>, beta: Vec<f32>) -> LayerNorm {
        assert_eq!(gamma.len(), beta.len());
        LayerNorm { gamma, beta, eps: 1e-5 }
    }

    pub fn identity(dim: usize) -> LayerNorm {
        LayerNorm::new(vec![1.0; dim], vec![0.0; dim])
    }

    pub fn forward_row(&self, x: &[f32], y: &mut [f32]) {
        let n = x.len() as f32;
        let mean: f32 = x.iter().sum::<f32>() / n;
        let var: f32 = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
        let inv = 1.0 / (var + self.eps).sqrt();
        for ((yo, &xi), (&g, &b)) in
            y.iter_mut().zip(x.iter()).zip(self.gamma.iter().zip(self.beta.iter()))
        {
            *yo = (xi - mean) * inv * g + b;
        }
    }
}

/// Pointwise activation functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Gelu,
}

impl Activation {
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::Relu => x.max(0.0),
            Activation::Gelu => {
                // tanh approximation (GPT-2 style)
                const C: f32 = 0.7978845608; // sqrt(2/pi)
                0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
            }
        }
    }

    pub fn apply_vec(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.apply(*x);
        }
    }

    pub fn parse(s: &str) -> Option<Activation> {
        match s.to_ascii_lowercase().as_str() {
            "relu" => Some(Activation::Relu),
            "gelu" => Some(Activation::Gelu),
            _ => None,
        }
    }
}

/// In-place numerically-stable softmax.
pub fn softmax(xs: &mut [f32]) {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

/// Causal (or full) multi-head self-attention over a (seq, d) activation
/// buffer. q, k, v are (seq, d) with `n_heads` heads of size d/n_heads.
/// Writes the mixed values (pre-projection) into `out`.
///
/// Delegates every query row to [`attend_one_query`] (each (row, head)
/// pair is independent, so the nesting order is free) — prefill
/// attention and batched-decode attention therefore run the *same*
/// arithmetic, the invariant the serving engine's token-exactness
/// rests on.
#[allow(clippy::too_many_arguments)]
pub fn attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    seq: usize,
    d: usize,
    n_heads: usize,
    causal: bool,
    out: &mut [f32],
) {
    assert_eq!(q.len(), seq * d);
    assert_eq!(out.len(), seq * d);
    let hd = d / n_heads;
    assert_eq!(hd * n_heads, d, "d must divide n_heads");
    for t in 0..seq {
        let limit = if causal { t + 1 } else { seq };
        attend_one_query(
            &q[t * d..(t + 1) * d],
            k,
            v,
            limit,
            d,
            n_heads,
            &mut out[t * d..(t + 1) * d],
        );
    }
}

/// Single-query multi-head attention of one new position over `t_len`
/// cached positions — the ragged-batch decode primitive: each in-flight
/// sequence calls this over its **own** KV slab and length, so a
/// batched step needs no cross-sequence masking at all.
///
/// `q` is one (d,) query row; `kc`/`vc` are `(t_len, d)` cached
/// keys/values (the new position's K/V already appended). Writes the
/// mixed values (pre-projection) into `out`.
pub fn attend_one_query(
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    t_len: usize,
    d: usize,
    n_heads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    debug_assert!(kc.len() >= t_len * d && vc.len() >= t_len * d);
    let hd = d / n_heads;
    debug_assert_eq!(hd * n_heads, d, "d must divide n_heads");
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores = vec![0.0f32; t_len];
    for h in 0..n_heads {
        let off = h * hd;
        for (s, score) in scores.iter_mut().enumerate() {
            let krow = &kc[s * d + off..s * d + off + hd];
            let mut dot = 0.0f32;
            for i in 0..hd {
                dot += q[off + i] * krow[i];
            }
            *score = dot * scale;
        }
        softmax(&mut scores);
        let orow = &mut out[off..off + hd];
        orow.iter_mut().for_each(|o| *o = 0.0);
        for (s, &w) in scores.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            let vrow = &vc[s * d + off..s * d + off + hd];
            for i in 0..hd {
                orow[i] += w * vrow[i];
            }
        }
    }
}

/// Single-query multi-head attention over a **quantized** KV slot — the
/// integer-datapath counterpart of [`attend_one_query`], extending the
/// paper's overflow-avoidance machinery to the last two matmuls of the
/// decode loop. Returns the number of accumulator overflow events
/// (always 0 when `spec.inner_bits` is at the data-type bound).
///
/// Per head:
/// 1. the query segment is quantized online (symmetric signed
///    `spec.op_bits` codes, one scale per head);
/// 2. the **score matmul** q·kᵀ runs through the multi-stage integer
///    datapath (`spec.tile`-sized P_I tiles, Eq. 22 outer width) via
///    [`crate::linalg::qgemm_multistage`], whose ℓ1-mass fast path
///    executes overflow-proof tiles at plain-GEMM speed; scores are
///    dequantized with the per-(position, head) key scales and
///    softmaxed in float (the paper's datapath quantizes matmuls only);
/// 3. the softmax probabilities are folded with the per-(position,
///    head) value scales into one non-negative operand, quantized to
///    unsigned `spec.op_bits` codes (one scale per head);
/// 4. the **value matmul** p·V runs through the same multi-stage
///    datapath and is dequantized with the probability-operand scale.
///
/// Each (row, head) is computed independently of any batchmates, so
/// quantized-KV batched decode keeps the bit-exactness-vs-sequential
/// property the serving engine rests on.
pub fn attend_one_query_quant(
    q: &[f32],
    kv: &QuantKvSlot<'_>,
    t_len: usize,
    d: usize,
    n_heads: usize,
    spec: &KvQuantSpec,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    debug_assert!(t_len >= 1);
    let hd = d / n_heads;
    debug_assert_eq!(hd * n_heads, d, "d must divide n_heads");
    let rsqrt = 1.0 / (hd as f32).sqrt();
    let inner = AccumSpec::new(spec.inner_bits, spec.mode);
    let score_outer =
        AccumSpec::new(outer_bits(spec.inner_bits, hd, spec.tile).min(64), spec.mode);
    let value_outer =
        AccumSpec::new(outer_bits(spec.inner_bits, t_len, spec.tile).min(64), spec.mode);
    let q_max = ((1i64 << (spec.op_bits - 1)) - 1) as f32; // signed query codes
    let p_max = ((1i64 << spec.op_bits) - 1) as f32; // unsigned probability codes
    let mut overflows = 0u64;

    let mut q_codes = vec![0i64; hd];
    let mut k_head = vec![0i32; t_len * hd];
    let mut score_acc = vec![0i64; t_len];
    let mut scores = vec![0f32; t_len];
    let mut p_codes = vec![0i64; t_len];
    let mut v_head_t = vec![0i32; hd * t_len];
    let mut val_acc = vec![0i64; hd];

    for h in 0..n_heads {
        let off = h * hd;
        // -- query operand: online symmetric quantization, one scale/head
        let qseg = &q[off..off + hd];
        let mut maxabs = 0.0f32;
        for &v in qseg {
            maxabs = maxabs.max(v.abs());
        }
        let q_scale = if maxabs > 0.0 { maxabs / q_max } else { 1.0 };
        for (i, &v) in qseg.iter().enumerate() {
            let c = (v / q_scale).round() as i64;
            q_codes[i] = c.clamp(-(q_max as i64), q_max as i64);
        }
        // gather this head's key codes, (t_len, hd) row-major
        for s in 0..t_len {
            for i in 0..hd {
                k_head[s * hd + i] = kv.k_code(s, off + i);
            }
        }
        // -- score matmul on the multi-stage integer datapath
        let ovf = qgemm_multistage(
            &q_codes,
            1,
            &k_head,
            t_len,
            hd,
            spec.tile,
            inner,
            score_outer,
            &mut score_acc,
        );
        overflows += ovf.iter().sum::<u64>();
        for s in 0..t_len {
            scores[s] = score_acc[s] as f32 * q_scale * kv.k_scale(s, h) * rsqrt;
        }
        softmax(&mut scores);
        // -- probability operand: fold the per-position value scale in,
        // so the value reduction has one common dequant scale per head
        let mut wmax = 0.0f32;
        for s in 0..t_len {
            let w = scores[s] * kv.v_scale(s, h);
            scores[s] = w;
            wmax = wmax.max(w);
        }
        let p_scale = if wmax > 0.0 { wmax / p_max } else { 1.0 };
        for (code, &w) in p_codes.iter_mut().zip(scores.iter()) {
            *code = ((w / p_scale).round() as i64).clamp(0, p_max as i64);
        }
        // gather this head's value codes transposed, (hd, t_len) row-major
        for i in 0..hd {
            for s in 0..t_len {
                v_head_t[i * t_len + s] = kv.v_code(s, off + i);
            }
        }
        // -- value matmul on the multi-stage integer datapath
        let ovf = qgemm_multistage(
            &p_codes,
            1,
            &v_head_t,
            hd,
            t_len,
            spec.tile,
            inner,
            value_outer,
            &mut val_acc,
        );
        overflows += ovf.iter().sum::<u64>();
        for i in 0..hd {
            out[off + i] = val_acc[i] as f32 * p_scale;
        }
    }
    overflows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layernorm_normalizes() {
        let ln = LayerNorm::identity(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut y = vec![0.0; 4];
        ln.forward_row(&x, &mut y);
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        let var: f32 = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn layernorm_gain_bias() {
        let ln = LayerNorm::new(vec![2.0, 2.0], vec![1.0, 1.0]);
        let mut y = vec![0.0; 2];
        ln.forward_row(&[-1.0, 1.0], &mut y);
        // normalized = [-1, 1] -> *2 + 1 = [-1, 3]
        assert!((y[0] + 1.0).abs() < 1e-3);
        assert!((y[1] - 3.0).abs() < 1e-3);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0];
        softmax(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut xs = vec![1000.0, -1000.0];
        softmax(&mut xs);
        assert!((xs[0] - 1.0).abs() < 1e-6);
        assert!(xs[1] < 1e-6);
    }

    #[test]
    fn activations() {
        assert_eq!(Activation::Relu.apply(-1.0), 0.0);
        assert_eq!(Activation::Relu.apply(2.0), 2.0);
        assert!(Activation::Gelu.apply(0.0).abs() < 1e-7);
        assert!((Activation::Gelu.apply(3.0) - 3.0).abs() < 0.02);
        assert!(Activation::Gelu.apply(-3.0).abs() < 0.02);
    }

    #[test]
    fn attention_uniform_values_passthrough() {
        // identical k rows -> uniform attention -> output = mean of v rows
        let seq = 3;
        let d = 4;
        let q = vec![1.0f32; seq * d];
        let k = vec![1.0f32; seq * d];
        let mut v = vec![0.0f32; seq * d];
        for t in 0..seq {
            for i in 0..d {
                v[t * d + i] = t as f32;
            }
        }
        let mut out = vec![0.0f32; seq * d];
        attention(&q, &k, &v, seq, d, 2, false, &mut out);
        // full attention, uniform -> every row = mean(0,1,2) = 1
        for t in 0..seq {
            for i in 0..d {
                assert!((out[t * d + i] - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn causal_attention_first_token_sees_itself() {
        let seq = 3;
        let d = 2;
        let q = vec![1.0f32; seq * d];
        let k = vec![1.0f32; seq * d];
        let mut v = vec![0.0f32; seq * d];
        for t in 0..seq {
            v[t * d] = (t + 1) as f32;
        }
        let mut out = vec![0.0f32; seq * d];
        attention(&q, &k, &v, seq, d, 1, true, &mut out);
        assert!((out[0] - 1.0).abs() < 1e-6, "token 0 attends only to itself");
        assert!((out[1 * d] - 1.5).abs() < 1e-6, "token 1 averages tokens 0,1");
    }

    #[test]
    fn one_query_matches_last_causal_row() {
        // attend_one_query over a full cache must equal the final row of
        // the batched causal helper, bit for bit (same loop order).
        let (seq, d, heads) = (5usize, 8usize, 2usize);
        let mut q = vec![0.0f32; seq * d];
        let mut k = vec![0.0f32; seq * d];
        let mut v = vec![0.0f32; seq * d];
        for (i, x) in q.iter_mut().enumerate() {
            *x = ((i * 37 % 11) as f32 - 5.0) * 0.13;
        }
        for (i, x) in k.iter_mut().enumerate() {
            *x = ((i * 23 % 13) as f32 - 6.0) * 0.11;
        }
        for (i, x) in v.iter_mut().enumerate() {
            *x = ((i * 41 % 7) as f32 - 3.0) * 0.17;
        }
        let mut full = vec![0.0f32; seq * d];
        attention(&q, &k, &v, seq, d, heads, true, &mut full);
        let mut one = vec![0.0f32; d];
        attend_one_query(&q[(seq - 1) * d..], &k, &v, seq, d, heads, &mut one);
        assert_eq!(&full[(seq - 1) * d..], &one[..]);
    }
}
