//! Non-linear building blocks: layer norm, activations, softmax,
//! attention math helpers — including the integer attention datapath
//! over the quantized KV cache ([`attend_one_query_quant`]).
//!
//! The single-query attention primitives take an
//! [`AttnScratch`] workspace instead of allocating their operand
//! buffers per call: the serving engine owns one workspace per engine
//! thread and reuses it across every (row, head) of every decode step,
//! which — together with the bulk K/V gathers
//! ([`super::kvquant::QuantKvSlot::gather_k_head`] /
//! [`super::kvquant::QuantKvSlot::gather_v_head_t`]) and the
//! out-parameter overflow counts of
//! [`crate::linalg::qgemm_multistage`] — makes the steady-state decode
//! step allocation-free. Scratch buffers are grow-only and therefore
//! usually *larger* than the live problem, so every access below slices
//! explicitly to `t_len` / `hd`; stale codes from a longer previous
//! query can never leak into a matmul.

use super::kvquant::{KvQuantSpec, QuantKvSlot};
use super::scratch::AttnScratch;
use crate::accum::simulator::AccumSpec;
use crate::linalg::qgemm_multistage;
use crate::quant::bounds::outer_bits;

/// Layer normalization with learned gain and bias.
#[derive(Clone, Debug)]
pub struct LayerNorm {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub eps: f32,
}

impl LayerNorm {
    pub fn new(gamma: Vec<f32>, beta: Vec<f32>) -> LayerNorm {
        assert_eq!(gamma.len(), beta.len());
        LayerNorm { gamma, beta, eps: 1e-5 }
    }

    pub fn identity(dim: usize) -> LayerNorm {
        LayerNorm::new(vec![1.0; dim], vec![0.0; dim])
    }

    pub fn forward_row(&self, x: &[f32], y: &mut [f32]) {
        let n = x.len() as f32;
        let mean: f32 = x.iter().sum::<f32>() / n;
        let var: f32 = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
        let inv = 1.0 / (var + self.eps).sqrt();
        for ((yo, &xi), (&g, &b)) in
            y.iter_mut().zip(x.iter()).zip(self.gamma.iter().zip(self.beta.iter()))
        {
            *yo = (xi - mean) * inv * g + b;
        }
    }
}

/// Pointwise activation functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Gelu,
}

impl Activation {
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::Relu => x.max(0.0),
            Activation::Gelu => {
                // tanh approximation (GPT-2 style)
                const C: f32 = 0.7978845608; // sqrt(2/pi)
                0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
            }
        }
    }

    pub fn apply_vec(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.apply(*x);
        }
    }

    pub fn parse(s: &str) -> Option<Activation> {
        match s.to_ascii_lowercase().as_str() {
            "relu" => Some(Activation::Relu),
            "gelu" => Some(Activation::Gelu),
            _ => None,
        }
    }
}

/// In-place numerically-stable softmax.
pub fn softmax(xs: &mut [f32]) {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

/// Causal (or full) multi-head self-attention over a (seq, d) activation
/// buffer. q, k, v are (seq, d) with `n_heads` heads of size d/n_heads.
/// Writes the mixed values (pre-projection) into `out`.
///
/// Delegates every query row to [`attend_one_query`] (each (row, head)
/// pair is independent, so the nesting order is free) — prefill
/// attention and batched-decode attention therefore run the *same*
/// arithmetic, the invariant the serving engine's token-exactness
/// rests on. The caller's scratch workspace is reused across all rows
/// (prefill passes its engine workspace through, so even f32-backend
/// admissions stay allocation-free).
#[allow(clippy::too_many_arguments)]
pub fn attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    seq: usize,
    d: usize,
    n_heads: usize,
    causal: bool,
    scratch: &mut AttnScratch,
    out: &mut [f32],
) {
    assert_eq!(q.len(), seq * d);
    assert_eq!(out.len(), seq * d);
    let hd = d / n_heads;
    assert_eq!(hd * n_heads, d, "d must divide n_heads");
    for t in 0..seq {
        let limit = if causal { t + 1 } else { seq };
        attend_one_query(
            &q[t * d..(t + 1) * d],
            k,
            v,
            limit,
            d,
            n_heads,
            scratch,
            &mut out[t * d..(t + 1) * d],
        );
    }
}

/// Position-resolved access to one slot's cached f32 K/V rows — the
/// float backend's single indirection point. The contiguous-slab view
/// ([`ContigKv`]) serves whole-buffer callers; the paged arena plugs in
/// its page-table resolver. The attention loops below only ever ask for
/// one position's row at a time, so the resolver is the *only* place
/// that knows (or cares) where rows physically live — the arithmetic,
/// and therefore the bit pattern, is identical across storage layouts.
pub trait KvRows {
    /// Cached key row of logical position `pos`, `(d,)`.
    fn k_row(&self, pos: usize) -> &[f32];
    /// Cached value row of logical position `pos`, `(d,)`.
    fn v_row(&self, pos: usize) -> &[f32];
}

/// [`KvRows`] over contiguous `(seq, d)` K/V slabs — the layout every
/// pre-paging caller (and the whole-buffer `attention` helper) uses.
pub struct ContigKv<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub d: usize,
}

impl KvRows for ContigKv<'_> {
    #[inline]
    fn k_row(&self, pos: usize) -> &[f32] {
        &self.k[pos * self.d..(pos + 1) * self.d]
    }

    #[inline]
    fn v_row(&self, pos: usize) -> &[f32] {
        &self.v[pos * self.d..(pos + 1) * self.d]
    }
}

/// Single-query multi-head attention of one new position over `t_len`
/// cached positions — the ragged-batch decode primitive: each in-flight
/// sequence calls this over its **own** KV rows and length, so a
/// batched step needs no cross-sequence masking at all.
///
/// `q` is one (d,) query row; `kv` resolves cached keys/values (the new
/// position's K/V already appended). Uses `scratch.scores` for the
/// per-head probability row (sliced to `t_len`). Writes the mixed
/// values (pre-projection) into `out`. The per-position row resolution
/// only changes *where* a row is read from, never the accumulation
/// order, so every [`KvRows`] backing produces bit-identical output.
#[allow(clippy::too_many_arguments)]
pub fn attend_one_query_rows<KV: KvRows + ?Sized>(
    q: &[f32],
    kv: &KV,
    t_len: usize,
    d: usize,
    n_heads: usize,
    scratch: &mut AttnScratch,
    out: &mut [f32],
) {
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    let hd = d / n_heads;
    debug_assert_eq!(hd * n_heads, d, "d must divide n_heads");
    let scale = 1.0 / (hd as f32).sqrt();
    scratch.ensure_scores(t_len);
    let scores = &mut scratch.scores[..t_len];
    for h in 0..n_heads {
        let off = h * hd;
        for (s, score) in scores.iter_mut().enumerate() {
            let krow = &kv.k_row(s)[off..off + hd];
            let mut dot = 0.0f32;
            for i in 0..hd {
                dot += q[off + i] * krow[i];
            }
            *score = dot * scale;
        }
        softmax(scores);
        let orow = &mut out[off..off + hd];
        orow.iter_mut().for_each(|o| *o = 0.0);
        for (s, &w) in scores.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            let vrow = &kv.v_row(s)[off..off + hd];
            for i in 0..hd {
                orow[i] += w * vrow[i];
            }
        }
    }
}

/// [`attend_one_query_rows`] over contiguous `(t_len, d)` K/V slabs —
/// kept as the natural entry point for whole-buffer callers.
#[allow(clippy::too_many_arguments)]
pub fn attend_one_query(
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    t_len: usize,
    d: usize,
    n_heads: usize,
    scratch: &mut AttnScratch,
    out: &mut [f32],
) {
    debug_assert!(kc.len() >= t_len * d && vc.len() >= t_len * d);
    let view = ContigKv { k: kc, v: vc, d };
    attend_one_query_rows(q, &view, t_len, d, n_heads, scratch, out);
}

/// Single-query multi-head attention over a **quantized** KV slot — the
/// integer-datapath counterpart of [`attend_one_query`], extending the
/// paper's overflow-avoidance machinery to the last two matmuls of the
/// decode loop. Returns the number of accumulator overflow events
/// (always 0 when `spec.inner_bits` is at the data-type bound).
///
/// Per head:
/// 1. the query segment is quantized online (symmetric signed
///    `spec.op_bits` codes, one scale per head);
/// 2. the head's key codes are **bulk-gathered** into a contiguous
///    `(t_len, hd)` panel ([`QuantKvSlot::gather_k_head`]: one slab
///    enum match, then contiguous widening copies);
/// 3. the **score matmul** q·kᵀ runs through the multi-stage integer
///    datapath (`spec.tile`-sized P_I tiles, Eq. 22 outer width) via
///    [`crate::linalg::qgemm_multistage`]'s serial single-row fast
///    path, whose ℓ1-mass argument executes overflow-proof tiles at
///    plain-GEMM speed; scores are dequantized with the per-(position,
///    head) key scales and softmaxed in float (the paper's datapath
///    quantizes matmuls only);
/// 4. the softmax probabilities are folded with the per-(position,
///    head) value scales into one non-negative operand, quantized to
///    unsigned `spec.op_bits` codes (one scale per head);
/// 5. the head's value codes are bulk-gathered transposed
///    ([`QuantKvSlot::gather_v_head_t`], blocked copy) and the **value
///    matmul** p·V runs through the same multi-stage datapath, then is
///    dequantized with the probability-operand scale.
///
/// All operand buffers live in `scratch` (grow-only, reused across
/// calls) and are sliced to the live `t_len`/`hd` before every use, so
/// a shorter query after a longer one can never read stale codes. Each
/// (row, head) is computed independently of any batchmates, so
/// quantized-KV batched decode keeps the bit-exactness-vs-sequential
/// property the serving engine rests on.
#[allow(clippy::too_many_arguments)]
pub fn attend_one_query_quant(
    q: &[f32],
    kv: &QuantKvSlot<'_>,
    t_len: usize,
    d: usize,
    n_heads: usize,
    spec: &KvQuantSpec,
    scratch: &mut AttnScratch,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    debug_assert!(t_len >= 1);
    let hd = d / n_heads;
    debug_assert_eq!(hd * n_heads, d, "d must divide n_heads");
    let rsqrt = 1.0 / (hd as f32).sqrt();
    let inner = AccumSpec::new(spec.inner_bits, spec.mode);
    let score_outer =
        AccumSpec::new(outer_bits(spec.inner_bits, hd, spec.tile).min(64), spec.mode);
    let value_outer =
        AccumSpec::new(outer_bits(spec.inner_bits, t_len, spec.tile).min(64), spec.mode);
    let q_max = ((1i64 << (spec.op_bits - 1)) - 1) as f32; // signed query codes
    let p_max = ((1i64 << spec.op_bits) - 1) as f32; // unsigned probability codes
    let mut overflows = 0u64;

    scratch.ensure(hd, t_len);
    // Explicit live-size slices over the grow-only buffers (see module
    // docs): everything downstream operates on exactly t_len / hd
    // elements, never on the buffers' high-water lengths.
    let AttnScratch { q_codes, k_head, score_acc, scores, p_codes, v_head_t, val_acc, row1 } =
        scratch;
    let q_codes = &mut q_codes[..hd];
    let k_head = &mut k_head[..t_len * hd];
    let score_acc = &mut score_acc[..t_len];
    let scores = &mut scores[..t_len];
    let p_codes = &mut p_codes[..t_len];
    let v_head_t = &mut v_head_t[..hd * t_len];
    let val_acc = &mut val_acc[..hd];

    for h in 0..n_heads {
        let off = h * hd;
        // -- query operand: online symmetric quantization, one scale/head
        let qseg = &q[off..off + hd];
        let mut maxabs = 0.0f32;
        for &v in qseg {
            maxabs = maxabs.max(v.abs());
        }
        let q_scale = if maxabs > 0.0 { maxabs / q_max } else { 1.0 };
        for (c, &v) in q_codes.iter_mut().zip(qseg.iter()) {
            let code = (v / q_scale).round() as i64;
            *c = code.clamp(-(q_max as i64), q_max as i64);
        }
        // gather this head's key codes, (t_len, hd) row-major — one
        // slab match + contiguous widening copies
        kv.gather_k_head(t_len, h, k_head);
        // -- score matmul on the multi-stage integer datapath (serial
        // single-row kernel path; overflow count via out-param)
        qgemm_multistage(
            q_codes,
            1,
            k_head,
            t_len,
            hd,
            spec.tile,
            inner,
            score_outer,
            score_acc,
            &mut row1[..],
        );
        overflows += row1[0];
        for (s, (score, &acc)) in scores.iter_mut().zip(score_acc.iter()).enumerate() {
            *score = acc as f32 * q_scale * kv.k_scale(s, h) * rsqrt;
        }
        softmax(scores);
        // -- probability operand: fold the per-position value scale in,
        // so the value reduction has one common dequant scale per head
        let mut wmax = 0.0f32;
        for (s, score) in scores.iter_mut().enumerate() {
            let w = *score * kv.v_scale(s, h);
            *score = w;
            wmax = wmax.max(w);
        }
        let p_scale = if wmax > 0.0 { wmax / p_max } else { 1.0 };
        for (code, &w) in p_codes.iter_mut().zip(scores.iter()) {
            *code = ((w / p_scale).round() as i64).clamp(0, p_max as i64);
        }
        // gather this head's value codes transposed, (hd, t_len)
        // row-major — one slab match + blocked copy
        kv.gather_v_head_t(t_len, h, v_head_t);
        // -- value matmul on the multi-stage integer datapath
        qgemm_multistage(
            p_codes,
            1,
            v_head_t,
            hd,
            t_len,
            spec.tile,
            inner,
            value_outer,
            val_acc,
            &mut row1[..],
        );
        overflows += row1[0];
        for (o, &acc) in out[off..off + hd].iter_mut().zip(val_acc.iter()) {
            *o = acc as f32 * p_scale;
        }
    }
    overflows
}

/// Causal attention of a multi-row **prefill chunk** over its own KV
/// slot within a shared ragged step (f32 backend): row `i` of the chunk
/// attends over the slot's `t0` pre-existing positions plus chunk rows
/// `0..=i` — all of which were appended to the slab before this call.
///
/// `q_rows` is `(len, d)`; `kv` resolves the slot's cached keys/values
/// covering at least `t0 + len` positions (the chunk's own K/V
/// included). Delegates every row to [`attend_one_query_rows`], so a
/// chunked prefill runs bit-for-bit the arithmetic of whole-prompt
/// prefill and of token-by-token decode — the invariant chunked
/// serving's token-exactness rests on — whatever the physical row
/// layout behind `kv`.
#[allow(clippy::too_many_arguments)]
pub fn attend_chunk_rows<KV: KvRows + ?Sized>(
    q_rows: &[f32],
    kv: &KV,
    t0: usize,
    len: usize,
    d: usize,
    n_heads: usize,
    scratch: &mut AttnScratch,
    out: &mut [f32],
) {
    debug_assert_eq!(q_rows.len(), len * d);
    debug_assert_eq!(out.len(), len * d);
    for i in 0..len {
        let t_len = t0 + i + 1;
        attend_one_query_rows(
            &q_rows[i * d..(i + 1) * d],
            kv,
            t_len,
            d,
            n_heads,
            scratch,
            &mut out[i * d..(i + 1) * d],
        );
    }
}

/// [`attend_chunk_rows`] over contiguous `(t0 + len, d)` K/V slabs.
#[allow(clippy::too_many_arguments)]
pub fn attend_chunk(
    q_rows: &[f32],
    kc: &[f32],
    vc: &[f32],
    t0: usize,
    len: usize,
    d: usize,
    n_heads: usize,
    scratch: &mut AttnScratch,
    out: &mut [f32],
) {
    debug_assert!(kc.len() >= (t0 + len) * d && vc.len() >= (t0 + len) * d);
    let view = ContigKv { k: kc, v: vc, d };
    attend_chunk_rows(q_rows, &view, t0, len, d, n_heads, scratch, out);
}

/// [`attend_chunk`] over a **quantized** KV slot: row `i` attends over
/// the `t0 + i + 1` just-appended codes through
/// [`attend_one_query_quant`] — exactly the arithmetic decode and
/// whole-prompt prefill run. Each row's overflow events are added to
/// `row_ovf[i]` (a chunk belongs entirely to one request, but the
/// *rows* must stay individually attributed: fill-time events are
/// recorded onto the page each row lands in, and page boundaries do not
/// respect chunk boundaries). Also returns the chunk total.
#[allow(clippy::too_many_arguments)]
pub fn attend_chunk_quant(
    q_rows: &[f32],
    kv: &QuantKvSlot<'_>,
    t0: usize,
    len: usize,
    d: usize,
    n_heads: usize,
    spec: &KvQuantSpec,
    scratch: &mut AttnScratch,
    out: &mut [f32],
    row_ovf: &mut [u64],
) -> u64 {
    debug_assert_eq!(q_rows.len(), len * d);
    debug_assert_eq!(out.len(), len * d);
    debug_assert_eq!(row_ovf.len(), len, "one overflow counter per chunk row");
    let mut overflows = 0u64;
    for i in 0..len {
        let ovf = attend_one_query_quant(
            &q_rows[i * d..(i + 1) * d],
            kv,
            t0 + i + 1,
            d,
            n_heads,
            spec,
            scratch,
            &mut out[i * d..(i + 1) * d],
        );
        row_ovf[i] += ovf;
        overflows += ovf;
    }
    overflows
}

/// Reference implementation of [`attend_one_query_quant`]: the PR 3
/// inner loop, kept verbatim as (a) the parity oracle the fast path is
/// tested bit-for-bit against, and (b) the "before" baseline the
/// decode-throughput bench measures the gather/scratch rework against.
/// Allocates its operand buffers per call and gathers K/V codes
/// element-by-element through the slab enum — do **not** use it on a
/// serving path.
pub fn attend_one_query_quant_ref(
    q: &[f32],
    kv: &QuantKvSlot<'_>,
    t_len: usize,
    d: usize,
    n_heads: usize,
    spec: &KvQuantSpec,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    debug_assert!(t_len >= 1);
    let hd = d / n_heads;
    debug_assert_eq!(hd * n_heads, d, "d must divide n_heads");
    let rsqrt = 1.0 / (hd as f32).sqrt();
    let inner = AccumSpec::new(spec.inner_bits, spec.mode);
    let score_outer =
        AccumSpec::new(outer_bits(spec.inner_bits, hd, spec.tile).min(64), spec.mode);
    let value_outer =
        AccumSpec::new(outer_bits(spec.inner_bits, t_len, spec.tile).min(64), spec.mode);
    let q_max = ((1i64 << (spec.op_bits - 1)) - 1) as f32;
    let p_max = ((1i64 << spec.op_bits) - 1) as f32;
    let mut overflows = 0u64;

    let mut q_codes = vec![0i64; hd];
    let mut k_head = vec![0i32; t_len * hd];
    let mut score_acc = vec![0i64; t_len];
    let mut scores = vec![0f32; t_len];
    let mut p_codes = vec![0i64; t_len];
    let mut v_head_t = vec![0i32; hd * t_len];
    let mut val_acc = vec![0i64; hd];
    let mut row1 = [0u64; 1];

    for h in 0..n_heads {
        let off = h * hd;
        let qseg = &q[off..off + hd];
        let mut maxabs = 0.0f32;
        for &v in qseg {
            maxabs = maxabs.max(v.abs());
        }
        let q_scale = if maxabs > 0.0 { maxabs / q_max } else { 1.0 };
        for (i, &v) in qseg.iter().enumerate() {
            let c = (v / q_scale).round() as i64;
            q_codes[i] = c.clamp(-(q_max as i64), q_max as i64);
        }
        // element-wise gather through the slab enum (the PR 3 shape)
        for s in 0..t_len {
            for i in 0..hd {
                k_head[s * hd + i] = kv.k_code(s, off + i);
            }
        }
        qgemm_multistage(
            &q_codes,
            1,
            &k_head,
            t_len,
            hd,
            spec.tile,
            inner,
            score_outer,
            &mut score_acc,
            &mut row1,
        );
        overflows += row1[0];
        for s in 0..t_len {
            scores[s] = score_acc[s] as f32 * q_scale * kv.k_scale(s, h) * rsqrt;
        }
        softmax(&mut scores);
        let mut wmax = 0.0f32;
        for s in 0..t_len {
            let w = scores[s] * kv.v_scale(s, h);
            scores[s] = w;
            wmax = wmax.max(w);
        }
        let p_scale = if wmax > 0.0 { wmax / p_max } else { 1.0 };
        for (code, &w) in p_codes.iter_mut().zip(scores.iter()) {
            *code = ((w / p_scale).round() as i64).clamp(0, p_max as i64);
        }
        for i in 0..hd {
            for s in 0..t_len {
                v_head_t[i * t_len + s] = kv.v_code(s, off + i);
            }
        }
        qgemm_multistage(
            &p_codes,
            1,
            &v_head_t,
            hd,
            t_len,
            spec.tile,
            inner,
            value_outer,
            &mut val_acc,
            &mut row1,
        );
        overflows += row1[0];
        for i in 0..hd {
            out[off + i] = val_acc[i] as f32 * p_scale;
        }
    }
    overflows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kvquant::{KvQuantSpec, QuantKv};
    use crate::model::paging::PageMap;
    use crate::util::rng::Rng;

    #[test]
    fn layernorm_normalizes() {
        let ln = LayerNorm::identity(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut y = vec![0.0; 4];
        ln.forward_row(&x, &mut y);
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        let var: f32 = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn layernorm_gain_bias() {
        let ln = LayerNorm::new(vec![2.0, 2.0], vec![1.0, 1.0]);
        let mut y = vec![0.0; 2];
        ln.forward_row(&[-1.0, 1.0], &mut y);
        // normalized = [-1, 1] -> *2 + 1 = [-1, 3]
        assert!((y[0] + 1.0).abs() < 1e-3);
        assert!((y[1] - 3.0).abs() < 1e-3);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0];
        softmax(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut xs = vec![1000.0, -1000.0];
        softmax(&mut xs);
        assert!((xs[0] - 1.0).abs() < 1e-6);
        assert!(xs[1] < 1e-6);
    }

    #[test]
    fn activations() {
        assert_eq!(Activation::Relu.apply(-1.0), 0.0);
        assert_eq!(Activation::Relu.apply(2.0), 2.0);
        assert!(Activation::Gelu.apply(0.0).abs() < 1e-7);
        assert!((Activation::Gelu.apply(3.0) - 3.0).abs() < 0.02);
        assert!(Activation::Gelu.apply(-3.0).abs() < 0.02);
    }

    #[test]
    fn attention_uniform_values_passthrough() {
        // identical k rows -> uniform attention -> output = mean of v rows
        let seq = 3;
        let d = 4;
        let q = vec![1.0f32; seq * d];
        let k = vec![1.0f32; seq * d];
        let mut v = vec![0.0f32; seq * d];
        for t in 0..seq {
            for i in 0..d {
                v[t * d + i] = t as f32;
            }
        }
        let mut out = vec![0.0f32; seq * d];
        attention(&q, &k, &v, seq, d, 2, false, &mut AttnScratch::new(), &mut out);
        // full attention, uniform -> every row = mean(0,1,2) = 1
        for t in 0..seq {
            for i in 0..d {
                assert!((out[t * d + i] - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn causal_attention_first_token_sees_itself() {
        let seq = 3;
        let d = 2;
        let q = vec![1.0f32; seq * d];
        let k = vec![1.0f32; seq * d];
        let mut v = vec![0.0f32; seq * d];
        for t in 0..seq {
            v[t * d] = (t + 1) as f32;
        }
        let mut out = vec![0.0f32; seq * d];
        attention(&q, &k, &v, seq, d, 1, true, &mut AttnScratch::new(), &mut out);
        assert!((out[0] - 1.0).abs() < 1e-6, "token 0 attends only to itself");
        assert!((out[1 * d] - 1.5).abs() < 1e-6, "token 1 averages tokens 0,1");
    }

    #[test]
    fn one_query_matches_last_causal_row() {
        // attend_one_query over a full cache must equal the final row of
        // the batched causal helper, bit for bit (same loop order).
        let (seq, d, heads) = (5usize, 8usize, 2usize);
        let mut q = vec![0.0f32; seq * d];
        let mut k = vec![0.0f32; seq * d];
        let mut v = vec![0.0f32; seq * d];
        for (i, x) in q.iter_mut().enumerate() {
            *x = ((i * 37 % 11) as f32 - 5.0) * 0.13;
        }
        for (i, x) in k.iter_mut().enumerate() {
            *x = ((i * 23 % 13) as f32 - 6.0) * 0.11;
        }
        for (i, x) in v.iter_mut().enumerate() {
            *x = ((i * 41 % 7) as f32 - 3.0) * 0.17;
        }
        let mut full = vec![0.0f32; seq * d];
        let mut scratch = AttnScratch::new();
        attention(&q, &k, &v, seq, d, heads, true, &mut scratch, &mut full);
        let mut one = vec![0.0f32; d];
        attend_one_query(&q[(seq - 1) * d..], &k, &v, seq, d, heads, &mut scratch, &mut one);
        assert_eq!(&full[(seq - 1) * d..], &one[..]);
    }

    /// A chunk attending over a slot (prefix + its own rows) must be
    /// bit-identical to issuing its rows as successive single queries —
    /// on both the float and the quantized path. This is the primitive
    /// the ragged chunked-prefill step rests on.
    #[test]
    fn chunk_attention_matches_per_query() {
        let (d, h, max) = (16usize, 2usize, 12usize);
        let mut rng = Rng::new(710);
        // float path: t0 = 5 cached positions, then a 4-row chunk
        let (t0, len) = (5usize, 4usize);
        let mut k = vec![0.0f32; max * d];
        let mut v = vec![0.0f32; max * d];
        for x in k.iter_mut().chain(v.iter_mut()) {
            *x = rng.normal() as f32;
        }
        let q_rows: Vec<f32> = (0..len * d).map(|_| rng.normal() as f32).collect();
        let mut scratch = AttnScratch::new();
        let mut chunk_out = vec![0.0f32; len * d];
        attend_chunk(&q_rows, &k, &v, t0, len, d, h, &mut scratch, &mut chunk_out);
        for i in 0..len {
            let mut one = vec![0.0f32; d];
            let qrow = &q_rows[i * d..(i + 1) * d];
            attend_one_query(qrow, &k, &v, t0 + i + 1, d, h, &mut scratch, &mut one);
            assert_eq!(&chunk_out[i * d..(i + 1) * d], &one[..], "float row {i}");
        }
        // quantized path, including a narrow overflowing register
        for spec in [KvQuantSpec::int8(), KvQuantSpec::new(8, 8, Some(6))] {
            // one page spanning the whole window: the trivial page table
            let table = [0u32];
            let map = PageMap::new(&table, 0, max);
            let mut kv = QuantKv::new(spec, 1, 1, max, d, h);
            for pos in 0..t0 + len {
                let kr: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let vr: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                kv.append_row(0, &map, pos, &kr, &vr);
            }
            let view = kv.slot_view(0, map);
            let mut got = vec![0.0f32; len * d];
            let mut row_ovf = vec![0u64; len];
            let ovf_chunk = attend_chunk_quant(
                &q_rows, &view, t0, len, d, h, &spec, &mut scratch, &mut got, &mut row_ovf,
            );
            assert_eq!(
                row_ovf.iter().sum::<u64>(),
                ovf_chunk,
                "{spec:?} per-row attribution must sum to the chunk total"
            );
            let mut ovf_rows = 0u64;
            for i in 0..len {
                let mut one = vec![0.0f32; d];
                ovf_rows += attend_one_query_quant(
                    &q_rows[i * d..(i + 1) * d],
                    &view,
                    t0 + i + 1,
                    d,
                    h,
                    &spec,
                    &mut scratch,
                    &mut one,
                );
                assert_eq!(&got[i * d..(i + 1) * d], &one[..], "{spec:?} quant row {i}");
            }
            assert_eq!(ovf_chunk, ovf_rows, "{spec:?} chunk overflow count diverges");
        }
    }

    /// THE scratch-path parity property: the gather/scratch fast path
    /// must be bit-for-bit identical to the PR 3 reference — outputs
    /// AND overflow counts — including when one workspace is reused
    /// across shrinking and growing t_len (the stale-buffer shape) and
    /// under narrow overflowing registers.
    #[test]
    fn scratch_path_matches_reference_across_reuse() {
        let mut rng = Rng::new(620);
        for spec in [
            KvQuantSpec::int8(),
            KvQuantSpec::int16(),
            KvQuantSpec::new(8, 8, Some(6)), // narrow: overflows are live
        ] {
            let (d, h, max) = (24usize, 3usize, 14usize);
            // two pages of 7 with a non-identity table: the fast path
            // must stay exact across real page-boundary runs
            let table = [1u32, 0];
            let map = PageMap::new(&table, 0, 7);
            let mut kv = QuantKv::new(spec, 1, 2, 7, d, h);
            for pos in 0..max {
                let row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let vrow: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                kv.append_row(0, &map, pos, &row, &vrow);
            }
            let mut scratch = AttnScratch::new();
            // long → short → long: reused buffers must never leak state
            for &t_len in &[max, 3usize, 1, 9, max] {
                let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let view = kv.slot_view(0, map);
                let mut want = vec![0.0f32; d];
                let ovf_want = attend_one_query_quant_ref(&q, &view, t_len, d, h, &spec, &mut want);
                let mut got = vec![0.0f32; d];
                let ovf_got = attend_one_query_quant(
                    &q,
                    &view,
                    t_len,
                    d,
                    h,
                    &spec,
                    &mut scratch,
                    &mut got,
                );
                assert_eq!(got, want, "{spec:?} t_len={t_len}: values diverge from reference");
                assert_eq!(
                    ovf_got, ovf_want,
                    "{spec:?} t_len={t_len}: overflow counts diverge from reference"
                );
            }
        }
    }
}
