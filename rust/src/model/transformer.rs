//! Decoder-only transformer inference substrate (the "pico-LM" family —
//! this repo's stand-in for OPT/GPT2/Pythia, see DESIGN.md §2).
//!
//! Three architecture variants mirror the paper's three LM families:
//! - `opt-ish`    — ReLU FFN, sequential residual
//! - `gpt2-ish`   — GELU FFN, sequential residual
//! - `pythia-ish` — GELU FFN, parallel residual
//!
//! The forward pass supports per-linear capture hooks so the coordinator
//! can collect calibration activations (float X and quantized-prefix X̃),
//! and every linear is swappable between float and integer-datapath
//! quantized execution.

use super::layers::{attention, Activation, LayerNorm};
use super::linear::Linear;
use std::collections::BTreeMap;

/// Architecture hyperparameters.
#[derive(Clone, Debug)]
pub struct TransformerConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub act: Activation,
    pub parallel_residual: bool,
}

impl TransformerConfig {
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let emb = self.vocab * d + self.max_seq * d;
        let per_block = 4 * d * d + 2 * d * self.d_ff + 4 * d /*ln*/ + 4 * d + self.d_ff + d;
        let head = d * self.vocab;
        emb + self.n_layers * per_block + head + 2 * d
    }
}

/// One transformer block.
#[derive(Clone, Debug)]
pub struct Block {
    pub ln1: LayerNorm,
    pub ln2: LayerNorm,
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub fc1: Linear,
    pub fc2: Linear,
}

/// Activation capture sink used for calibration: rows of inputs to each
/// named linear layer.
#[derive(Debug, Default)]
pub struct Capture {
    /// Only record layers whose name is in this set (empty = record all).
    pub filter: Option<Vec<String>>,
    /// layer name -> (in_dim, concatenated rows)
    pub store: BTreeMap<String, (usize, Vec<f32>)>,
}

impl Capture {
    pub fn for_layers(names: &[String]) -> Capture {
        Capture { filter: Some(names.to_vec()), store: BTreeMap::new() }
    }

    #[inline]
    fn wants(&self, name: &str) -> bool {
        match &self.filter {
            None => true,
            Some(f) => f.iter().any(|n| n == name),
        }
    }

    #[inline]
    pub fn record(&mut self, name: &str, row: &[f32]) {
        if !self.wants(name) {
            return;
        }
        let entry = self.store.entry(name.to_string()).or_insert_with(|| (row.len(), Vec::new()));
        debug_assert_eq!(entry.0, row.len());
        entry.1.extend_from_slice(row);
    }

    /// Captured rows for a layer as a K×D matrix (neuron-major, the
    /// layout the PTQ algorithms consume).
    pub fn matrix_kd(&self, name: &str) -> Option<crate::linalg::Mat> {
        let (k, rows) = self.store.get(name)?;
        let d = rows.len() / k;
        let mut m = crate::linalg::Mat::zeros(*k, d);
        for (r, chunk) in rows.chunks(*k).enumerate() {
            for (i, &v) in chunk.iter().enumerate() {
                m.set(i, r, v as f64);
            }
        }
        Some(m)
    }

    /// Raw samples (all rows flattened) for percentile calibration.
    pub fn samples(&self, name: &str) -> Option<&[f32]> {
        self.store.get(name).map(|(_, rows)| rows.as_slice())
    }

    pub fn clear(&mut self) {
        self.store.clear();
    }
}

/// Decoder-only transformer.
#[derive(Debug)]
pub struct Transformer {
    pub cfg: TransformerConfig,
    /// vocab × d token embedding.
    pub embed: Vec<f32>,
    /// max_seq × d learned positional embedding.
    pub pos: Vec<f32>,
    pub blocks: Vec<Block>,
    pub ln_f: LayerNorm,
    /// Final projection to vocabulary — held in float (paper App. C.1).
    pub head: super::linear::FloatLinear,
    /// Attention-matmul overflow events observed on the quantized-KV
    /// integer datapath — folded into [`Transformer::overflow_events`]
    /// so eval and serve report one model-wide number (attention events
    /// previously lived on a separate arena-side counter).
    pub(crate) attn_overflows: std::sync::atomic::AtomicU64,
}

impl Clone for Transformer {
    fn clone(&self) -> Transformer {
        use std::sync::atomic::{AtomicU64, Ordering};
        Transformer {
            cfg: self.cfg.clone(),
            embed: self.embed.clone(),
            pos: self.pos.clone(),
            blocks: self.blocks.clone(),
            ln_f: self.ln_f.clone(),
            head: self.head.clone(),
            attn_overflows: AtomicU64::new(self.attn_overflows.load(Ordering::Relaxed)),
        }
    }
}

impl Transformer {
    /// Names of the quantizable linear layers in topological order.
    pub fn linear_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for b in 0..self.cfg.n_layers {
            for l in ["wq", "wk", "wv", "wo", "fc1", "fc2"] {
                names.push(format!("b{b}.{l}"));
            }
        }
        names
    }

    /// Names grouped per block (the granularity at which the coordinator
    /// refreshes quantized-prefix activations).
    pub fn block_groups(&self) -> Vec<Vec<String>> {
        (0..self.cfg.n_layers)
            .map(|b| {
                ["wq", "wk", "wv", "wo", "fc1", "fc2"]
                    .iter()
                    .map(|l| format!("b{b}.{l}"))
                    .collect()
            })
            .collect()
    }

    pub fn get_linear(&self, name: &str) -> Option<&Linear> {
        let (b, l) = parse_name(name)?;
        let blk = self.blocks.get(b)?;
        Some(match l {
            "wq" => &blk.wq,
            "wk" => &blk.wk,
            "wv" => &blk.wv,
            "wo" => &blk.wo,
            "fc1" => &blk.fc1,
            "fc2" => &blk.fc2,
            _ => return None,
        })
    }

    pub fn get_linear_mut(&mut self, name: &str) -> Option<&mut Linear> {
        let (b, l) = parse_name(name)?;
        let blk = self.blocks.get_mut(b)?;
        Some(match l {
            "wq" => &mut blk.wq,
            "wk" => &mut blk.wk,
            "wv" => &mut blk.wv,
            "wo" => &mut blk.wo,
            "fc1" => &mut blk.fc1,
            "fc2" => &mut blk.fc2,
            _ => return None,
        })
    }

    /// Forward a token sequence, returning logits (seq × vocab) and
    /// optionally recording linear inputs into `capture`.
    ///
    /// All linears run batched over the whole sequence
    /// ([`Linear::forward_rows`]), so quantized layers hit the fused
    /// qgemm kernel once per layer instead of once per token row.
    pub fn forward(&self, tokens: &[u16], mut capture: Option<&mut Capture>) -> Vec<f32> {
        let d = self.cfg.d_model;
        let seq = tokens.len();
        assert!(seq <= self.cfg.max_seq, "sequence too long");
        let mut h = vec![0.0f32; seq * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let e = &self.embed[(tok as usize) * d..(tok as usize + 1) * d];
            let p = &self.pos[t * d..(t + 1) * d];
            for i in 0..d {
                h[t * d + i] = e[i] + p[i];
            }
        }
        let mut ln_out = vec![0.0f32; seq * d];
        let mut q = vec![0.0f32; seq * d];
        let mut k = vec![0.0f32; seq * d];
        let mut v = vec![0.0f32; seq * d];
        let mut mix = vec![0.0f32; seq * d];
        let mut attn_out = vec![0.0f32; seq * d];
        let mut ff = vec![0.0f32; seq * self.cfg.d_ff];
        let mut ff_out = vec![0.0f32; seq * d];
        let mut attn_scratch = super::scratch::AttnScratch::new();

        for (bi, blk) in self.blocks.iter().enumerate() {
            // --- attention path
            for t in 0..seq {
                blk.ln1.forward_row(&h[t * d..(t + 1) * d], &mut ln_out[t * d..(t + 1) * d]);
            }
            if let Some(c) = capture.as_deref_mut() {
                for t in 0..seq {
                    let row = &ln_out[t * d..(t + 1) * d];
                    c.record(&format!("b{bi}.wq"), row);
                    c.record(&format!("b{bi}.wk"), row);
                    c.record(&format!("b{bi}.wv"), row);
                }
            }
            blk.wq.forward_rows(&ln_out, seq, &mut q);
            blk.wk.forward_rows(&ln_out, seq, &mut k);
            blk.wv.forward_rows(&ln_out, seq, &mut v);
            attention(&q, &k, &v, seq, d, self.cfg.n_heads, true, &mut attn_scratch, &mut mix);
            if let Some(c) = capture.as_deref_mut() {
                for t in 0..seq {
                    c.record(&format!("b{bi}.wo"), &mix[t * d..(t + 1) * d]);
                }
            }
            blk.wo.forward_rows(&mix, seq, &mut attn_out);
            // --- mlp path (parallel residual reads h pre-attention)
            if !self.cfg.parallel_residual {
                for i in 0..seq * d {
                    h[i] += attn_out[i];
                }
            }
            for t in 0..seq {
                blk.ln2.forward_row(&h[t * d..(t + 1) * d], &mut ln_out[t * d..(t + 1) * d]);
            }
            let dff = self.cfg.d_ff;
            if let Some(c) = capture.as_deref_mut() {
                for t in 0..seq {
                    c.record(&format!("b{bi}.fc1"), &ln_out[t * d..(t + 1) * d]);
                }
            }
            blk.fc1.forward_rows(&ln_out, seq, &mut ff);
            self.cfg.act.apply_vec(&mut ff);
            if let Some(c) = capture.as_deref_mut() {
                for t in 0..seq {
                    c.record(&format!("b{bi}.fc2"), &ff[t * dff..(t + 1) * dff]);
                }
            }
            blk.fc2.forward_rows(&ff, seq, &mut ff_out);
            if self.cfg.parallel_residual {
                for i in 0..seq * d {
                    h[i] += attn_out[i] + ff_out[i];
                }
            } else {
                for i in 0..seq * d {
                    h[i] += ff_out[i];
                }
            }
        }
        // final norm + head — one banded GEMM over every position, the
        // same head datapath `decode_step_batch`/`prefill` run, so full
        // recompute and incremental decode stay numerically identical
        let vocab = self.cfg.vocab;
        for t in 0..seq {
            blk_ln(&self.ln_f, &h[t * d..(t + 1) * d], &mut ln_out[t * d..(t + 1) * d]);
        }
        let mut logits = vec![0.0f32; seq * vocab];
        self.head.forward_rows(&ln_out, seq, &mut logits);
        logits
    }

    /// Total overflow events observed on the integer datapath — the
    /// **unified** model-wide view: quantized-linear events plus the
    /// attention-matmul events from quantized-KV decoding. Eval
    /// (perplexity deltas) and the serve report both read this one
    /// number.
    pub fn overflow_events(&self) -> u64 {
        let mut total = self.attention_overflow_events();
        for name in self.linear_names() {
            if let Some(Linear::Quant(q)) = self.get_linear(&name) {
                total += q.overflow_count();
            }
        }
        total
    }

    /// The attention-matmul share of [`Transformer::overflow_events`]
    /// (0 on the f32 KV backend or at the data-type-safe inner width).
    pub fn attention_overflow_events(&self) -> u64 {
        self.attn_overflows.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Record attention overflow events (decode/prefill internals).
    pub(crate) fn add_attention_overflows(&self, n: u64) {
        self.attn_overflows.fetch_add(n, std::sync::atomic::Ordering::Relaxed);
    }
}

#[inline]
fn blk_ln(ln: &LayerNorm, x: &[f32], y: &mut [f32]) {
    ln.forward_row(x, y);
}

fn parse_name(name: &str) -> Option<(usize, &str)> {
    let rest = name.strip_prefix('b')?;
    let dotpos = rest.find('.')?;
    let b: usize = rest[..dotpos].parse().ok()?;
    Some((b, &rest[dotpos + 1..]))
}

/// Build a randomly-initialized transformer (tests and synthetic runs).
pub fn random_transformer(cfg: TransformerConfig, seed: u64) -> Transformer {
    use super::linear::FloatLinear;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let d = cfg.d_model;
    let std = 0.08f64;
    let mk = |inp: usize, out: usize, rng: &mut Rng| {
        let w: Vec<f32> = (0..inp * out).map(|_| (rng.normal() * std) as f32).collect();
        let b: Vec<f32> = vec![0.0; out];
        Linear::Float(FloatLinear::new(inp, out, w, b))
    };
    let blocks = (0..cfg.n_layers)
        .map(|_| Block {
            ln1: LayerNorm::identity(d),
            ln2: LayerNorm::identity(d),
            wq: mk(d, d, &mut rng),
            wk: mk(d, d, &mut rng),
            wv: mk(d, d, &mut rng),
            wo: mk(d, d, &mut rng),
            fc1: mk(d, cfg.d_ff, &mut rng),
            fc2: mk(cfg.d_ff, d, &mut rng),
        })
        .collect();
    let embed: Vec<f32> = (0..cfg.vocab * d).map(|_| (rng.normal() * std) as f32).collect();
    let pos: Vec<f32> = (0..cfg.max_seq * d).map(|_| (rng.normal() * std) as f32).collect();
    let head_w: Vec<f32> = (0..cfg.vocab * d).map(|_| (rng.normal() * std) as f32).collect();
    let head = FloatLinear::new(d, cfg.vocab, head_w, vec![0.0; cfg.vocab]);
    Transformer {
        cfg,
        embed,
        pos,
        blocks,
        ln_f: LayerNorm::identity(d),
        head,
        attn_overflows: std::sync::atomic::AtomicU64::new(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TransformerConfig {
        TransformerConfig {
            name: "tiny".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 12,
            act: Activation::Gelu,
            parallel_residual: false,
        }
    }

    #[test]
    fn forward_shapes() {
        let m = random_transformer(tiny_cfg(), 1);
        let toks: Vec<u16> = vec![1, 5, 9, 3];
        let logits = m.forward(&toks, None);
        assert_eq!(logits.len(), 4 * 32);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_holds() {
        // changing a later token must not change earlier logits
        let m = random_transformer(tiny_cfg(), 2);
        let a: Vec<u16> = vec![1, 2, 3, 4];
        let b: Vec<u16> = vec![1, 2, 3, 31];
        let la = m.forward(&a, None);
        let lb = m.forward(&b, None);
        for i in 0..3 * 32 {
            assert!((la[i] - lb[i]).abs() < 1e-5, "position {} leaked", i / 32);
        }
        // last position must differ
        let diff: f32 =
            (3 * 32..4 * 32).map(|i| (la[i] - lb[i]).abs()).sum();
        assert!(diff > 1e-6);
    }

    #[test]
    fn parallel_residual_variant_runs() {
        let mut cfg = tiny_cfg();
        cfg.parallel_residual = true;
        let m = random_transformer(cfg, 3);
        let logits = m.forward(&[0, 1, 2], None);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn capture_collects_expected_shapes() {
        let m = random_transformer(tiny_cfg(), 4);
        let names = m.linear_names();
        assert_eq!(names.len(), 12);
        let mut cap = Capture::for_layers(&names);
        m.forward(&[1, 2, 3, 4, 5], Some(&mut cap));
        // wq input: 5 rows of 16
        let x = cap.matrix_kd("b0.wq").unwrap();
        assert_eq!(x.rows(), 16);
        assert_eq!(x.cols(), 5);
        // fc2 input: 5 rows of d_ff
        let x2 = cap.matrix_kd("b1.fc2").unwrap();
        assert_eq!(x2.rows(), 32);
        assert_eq!(x2.cols(), 5);
    }

    #[test]
    fn capture_filter_restricts() {
        let m = random_transformer(tiny_cfg(), 5);
        let mut cap = Capture::for_layers(&["b0.fc1".to_string()]);
        m.forward(&[1, 2], Some(&mut cap));
        assert!(cap.matrix_kd("b0.fc1").is_some());
        assert!(cap.matrix_kd("b0.wq").is_none());
    }

    #[test]
    fn linear_accessors_roundtrip() {
        let mut m = random_transformer(tiny_cfg(), 6);
        for name in m.linear_names() {
            assert!(m.get_linear(&name).is_some(), "{name}");
            assert!(m.get_linear_mut(&name).is_some(), "{name}");
        }
        assert!(m.get_linear("b9.wq").is_none());
        assert!(m.get_linear("nope").is_none());
    }

    #[test]
    fn param_count_sane() {
        let cfg = tiny_cfg();
        let n = cfg.param_count();
        // vocab=32,d=16: emb 512+192, 2 blocks ~ (4·256 + 2·512 + ...), head 512
        assert!(n > 3_000 && n < 100_000, "n={n}");
    }
}
