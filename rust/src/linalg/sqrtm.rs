//! Symmetric PSD matrix square root via scaled Newton–Schulz iteration.
//!
//! The memory-efficient GPFQ reformulation (paper, Theorem B.1) needs
//! H = (X̃X̃ᵀ)^{1/2}. Newton–Schulz is GEMM-bound (no eigendecomposition)
//! and converges quadratically once the spectrum is scaled into (0, √3):
//!
//!   Y₀ = A/c,  Z₀ = I,   with c = ‖A‖_F (so ‖Y₀‖ ≤ 1)
//!   Yₖ₊₁ = ½ Yₖ (3I − Zₖ Yₖ)
//!   Zₖ₊₁ = ½ (3I − Zₖ Yₖ) Zₖ
//!   then √A = √c · Y_∞ ,  A^{-1/2} = Z_∞ / √c.
//!
//! A small diagonal damping keeps rank-deficient Gram matrices inside the
//! convergence region (the caller controls it, mirroring OPTQ's η).

use super::matrix::Mat;
use std::fmt;

#[derive(Debug)]
pub enum SqrtmError {
    NotSquare(usize, usize),
    NoConvergence(usize, f64),
}

impl fmt::Display for SqrtmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SqrtmError::NotSquare(rows, cols) => {
                write!(f, "matrix must be square, got {rows}x{cols}")
            }
            SqrtmError::NoConvergence(iters, residual) => {
                write!(f, "newton-schulz did not converge after {iters} iterations (residual {residual})")
            }
        }
    }
}

impl std::error::Error for SqrtmError {}

/// Result of [`sqrtm_psd`]: the square root and, for free, its inverse.
pub struct SqrtmResult {
    pub sqrt: Mat,
    pub inv_sqrt: Mat,
    pub iterations: usize,
}

/// Square root of a symmetric PSD matrix (caller should pre-damp if the
/// matrix may be singular). `tol` is the relative Frobenius residual on
/// ‖ZY − I‖ used as the convergence check.
pub fn sqrtm_psd(a: &Mat, tol: f64, max_iter: usize) -> Result<SqrtmResult, SqrtmError> {
    if a.rows() != a.cols() {
        return Err(SqrtmError::NotSquare(a.rows(), a.cols()));
    }
    let n = a.rows();
    if n == 0 {
        return Ok(SqrtmResult { sqrt: Mat::zeros(0, 0), inv_sqrt: Mat::zeros(0, 0), iterations: 0 });
    }
    // Spectral scaling (§Perf): scale by a λ_max estimate instead of the
    // Frobenius norm. ‖A‖_F ≈ λ_max·√(eff. rank), so Frobenius scaling
    // shrinks the spectrum by an extra √rank and Newton–Schulz burns
    // ~log2(√rank) iterations recovering it — ~30-40% of total runtime
    // at K≈512. A few power iterations give λ_max within a few percent;
    // the 1.01 safety factor keeps the spectrum inside (0, 1].
    let c = if std::env::var("AXE_SQRTM_FROB").is_ok() {
        a.frob_norm().max(f64::MIN_POSITIVE)
    } else {
        (spectral_norm_est(a, 12) * 1.01).max(f64::MIN_POSITIVE)
    };
    let mut y = a.clone();
    y.scale(1.0 / c);
    let mut z = Mat::eye(n);
    let sqrt_n = (n as f64).sqrt();
    let mut iters = 0;
    let mut residual = f64::INFINITY;
    for k in 0..max_iter {
        iters = k + 1;
        let zy = z.matmul(&y);
        // residual ‖ZY − I‖_F / √n
        let mut r = 0.0;
        for i in 0..n {
            for j in 0..n {
                let target = if i == j { 1.0 } else { 0.0 };
                let d = zy.get(i, j) - target;
                r += d * d;
            }
        }
        residual = r.sqrt() / sqrt_n;
        if residual < tol {
            break;
        }
        // T = ½(3I − ZY)
        let mut t = zy;
        t.scale(-0.5);
        t.add_diag(1.5);
        y = y.matmul(&t);
        z = t.matmul(&z);
    }
    if residual >= tol && residual.is_finite() && residual > tol * 10.0 {
        return Err(SqrtmError::NoConvergence(iters, residual));
    }
    let s = c.sqrt();
    y.scale(s);
    z.scale(1.0 / s);
    y.symmetrize();
    z.symmetrize();
    Ok(SqrtmResult { sqrt: y, inv_sqrt: z, iterations: iters })
}

/// Power-iteration estimate of λ_max for a symmetric PSD matrix.
fn spectral_norm_est(a: &Mat, iters: usize) -> f64 {
    let n = a.rows();
    // deterministic pseudo-random start vector (avoids orthogonal bad luck)
    let mut v: Vec<f64> = (0..n)
        .map(|i| {
            let x = (i as f64 * 0.754877666 + 0.5).fract() - 0.5;
            x + 0.25
        })
        .collect();
    let mut lambda = a.frob_norm(); // safe fallback upper bound
    for _ in 0..iters {
        let w = a.matvec(&v);
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm < 1e-300 {
            return lambda.max(f64::MIN_POSITIVE);
        }
        lambda = norm / v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
        v = w.iter().map(|x| x / norm).collect();
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::frob_diff;
    use crate::util::rng::Rng;

    fn random_gram(n: usize, d: usize, rng: &mut Rng, damp: f64) -> Mat {
        let x = Mat::random_normal(n, d, rng, 1.0);
        let mut g = x.gram();
        let mean_diag = g.diag().iter().sum::<f64>() / n as f64;
        g.add_diag(damp * mean_diag.max(1e-12));
        g
    }

    #[test]
    fn sqrt_squares_back() {
        let mut rng = Rng::new(20);
        for &(n, d) in &[(4usize, 16usize), (16, 64), (48, 32)] {
            let a = random_gram(n, d, &mut rng, 0.01);
            let r = sqrtm_psd(&a, 1e-12, 60).unwrap();
            let sq = r.sqrt.matmul(&r.sqrt);
            let rel = frob_diff(&sq, &a) / a.frob_norm();
            assert!(rel < 1e-7, "n={n} d={d} rel={rel}");
        }
    }

    #[test]
    fn inv_sqrt_is_inverse_of_sqrt() {
        let mut rng = Rng::new(21);
        let a = random_gram(24, 48, &mut rng, 0.01);
        let r = sqrtm_psd(&a, 1e-12, 60).unwrap();
        let prod = r.sqrt.matmul(&r.inv_sqrt);
        assert!(frob_diff(&prod, &Mat::eye(24)) < 1e-6);
    }

    #[test]
    fn sqrt_of_identity() {
        let i = Mat::eye(8);
        let r = sqrtm_psd(&i, 1e-13, 60).unwrap();
        assert!(frob_diff(&r.sqrt, &Mat::eye(8)) < 1e-10);
    }

    #[test]
    fn sqrt_of_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a.set(0, 0, 4.0);
        a.set(1, 1, 9.0);
        a.set(2, 2, 16.0);
        let r = sqrtm_psd(&a, 1e-13, 80).unwrap();
        assert!((r.sqrt.get(0, 0) - 2.0).abs() < 1e-8);
        assert!((r.sqrt.get(1, 1) - 3.0).abs() < 1e-8);
        assert!((r.sqrt.get(2, 2) - 4.0).abs() < 1e-8);
    }

    #[test]
    fn rejects_non_square() {
        let a = Mat::zeros(3, 4);
        assert!(matches!(sqrtm_psd(&a, 1e-10, 10), Err(SqrtmError::NotSquare(3, 4))));
    }

    #[test]
    fn rank_deficient_with_damping_converges() {
        let mut rng = Rng::new(22);
        // n > d  =>  rank-deficient Gram; damping rescues it.
        let a = random_gram(40, 10, &mut rng, 0.05);
        let r = sqrtm_psd(&a, 1e-11, 80).unwrap();
        let sq = r.sqrt.matmul(&r.sqrt);
        assert!(frob_diff(&sq, &a) / a.frob_norm() < 1e-6);
    }
}
