//! Dense linear algebra substrate.
//!
//! The PTQ algorithms (GPFQ/OPTQ and their memory-efficient variants)
//! need GEMM, Cholesky factorization/inversion and a symmetric-PSD
//! matrix square root. No BLAS/LAPACK is available offline, so this
//! module carries a cache-blocked, multi-threaded f64 implementation
//! sized for the K ≤ ~2048 matrices that show up per layer.

mod cholesky;
mod matrix;
pub mod qgemm;
mod sqrtm;

pub use cholesky::{cholesky_lower, solve_lower, solve_lower_transpose, spd_inverse, CholeskyError};
pub use matrix::{dot, gemm_bt_into, num_threads, Mat};
pub use qgemm::{
    dot_multistage_fused, dot_multistage_fused_scalar, qgemm_exact, qgemm_multistage,
    qgemm_multistage_scalar, simd_enabled,
};
pub use sqrtm::{sqrtm_psd, SqrtmError};

/// Frobenius norm of the difference of two matrices (test helper).
pub fn frob_diff(a: &Mat, b: &Mat) -> f64 {
    assert_eq!(a.rows(), b.rows());
    assert_eq!(a.cols(), b.cols());
    a.data()
        .iter()
        .zip(b.data().iter())
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}
