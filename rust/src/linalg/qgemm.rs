//! Fused multi-stage integer GEMM — the serving datapath.
//!
//! The bit-accurate per-MAC simulator in [`crate::accum::simulator`] is
//! the *oracle*: it narrows a register after every addition, which makes
//! it ~two orders of magnitude slower than a plain integer matmul. The
//! paper's whole point (Eq. 22 + the A2Q line of work) is that once the
//! weights carry a *static* overflow-avoidance guarantee, the tiled
//! P_I-bit inner / P_O-bit outer datapath can be executed as an ordinary
//! blocked integer GEMM — no per-step narrowing can ever trigger.
//!
//! This kernel exploits exactly that, while staying **bit-for-bit equal
//! to [`dot_multistage`]** for *any* input (including unsafe codes):
//!
//! - Per (row, channel, tile): accumulate the tile dot product in plain
//!   i64 while tracking Σ|x_i·w_i|. Any prefix of the tile sum is
//!   bounded by that ℓ1 mass, so if it fits the inner register's
//!   positive capacity, **no per-MAC narrowing could have fired** — in
//!   any overflow mode — and the plain sum is exactly what the
//!   simulator would produce, with zero overflow events.
//! - Otherwise (rare: the guarantee is absent or violated) the tile
//!   falls back to the scalar per-MAC simulator, reproducing wraparound
//!   or saturation trajectories and overflow counts exactly.
//! - Tile partials feed the outer register through the same
//!   [`AccumSpec::narrow`] step the simulator uses.
//!
//! Two execution strategies, chosen per call:
//!
//! - **Serial fast path** — sub-threshold work runs inline, which
//!   includes every decode-attention call (one query row against t_len
//!   cached positions): no band setup, no scoped threads, and the
//!   per-row overflow counters are plain `u64` adds. This path performs
//!   **zero heap allocations**, which is what the steady-state decode
//!   loop rides on (see [`crate::model::DecodeScratch`]). The only
//!   exception is the rare ℓ1-violation fallback above, which buffers
//!   one tile of widened codes.
//! - **Threaded band path** — larger batched calls fan channels out
//!   across threads with the band-parallel `std::thread::scope` idiom
//!   proven in [`super::matrix`]; each band writes a disjoint set of
//!   output columns, and the shared per-row overflow counters are
//!   touched through atomics (only when a row actually overflowed
//!   inside a band, i.e. never on guaranteed-safe codes).
//!
//! The safe-tile inner step additionally carries an **explicit-SIMD
//! variant** (AVX2 `_mm256_madd_epi16` widening accumulate), runtime-
//! dispatched per process ([`simd_enabled`]: host AVX2 + `AXE_SIMD`
//! env override) and engaged per tile only inside the 8-bit operand
//! envelope where it is provably bit-identical to the scalar step —
//! [`qgemm_multistage_scalar`] / [`dot_multistage_fused_scalar`] force
//! the scalar step and serve as the in-process parity oracles.
//!
//! Precondition (documented, debug-asserted): products and per-tile
//! ℓ1 masses must fit in i64 — true for any real quantized-code
//! alphabet (|w| < 2^31, |x| < 2^31, tile · |x·w| < 2^63).

use crate::accum::simulator::{dot_monolithic, AccumSpec, OverflowMode};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Minimum `rows * c * k` MAC count before a kernel call fans out to
/// scoped threads; below it the inline serial path wins on latency.
const PAR_MIN_WORK: usize = 64 * 64 * 64;

/// Runtime SIMD dispatch for the safe-tile inner step: enabled when the
/// host has AVX2 and `AXE_SIMD` is not `off`/`0`/`false`. Cached once —
/// the decision is per-process, and the scalar kernel remains reachable
/// in the same process through [`qgemm_multistage_scalar`] /
/// [`dot_multistage_fused_scalar`] (the parity oracles).
pub fn simd_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        if let Ok(v) = std::env::var("AXE_SIMD") {
            if v == "off" || v == "0" || v == "false" {
                return false;
            }
        }
        #[cfg(target_arch = "x86_64")]
        {
            std::arch::is_x86_feature_detected!("avx2")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// AVX2 widening-accumulate inner step for safe tiles whose codes fit
/// the 8-bit operand envelope. Bit-exactness argument: within the
/// [`tile_in_range`] bounds the scalar accumulator can neither wrap
/// (|Σ x·w| ≤ 2^19 · 255·127 ≪ 2^63) nor saturate its ℓ1 mass, and the
/// vector kernel computes the same mathematical sums exactly — so both
/// paths return identical `(acc, l1)` and therefore identical overflow
/// decisions downstream.
#[cfg(target_arch = "x86_64")]
mod simd {
    /// SIMD only pays off past this tile length; shorter tiles stay on
    /// the scalar loop.
    pub const MIN_SIMD_TILE: usize = 32;
    /// i32-lane safety bound: each 16-wide step adds ≤ 2·255·127 =
    /// 64 770 per lane, so 2^19/16 = 32 768 steps stay under i32::MAX.
    pub const MAX_SIMD_TILE: usize = 1 << 19;

    /// The operand envelope the vector kernel is exact for: unsigned
    /// 8-bit activation codes (and the attention path's signed q/p
    /// codes) on one side, signed 8-bit weight/KV codes on the other.
    /// i16-KV or wider codes fail this check and fall back to scalar.
    #[inline]
    pub fn tile_in_range(x: &[i64], w: &[i32]) -> bool {
        x.iter().all(|&v| v.unsigned_abs() <= 255)
            && w.iter().all(|&v| v.unsigned_abs() <= 127)
    }

    /// `(Σ x·w, Σ|x·w|)` over one tile via `_mm256_madd_epi16`.
    ///
    /// i16 staging is exact for in-range codes, and each madd pair is
    /// ≤ 2·255·127 = 64 770 — far under the i16-saturation hazard that
    /// rules out `_mm256_maddubs_epi16` (2·255·127 > i16::MAX), and
    /// under the i32 lane bound for `MAX_SIMD_TILE` steps. The ±255/127
    /// range also keeps `_mm256_abs_epi16` away from its i16::MIN edge
    /// case.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support (see [`super::simd_enabled`])
    /// and `tile_in_range(x, w)` with `x.len() <= MAX_SIMD_TILE`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile_acc_l1_avx2(x: &[i64], w: &[i32]) -> (i64, u64) {
        use std::arch::x86_64::*;
        debug_assert_eq!(x.len(), w.len());
        debug_assert!(x.len() <= MAX_SIMD_TILE);
        let n = x.len();
        let mut acc_v = _mm256_setzero_si256();
        let mut l1_v = _mm256_setzero_si256();
        let mut xs = [0i16; 16];
        let mut ws = [0i16; 16];
        let mut i = 0usize;
        while i + 16 <= n {
            for (s, &v) in xs.iter_mut().zip(&x[i..i + 16]) {
                *s = v as i16;
            }
            for (s, &v) in ws.iter_mut().zip(&w[i..i + 16]) {
                *s = v as i16;
            }
            let xv = _mm256_loadu_si256(xs.as_ptr() as *const __m256i);
            let wv = _mm256_loadu_si256(ws.as_ptr() as *const __m256i);
            acc_v = _mm256_add_epi32(acc_v, _mm256_madd_epi16(xv, wv));
            l1_v = _mm256_add_epi32(
                l1_v,
                _mm256_madd_epi16(_mm256_abs_epi16(xv), _mm256_abs_epi16(wv)),
            );
            i += 16;
        }
        let mut lanes = [0i32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc_v);
        let mut acc: i64 = lanes.iter().map(|&v| v as i64).sum();
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, l1_v);
        // all ℓ1 lanes are sums of non-negative madd pairs
        let mut l1: u64 = lanes.iter().map(|&v| v as u64).sum();
        while i < n {
            let p = x[i] * (w[i] as i64);
            acc += p;
            l1 += p.unsigned_abs();
            i += 1;
        }
        (acc, l1)
    }
}

/// Exact integer GEMM: `out[r][ch] = Σ_i x[r][i] · w[ch][i]`.
///
/// * `x` — `rows`×`k` activation codes, row-major.
/// * `w` — `c`×`k` weight codes, row-major (`[out, in]`, the
///   [`crate::model::QuantLinear`] layout).
/// * `out` — `rows`×`c`, row-major.
///
/// This is the `Datapath::Exact` kernel: valid whenever overflow is
/// impossible (wide registers or an audited guarantee).
pub fn qgemm_exact(x: &[i64], rows: usize, w: &[i32], c: usize, k: usize, out: &mut [i64]) {
    assert_eq!(x.len(), rows * k, "x must be rows*k");
    assert_eq!(w.len(), c * k, "w must be c*k");
    assert_eq!(out.len(), rows * c, "out must be rows*c");
    run_channel_bands(c, rows * c * k, out, |lo, hi, band| {
        for r in 0..rows {
            let xrow = &x[r * k..(r + 1) * k];
            let orow = band.row(r);
            for ch in lo..hi {
                orow[ch - lo] = dot_codes(xrow, &w[ch * k..(ch + 1) * k]);
            }
        }
    });
}

/// Fused multi-stage integer GEMM, bit-for-bit equal to evaluating
/// [`crate::accum::simulator::dot_multistage`] at every `(row, channel)`
/// pair.
///
/// **Per-row overflow counts are written into the `row_ovf`
/// out-parameter** (`len == rows`, overwrite semantics: every entry is
/// set to the count for that row, all zeros whenever the codes honour
/// their accumulator guarantee). The serving engine uses them to
/// attribute overflow events to the individual sequences stacked into
/// one batched call; sum the slice for the call total. The out-param
/// (instead of a returned `Vec`) keeps the single-row decode-attention
/// calls allocation-free: the serial path does plain `u64` adds, and
/// only the threaded band path promotes the counters to atomics
/// (in place — `AtomicU64` is layout-identical to `u64`).
///
/// Layouts match [`qgemm_exact`]; `tile`, `inner` and `outer` match the
/// simulator's multi-stage datapath (Fig. 2b / Eq. 22).
#[allow(clippy::too_many_arguments)]
pub fn qgemm_multistage(
    x: &[i64],
    rows: usize,
    w: &[i32],
    c: usize,
    k: usize,
    tile: usize,
    inner: AccumSpec,
    outer: AccumSpec,
    out: &mut [i64],
    row_ovf: &mut [u64],
) {
    qgemm_multistage_impl(x, rows, w, c, k, tile, inner, outer, out, row_ovf, simd_enabled());
}

/// [`qgemm_multistage`] with the explicit-SIMD safe-tile step forced
/// OFF — the in-process parity oracle for the vector path (values and
/// overflow counts must be bit-identical; see `tests/qgemm_parity.rs`).
#[allow(clippy::too_many_arguments)]
pub fn qgemm_multistage_scalar(
    x: &[i64],
    rows: usize,
    w: &[i32],
    c: usize,
    k: usize,
    tile: usize,
    inner: AccumSpec,
    outer: AccumSpec,
    out: &mut [i64],
    row_ovf: &mut [u64],
) {
    qgemm_multistage_impl(x, rows, w, c, k, tile, inner, outer, out, row_ovf, false);
}

#[allow(clippy::too_many_arguments)]
fn qgemm_multistage_impl(
    x: &[i64],
    rows: usize,
    w: &[i32],
    c: usize,
    k: usize,
    tile: usize,
    inner: AccumSpec,
    outer: AccumSpec,
    out: &mut [i64],
    row_ovf: &mut [u64],
    use_simd: bool,
) {
    assert_eq!(x.len(), rows * k, "x must be rows*k");
    assert_eq!(w.len(), c * k, "w must be c*k");
    assert_eq!(out.len(), rows * c, "out must be rows*c");
    assert_eq!(row_ovf.len(), rows, "one overflow counter per row");
    assert!(tile >= 1, "tile must be >= 1");

    let nthreads = crate::linalg::num_threads().min(c.max(1));
    if nthreads <= 1 || rows * c * k < PAR_MIN_WORK {
        // Serial fast path: no band setup, no atomics, no allocations.
        // The decode-attention shape (one query row against t_len
        // cached positions, c·k ≪ PAR_MIN_WORK) always lands here,
        // keeping its latency flat; large single-row linear forwards
        // still fan out across channel bands below.
        for r in 0..rows {
            let xrow = &x[r * k..(r + 1) * k];
            let orow = &mut out[r * c..(r + 1) * c];
            let mut row_total = 0u64;
            for (ch, o) in orow.iter_mut().enumerate() {
                let (value, overflows) = dot_multistage_fused_impl(
                    xrow,
                    &w[ch * k..(ch + 1) * k],
                    tile,
                    inner,
                    outer,
                    use_simd,
                );
                *o = value;
                row_total += overflows as u64;
            }
            row_ovf[r] = row_total;
        }
        return;
    }

    // Threaded band path: channel bands run concurrently and each
    // touches every row, so the caller's counters are promoted to
    // atomics in place; bands only pay the fetch_add when a row
    // actually overflowed inside the band (never on guaranteed-safe
    // codes).
    row_ovf.fill(0);
    // SAFETY: `AtomicU64` has the same size and alignment as `u64`
    // (guaranteed by std: "same in-memory representation as the
    // underlying integer type"), and we hold the only reference to
    // `row_ovf` for the duration of the scope below.
    let counters: &[AtomicU64] =
        unsafe { &*(row_ovf as *mut [u64] as *const [AtomicU64]) };
    run_channel_bands(c, rows * c * k, out, |lo, hi, band| {
        for r in 0..rows {
            let xrow = &x[r * k..(r + 1) * k];
            let orow = band.row(r);
            let mut row_total = 0u64;
            for ch in lo..hi {
                let (value, overflows) = dot_multistage_fused_impl(
                    xrow,
                    &w[ch * k..(ch + 1) * k],
                    tile,
                    inner,
                    outer,
                    use_simd,
                );
                orow[ch - lo] = value;
                row_total += overflows as u64;
            }
            if row_total > 0 {
                counters[r].fetch_add(row_total, Ordering::Relaxed);
            }
        }
    });
}

/// One fused multi-stage dot product (see module docs for the fast-path
/// argument). Public so audits and tests can target single vectors.
pub fn dot_multistage_fused(
    x: &[i64],
    w: &[i32],
    tile: usize,
    inner: AccumSpec,
    outer: AccumSpec,
) -> (i64, usize) {
    dot_multistage_fused_impl(x, w, tile, inner, outer, simd_enabled())
}

/// [`dot_multistage_fused`] with the SIMD tile step forced OFF — the
/// single-vector parity oracle for the vector path.
pub fn dot_multistage_fused_scalar(
    x: &[i64],
    w: &[i32],
    tile: usize,
    inner: AccumSpec,
    outer: AccumSpec,
) -> (i64, usize) {
    dot_multistage_fused_impl(x, w, tile, inner, outer, false)
}

/// `(Σ x·w wrapping, Σ|x·w| saturating)` over one tile — the scalar
/// reference step. Wrapping/saturating only matter on codes that
/// violate the i64 precondition envelope; whenever the ℓ1 mass fits
/// the inner register (the fast-path condition) neither fires.
#[inline]
fn tile_acc_l1_scalar(xc: &[i64], wc: &[i32]) -> (i64, u64) {
    let mut acc: i64 = 0;
    let mut l1: u64 = 0;
    for (xv, wv) in xc.iter().zip(wc.iter()) {
        let p = xv * (*wv as i64);
        acc = acc.wrapping_add(p);
        l1 = l1.saturating_add(p.unsigned_abs());
    }
    (acc, l1)
}

/// Per-tile accumulate step with runtime SIMD dispatch: tiles long
/// enough to amortize staging AND inside the 8-bit operand envelope go
/// through the AVX2 kernel (bit-identical by construction — see the
/// `simd` module); everything else takes the scalar reference step.
#[inline]
fn tile_acc_l1(xc: &[i64], wc: &[i32], use_simd: bool) -> (i64, u64) {
    #[cfg(target_arch = "x86_64")]
    if use_simd
        && xc.len() >= simd::MIN_SIMD_TILE
        && xc.len() <= simd::MAX_SIMD_TILE
        && simd::tile_in_range(xc, wc)
    {
        // SAFETY: `use_simd` is only ever true after `simd_enabled()`
        // verified AVX2 support, and the range/length guards above are
        // exactly `tile_acc_l1_avx2`'s contract.
        return unsafe { simd::tile_acc_l1_avx2(xc, wc) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = use_simd;
    tile_acc_l1_scalar(xc, wc)
}

fn dot_multistage_fused_impl(
    x: &[i64],
    w: &[i32],
    tile: usize,
    inner: AccumSpec,
    outer: AccumSpec,
    use_simd: bool,
) -> (i64, usize) {
    debug_assert_eq!(x.len(), w.len());
    assert!(tile >= 1, "tile must be >= 1");
    let inner_cap = inner.max() as u64; // bits >= 2 ⇒ max() >= 1
    let mut outer_acc: i64 = 0;
    let mut overflows = 0usize;
    for (xc, wc) in x.chunks(tile).zip(w.chunks(tile)) {
        let (acc, l1) = tile_acc_l1(xc, wc, use_simd);
        let part = if l1 <= inner_cap {
            // Every prefix of the tile sum is within ±l1 ⊆ the register
            // range, so the per-MAC simulator could never have narrowed:
            // the plain sum IS the simulated value, with zero events.
            acc
        } else {
            // Slow path: replay the tile through the per-MAC oracle so
            // wrap/saturate trajectories and event counts match exactly.
            let w64: Vec<i64> = wc.iter().map(|&v| v as i64).collect();
            let mono = dot_monolithic(xc, &w64, inner);
            overflows += mono.overflows;
            mono.value
        };
        // Outer accumulation: identical to the simulator's per-tile step.
        let wide = outer_acc as i128 + part as i128;
        let (narrowed, ov) = outer.narrow(wide);
        outer_acc = if outer.mode == OverflowMode::Checked { wide as i64 } else { narrowed };
        overflows += ov as usize;
    }
    (outer_acc, overflows)
}

/// Plain i64 code dot product (the vectorizable hot loop).
#[inline]
fn dot_codes(x: &[i64], w: &[i32]) -> i64 {
    debug_assert_eq!(x.len(), w.len());
    let mut acc: i64 = 0;
    for (xv, wv) in x.iter().zip(w.iter()) {
        acc += xv * (*wv as i64);
    }
    acc
}

/// Mutable view of one thread's channel band over a `rows`×`c` output
/// buffer: [`ChannelBand::row`] hands out the sub-slice
/// `out[r*c + lo .. r*c + hi]` for one row at a time. References are
/// only ever materialized over memory inside the band, and bands
/// partition `0..c`, so concurrent workers never hold overlapping
/// `&mut` — unlike a shared full-buffer view, this stays within Rust's
/// aliasing rules.
struct ChannelBand {
    /// `*mut i64` laundered through usize so the band is Send.
    base: usize,
    c: usize,
    lo: usize,
    hi: usize,
}

impl ChannelBand {
    /// This band's writable slice of row `r` (length `hi - lo`; index
    /// by `ch - lo`).
    #[inline]
    fn row(&mut self, r: usize) -> &mut [i64] {
        // SAFETY: [r*c+lo, r*c+hi) lies inside the output buffer the
        // base pointer was derived from, and is owned exclusively by
        // this band for the duration of run_channel_bands.
        unsafe {
            std::slice::from_raw_parts_mut(
                (self.base as *mut i64).add(r * self.c + self.lo),
                self.hi - self.lo,
            )
        }
    }
}

/// Split channels `0..c` into per-thread bands and run `body(lo, hi,
/// band)` on each. Small problems run inline to keep decode latency
/// flat.
fn run_channel_bands<F>(c: usize, work: usize, out: &mut [i64], body: F)
where
    F: Fn(usize, usize, &mut ChannelBand) + Sync,
{
    let base = out.as_mut_ptr() as usize;
    let nthreads = crate::linalg::num_threads().min(c.max(1));
    if nthreads <= 1 || work < PAR_MIN_WORK {
        body(0, c, &mut ChannelBand { base, c, lo: 0, hi: c });
        return;
    }
    let band = c.div_ceil(nthreads);
    let body_ref = &body;
    std::thread::scope(|scope| {
        for t in 0..nthreads {
            let lo = t * band;
            let hi = ((t + 1) * band).min(c);
            if lo >= hi {
                continue;
            }
            scope.spawn(move || {
                body_ref(lo, hi, &mut ChannelBand { base, c, lo, hi });
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accum::simulator::{dot_exact, dot_multistage};
    use crate::util::prop::quick;
    use crate::util::rng::Rng;

    /// Per-(row, channel) simulator reference — this produces exactly
    /// what the pre-out-param `qgemm_multistage` used to *return* as a
    /// `Vec<u64>`, so comparing the out-param slice against it is the
    /// old-vs-new semantics parity check.
    #[allow(clippy::too_many_arguments)]
    fn simulate_gemm(
        x: &[i64],
        rows: usize,
        w: &[i32],
        c: usize,
        k: usize,
        tile: usize,
        inner: AccumSpec,
        outer: AccumSpec,
    ) -> (Vec<i64>, Vec<u64>) {
        let mut out = vec![0i64; rows * c];
        let mut overflows = vec![0u64; rows];
        for r in 0..rows {
            let xrow = &x[r * k..(r + 1) * k];
            for ch in 0..c {
                let w64: Vec<i64> = w[ch * k..(ch + 1) * k].iter().map(|&v| v as i64).collect();
                let o = dot_multistage(xrow, &w64, tile, inner, outer);
                out[r * c + ch] = o.value;
                overflows[r] += o.overflows as u64;
            }
        }
        (out, overflows)
    }

    #[test]
    fn exact_kernel_matches_dot_exact() {
        let mut rng = Rng::new(900);
        for _ in 0..20 {
            let rows = rng.int_in(1, 5) as usize;
            let k = rng.int_in(1, 80) as usize;
            let c = rng.int_in(1, 9) as usize;
            let x: Vec<i64> = (0..rows * k).map(|_| rng.int_in(0, 255)).collect();
            let w: Vec<i32> = (0..c * k).map(|_| rng.int_in(-127, 127) as i32).collect();
            let mut out = vec![0i64; rows * c];
            qgemm_exact(&x, rows, &w, c, k, &mut out);
            for r in 0..rows {
                for ch in 0..c {
                    let w64: Vec<i64> =
                        w[ch * k..(ch + 1) * k].iter().map(|&v| v as i64).collect();
                    assert_eq!(out[r * c + ch], dot_exact(&x[r * k..(r + 1) * k], &w64));
                }
            }
        }
    }

    /// THE parity property: the fused kernel equals the per-MAC
    /// simulator bit-for-bit — values AND per-row overflow-event
    /// counts — over random codes, shapes, tile sizes, register widths
    /// and overflow modes (saturating and wrapping), safe and unsafe
    /// alike.
    #[test]
    fn prop_fused_kernel_matches_simulator() {
        quick(
            "qgemm_matches_dot_multistage",
            |rng: &mut Rng| {
                let rows = rng.int_in(1, 4) as usize;
                let k = rng.int_in(1, 96) as usize;
                let c = rng.int_in(1, 8) as usize;
                let tile = rng.int_in(1, 48) as usize;
                let p_inner = rng.int_in(6, 20) as u32;
                let p_outer = rng.int_in(6, 24) as u32;
                let n = rng.int_in(2, 8) as u32;
                let mode = if rng.chance(0.5) {
                    OverflowMode::Wraparound
                } else {
                    OverflowMode::Saturate
                };
                let nu = (1i64 << n) - 1;
                let x: Vec<i64> = (0..rows * k).map(|_| rng.int_in(0, nu)).collect();
                let w: Vec<i32> = (0..c * k).map(|_| rng.int_in(-20, 20) as i32).collect();
                (rows, k, c, tile, p_inner, p_outer, mode, x, w)
            },
            |(rows, k, c, tile, p_inner, p_outer, mode, x, w)| {
                let inner = AccumSpec::new(*p_inner, *mode);
                let outer = AccumSpec::new(*p_outer, *mode);
                let mut out = vec![0i64; rows * c];
                let mut got_ovf = vec![0u64; *rows];
                qgemm_multistage(x, *rows, w, *c, *k, *tile, inner, outer, &mut out, &mut got_ovf);
                let (want, want_ovf) =
                    simulate_gemm(x, *rows, w, *c, *k, *tile, inner, outer);
                if out != want {
                    return Err("kernel values diverge from the simulator".into());
                }
                if got_ovf != want_ovf {
                    return Err(format!(
                        "per-row overflow counts diverge: \
                         kernel {got_ovf:?} vs simulator {want_ovf:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    /// The out-parameter has overwrite semantics on **both** execution
    /// paths: pre-poisoned counters must come back as exactly the
    /// per-row counts the old return-`Vec` API produced — bit for bit
    /// against the simulator — including the all-zero case.
    #[test]
    fn out_param_overwrites_and_matches_legacy_vec_semantics() {
        let mut rng = Rng::new(910);
        // serial shape (small) and threaded shape (above PAR_MIN_WORK)
        for &(rows, k, c, tile, p_inner) in
            &[(3usize, 48usize, 6usize, 8usize, 10u32), (4, 1024, 128, 64, 12)]
        {
            let inner = AccumSpec::wraparound(p_inner);
            let outer = AccumSpec::wraparound(p_inner + 6);
            let x: Vec<i64> = (0..rows * k).map(|_| rng.int_in(0, 255)).collect();
            let w: Vec<i32> = (0..c * k).map(|_| rng.int_in(-9, 9) as i32).collect();
            let mut out = vec![0i64; rows * c];
            let mut ovf = vec![u64::MAX; rows]; // poisoned: must be overwritten
            qgemm_multistage(&x, rows, &w, c, k, tile, inner, outer, &mut out, &mut ovf);
            let (want, want_ovf) = simulate_gemm(&x, rows, &w, c, k, tile, inner, outer);
            assert_eq!(out, want, "rows={rows} k={k}");
            assert_eq!(ovf, want_ovf, "rows={rows} k={k}: stale counter state leaked");
            // and the zero case: wide registers, counters poisoned again
            let wide = AccumSpec::wraparound(40);
            let mut ovf0 = vec![7u64; rows];
            qgemm_multistage(&x, rows, &w, c, k, tile, wide, wide, &mut out, &mut ovf0);
            assert!(ovf0.iter().all(|&v| v == 0), "zero-event rows must be overwritten to 0");
        }
    }

    #[test]
    fn checked_mode_keeps_exact_values() {
        let mut rng = Rng::new(901);
        let (rows, k, c, tile) = (2usize, 64usize, 4usize, 16usize);
        let inner = AccumSpec::checked(10); // deliberately too narrow
        let outer = AccumSpec::checked(12);
        let x: Vec<i64> = (0..rows * k).map(|_| rng.int_in(0, 255)).collect();
        let w: Vec<i32> = (0..c * k).map(|_| rng.int_in(-7, 7) as i32).collect();
        let mut out = vec![0i64; rows * c];
        let mut ovf = vec![0u64; rows];
        qgemm_multistage(&x, rows, &w, c, k, tile, inner, outer, &mut out, &mut ovf);
        let (want, want_ovf) = simulate_gemm(&x, rows, &w, c, k, tile, inner, outer);
        assert_eq!(out, want);
        assert_eq!(ovf, want_ovf);
        assert!(ovf.iter().sum::<u64>() > 0, "narrow checked registers must flag events");
        // checked mode preserves exact arithmetic
        for r in 0..rows {
            for ch in 0..c {
                let w64: Vec<i64> = w[ch * k..(ch + 1) * k].iter().map(|&v| v as i64).collect();
                assert_eq!(out[r * c + ch], dot_exact(&x[r * k..(r + 1) * k], &w64));
            }
        }
    }

    #[test]
    fn threaded_band_path_matches_simulator() {
        // rows*c*k above the inline threshold so the scoped-thread bands
        // actually run (rows > 1: single-row calls always stay serial).
        let mut rng = Rng::new(902);
        let (rows, k, c, tile) = (4usize, 1024usize, 128usize, 64usize);
        let inner = AccumSpec::wraparound(16);
        let outer = AccumSpec::wraparound(crate::quant::bounds::outer_bits(16, k, tile));
        let x: Vec<i64> = (0..rows * k).map(|_| rng.int_in(0, 255)).collect();
        let w: Vec<i32> = (0..c * k).map(|_| rng.int_in(-2, 2) as i32).collect();
        let mut out = vec![0i64; rows * c];
        let mut ovf = vec![0u64; rows];
        qgemm_multistage(&x, rows, &w, c, k, tile, inner, outer, &mut out, &mut ovf);
        let (want, want_ovf) = simulate_gemm(&x, rows, &w, c, k, tile, inner, outer);
        assert_eq!(out, want);
        assert_eq!(ovf, want_ovf);
    }

    #[test]
    fn single_row_serial_path_matches_simulator_at_scale() {
        // a serving-depth single-row call (1·96·2048 MACs, just under
        // PAR_MIN_WORK) rides the serial fast path; it must still be
        // bit-exact (values + counts).
        let mut rng = Rng::new(904);
        let (k, c, tile) = (2048usize, 96usize, 64usize);
        let inner = AccumSpec::wraparound(14); // narrow: some tiles overflow
        let outer = AccumSpec::wraparound(20);
        let x: Vec<i64> = (0..k).map(|_| rng.int_in(0, 255)).collect();
        let w: Vec<i32> = (0..c * k).map(|_| rng.int_in(-7, 7) as i32).collect();
        let mut out = vec![0i64; c];
        let mut ovf = [0u64; 1];
        qgemm_multistage(&x, 1, &w, c, k, tile, inner, outer, &mut out, &mut ovf);
        let (want, want_ovf) = simulate_gemm(&x, 1, &w, c, k, tile, inner, outer);
        assert_eq!(out, want);
        assert_eq!(&ovf[..], &want_ovf[..]);
    }

    /// The dispatched tile step vs the scalar reference step, across
    /// the SIMD engagement boundary (lengths straddling MIN_SIMD_TILE,
    /// remainders exercising the scalar tail) on in-envelope codes.
    /// When this process runs without AVX2 (or with AXE_SIMD=off) both
    /// sides are scalar and the test is a tautology — CI re-runs the
    /// suite with SIMD live on x86_64, where it bites.
    #[test]
    fn simd_tile_step_matches_scalar_reference() {
        let mut rng = Rng::new(905);
        for &n in &[1usize, 15, 16, 31, 32, 33, 48, 63, 64, 100, 256, 1000] {
            let x: Vec<i64> = (0..n).map(|_| rng.int_in(-255, 255)).collect();
            let w: Vec<i32> = (0..n).map(|_| rng.int_in(-127, 127) as i32).collect();
            let scalar = tile_acc_l1_scalar(&x, &w);
            let dispatched = tile_acc_l1(&x, &w, simd_enabled());
            assert_eq!(dispatched, scalar, "n={n}");
        }
    }

    /// Codes outside the 8-bit envelope (i16-KV magnitudes) must fall
    /// back to the scalar step — and stay exact either way.
    #[test]
    fn out_of_envelope_tiles_fall_back_to_scalar() {
        let mut rng = Rng::new(906);
        let n = 64usize;
        let x: Vec<i64> = (0..n).map(|_| rng.int_in(-30000, 30000)).collect();
        let w: Vec<i32> = (0..n).map(|_| rng.int_in(-30000, 30000) as i32).collect();
        assert_eq!(tile_acc_l1(&x, &w, true), tile_acc_l1_scalar(&x, &w));
        let want: i64 = x.iter().zip(w.iter()).map(|(&a, &b)| a * b as i64).sum();
        assert_eq!(tile_acc_l1(&x, &w, simd_enabled()).0, want);
    }

    /// Full-kernel SIMD-vs-scalar parity on SIMD-eligible shapes
    /// (tile ≥ 32, 8-bit codes): values and per-row overflow counts
    /// must be bit-identical through both public entry points, in
    /// saturating and wrapping modes, against the per-MAC simulator.
    #[test]
    fn qgemm_simd_matches_forced_scalar_and_simulator() {
        let mut rng = Rng::new(907);
        let (rows, k, c, tile) = (3usize, 256usize, 8usize, 64usize);
        for mode in [OverflowMode::Wraparound, OverflowMode::Saturate] {
            let inner = AccumSpec::new(13, mode); // narrow: some tiles overflow
            let outer = AccumSpec::new(18, mode);
            let x: Vec<i64> = (0..rows * k).map(|_| rng.int_in(0, 255)).collect();
            let w: Vec<i32> = (0..c * k).map(|_| rng.int_in(-127, 127) as i32).collect();
            let mut out = vec![0i64; rows * c];
            let mut ovf = vec![0u64; rows];
            qgemm_multistage(&x, rows, &w, c, k, tile, inner, outer, &mut out, &mut ovf);
            let mut out_s = vec![0i64; rows * c];
            let mut ovf_s = vec![0u64; rows];
            qgemm_multistage_scalar(
                &x, rows, &w, c, k, tile, inner, outer, &mut out_s, &mut ovf_s,
            );
            assert_eq!(out, out_s, "mode {mode:?}: SIMD values diverge from scalar oracle");
            assert_eq!(ovf, ovf_s, "mode {mode:?}: SIMD overflow counts diverge");
            let (want, want_ovf) = simulate_gemm(&x, rows, &w, c, k, tile, inner, outer);
            assert_eq!(out, want, "mode {mode:?} vs simulator");
            assert_eq!(ovf, want_ovf, "mode {mode:?} counts vs simulator");
        }
    }

    #[test]
    fn tile_larger_than_k_is_monolithic() {
        let mut rng = Rng::new(903);
        let k = 24usize;
        let x: Vec<i64> = (0..k).map(|_| rng.int_in(0, 255)).collect();
        let w: Vec<i32> = (0..k).map(|_| rng.int_in(-7, 7) as i32).collect();
        let spec = AccumSpec::wraparound(20);
        let (v, ovf) = dot_multistage_fused(&x, &w, 1000, spec, spec);
        let w64: Vec<i64> = w.iter().map(|&q| q as i64).collect();
        let want = dot_multistage(&x, &w64, 1000, spec, spec);
        assert_eq!(v, want.value);
        assert_eq!(ovf, want.overflows);
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let mut out: Vec<i64> = Vec::new();
        qgemm_exact(&[], 0, &[], 0, 7, &mut out);
        qgemm_multistage(
            &[],
            0,
            &[],
            0,
            7,
            4,
            AccumSpec::wraparound(16),
            AccumSpec::wraparound(16),
            &mut out,
            &mut [],
        );
        // k = 0: every dot product is the empty sum
        let mut out1 = vec![99i64; 2];
        qgemm_exact(&[], 2, &[], 1, 0, &mut out1[..2]);
        assert_eq!(out1, vec![0, 0]);
        let mut ovf = [5u64; 2];
        qgemm_multistage(
            &[],
            2,
            &[],
            1,
            0,
            4,
            AccumSpec::wraparound(16),
            AccumSpec::wraparound(16),
            &mut out1[..2],
            &mut ovf,
        );
        assert_eq!(ovf, [0, 0], "k=0 rows carry zero events");
    }
}
