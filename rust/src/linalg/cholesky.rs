//! Cholesky factorization, triangular solves and SPD inversion.
//!
//! OPTQ needs `Cholesky((2X̃X̃ᵀ + ηI)⁻¹)` (upper factor); the
//! memory-efficient GPFQ needs `G H⁻¹` solves. Everything here works on
//! the dense [`Mat`] type.

use super::matrix::Mat;
use std::fmt;

#[derive(Debug)]
pub enum CholeskyError {
    NotPositiveDefinite(usize, f64),
    NotSquare(usize, usize),
}

impl fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CholeskyError::NotPositiveDefinite(pivot, value) => {
                write!(f, "matrix is not positive definite at pivot {pivot} (value {value})")
            }
            CholeskyError::NotSquare(rows, cols) => {
                write!(f, "matrix must be square, got {rows}x{cols}")
            }
        }
    }
}

impl std::error::Error for CholeskyError {}

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
pub fn cholesky_lower(a: &Mat) -> Result<Mat, CholeskyError> {
    if a.rows() != a.cols() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    let n = a.rows();
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // s = A[i][j] - sum_k L[i][k] L[j][k]
            let li = l.row(i);
            let lj = l.row(j);
            let mut s = 0.0;
            for k in 0..j {
                s += li[k] * lj[k];
            }
            let s = a.get(i, j) - s;
            if i == j {
                if s <= 0.0 {
                    return Err(CholeskyError::NotPositiveDefinite(i, s));
                }
                l.set(i, j, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve L y = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let row = l.row(i);
        for j in 0..i {
            s -= row[j] * y[j];
        }
        y[i] = s / row[i];
    }
    y
}

/// Solve Lᵀ x = y for lower-triangular L (backward substitution).
pub fn solve_lower_transpose(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in (i + 1)..n {
            s -= l.get(j, i) * x[j];
        }
        x[i] = s / l.get(i, i);
    }
    x
}

/// Inverse of an SPD matrix via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹.
pub fn spd_inverse(a: &Mat) -> Result<Mat, CholeskyError> {
    let n = a.rows();
    let l = cholesky_lower(a)?;
    // Invert L in place (lower triangular inverse).
    let mut linv = Mat::zeros(n, n);
    for i in 0..n {
        linv.set(i, i, 1.0 / l.get(i, i));
        for j in 0..i {
            let mut s = 0.0;
            for k in j..i {
                s += l.get(i, k) * linv.get(k, j);
            }
            linv.set(i, j, -s / l.get(i, i));
        }
    }
    // A⁻¹ = L⁻ᵀ L⁻¹ — symmetric product.
    let mut inv = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0;
            // (L⁻ᵀ L⁻¹)[i][j] = sum_k Linv[k][i] Linv[k][j], k >= max(i,j)
            for k in i..n {
                s += linv.get(k, i) * linv.get(k, j);
            }
            inv.set(i, j, s);
            inv.set(j, i, s);
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::frob_diff;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let x = Mat::random_normal(n, n + 8, rng, 1.0);
        let mut g = x.gram();
        g.add_diag(0.5);
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(10);
        for &n in &[1usize, 4, 17, 64] {
            let a = random_spd(n, &mut rng);
            let l = cholesky_lower(&a).unwrap();
            let recon = l.matmul(&l.transpose());
            assert!(frob_diff(&a, &recon) < 1e-8 * n as f64, "n={n}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a.set(2, 2, -1.0);
        assert!(matches!(cholesky_lower(&a), Err(CholeskyError::NotPositiveDefinite(2, _))));
    }

    #[test]
    fn cholesky_rejects_non_square() {
        let a = Mat::zeros(2, 3);
        assert!(matches!(cholesky_lower(&a), Err(CholeskyError::NotSquare(2, 3))));
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(11);
        let a = random_spd(20, &mut rng);
        let l = cholesky_lower(&a).unwrap();
        let x_true = rng.normal_vec(20);
        // b = A x = L (Lᵀ x)
        let b = a.matvec(&x_true);
        let y = solve_lower(&l, &b);
        let x = solve_lower_transpose(&l, &y);
        for (xi, ti) in x.iter().zip(x_true.iter()) {
            assert!((xi - ti).abs() < 1e-8);
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let mut rng = Rng::new(12);
        for &n in &[3usize, 10, 33] {
            let a = random_spd(n, &mut rng);
            let inv = spd_inverse(&a).unwrap();
            let prod = a.matmul(&inv);
            assert!(frob_diff(&prod, &Mat::eye(n)) < 1e-7 * n as f64, "n={n}");
            assert!(inv.is_symmetric(1e-9));
        }
    }
}
