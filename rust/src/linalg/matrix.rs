//! Row-major dense f64 matrix with blocked, multi-threaded GEMM.

use crate::util::rng::Rng;

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// GEMM micro-kernel block edge (rows of A / cols of B per tile).
const BLOCK: usize = 64;

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn<F: FnMut(usize, usize) -> f64>(rows: usize, cols: usize, mut f: F) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.set(i, j, f(i, j));
            }
        }
        m
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn random_normal(rows: usize, cols: usize, rng: &mut Rng, std: f64) -> Mat {
        let data = (0..rows * cols).map(|_| rng.normal() * std).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const TB: usize = 32;
        for ib in (0..self.rows).step_by(TB) {
            for jb in (0..self.cols).step_by(TB) {
                for i in ib..(ib + TB).min(self.rows) {
                    for j in jb..(jb + TB).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_scaled(&mut self, other: &Mat, s: f64) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += s * b;
        }
    }

    /// Add `v` to the diagonal (damping).
    pub fn add_diag(&mut self, v: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += v;
        }
    }

    pub fn diag(&self) -> Vec<f64> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.get(i, i)).collect()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max absolute element.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    /// C = A @ B, blocked over K with a transposed-B packing so the inner
    /// loop is two contiguous streams; parallelized over row bands.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let bt = b.transpose();
        let mut out = Mat::zeros(m, n);
        let nthreads = num_threads().min(m.max(1));
        if m * n * k < 64 * 64 * 64 || nthreads <= 1 {
            matmul_band(&self.data, &bt.data, &mut out.data, 0, m, k, n);
            return out;
        }
        let band = m.div_ceil(nthreads);
        let a_data = &self.data;
        let bt_data = &bt.data;
        let out_ptr = out.data.as_mut_ptr() as usize;
        std::thread::scope(|scope| {
            for t in 0..nthreads {
                let lo = t * band;
                let hi = ((t + 1) * band).min(m);
                if lo >= hi {
                    continue;
                }
                scope.spawn(move || {
                    // SAFETY: bands [lo,hi) are disjoint per thread.
                    let out_slice = unsafe {
                        std::slice::from_raw_parts_mut(out_ptr as *mut f64, m * n)
                    };
                    matmul_band(a_data, bt_data, out_slice, lo, hi, k, n);
                });
            }
        });
        out
    }

    /// A @ Bᵀ without materializing the transpose of B (B given row-major,
    /// so rows of B are the contraction vectors) — the natural layout for
    /// Gram matrices X Xᵀ.
    pub fn matmul_bt(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_bt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut out = Mat::zeros(m, n);
        gemm_bt_into(&self.data, &b.data, m, k, n, &mut out.data);
        out
    }

    /// Symmetric Gram matrix self @ selfᵀ (rows are vectors).
    pub fn gram(&self) -> Mat {
        self.matmul_bt(self)
    }

    /// y = self @ x for a vector x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// Check symmetry within tolerance (debug helper).
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self.get(i, j) - self.get(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Force exact symmetry: (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self.get(i, j) + self.get(j, i));
                self.set(i, j, v);
                self.set(j, i, v);
            }
        }
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 8 independent accumulators: enough ILP to keep two FMA ports busy
    // once the compiler vectorizes (target-cpu=native); measured ~1.9x
    // over the 4-way version on the single-core Xeon (§Perf).
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f64; 8];
    for c in 0..chunks {
        let i = c * 8;
        let (ab, bb) = (&a[i..i + 8], &b[i..i + 8]);
        for j in 0..8 {
            acc[j] += ab[j] * bb[j];
        }
    }
    let mut s = acc.iter().sum::<f64>();
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// `out = A·Bᵀ` into a caller-provided buffer: `a` is `m`×`k` row-major,
/// `b` is `n`×`k` row-major (rows of B are the contraction vectors),
/// `out` is `m`×`n` row-major. The allocation-free core of
/// [`Mat::matmul_bt`] — the decode hot path feeds pre-sized scratch
/// buffers through here ([`crate::model::DecodeScratch`]) so a steady-
/// state float-linear forward performs no heap allocation. Parallelized
/// over row bands above the same work threshold as [`Mat::matmul`];
/// each output row is accumulated sequentially, so per-row results are
/// batch-size invariant.
pub fn gemm_bt_into(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, out: &mut [f64]) {
    assert_eq!(a.len(), m * k, "a must be m*k");
    assert_eq!(b.len(), n * k, "b must be n*k");
    assert_eq!(out.len(), m * n, "out must be m*n");
    let nthreads = num_threads().min(m.max(1));
    if m * n * k < 64 * 64 * 64 || nthreads <= 1 {
        matmul_band(a, b, out, 0, m, k, n);
        return;
    }
    let band = m.div_ceil(nthreads);
    let out_ptr = out.as_mut_ptr() as usize;
    std::thread::scope(|scope| {
        for t in 0..nthreads {
            let lo = t * band;
            let hi = ((t + 1) * band).min(m);
            if lo >= hi {
                continue;
            }
            scope.spawn(move || {
                // SAFETY: bands [lo,hi) are disjoint per thread.
                let out_slice =
                    unsafe { std::slice::from_raw_parts_mut(out_ptr as *mut f64, m * n) };
                matmul_band(a, b, out_slice, lo, hi, k, n);
            });
        }
    });
}

/// Compute rows [row_lo, row_hi) of C = A·Bᵀpacked where `bt` holds B
/// transposed row-major (n rows of length k).
fn matmul_band(a: &[f64], bt: &[f64], out: &mut [f64], row_lo: usize, row_hi: usize, k: usize, n: usize) {
    for ib in (row_lo..row_hi).step_by(BLOCK) {
        let ie = (ib + BLOCK).min(row_hi);
        for jb in (0..n).step_by(BLOCK) {
            let je = (jb + BLOCK).min(n);
            for i in ib..ie {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in jb..je {
                    let brow = &bt[j * k..(j + 1) * k];
                    orow[j] = dot(arow, brow);
                }
            }
        }
    }
}

/// Number of worker threads for GEMM bands.
pub fn num_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("AXE_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a.get(i, p) * b.get(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(3usize, 4usize, 5usize), (17, 33, 9), (70, 65, 130)] {
            let a = Mat::random_normal(m, k, &mut rng, 1.0);
            let b = Mat::random_normal(k, n, &mut rng, 1.0);
            let fast = a.matmul(&b);
            let slow = naive_matmul(&a, &b);
            assert!(crate::linalg::frob_diff(&fast, &slow) < 1e-9 * (m * n) as f64);
        }
    }

    #[test]
    fn matmul_bt_matches() {
        let mut rng = Rng::new(2);
        let a = Mat::random_normal(20, 31, &mut rng, 1.0);
        let b = Mat::random_normal(15, 31, &mut rng, 1.0);
        let via_t = a.matmul(&b.transpose());
        let direct = a.matmul_bt(&b);
        assert!(crate::linalg::frob_diff(&via_t, &direct) < 1e-10);
    }

    #[test]
    fn gram_is_symmetric_psd() {
        let mut rng = Rng::new(3);
        let x = Mat::random_normal(10, 40, &mut rng, 1.0);
        let g = x.gram();
        assert!(g.is_symmetric(1e-12));
        // PSD: vᵀGv >= 0
        for _ in 0..10 {
            let v = rng.normal_vec(10);
            let gv = g.matvec(&v);
            let q = dot(&v, &gv);
            assert!(q >= -1e-9, "q={q}");
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(4);
        let a = Mat::random_normal(13, 29, &mut rng, 1.0);
        assert_eq!(a, a.transpose().transpose());
    }

    #[test]
    fn eye_is_identity_for_matmul() {
        let mut rng = Rng::new(5);
        let a = Mat::random_normal(12, 12, &mut rng, 1.0);
        let i = Mat::eye(12);
        assert!(crate::linalg::frob_diff(&a.matmul(&i), &a) < 1e-12);
        assert!(crate::linalg::frob_diff(&i.matmul(&a), &a) < 1e-12);
    }

    #[test]
    fn large_threaded_matmul_matches() {
        let mut rng = Rng::new(6);
        let a = Mat::random_normal(150, 80, &mut rng, 1.0);
        let b = Mat::random_normal(80, 90, &mut rng, 1.0);
        let fast = a.matmul(&b);
        let slow = naive_matmul(&a, &b);
        assert!(crate::linalg::frob_diff(&fast, &slow) < 1e-8);
    }

    #[test]
    fn add_diag_and_symmetrize() {
        let mut m = Mat::zeros(3, 3);
        m.set(0, 1, 2.0);
        m.add_diag(5.0);
        assert_eq!(m.get(0, 0), 5.0);
        m.symmetrize();
        assert_eq!(m.get(1, 0), 1.0);
        assert!(m.is_symmetric(0.0));
    }
}
