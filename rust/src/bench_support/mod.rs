//! Mini-criterion: timing loops with warmup and robust statistics (no
//! `criterion` in the offline registry). The experiment benches also use
//! this module's table printer to emit paper-style rows. The [`load`]
//! submodule is the seeded load-generator + fault-injection harness for
//! overload testing.

pub mod load;

use std::time::Instant;

/// Statistics over a sample of timings (seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            mean,
            median: xs[n / 2],
            stddev: var.sqrt(),
            min: xs[0],
            max: xs[n - 1],
            n,
        }
    }
}

/// Benchmark a closure: `warmup` untimed runs, then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let s = Stats::from_samples(samples);
    println!(
        "bench {name:<40} mean {:>10}  median {:>10}  σ {:>9}  (n={})",
        crate::util::fmt_duration(s.mean),
        crate::util::fmt_duration(s.median),
        crate::util::fmt_duration(s.stddev),
        s.n
    );
    s
}

/// Time a single run of a closure, returning (result, seconds).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed().as_secs_f64())
}

/// Throughput helper: items per second.
pub fn throughput(items: usize, seconds: f64) -> f64 {
    items as f64 / seconds.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant() {
        let s = Stats::from_samples(vec![2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.n, 10);
    }

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut count = 0;
        let s = bench("test", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn throughput_math() {
        assert!((throughput(100, 2.0) - 50.0).abs() < 1e-9);
    }
}
