//! Seeded load-generator + fault-injection harness for overload
//! testing.
//!
//! [`schedule`] expands a [`LoadSpec`] into a deterministic arrival
//! trace (Poisson or bursty inter-arrivals, seeded prompt/output
//! lengths, optional cancellation and deadline annotations) and
//! [`run_load`] replays that trace tick by tick against a single
//! [`StepEngine`] behind a bounded [`ServeQueue`] — the same
//! admission seam the engine threads use in production, driven
//! synchronously so tests can inject faults between steps and assert
//! on the exact step-record stream.
//!
//! Determinism contract: [`schedule`] is a pure function of
//! `(spec, seed)`, and with deadlines and cancellation disabled the
//! whole run is tick-deterministic — same seed, same shed decisions,
//! same survivor token streams, bit for bit. Deadlines are wall-clock
//! (`Instant`), so traces that use them conserve and bound but do not
//! replay exactly.

use std::time::{Duration, Instant};

use crate::coordinator::serve::{
    CancelToken, Request, Response, ServeConfig, ServeQueue, ShedPolicy, Status, StepEngine,
};
use crate::coordinator::telemetry::{MetricsSummary, StepRecord};
use crate::model::Transformer;
use crate::util::rng::Rng;

/// Arrival process for the synthetic trace, in scheduler ticks (one
/// tick = one driver iteration = at most one ragged step).
#[derive(Clone, Copy, Debug)]
pub enum Arrivals {
    /// Exponential inter-arrival gaps with the given mean — the
    /// classic open-loop Poisson load.
    Poisson { mean_ticks: f64 },
    /// `burst` simultaneous arrivals every `period` ticks — the
    /// queue-saturation fault: each burst lands on one admission
    /// check and overflows any cap smaller than the burst.
    Bursty { burst: usize, period: u64 },
}

/// Declarative description of a synthetic load trace.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    pub arrivals: Arrivals,
    pub n_requests: usize,
    /// Inclusive prompt-length range, sampled per request.
    pub prompt_lens: (usize, usize),
    /// Inclusive output-length range, sampled per request.
    pub output_lens: (usize, usize),
    /// Prompt tokens are sampled below this bound.
    pub vocab: u16,
    /// Probability a request carries a [`CancelToken`] that fires
    /// `cancel_after` ticks past its arrival. 0.0 = no cancellation
    /// (required for bit-exact replay assertions).
    pub cancel_p: f64,
    pub cancel_after: u64,
    /// Wall-clock deadline attached at submission, in milliseconds.
    /// 0 = no deadlines (required for bit-exact replay assertions).
    pub deadline_ms: u64,
}

impl LoadSpec {
    fn base(arrivals: Arrivals, n_requests: usize) -> LoadSpec {
        LoadSpec {
            arrivals,
            n_requests,
            prompt_lens: (1, 12),
            output_lens: (1, 8),
            vocab: 32,
            cancel_p: 0.0,
            cancel_after: 0,
            deadline_ms: 0,
        }
    }

    /// Burst storm: `burst` arrivals every `period` ticks.
    pub fn bursty(n_requests: usize, burst: usize, period: u64) -> LoadSpec {
        LoadSpec::base(Arrivals::Bursty { burst: burst.max(1), period: period.max(1) }, n_requests)
    }

    /// Open-loop Poisson arrivals with the given mean gap in ticks.
    pub fn poisson(n_requests: usize, mean_ticks: f64) -> LoadSpec {
        LoadSpec::base(Arrivals::Poisson { mean_ticks: mean_ticks.max(1e-9) }, n_requests)
    }
}

/// One scheduled arrival: the request, its arrival tick, and optional
/// cancellation / deadline annotations resolved by the driver.
#[derive(Clone, Debug)]
pub struct LoadEvent {
    pub tick: u64,
    pub req: Request,
    /// Fire `req.cancel` at this tick (the token is already attached
    /// to the request).
    pub cancel_at: Option<u64>,
    /// Attach `Instant::now() + deadline_ms` at submission time.
    /// 0 = none.
    pub deadline_ms: u64,
}

/// Expand a spec into its deterministic arrival trace. Pure in
/// `(spec, seed)`: every field of every event — ticks, prompts,
/// output budgets, cancellation picks — replays exactly.
pub fn schedule(spec: &LoadSpec, seed: u64) -> Vec<LoadEvent> {
    let mut rng = Rng::new(seed);
    let mut arrivals = rng.fork(1);
    let mut shapes = rng.fork(2);
    let mut cancels = rng.fork(3);
    let mut events = Vec::with_capacity(spec.n_requests);
    let mut t = 0.0f64;
    for i in 0..spec.n_requests {
        let tick = match spec.arrivals {
            Arrivals::Poisson { mean_ticks } => {
                t += -(1.0 - arrivals.f64()).ln() * mean_ticks;
                t as u64
            }
            Arrivals::Bursty { burst, period } => (i / burst) as u64 * period,
        };
        let (plo, phi) = spec.prompt_lens;
        let (olo, ohi) = spec.output_lens;
        let plen = shapes.int_in(plo.max(1) as i64, phi.max(plo).max(1) as i64) as usize;
        let olen = shapes.int_in(olo as i64, ohi.max(olo) as i64) as usize;
        let prompt: Vec<u16> =
            (0..plen).map(|_| shapes.below(spec.vocab.max(1) as usize) as u16).collect();
        let cancel_at = if spec.cancel_p > 0.0 && cancels.chance(spec.cancel_p) {
            Some(tick + spec.cancel_after)
        } else {
            None
        };
        events.push(LoadEvent {
            tick,
            req: Request {
                id: i as u64,
                prompt,
                max_new_tokens: olen,
                deadline: None,
                cancel: cancel_at.map(|_| CancelToken::new()),
            },
            cancel_at,
            deadline_ms: spec.deadline_ms,
        });
    }
    events
}

/// Faults injected by the driver between steps. `Default` = none.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultSpec {
    /// Sleep before every `slow_every`-th tick's step (1 = every
    /// step). 0 = off. Paired with deadlines this forces mid-flight
    /// deadline misses without touching the scheduler.
    pub slow_every: usize,
    pub slow_ms: u64,
}

/// Everything a run produced, for assertions.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// All terminal responses (accepted and shed), drained from the
    /// queue after close.
    pub responses: Vec<Response>,
    /// The complete step-record stream, drained every tick (no ring
    /// overwrites at test scale).
    pub records: Vec<StepRecord>,
    /// Engine telemetry summary (`None` with telemetry off).
    pub summary: Option<MetricsSummary>,
    /// Conservation left-hand side: requests accepted by `submit`.
    pub submitted: u64,
    pub shed: u64,
    pub depth_hwm: usize,
    /// Driver iterations until quiescence.
    pub ticks: u64,
}

impl LoadReport {
    /// `(ok, shed, deadline_miss, cancelled)` response counts.
    pub fn status_counts(&self) -> (usize, usize, usize, usize) {
        let mut c = (0, 0, 0, 0);
        for r in &self.responses {
            match r.status {
                Status::Ok => c.0 += 1,
                Status::Shed => c.1 += 1,
                Status::DeadlineMiss => c.2 += 1,
                Status::Cancelled => c.3 += 1,
            }
        }
        c
    }

    /// Every submitted request resolved to exactly one response.
    pub fn conserved(&self) -> bool {
        self.responses.len() as u64 == self.submitted
    }
}

/// Replay an arrival trace against one engine behind a bounded queue.
///
/// Each tick: submit due arrivals (attaching deadlines), fire due
/// cancellations, poll admissions into free slots, fold queue
/// depth/shed telemetry, optionally inject a slow-step fault, run one
/// ragged step, complete finished responses, and drain the step
/// records. Runs until the trace is exhausted and both the queue and
/// the engine are empty, then closes the queue, flushes the final
/// shed delta through an empty step, and drains the responses.
pub fn run_load(
    model: &Transformer,
    cfg: ServeConfig,
    queue_cap: usize,
    policy: ShedPolicy,
    events: &[LoadEvent],
    faults: FaultSpec,
) -> LoadReport {
    let queue = ServeQueue::bounded(queue_cap, policy);
    let mut eng = StepEngine::new(model, cfg);
    let mut pending_cancels: Vec<(u64, CancelToken)> = Vec::new();
    let mut records = Vec::new();
    let mut scratch = Vec::new();
    let mut next_ev = 0usize;
    let mut tick = 0u64;
    loop {
        while next_ev < events.len() && events[next_ev].tick <= tick {
            let ev = &events[next_ev];
            let mut req = ev.req.clone();
            if ev.deadline_ms > 0 {
                req.deadline = Some(Instant::now() + Duration::from_millis(ev.deadline_ms));
            }
            if let Some(at) = ev.cancel_at {
                // mint a fresh token per run — the scheduled token is a
                // shared Arc and would replay as already-cancelled
                let tok = CancelToken::new();
                req.cancel = Some(tok.clone());
                pending_cancels.push((at, tok));
            }
            let _ = queue.submit(req); // sheds resolve via the queue
            next_ev += 1;
        }
        pending_cancels.retain(|(at, tok)| {
            if *at <= tick {
                tok.cancel();
                false
            } else {
                true
            }
        });
        for (req, enqueued) in queue.poll(eng.free_slots()) {
            eng.admit(req, enqueued);
        }
        eng.note_queue_depth(queue.depth());
        eng.note_shed(queue.take_shed_delta());
        if faults.slow_every > 0 && (tick as usize) % faults.slow_every == 0 && faults.slow_ms > 0 {
            std::thread::sleep(Duration::from_millis(faults.slow_ms));
        }
        eng.step();
        queue.complete(eng.take_finished());
        if let Some(m) = eng.metrics() {
            m.with(|mm| mm.take_buffered(&mut scratch));
            records.extend(scratch.drain(..));
        }
        tick += 1;
        assert!(tick < 1_000_000, "load driver failed to quiesce");
        if next_ev >= events.len() && queue.depth() == 0 && !eng.has_work() {
            break;
        }
    }
    queue.close();
    // late sheds cannot exist here (nothing submits after the trace),
    // but mirror run_engine's final flush so drain records are never
    // silently lost if the driver grows richer fault hooks
    eng.note_shed(queue.take_shed_delta());
    eng.step();
    if let Some(m) = eng.metrics() {
        m.with(|mm| mm.take_buffered(&mut scratch));
        records.extend(scratch.drain(..));
    }
    let summary = eng.metrics().map(|m| m.summary());
    LoadReport {
        responses: queue.drain(),
        records,
        summary,
        submitted: queue.submitted_count(),
        shed: queue.shed_count(),
        depth_hwm: queue.depth_hwm(),
        ticks: tick,
    }
}

/// The no-contention oracle: run one request alone (batch 1, no
/// deadline, no cancellation) and return its response. Survivor token
/// streams from any overloaded run must match this bit for bit — the
/// overload machinery is allowed to reorder and refuse work, never to
/// change it.
pub fn solo_reference(model: &Transformer, cfg: ServeConfig, req: &Request) -> Response {
    let mut solo = cfg;
    solo.max_batch = 1;
    let mut eng = StepEngine::new(model, solo);
    let mut clean = req.clone();
    clean.deadline = None;
    clean.cancel = None;
    eng.admit(clean, Instant::now());
    while eng.has_work() {
        eng.step();
    }
    let mut done = eng.take_finished();
    assert_eq!(done.len(), 1, "solo reference must retire exactly one response");
    done.pop().expect("len checked above")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let spec = LoadSpec::poisson(12, 2.0);
        let a = schedule(&spec, 9);
        let b = schedule(&spec, 9);
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tick, y.tick);
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.req.max_new_tokens, y.req.max_new_tokens);
            assert_eq!(x.cancel_at, y.cancel_at);
        }
        let c = schedule(&spec, 10);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.tick != y.tick || x.req.prompt != y.req.prompt),
            "different seeds must produce different traces"
        );
    }

    #[test]
    fn bursty_schedule_lands_in_bursts() {
        let ev = schedule(&LoadSpec::bursty(9, 3, 5), 1);
        let ticks: Vec<u64> = ev.iter().map(|e| e.tick).collect();
        assert_eq!(ticks, vec![0, 0, 0, 5, 5, 5, 10, 10, 10]);
        for e in &ev {
            assert!(!e.req.prompt.is_empty());
            assert!(e.cancel_at.is_none());
            assert_eq!(e.deadline_ms, 0);
        }
    }

    #[test]
    fn cancel_annotations_follow_probability() {
        let mut spec = LoadSpec::poisson(64, 1.0);
        spec.cancel_p = 1.0;
        spec.cancel_after = 3;
        let ev = schedule(&spec, 4);
        for e in &ev {
            assert_eq!(e.cancel_at, Some(e.tick + 3));
            assert!(e.req.cancel.is_some());
        }
        spec.cancel_p = 0.0;
        assert!(schedule(&spec, 4).iter().all(|e| e.cancel_at.is_none()));
    }
}
