//! # AXE — Accumulator-Aware Post-Training Quantization
//!
//! A Rust + JAX + Pallas reproduction of *"Accumulator-Aware
//! Post-Training Quantization"* (Colbert et al., 2024): layer-wise PTQ
//! (GPFQ, OPTQ) extended with overflow-avoidance guarantees for
//! user-chosen accumulator bit widths, including the multi-stage tiled
//! datapath that scales the guarantee to LLMs.
//!
//! Layer map:
//! - [`quant`] — quantizers, bounds, ℓ1 machinery, GPFQ/OPTQ ± AXE,
//!   EP-init and naïve baselines.
//! - [`accum`] — bit-accurate P-bit MAC simulation + overflow audit
//!   (the oracle the serving kernel is verified against).
//! - [`linalg`] — dense f64 GEMM/Cholesky/sqrtm plus the fused
//!   multi-stage integer GEMM kernel ([`linalg::qgemm`]) that executes
//!   the tiled P_I/P_O datapath at matmul speed.
//! - [`model`] — inference substrate (transformers, MLPs, quantized
//!   linear layers running on the fused integer datapath; multi-sequence
//!   KV arena + batched decode for serving).
//! - [`calib`] — calibration capture, SmoothQuant-style equalization,
//!   bias correction.
//! - [`coordinator`] — the layer-by-layer PTQ pipeline (layer-parallel
//!   within each block), the continuous-batching serving engine
//!   ([`coordinator::serve`]) and experiment harness.
//! - [`runtime`] — PJRT (XLA) execution of the AOT-compiled JAX/Pallas
//!   artifacts; gated behind the off-by-default `pjrt` feature (the
//!   `xla` bindings are unavailable offline) with a stub fallback.
//! - [`eval`] — perplexity / accuracy evaluation and dataset readers.
//! - [`util`], [`bench_support`] — self-contained substrates.

// Index loops mirror the paper's equations throughout the numeric code;
// iterator rewrites would obscure the math without changing codegen.
#![allow(clippy::needless_range_loop)]

pub mod accum;
pub mod bench_support;
pub mod calib;
pub mod coordinator;
pub mod eval;
pub mod linalg;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;

/// Repository-relative path to the artifacts directory, overridable via
/// `AXE_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("AXE_ARTIFACTS") {
        return p.into();
    }
    // walk up from cwd looking for artifacts/
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
