//! Mini property-testing harness (no `proptest` in the offline registry).
//!
//! `check` runs a property over `n` random cases generated from a seeded
//! [`Rng`]; on failure it attempts a simple halving shrink over the case
//! index space by re-running with the failing seed and reporting it, so
//! failures are reproducible (`AXE_PROP_SEED=<seed>` re-runs one case).

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 64, seed: 0xAE5E_2024 }
    }
}

/// Run `prop` on `cfg.cases` generated cases. `gen` builds a case from a
/// per-case RNG; `prop` returns Err(description) on failure.
pub fn check<T, G, P>(name: &str, cfg: PropConfig, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    // Environment override to replay a single failing case.
    if let Ok(seed_s) = std::env::var("AXE_PROP_SEED") {
        if let Ok(seed) = seed_s.parse::<u64>() {
            let mut rng = Rng::new(seed);
            let case = gen(&mut rng);
            if let Err(msg) = prop(&case) {
                panic!("[{name}] replay seed {seed} failed: {msg}\ncase: {case:?}");
            }
            return;
        }
    }
    for i in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add((i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(case_seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "[{name}] property failed on case {i}/{} (replay: AXE_PROP_SEED={case_seed}): {msg}\ncase: {case:?}",
                cfg.cases
            );
        }
    }
}

/// Convenience: run with default config.
pub fn quick<T, G, P>(name: &str, gen: G, prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    check(name, PropConfig::default(), gen, prop);
}

/// Assert two slices are element-wise close.
pub fn assert_allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        quick(
            "add_commutes",
            |rng| (rng.normal(), rng.normal()),
            |&(a, b)| {
                if (a + b - (b + a)).abs() < 1e-12 {
                    Ok(())
                } else {
                    Err("not commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        quick(
            "always_fails",
            |rng| rng.f64(),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn allclose_detects_mismatch() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 1e-9, 1e-12).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-3, 1e-6).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-3, 1e-6).is_err());
    }
}
