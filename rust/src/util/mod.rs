//! Self-contained substrates: PRNG, JSON, CLI parsing, property testing,
//! timing and progress reporting. The build environment is offline with
//! only the `xla` crate's dependency closure available, so these small
//! utilities replace `rand`, `serde_json`, `clap` and `proptest`.

pub mod argparse;
pub mod json;
pub mod prop;
pub mod rng;

use std::time::Instant;

/// Simple scope timer for coarse profiling.
pub struct Timer {
    start: Instant,
    label: String,
}

impl Timer {
    pub fn start(label: &str) -> Self {
        Self { start: Instant::now(), label: label.to_string() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!("[{}] {:.3}s", self.label, self.elapsed_s())
    }
}

/// Human-readable duration.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2}s")
    } else {
        format!("{:.1}m", secs / 60.0)
    }
}

/// Fixed-width markdown-ish table printer used by the bench harnesses so
/// the output matches the row/column layout of the paper's tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&line(&sep));
        for r in &self.rows {
            out.push_str(&line(r));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "ppl"]);
        t.row(&["pico-70k".into(), "61.7".into()]);
        t.row(&["x".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("| model    | ppl  |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn duration_formats() {
        assert!(fmt_duration(0.0000005).ends_with("µs"));
        assert!(fmt_duration(0.05).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with("s"));
        assert!(fmt_duration(300.0).ends_with("m"));
    }
}
