//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, so we carry our own small,
//! well-understood generators: SplitMix64 for seeding and PCG-XSH-RR for
//! the main stream, plus Box–Muller normals and a few sampling helpers.
//! Everything is reproducible from a single `u64` seed, which the
//! experiment harness relies on.

/// SplitMix64 — used to expand a user seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid for simulation use.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams (seeded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = sm.next_u64();
        let inc = sm.next_u64() | 1;
        let mut rng = Self { state, inc, spare_normal: None };
        // advance once so state depends on inc
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-thread fanout).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) single precision.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.range_f64(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(11);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
    }

    #[test]
    fn int_in_bounds() {
        let mut rng = Rng::new(13);
        for _ in 0..1000 {
            let v = rng.int_in(-5, 5);
            assert!((-5..=5).contains(&v));
        }
    }
}
